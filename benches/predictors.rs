//! Branch-predictor throughput and accuracy across the Table 3 predictor
//! choices (the detailed simulator's per-branch cost).

use tao_sim::detailed::predictor;
use tao_sim::uarch::PredictorKind;
use tao_sim::util::benchkit::Bench;
use tao_sim::util::Rng;

fn main() {
    // Synthetic branch stream: biased + loop + correlated branches.
    let n = 1_000_000usize;
    let mut rng = Rng::new(9);
    let mut stream = Vec::with_capacity(n);
    let mut i = 0u64;
    while stream.len() < n {
        i += 1;
        stream.push((0x400100u64, !i.is_multiple_of(8))); // loop branch, trip 8
        stream.push((0x400200u64, rng.chance(0.9))); // biased
        stream.push((0x400300u64, i.is_multiple_of(2))); // alternating
    }
    stream.truncate(n);

    let b = Bench::new("predictor").iters(3);
    for kind in PredictorKind::ALL {
        let mut correct = 0u64;
        b.run(kind.name(), n as u64, || {
            let mut bp = predictor::build(kind);
            correct = 0;
            for &(pc, taken) in &stream {
                if bp.predict(pc) == taken {
                    correct += 1;
                }
                bp.update(pc, taken);
            }
            correct
        });
        println!(
            "    accuracy {:<12}: {:.2}%",
            kind.name(),
            correct as f64 * 100.0 / n as f64
        );
    }
}
