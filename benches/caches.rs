//! Cache-hierarchy throughput across Table 3 geometries and access
//! patterns (sequential stream / strided / random / hot-set).

use tao_sim::detailed::cache::{Cache, DataHierarchy};
use tao_sim::uarch::{CacheGeometry, Timing, UarchConfig};
use tao_sim::util::benchkit::Bench;
use tao_sim::util::Rng;

fn pattern(name: &str, n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let base = 0x1000_0000u64;
    match name {
        "stream" => (0..n).map(|i| base + i as u64 * 8).collect(),
        "strided" => (0..n).map(|i| base + i as u64 * 256).collect(),
        "random4m" => (0..n).map(|_| base + rng.gen_range(4 << 20)).collect(),
        "hot32k" => (0..n).map(|_| base + rng.gen_range(32 << 10)).collect(),
        _ => unreachable!(),
    }
}

fn main() {
    let n = 1_000_000usize;
    let b = Bench::new("cache").iters(3);
    for geom_name in ["uarch_a", "uarch_c"] {
        let cfg = UarchConfig::preset(geom_name).unwrap();
        for pat in ["stream", "strided", "random4m", "hot32k"] {
            let addrs = pattern(pat, n, 3);
            let case = format!("{geom_name}/{pat}");
            let mut hits = 0u64;
            b.run(&case, n as u64, || {
                let mut l2 = Cache::new(cfg.l2);
                let mut dh = DataHierarchy::new(cfg.l1d, Timing::default());
                hits = 0;
                for &a in &addrs {
                    let r = dh.access(a, &mut l2);
                    hits += (r.level == tao_sim::trace::AccessLevel::L1) as u64;
                }
                hits
            });
            println!("    L1 hit rate {case}: {:.1}%", hits as f64 * 100.0 / n as f64);
        }
    }

    // Raw single-cache access cost by associativity.
    let b2 = Bench::new("cache-assoc").iters(3);
    for assoc in [2u32, 4, 6, 8] {
        let geom = CacheGeometry { size_bytes: 32 << 10, assoc };
        let addrs = pattern("hot32k", n, 5);
        b2.run(&format!("assoc{assoc}"), n as u64, || {
            let mut c = Cache::new(geom);
            let mut hits = 0u64;
            for &a in &addrs {
                hits += c.access(a) as u64;
            }
            hits
        });
    }
}
