//! Trace-generation throughput (the Figure 10b / Table 4 cost axis):
//! functional vs detailed simulation, per benchmark and per µarch.

use tao_sim::detailed::DetailedSim;
use tao_sim::functional::FunctionalSim;
use tao_sim::uarch::UarchConfig;
use tao_sim::util::benchkit::Bench;
use tao_sim::workloads;

fn main() {
    let insts = 200_000u64;
    println!("== tracegen: functional (AtomicSimpleCPU-equivalent) ==");
    let b = Bench::new("functional").iters(3);
    for w in workloads::suite() {
        let program = w.build(42);
        b.run(w.name, insts, || {
            FunctionalSim::new(&program).run(insts).records.len()
        });
    }

    println!("== tracegen: detailed O3, stats only ==");
    for cfg in [UarchConfig::uarch_a(), UarchConfig::uarch_c()] {
        let b = Bench::new(&format!("detailed/{}", cfg.name)).iters(3);
        for w in workloads::suite() {
            let program = w.build(42);
            b.run(w.name, insts, || {
                DetailedSim::new(&program, &cfg)
                    .stats_only()
                    .run(insts)
                    .1
                    .instructions
            });
        }
    }

    println!("== tracegen: detailed O3, full trace records ==");
    let cfg = UarchConfig::uarch_a();
    let b = Bench::new("detailed-records/uarch_a").iters(3);
    for w in ["dee", "mcf"] {
        let program = workloads::by_name(w).unwrap().build(42);
        b.run(w, insts, || {
            DetailedSim::new(&program, &cfg).run(insts).0.records.len()
        });
    }
}
