//! Feature-extraction throughput — the Rust-side hot-path component in
//! front of every model batch (paper §4.2 pipeline).

use tao_sim::features::{FeatureConfig, FeatureExtractor};
use tao_sim::functional::FunctionalSim;
use tao_sim::util::benchkit::Bench;
use tao_sim::workloads;

fn main() {
    let insts = 200_000u64;
    let b = Bench::new("features").iters(5);
    for w in ["dee", "mcf", "rom"] {
        let program = workloads::by_name(w).unwrap().build(42);
        let trace = FunctionalSim::new(&program).run(insts);
        for cfg in [
            FeatureConfig { nb: 256, nq: 8, nm: 16 },
            FeatureConfig::default(), // paper values: 1k / 32 / 64
        ] {
            let case = format!("{w}/nb{}-nq{}-nm{}", cfg.nb, cfg.nq, cfg.nm);
            let mut out = vec![0.0f32; cfg.feature_dim()];
            b.run(&case, insts, || {
                let mut fx = FeatureExtractor::new(cfg);
                let mut acc = 0i64;
                for rec in &trace.records {
                    acc += fx.extract(rec, &mut out) as i64;
                }
                acc
            });
        }
    }
}
