//! Feature-extraction throughput — the Rust-side hot-path component in
//! front of every model batch (paper §4.2 pipeline).
//!
//! Measures `extract_into` over the AoS record stream and over the SoA
//! columnar trace (assembled per instruction via `TraceColumns::record`)
//! to track the storage-layout effect on the extraction scan.
//!
//! Flags: `--smoke` (reduced counts), `--json <path>` (write metrics).

use tao_sim::features::{FeatureConfig, FeatureExtractor};
use tao_sim::functional::FunctionalSim;
use tao_sim::util::benchkit::{Bench, BenchOpts, BenchReport};
use tao_sim::workloads;

fn main() {
    let opts = BenchOpts::from_env();
    let insts: u64 = if opts.smoke { 50_000 } else { 200_000 };
    let iters = if opts.smoke { 2 } else { 5 };
    let mut report = BenchReport::new();
    report.metric("smoke", if opts.smoke { 1.0 } else { 0.0 });
    let b = Bench::new("features").iters(iters);
    for w in ["dee", "mcf", "rom"] {
        let program = workloads::by_name(w).unwrap().build(42);
        let trace = FunctionalSim::new(&program).run(insts);
        let cols = trace.to_columns();
        for cfg in [
            FeatureConfig { nb: 256, nq: 8, nm: 16 },
            FeatureConfig::default(), // paper values: 1k / 32 / 64
        ] {
            let case = format!("{w}/nb{}-nq{}-nm{}", cfg.nb, cfg.nq, cfg.nm);
            let mut out = vec![0.0f32; cfg.feature_dim()];
            let m = b.run(&format!("{case}/aos"), insts, || {
                let mut fx = FeatureExtractor::new(cfg);
                let mut acc = 0i64;
                for rec in &trace.records {
                    acc += fx.extract_into(rec, &mut out) as i64;
                }
                acc
            });
            report.push(m);
            let m = b.run(&format!("{case}/soa"), insts, || {
                let mut fx = FeatureExtractor::new(cfg);
                let mut acc = 0i64;
                for i in 0..cols.len() {
                    acc += fx.extract_into(&cols.record(i), &mut out) as i64;
                }
                acc
            });
            report.push(m);
        }
    }
    if let Some(path) = &opts.json {
        report.write_json(path).expect("write bench json");
        println!("wrote {}", path.display());
    }
}
