//! Feature-extraction throughput — the Rust-side hot-path component in
//! front of every model batch (paper §4.2 pipeline).
//!
//! Measures `extract_into` over the AoS record stream and over the SoA
//! columnar trace (assembled per instruction via `TraceColumns::record`)
//! to track the storage-layout effect on the extraction scan, plus the
//! datagen dataset writers: the in-memory `featurize` (full `[M, F]`
//! matrix resident) against the bounded-memory chunk-streaming sharded
//! writer (`stream_dataset`, disk I/O included).
//!
//! Flags: `--smoke` (reduced counts), `--json <path>` (write metrics).

use tao_sim::datagen::{self, StreamOptions};
use tao_sim::dataset::{AdjustedTrace, Labels, Sample};
use tao_sim::features::{FeatureConfig, FeatureExtractor};
use tao_sim::functional::FunctionalSim;
use tao_sim::trace::{
    open_trace_source, AccessLevel, ChunkBuf, ChunkSource, TraceFormat, TraceWriteOptions,
};
use tao_sim::util::benchkit::{Bench, BenchOpts, BenchReport};
use tao_sim::workloads;

/// Synthetic adjusted trace over a real functional trace: cheap labels,
/// real feature inputs — isolates datagen writer throughput from the
/// detailed simulator.
fn synthetic_adjusted(bench: &str, insts: u64) -> AdjustedTrace {
    let program = workloads::by_name(bench).unwrap().build(7);
    let trace = FunctionalSim::new(&program).run(insts);
    let samples: Vec<Sample> = trace
        .records
        .iter()
        .map(|r| Sample {
            func: *r,
            labels: Labels {
                fetch_latency: 1,
                exec_latency: 4,
                branch_mispred: false,
                access_level: AccessLevel::None,
                icache_miss: false,
                tlb_miss: false,
            },
        })
        .collect();
    AdjustedTrace {
        name: bench.to_string(),
        uarch: "bench".to_string(),
        samples,
        total_cycles: 5 * insts,
    }
}

fn main() {
    let opts = BenchOpts::from_env();
    let insts: u64 = if opts.smoke { 50_000 } else { 200_000 };
    let iters = if opts.smoke { 2 } else { 5 };
    let mut report = BenchReport::new();
    report.metric("smoke", if opts.smoke { 1.0 } else { 0.0 });
    let b = Bench::new("features").iters(iters);
    for w in ["dee", "mcf", "rom"] {
        let program = workloads::by_name(w).unwrap().build(42);
        let trace = FunctionalSim::new(&program).run(insts);
        let cols = trace.to_columns();
        for cfg in [
            FeatureConfig { nb: 256, nq: 8, nm: 16 },
            FeatureConfig::default(), // paper values: 1k / 32 / 64
        ] {
            // The instruction count is part of the case name so the
            // bench gate never cross-compares smoke and full runs.
            let case = format!("{w}-{}k/nb{}-nq{}-nm{}", insts / 1000, cfg.nb, cfg.nq, cfg.nm);
            let mut out = vec![0.0f32; cfg.feature_dim()];
            let m = b.run(&format!("{case}/aos"), insts, || {
                let mut fx = FeatureExtractor::new(cfg);
                let mut acc = 0i64;
                for rec in &trace.records {
                    acc += fx.extract_into(rec, &mut out) as i64;
                }
                acc
            });
            report.push(m);
            let m = b.run(&format!("{case}/soa"), insts, || {
                let mut fx = FeatureExtractor::new(cfg);
                let mut acc = 0i64;
                for i in 0..cols.len() {
                    acc += fx.extract_into(&cols.record(i), &mut out) as i64;
                }
                acc
            });
            report.push(m);
        }
    }
    // --- datagen writers: in-memory featurize vs streamed shards ---
    let dg_insts: u64 = if opts.smoke { 20_000 } else { 100_000 };
    let adjusted = synthetic_adjusted("mcf", dg_insts);
    let trace_records: Vec<_> = adjusted.samples.iter().map(|s| s.func).collect();
    let cfg = FeatureConfig::default();
    let dg = Bench::new("datagen").iters(if opts.smoke { 2 } else { 3 });
    let dir = std::env::temp_dir().join(format!("tao-bench-dg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir bench datagen dir");

    let m = dg.run(&format!("in-memory-{}k", dg_insts / 1000), dg_insts, || {
        datagen::featurize(&adjusted, cfg).len()
    });
    report.metric("datagen_inmem_ips", m.items_per_sec());
    report.push(m);

    for shards in [1usize, 4] {
        let case = format!("stream-{}k/shards{shards}", dg_insts / 1000);
        let out = dir.join(format!("s{shards}"));
        let stream = StreamOptions {
            chunk_size: 8_192,
            shards,
            keep_shards: true,
        };
        let m = dg.run(&case, dg_insts, || {
            let (manifest, _) = datagen::stream_dataset(
                &out,
                &trace_records[..],
                &adjusted.samples,
                adjusted.total_cycles,
                cfg,
                stream,
            )
            .expect("stream dataset");
            manifest.rows
        });
        report.metric(&format!("datagen_stream_ips_shards{shards}"), m.items_per_sec());
        report.push(m);
    }
    // --- pull-based chunk sources (end-to-end streaming pipeline) ---
    // stream-src/slice: the sequential pull writer over the in-memory
    // paired adapter (per-chunk alignment included) — isolates the
    // ChunkSource plumbing cost against `stream-*k/shards1` above.
    // stream-src/e2e: generator-backed SimPairSource — functional +
    // detailed simulation, alignment, featurization and shard writes in
    // one O(chunk) pass (the `tao datagen --stream` hot path).
    let src_stream = StreamOptions {
        chunk_size: 8_192,
        shards: 1,
        keep_shards: true,
    };
    let out = dir.join("src-slice");
    let m = dg.run(&format!("stream-src-{}k/slice", dg_insts / 1000), dg_insts, || {
        let mut source = datagen::PairedSliceSource::new(
            &trace_records[..],
            &adjusted.samples,
            adjusted.total_cycles,
        );
        let (manifest, _) = datagen::stream_dataset_source(&out, &mut source, cfg, src_stream)
            .expect("stream dataset from slice source");
        manifest.rows
    });
    report.metric("datagen_stream_src_slice_ips", m.items_per_sec());
    report.push(m);

    let wl = workloads::by_name("mcf").unwrap();
    let uarch = tao_sim::uarch::UarchConfig::uarch_a();
    let out = dir.join("src-e2e");
    let m = dg.run(&format!("stream-src-{}k/e2e", dg_insts / 1000), dg_insts, || {
        let mut source = datagen::SimPairSource::new(&wl, &uarch, dg_insts, 7);
        let (manifest, _) = datagen::stream_dataset_source(&out, &mut source, cfg, src_stream)
            .expect("stream dataset from generator source");
        manifest.rows
    });
    report.metric("datagen_stream_src_e2e_ips", m.items_per_sec());
    report.push(m);

    // --- trace I/O: the two on-disk formats (flat v1 vs compressed v2)
    // Decode throughput is the supply ceiling of the chunk-prefetch
    // stage feeding the pipelined engine; bytes-per-instruction tracks
    // the compression ratio itself (v1 is fixed at 27 B + header).
    let tr_insts: u64 = if opts.smoke { 50_000 } else { 200_000 };
    let tr_program = workloads::by_name("mcf").unwrap().build(42);
    let tr_trace = FunctionalSim::new(&tr_program).run(tr_insts);
    let tr_cols = tr_trace.to_columns();
    let tio = Bench::new("trace-io").iters(iters);
    for (tag, format) in [("v1", TraceFormat::V1), ("v2", TraceFormat::V2)] {
        let path = dir.join(format!("mcf.{tag}.trace"));
        TraceWriteOptions::new(format)
            .write(&path, &tr_trace.name, &tr_cols)
            .expect("write trace");
        let bytes = std::fs::metadata(&path).expect("stat trace").len();
        report.metric(&format!("trace_bytes_per_inst_{tag}"), bytes as f64 / tr_insts as f64);
        let m = tio.run(&format!("decode-{}k/{tag}", tr_insts / 1000), tr_insts, || {
            let mut src = open_trace_source(&path).expect("open trace");
            let mut buf = ChunkBuf::new();
            let mut rows = 0usize;
            loop {
                let n = src.next_chunk(&mut buf, 8_192).expect("decode chunk");
                if n == 0 {
                    break;
                }
                rows += n;
            }
            rows
        });
        report.metric(&format!("trace_decode_{tag}_ips"), m.items_per_sec());
        report.push(m);
    }

    // The kept shard files are ~100 MB per run; don't let them pile up
    // in the temp dir across invocations.
    let _ = std::fs::remove_dir_all(&dir);

    if let Some(path) = &opts.json {
        report.write_json(path).expect("write bench json");
        println!("wrote {}", path.display());
    }
}
