//! Coordinator hot-path benches: window batching (overlap-aware vs the
//! seed's per-window ring copy) and end-to-end DL-simulation throughput
//! — the paper's headline MIPS axis (Table 4), scaled to this CPU
//! testbed.
//!
//! Flags (after `cargo bench --bench coordinator --`):
//!
//! * `--smoke`        — reduced instruction counts/iterations for CI;
//! * `--json <path>`  — write measurements + derived metrics
//!                      (instructions/sec, per-batch staging latency,
//!                      speedup) as JSON, e.g. `BENCH_coordinator.json`.
//!
//! The end-to-end engine section prefers a real artifact
//! (`artifacts/tao_uarch_a.hlo.txt` from `make artifacts`) and falls
//! back to a surrogate artifact executed by the vendored PJRT stand-in,
//! so the full extract→batch→execute→accumulate path is measurable in
//! every environment.

use std::path::{Path, PathBuf};
use tao_sim::coordinator::engine::{self, NaiveWindowBatcher, ParallelOptions, WindowBatcher};
use tao_sim::features::FeatureConfig;
use tao_sim::functional::FunctionalSim;
use tao_sim::trace::SliceChunkSource;
use tao_sim::util::benchkit::{Bench, BenchOpts, BenchReport};
use tao_sim::workloads;

/// Surrogate artifact for the vendored PJRT stand-in, shaped like the
/// default Tao export (shared constructor in `runtime::artifact`).
fn surrogate_artifact(batch: usize, context: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tao-bench-art-{}", std::process::id()));
    tao_sim::runtime::write_surrogate_artifact(&dir, "bench", batch, context).unwrap()
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut report = BenchReport::new();
    report.metric("smoke", if opts.smoke { 1.0 } else { 0.0 });

    // --- window batching alone (no model), seed shape T=32 F=154 B=256 ---
    let t = 32usize;
    let f = FeatureConfig::default().feature_dim();
    let batch = 256usize;
    let n: u64 = if opts.smoke { 50_000 } else { 200_000 };
    let iters = if opts.smoke { 2 } else { 5 };
    // Spot-check staging equivalence before timing (the exhaustive 100k
    // gate lives in the integration tests).
    engine::check_batcher_equivalence(t, f, batch, 3 * batch + 17, 0xE01_5EED);
    println!("batcher equivalence (n={}): OK", 3 * batch + 17);

    let feats = vec![0.5f32; f];
    let mut ops_buf = vec![0i32; batch * t];
    let mut feat_buf = vec![0.0f32; batch * t * f];
    let b = Bench::new("batcher").iters(iters);

    let naive_m = b.run(&format!("naive-push-{}k", n / 1000), n, || {
        let mut wb = NaiveWindowBatcher::new(t, f, batch);
        let mut flushes = 0u64;
        for i in 0..n {
            if wb.push(i as i32 % 39, &feats, &mut ops_buf, &mut feat_buf) {
                wb.clear_staged();
                flushes += 1;
            }
        }
        // Final partial flush, mirroring the engine (the naive batcher
        // staged it per push; flushing is just releasing the windows).
        if wb.staged > 0 {
            wb.clear_staged();
            flushes += 1;
        }
        flushes
    });

    let overlap_m = b.run(&format!("overlap-push-{}k", n / 1000), n, || {
        let mut wb = WindowBatcher::new(t, f, batch);
        let mut flushes = 0u64;
        for i in 0..n {
            if wb.push(i as i32 % 39, &feats) {
                wb.materialize(&mut ops_buf, &mut feat_buf);
                wb.clear_staged();
                flushes += 1;
            }
        }
        if wb.staged > 0 {
            wb.materialize(&mut ops_buf, &mut feat_buf);
            wb.clear_staged();
            flushes += 1;
        }
        flushes
    });

    let speedup = overlap_m.items_per_sec() / naive_m.items_per_sec();
    // Per-batch staging latency: the whole staging pipeline (all pushes
    // + materialize for overlap; per-push window copies for naive)
    // amortized over the flushes each loop actually performed
    // (div_ceil — both loops flush the final partial batch).
    let flushes = n.div_ceil(batch as u64);
    let stage_latency_us = overlap_m.mean_ns / 1e3 / flushes as f64;
    let naive_stage_latency_us = naive_m.mean_ns / 1e3 / flushes as f64;
    println!(
        "batcher: overlap {:.3} Minst/s vs naive {:.3} Minst/s — {:.2}x; staging/batch {:.1}us (naive {:.1}us)",
        overlap_m.items_per_sec() / 1e6,
        naive_m.items_per_sec() / 1e6,
        speedup,
        stage_latency_us,
        naive_stage_latency_us,
    );
    report.metric("batcher_naive_ips", naive_m.items_per_sec());
    report.metric("batcher_overlap_ips", overlap_m.items_per_sec());
    report.metric("batcher_speedup", speedup);
    report.metric("batch_stage_latency_us", stage_latency_us);
    report.metric("batch_stage_latency_naive_us", naive_stage_latency_us);
    report.push(naive_m);
    report.push(overlap_m);

    // --- end-to-end engine (real artifact if built, else surrogate) ---
    let real = Path::new("artifacts/tao_uarch_a.hlo.txt");
    let artifact = if real.exists() {
        println!("engine: using real artifact {real:?}");
        real.to_path_buf()
    } else {
        println!("engine: artifacts not built; using the surrogate PJRT stand-in");
        surrogate_artifact(batch, t)
    };
    let insts: u64 = if opts.smoke { 20_000 } else { 60_000 };
    let program = workloads::by_name("dee").unwrap().build(42);
    let cols = FunctionalSim::new(&program).run(insts).to_columns();
    let eb = Bench::new("engine").iters(if opts.smoke { 1 } else { 2 });
    let serial_opts = ParallelOptions {
        chunk: 8_192,
        warmup: 1_024,
        pipeline: false,
    };
    let popts = ParallelOptions { pipeline: true, ..serial_opts };

    // Pipelined (double-buffered stage/execute, the default path) vs
    // the serial single-threaded oracle, per worker count — the
    // offline-pipelining trajectory the gate watches.
    for workers in [1usize, 2, 4] {
        let ms = eb.run(&format!("dee-{}k/serial-workers{workers}", insts / 1000), insts, || {
            engine::simulate_parallel_opts(&artifact, &cols, workers, None, serial_opts)
                .expect("simulate")
                .metrics
                .instructions
        });
        let mp = eb.run(&format!("dee-{}k/workers{workers}", insts / 1000), insts, || {
            engine::simulate_parallel_opts(&artifact, &cols, workers, None, popts)
                .expect("simulate")
                .metrics
                .instructions
        });
        report.metric(&format!("engine_serial_ips_workers{workers}"), ms.items_per_sec());
        report.metric(&format!("engine_ips_workers{workers}"), mp.items_per_sec());
        report.metric(
            &format!("pipeline_speedup_workers{workers}"),
            mp.items_per_sec() / ms.items_per_sec(),
        );
        report.push(ms);
        report.push(mp);
    }

    // Occupancy counters from one instrumented pipelined run: is the
    // pipeline execute-bound (executor busy, stager stalling on free
    // buffers) or stage-bound (executor idling)?
    let occ = engine::simulate_parallel_opts(&artifact, &cols, 2, None, popts).expect("simulate");
    if let Some(ps) = occ.pipeline {
        report.metric("pipeline_batches", ps.batches as f64);
        report.metric("pipeline_exec_busy_frac", ps.exec_busy_fraction());
        report.metric("pipeline_exec_idle_ms", ps.exec_idle_ns as f64 / 1e6);
        report.metric("pipeline_stage_stall_ms", ps.stage_stall_ns as f64 / 1e6);
        println!(
            "engine: pipeline occupancy — {} batches, exec busy {:.1}%, stage stall {:.1}ms",
            ps.batches,
            ps.exec_busy_fraction() * 100.0,
            ps.stage_stall_ns as f64 / 1e6,
        );
    }

    // --- telemetry overhead: armed vs disarmed on the hot engine path ---
    // The engine's stage spans, pipeline counters, and queue metrics all
    // sit on this path. Disarmed they cost one relaxed atomic load per
    // site; armed the whole layer must stay within a 2% throughput
    // budget (`tools/bench_gate.rs` warns above it).
    tao_sim::telemetry::disarm();
    let tm_off = eb.run(&format!("dee-{}k/telemetry-disarmed", insts / 1000), insts, || {
        engine::simulate_parallel_opts(&artifact, &cols, 2, None, popts)
            .expect("simulate")
            .metrics
            .instructions
    });
    tao_sim::telemetry::arm();
    let tm_on = eb.run(&format!("dee-{}k/telemetry-armed", insts / 1000), insts, || {
        engine::simulate_parallel_opts(&artifact, &cols, 2, None, popts)
            .expect("simulate")
            .metrics
            .instructions
    });
    tao_sim::telemetry::disarm();
    let overhead_pct = (tm_off.items_per_sec() / tm_on.items_per_sec() - 1.0) * 100.0;
    println!(
        "telemetry: armed {:.3} Minst/s vs disarmed {:.3} Minst/s — {:.2}% overhead (budget 2%)",
        tm_on.items_per_sec() / 1e6,
        tm_off.items_per_sec() / 1e6,
        overhead_pct,
    );
    report.metric("telemetry_armed_ips", tm_on.items_per_sec());
    report.metric("telemetry_disarmed_ips", tm_off.items_per_sec());
    report.metric("telemetry_overhead_pct", overhead_pct);
    report.push(tm_off);
    report.push(tm_on);

    // The chunked pull path (every `tao simulate --stream` run):
    // dispatch-thread chunk prefetch + per-worker pipelining vs the
    // fully serial pull.
    for pipeline in [false, true] {
        let tag = if pipeline { "chunked-pipelined" } else { "chunked-serial" };
        let m = eb.run(&format!("dee-{}k/{tag}-workers2", insts / 1000), insts, || {
            let mut src = SliceChunkSource::new(&cols, None).unwrap();
            engine::simulate_parallel_chunked(
                &artifact,
                &mut src,
                2,
                ParallelOptions { pipeline, ..serial_opts },
            )
            .expect("simulate")
            .metrics
            .instructions
        });
        report.metric(
            &format!("engine_chunked_{}_ips", if pipeline { "pipelined" } else { "serial" }),
            m.items_per_sec(),
        );
        report.push(m);
    }

    // --- phase-sampled replay vs full replay on a mixed-phase trace ---
    // Concatenating every Table 2 workload gives a trace with real
    // phase structure; the sampled path must reconstruct its CPI within
    // the declared bound while simulating a fraction of the rows.
    // `tools/bench_gate.rs` warns when the speedup dips below 4x or the
    // measured error exceeds the declared bound.
    const SAMPLED_ERROR_BOUND_PCT: f64 = 15.0;
    let per: u64 = if opts.smoke { 6_000 } else { 25_000 };
    let mut mixed = tao_sim::trace::TraceColumns::new();
    for w in workloads::suite() {
        let t = FunctionalSim::new(&w.build(3)).run(per).to_columns();
        mixed.extend_from(&t, 0, t.len());
    }
    let total = mixed.len() as u64;
    let bench_dir = std::env::temp_dir().join(format!("tao-bench-art-{}", std::process::id()));
    std::fs::create_dir_all(&bench_dir).unwrap();
    let mixed_trace = bench_dir.join("mixed.trace");
    tao_sim::trace::TraceWriteOptions::new(tao_sim::trace::TraceFormat::V2)
        .chunk_rows(8_192)
        .write(&mixed_trace, "mixed", &mixed)
        .unwrap();
    let plan = tao_sim::sampling::plan_trace(
        &mixed_trace,
        &tao_sim::sampling::SamplingOptions {
            slice_rows: per / 3,
            max_phases: 5,
            seed: 42,
        },
    )
    .expect("sampling plan");
    println!(
        "sampled: {} phases over {} slices, {:.1}% coverage",
        plan.phases.len(),
        total.div_ceil(per / 3),
        plan.coverage() * 100.0
    );
    let full_run = eb.run(&format!("mixed-{}k/full-workers2", total / 1000), total, || {
        let mut src = tao_sim::trace::open_trace_source(&mixed_trace).unwrap();
        engine::simulate_parallel_chunked(&artifact, &mut *src, 2, popts)
            .expect("simulate")
            .metrics
            .instructions
    });
    let full_cpi = {
        let mut src = tao_sim::trace::open_trace_source(&mixed_trace).unwrap();
        engine::simulate_parallel_chunked(&artifact, &mut *src, 2, popts)
            .expect("simulate")
            .metrics
            .cpi()
    };
    // Items = represented instructions: the sampled path answers for
    // the whole trace, so its throughput is measured in trace rows.
    let sampled_run =
        eb.run(&format!("mixed-{}k/sampled-workers2", total / 1000), total, || {
            engine::simulate_sampled(&artifact, &mixed_trace, &plan, 2, popts)
                .expect("simulate sampled")
                .result
                .metrics
                .instructions
        });
    let sampled_out =
        engine::simulate_sampled(&artifact, &mixed_trace, &plan, 2, popts).expect("sampled");
    let sampled_cpi = sampled_out.result.metrics.cpi();
    let error_pct = (sampled_cpi - full_cpi).abs() / full_cpi * 100.0;
    let sampled_speedup = sampled_run.items_per_sec() / full_run.items_per_sec();
    println!(
        "sampled: {:.3} Minst/s vs full {:.3} Minst/s — {:.2}x; CPI {:.4} vs {:.4} ({:.2}% error, bound {:.0}%)",
        sampled_run.items_per_sec() / 1e6,
        full_run.items_per_sec() / 1e6,
        sampled_speedup,
        sampled_cpi,
        full_cpi,
        error_pct,
        SAMPLED_ERROR_BOUND_PCT,
    );
    report.metric("sampled_full_ips", full_run.items_per_sec());
    report.metric("sampled_ips", sampled_run.items_per_sec());
    report.metric("sampled_speedup", sampled_speedup);
    report.metric("sampled_coverage_pct", plan.coverage() * 100.0);
    report.metric(
        "sampled_simulated_frac_pct",
        sampled_out.simulated_rows as f64 / total as f64 * 100.0,
    );
    report.metric("sampled_max_error_pct", error_pct);
    report.metric("sampled_error_bound_pct", SAMPLED_ERROR_BOUND_PCT);
    report.push(full_run);
    report.push(sampled_run);

    // Pallas-kernel artifact variant, if exported (`make artifacts`).
    let pallas = Path::new("artifacts/tao_uarch_a.pallas.hlo.txt");
    if pallas.exists() {
        let small = 4_096.min(cols.len());
        let view = cols.slice(0, small);
        let m = eb.run("dee-4k/pallas-artifact", small as u64, || {
            engine::simulate_parallel_opts(pallas, &view, 1, None, popts)
                .expect("simulate")
                .metrics
                .instructions
        });
        report.metric("engine_ips_pallas", m.items_per_sec());
        report.push(m);
    }

    if let Some(path) = &opts.json {
        report.write_json(path).expect("write bench json");
        println!("wrote {}", path.display());
    }
}
