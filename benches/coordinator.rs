//! Coordinator hot-path benches: window batching and (when artifacts are
//! built) end-to-end DL-simulation throughput — the paper's headline
//! MIPS axis (Table 4), scaled to this CPU testbed.

use std::path::Path;
use tao_sim::coordinator::engine::{self, WindowBatcher};
use tao_sim::functional::FunctionalSim;
use tao_sim::util::benchkit::Bench;
use tao_sim::workloads;

fn main() {
    // --- window batcher alone (no model) ---
    let t = 32usize;
    let f = 154usize;
    let batch = 256usize;
    let n = 200_000u64;
    let feats = vec![0.5f32; f];
    let mut ops_buf = vec![0i32; batch * t];
    let mut feat_buf = vec![0.0f32; batch * t * f];
    let b = Bench::new("batcher").iters(5);
    b.run("push-200k", n, || {
        let mut wb = WindowBatcher::new(t, f, batch);
        let mut flushes = 0u64;
        for i in 0..n {
            if wb.push(i as i32 % 39, &feats, &mut ops_buf, &mut feat_buf) {
                wb.clear_staged();
                flushes += 1;
            }
        }
        flushes
    });

    // --- end-to-end engine (needs `make artifacts`) ---
    let artifact = Path::new("artifacts/tao_uarch_a.hlo.txt");
    if !artifact.exists() {
        println!("(artifacts missing — run `make artifacts` for end-to-end benches)");
        return;
    }
    let insts = 20_000u64;
    let program = workloads::by_name("dee").unwrap().build(42);
    let trace = FunctionalSim::new(&program).run(insts);
    let b = Bench::new("engine").iters(2);
    for workers in [1usize, 2, 4] {
        b.run(&format!("dee-20k/workers{workers}"), insts, || {
            engine::simulate_parallel(artifact, &trace.records, workers, None)
                .expect("simulate")
                .metrics
                .instructions
        });
    }
    // Pallas-kernel artifact variant, if exported.
    let pallas = Path::new("artifacts/tao_uarch_a.pallas.hlo.txt");
    if pallas.exists() {
        let small = &trace.records[..4_096.min(trace.records.len())];
        b.run("dee-4k/pallas-artifact", small.len() as u64, || {
            engine::simulate_parallel(pallas, small, 1, None)
                .expect("simulate")
                .metrics
                .instructions
        });
    }
}
