//! Feature engineering — the paper's §4.2 input pipeline.
//!
//! From the microarchitecture-agnostic functional trace we extract, per
//! instruction:
//!
//! * **opcode id** — integer mapping into the embedding lookup table;
//! * **register bitmap** — one bit per architectural register (src+dst);
//! * **branch history** — a hash table of `Nb` buckets, each holding the
//!   last `Nq` outcomes of the branches that hash there (paper Figure 4);
//!   retrieved *before* the current outcome is inserted;
//! * **access distances** — deltas between the current memory address and
//!   the previous `Nm` accesses (paper Figure 3), log-compressed;
//! * **scalar flags** — instruction-class indicators.
//!
//! The same extractor runs in `tao datagen` (training features) and in the
//! coordinator's inference hot path, so train/serve skew is impossible by
//! construction. The extractor is sequential state — one instance per
//! trace shard.

use crate::isa::{Opcode, NUM_REGS};
use crate::trace::FuncRecord;

/// Number of scalar flag features (see [`FeatureExtractor::extract`]).
pub const NUM_SCALARS: usize = 10;

/// Sentinel feature value for "no history yet" slots.
pub const EMPTY_SLOT: f32 = -1.0;

/// Feature-engineering hyperparameters (paper §4.2 / Figure 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureConfig {
    /// Branch-history hash buckets `Nb` (paper default 1k).
    pub nb: usize,
    /// Outcomes kept per bucket `Nq` (paper default 32).
    pub nq: usize,
    /// Memory-context queue length `Nm` (paper default 64).
    pub nm: usize,
}

impl Default for FeatureConfig {
    fn default() -> FeatureConfig {
        // The values §5.4 selects: Nb=1k, Nq=32, Nm=64.
        FeatureConfig {
            nb: 1024,
            nq: 32,
            nm: 64,
        }
    }
}

impl FeatureConfig {
    /// Total per-instruction feature vector width `F`.
    pub fn feature_dim(&self) -> usize {
        NUM_REGS + self.nq + self.nm + NUM_SCALARS
    }
}

/// Stateful feature extractor over a committed instruction stream.
pub struct FeatureExtractor {
    config: FeatureConfig,
    /// Branch history: `nb` ring buffers of the last `nq` outcomes.
    /// Flattened as `history[bucket * nq + slot]`; -1 = empty, 0 = not
    /// taken, 1 = taken. `head[bucket]` is the next write position.
    history: Vec<i8>,
    head: Vec<u32>,
    filled: Vec<u32>,
    /// Memory context: ring of the last `nm` addresses.
    mem_ring: Vec<u64>,
    mem_head: usize,
    mem_filled: usize,
    /// Dependency tracking: per-register (ordinal of last writer, writer
    /// was a load). Register dataflow is program semantics — fully
    /// microarchitecture agnostic — and exposes serialized dependence
    /// chains (e.g. pointer chasing) that the window's raw features
    /// cannot distinguish from independent access streams.
    last_writer: Vec<u64>,
    writer_was_load: Vec<bool>,
    ordinal: u64,
}

impl FeatureExtractor {
    /// New extractor with empty history.
    pub fn new(config: FeatureConfig) -> FeatureExtractor {
        FeatureExtractor {
            config,
            history: vec![-1; config.nb * config.nq],
            head: vec![0; config.nb],
            filled: vec![0; config.nb],
            mem_ring: vec![0; config.nm],
            mem_head: 0,
            mem_filled: 0,
            last_writer: vec![0; crate::isa::NUM_REGS],
            writer_was_load: vec![false; crate::isa::NUM_REGS],
            ordinal: 0,
        }
    }

    /// The configuration in use.
    pub fn config(&self) -> FeatureConfig {
        self.config
    }

    /// Reset all history (new trace shard).
    pub fn reset(&mut self) {
        self.history.fill(-1);
        self.head.fill(0);
        self.filled.fill(0);
        self.mem_head = 0;
        self.mem_filled = 0;
        self.last_writer.fill(0);
        self.writer_was_load.fill(false);
        self.ordinal = 0;
    }

    /// Bucket for a branch PC. PCs are 4-byte aligned, so this is the
    /// paper's `PC % 4·Nb` bucket selection expressed on word addresses.
    fn bucket(&self, pc: u64) -> usize {
        ((pc >> 2) as usize) % self.config.nb
    }

    /// Signed log compression for address deltas: keeps near/far structure
    /// while bounding the dynamic range for the model.
    fn compress_delta(d: i64) -> f32 {
        let mag = (d.unsigned_abs() as f64 + 1.0).log2() as f32 / 48.0;
        if d < 0 {
            -mag
        } else {
            mag
        }
    }

    /// Extract the feature vector for `rec` into `out` (length must be
    /// `config.feature_dim()`), returning the opcode id. Updates the
    /// branch/memory history state *after* reading it, so no label leaks
    /// into the instruction's own features.
    ///
    /// This is the allocation-free hot-path entry: callers hand in the
    /// destination row — a dataset matrix row in `datagen`, or the window
    /// batcher's rolling-buffer slot on the inference path — so the
    /// features are written exactly once, in place, with no intermediate
    /// row buffer.
    pub fn extract_into(&mut self, rec: &FuncRecord, out: &mut [f32]) -> i32 {
        let cfg = self.config;
        debug_assert_eq!(out.len(), cfg.feature_dim());
        let (reg_part, rest) = out.split_at_mut(NUM_REGS);
        let (branch_part, rest) = rest.split_at_mut(cfg.nq);
        let (mem_part, scalar_part) = rest.split_at_mut(cfg.nm);

        // --- register bitmap ---
        for (i, slot) in reg_part.iter_mut().enumerate() {
            *slot = ((rec.reg_bitmap >> i) & 1) as f32;
        }

        // --- branch history (read before update) ---
        if rec.opcode.is_cond_branch() {
            let b = self.bucket(rec.pc);
            let base = b * cfg.nq;
            let filled = self.filled[b] as usize;
            let head = self.head[b] as usize;
            // Most recent outcome first.
            for (j, slot) in branch_part.iter_mut().enumerate() {
                if j < filled {
                    let idx = (head + cfg.nq - 1 - j) % cfg.nq;
                    *slot = self.history[base + idx] as f32;
                } else {
                    *slot = EMPTY_SLOT;
                }
            }
        } else {
            branch_part.fill(EMPTY_SLOT);
        }

        // --- access distances (read before update) ---
        if rec.is_mem() {
            let filled = self.mem_filled;
            for (j, slot) in mem_part.iter_mut().enumerate() {
                if j < filled {
                    let idx = (self.mem_head + cfg.nm - 1 - j) % cfg.nm;
                    let prev = self.mem_ring[idx];
                    *slot = Self::compress_delta(rec.mem_addr as i64 - prev as i64);
                } else {
                    *slot = EMPTY_SLOT;
                }
            }
        } else {
            mem_part.fill(EMPTY_SLOT);
        }

        // --- scalar flags ---
        let op = rec.opcode;
        scalar_part[0] = op.is_load() as u8 as f32;
        scalar_part[1] = op.is_store() as u8 as f32;
        scalar_part[2] = op.is_cond_branch() as u8 as f32;
        scalar_part[3] = (op.is_branch() && !op.is_cond_branch()) as u8 as f32;
        scalar_part[4] = matches!(
            op.class(),
            crate::isa::OpcodeClass::FpAlu
                | crate::isa::OpcodeClass::FpMul
                | crate::isa::OpcodeClass::FpDiv
        ) as u8 as f32;
        scalar_part[5] = rec.mem_bytes as f32 / 8.0;
        scalar_part[6] = matches!(
            op.class(),
            crate::isa::OpcodeClass::IntMul | crate::isa::OpcodeClass::IntDiv
        ) as u8 as f32;
        scalar_part[7] = (rec.reg_bitmap.count_ones() as f32) / 4.0;
        // Dependency features: distance (in instructions) to the nearest
        // producer of any source register, and whether that producer was
        // a load (serialized memory dependence, e.g. pointer chasing).
        let mut dep_dist = f32::INFINITY;
        let mut dep_on_load = false;
        for i in 0..NUM_REGS {
            if rec.reg_bitmap & (1u64 << i) != 0 && self.last_writer[i] != 0 {
                let d = (self.ordinal - self.last_writer[i]) as f32;
                if d < dep_dist {
                    dep_dist = d;
                    dep_on_load = self.writer_was_load[i];
                }
            }
        }
        scalar_part[8] = if dep_dist.is_finite() {
            (dep_dist + 1.0).log2() / 16.0
        } else {
            EMPTY_SLOT
        };
        scalar_part[9] = (dep_on_load && dep_dist <= 8.0) as u8 as f32;

        // --- state updates (after reads) ---
        self.update_state(rec);

        rec.opcode.index() as i32
    }

    /// Fold `rec` into the history state without computing its feature
    /// row. This is the cheap warm-path behind sharded datagen: a shard
    /// worker `advance`s over the instructions before its shard start
    /// and lands on *exactly* the state a sequential `extract_into` pass
    /// would have reached — no O(F) row writes, no approximation — so
    /// sharded featurization stays byte-identical to the in-memory path.
    #[inline]
    pub fn advance(&mut self, rec: &FuncRecord) {
        self.update_state(rec);
    }

    /// The state-update tail shared by [`FeatureExtractor::extract_into`]
    /// (which runs it after reading the pre-update state into the row)
    /// and [`FeatureExtractor::advance`] (which runs only this).
    fn update_state(&mut self, rec: &FuncRecord) {
        let cfg = self.config;
        if rec.opcode.is_cond_branch() {
            let b = self.bucket(rec.pc);
            let base = b * cfg.nq;
            let head = self.head[b] as usize;
            self.history[base + head] = rec.taken as i8;
            self.head[b] = ((head + 1) % cfg.nq) as u32;
            self.filled[b] = (self.filled[b] + 1).min(cfg.nq as u32);
        }
        if rec.is_mem() {
            self.mem_ring[self.mem_head] = rec.mem_addr;
            self.mem_head = (self.mem_head + 1) % cfg.nm;
            self.mem_filled = (self.mem_filled + 1).min(cfg.nm);
        }
        self.ordinal += 1;
        // Approximate writer tracking from the bitmap: loads and ALU ops
        // write their destination; we mark every register the instruction
        // touches that is plausibly a destination. Over-approximation is
        // acceptable — the feature is a hint, not an exact dataflow graph.
        if !rec.opcode.is_store() && !rec.opcode.is_branch() {
            for i in 0..NUM_REGS {
                if rec.reg_bitmap & (1u64 << i) != 0 {
                    self.last_writer[i] = self.ordinal;
                    self.writer_was_load[i] = rec.opcode.is_load();
                }
            }
        }
    }

    /// Back-compat alias for [`FeatureExtractor::extract_into`].
    pub fn extract(&mut self, rec: &FuncRecord, out: &mut [f32]) -> i32 {
        self.extract_into(rec, out)
    }
}

/// Opcode-id mapping metadata (recorded in the AOT artifact and validated
/// at load time so the Rust hot path and the trained model can never
/// disagree on the vocabulary).
pub fn opcode_vocabulary() -> Vec<(&'static str, usize)> {
    Opcode::ALL
        .iter()
        .map(|op| (op.mnemonic(), op.index()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    fn rec(opcode: Opcode, pc: u64, mem_addr: u64, taken: bool) -> FuncRecord {
        FuncRecord {
            pc,
            opcode,
            reg_bitmap: 0b101,
            mem_addr,
            mem_bytes: if opcode.is_mem() {
                crate::isa::Instruction::new(opcode).mem_width().unwrap().bytes() as u8
            } else {
                0
            },
            taken,
        }
    }

    fn extract_one(fx: &mut FeatureExtractor, r: &FuncRecord) -> (i32, Vec<f32>) {
        let mut out = vec![0.0; fx.config().feature_dim()];
        let id = fx.extract(r, &mut out);
        (id, out)
    }

    #[test]
    fn feature_dim_matches_layout() {
        let cfg = FeatureConfig::default();
        assert_eq!(cfg.feature_dim(), NUM_REGS + 32 + 64 + NUM_SCALARS);
    }

    #[test]
    fn register_bitmap_roundtrip() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        let r = rec(Opcode::Add, 0x400000, 0, false);
        let (_, out) = extract_one(&mut fx, &r);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[2], 1.0);
    }

    #[test]
    fn branch_history_no_self_leak() {
        // The branch's own outcome must NOT appear in its features.
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        let b = rec(Opcode::Bcond, 0x400100, 0, true);
        let (_, out) = extract_one(&mut fx, &b);
        let hist = &out[NUM_REGS..NUM_REGS + 32];
        assert!(hist.iter().all(|&v| v == EMPTY_SLOT), "history leaked");
    }

    #[test]
    fn branch_history_accumulates_most_recent_first() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        let pc = 0x400100;
        for taken in [true, false, true] {
            extract_one(&mut fx, &rec(Opcode::Bcond, pc, 0, taken));
        }
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Bcond, pc, 0, false));
        let hist = &out[NUM_REGS..NUM_REGS + 32];
        // Most recent first: true, false, true, then empty.
        assert_eq!(&hist[..3], &[1.0, 0.0, 1.0]);
        assert_eq!(hist[3], EMPTY_SLOT);
    }

    #[test]
    fn branch_buckets_separate_pcs() {
        // Figure 4's point: different branches land in different buckets.
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        extract_one(&mut fx, &rec(Opcode::Bcond, 0x400100, 0, true));
        extract_one(&mut fx, &rec(Opcode::Bcond, 0x400104, 0, true));
        // A fresh PC in yet another bucket sees empty history.
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Bcond, 0x400108, 0, false));
        let hist = &out[NUM_REGS..NUM_REGS + 32];
        assert!(hist.iter().all(|&v| v == EMPTY_SLOT));
        // While the first PC sees only its own outcome.
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Bcond, 0x400100, 0, false));
        let hist = &out[NUM_REGS..NUM_REGS + 32];
        assert_eq!(hist[0], 1.0);
        assert_eq!(hist[1], EMPTY_SLOT);
    }

    #[test]
    fn aliasing_pcs_share_a_bucket() {
        // PCs nb*4 apart hash to the same bucket — the paper notes this
        // provides a shared global history.
        let cfg = FeatureConfig { nb: 16, nq: 4, nm: 4 };
        let mut fx = FeatureExtractor::new(cfg);
        let pc_a = 0x400000;
        let pc_b = 0x400000 + (cfg.nb as u64 * 4);
        extract_one(&mut fx, &rec(Opcode::Bcond, pc_a, 0, true));
        let mut out = vec![0.0; cfg.feature_dim()];
        fx.extract(&rec(Opcode::Bcond, pc_b, 0, false), &mut out);
        assert_eq!(out[NUM_REGS], 1.0, "aliased bucket should see pc_a's outcome");
    }

    #[test]
    fn access_distance_computed_against_history() {
        let cfg = FeatureConfig { nb: 16, nq: 4, nm: 4 };
        let mut fx = FeatureExtractor::new(cfg);
        extract_one(&mut fx, &rec(Opcode::Ldr, 0x400000, 1000, false));
        extract_one(&mut fx, &rec(Opcode::Ldr, 0x400004, 1064, false));
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Ldr, 0x400008, 1064, false));
        let mem = &out[NUM_REGS + cfg.nq..NUM_REGS + cfg.nq + cfg.nm];
        // Most recent distance: 1064-1064 = 0 -> log2(1)=0.
        assert_eq!(mem[0], 0.0);
        // Next: 1064-1000=64 -> positive.
        assert!(mem[1] > 0.0);
        assert_eq!(mem[2], EMPTY_SLOT);
    }

    #[test]
    fn negative_distance_is_signed() {
        let cfg = FeatureConfig { nb: 16, nq: 4, nm: 4 };
        let mut fx = FeatureExtractor::new(cfg);
        extract_one(&mut fx, &rec(Opcode::Ldr, 0x400000, 5000, false));
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Str, 0x400004, 1000, false));
        let mem = &out[NUM_REGS + cfg.nq..NUM_REGS + cfg.nq + cfg.nm];
        assert!(mem[0] < 0.0, "delta back in memory should be negative");
    }

    #[test]
    fn non_mem_instruction_has_empty_mem_features() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        extract_one(&mut fx, &rec(Opcode::Ldr, 0x400000, 1000, false));
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Add, 0x400004, 0, false));
        let cfg = fx.config();
        let mem = &out[NUM_REGS + cfg.nq..NUM_REGS + cfg.nq + cfg.nm];
        assert!(mem.iter().all(|&v| v == EMPTY_SLOT));
    }

    #[test]
    fn scalar_flags_identify_classes() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        let base = NUM_REGS + 32 + 64;
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Ldr, 0x400000, 8, false));
        assert_eq!(out[base], 1.0); // load
        assert_eq!(out[base + 1], 0.0);
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Strb, 0x400004, 8, false));
        assert_eq!(out[base + 1], 1.0); // store
        assert!(out[base + 5] > 0.0 && out[base + 5] < 1.0); // 1 byte / 8
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Fmadd, 0x400008, 0, false));
        assert_eq!(out[base + 4], 1.0); // fp
    }

    #[test]
    fn opcode_id_matches_vocabulary() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        for op in Opcode::ALL {
            let (id, _) = extract_one(&mut fx, &rec(op, 0x400000, 0, false));
            assert_eq!(id as usize, op.index());
        }
        assert_eq!(opcode_vocabulary().len(), Opcode::COUNT);
    }

    #[test]
    fn reset_clears_state() {
        let mut fx = FeatureExtractor::new(FeatureConfig::default());
        extract_one(&mut fx, &rec(Opcode::Bcond, 0x400100, 0, true));
        extract_one(&mut fx, &rec(Opcode::Ldr, 0x400104, 512, false));
        fx.reset();
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Bcond, 0x400100, 0, false));
        assert!(out[NUM_REGS..NUM_REGS + 32].iter().all(|&v| v == EMPTY_SLOT));
    }

    #[test]
    fn extractor_is_deterministic() {
        let p = crate::workloads::by_name("dee").unwrap().build(5);
        let t = crate::functional::FunctionalSim::new(&p).run(2_000);
        let cfg = FeatureConfig::default();
        let run = || {
            let mut fx = FeatureExtractor::new(cfg);
            let mut all = Vec::new();
            let mut buf = vec![0.0; cfg.feature_dim()];
            for r in &t.records {
                fx.extract(r, &mut buf);
                all.extend_from_slice(&buf);
            }
            all
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn advance_reaches_exact_mid_trace_state() {
        // `advance` over a prefix must leave the extractor in exactly the
        // state `extract_into` over the same prefix would — every suffix
        // row byte-identical, for splits at and around ring boundaries.
        let p = crate::workloads::by_name("mcf").unwrap().build(3);
        let t = crate::functional::FunctionalSim::new(&p).run(3_000);
        let cfg = FeatureConfig { nb: 64, nq: 8, nm: 16 };
        for split in [0usize, 1, 7, 100, 1023, 2999] {
            let mut fx_full = FeatureExtractor::new(cfg);
            let mut fx_adv = FeatureExtractor::new(cfg);
            let mut row_full = vec![0.0f32; cfg.feature_dim()];
            let mut row_adv = vec![0.0f32; cfg.feature_dim()];
            for r in &t.records[..split] {
                fx_full.extract_into(r, &mut row_full);
                fx_adv.advance(r);
            }
            for (i, r) in t.records[split..].iter().enumerate() {
                let a = fx_full.extract_into(r, &mut row_full);
                let b = fx_adv.extract_into(r, &mut row_adv);
                assert_eq!(a, b, "opcode id {i} rows after split {split}");
                assert_eq!(row_full, row_adv, "row {i} after split {split}");
            }
        }
    }

    #[test]
    fn queue_wraps_beyond_capacity() {
        let cfg = FeatureConfig { nb: 4, nq: 2, nm: 2 };
        let mut fx = FeatureExtractor::new(cfg);
        let pc = 0x400100;
        for taken in [true, true, false] {
            extract_one(&mut fx, &rec(Opcode::Bcond, pc, 0, taken));
        }
        let (_, out) = extract_one(&mut fx, &rec(Opcode::Bcond, pc, 0, true));
        // Only the last nq=2 outcomes retained: false (most recent), true.
        assert_eq!(out[NUM_REGS], 0.0);
        assert_eq!(out[NUM_REGS + 1], 1.0);
    }
}
