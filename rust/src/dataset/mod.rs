//! Training-dataset construction — the paper's §4.1 workflow.
//!
//! Detailed traces contain two kinds of records a functional trace lacks:
//! squashed wrong-path instructions and pipeline-stall `nop`s. The
//! adjustment workflow *removes* both and *re-attributes* their timing to
//! the next retired instruction through the fetch-clock delta, exactly as
//! the paper's Figure 2 walks through: after adjustment the trace has the
//! functional trace's instruction sequence, each instruction labelled with
//! microarchitecture-specific performance metrics, and the **total cycle
//! count is preserved** (the Figure 2 invariant, enforced by tests and a
//! randomized property test).

use crate::trace::{AccessLevel, DetailedTrace, FuncRecord, FunctionalTrace, RecordSource};
use anyhow::{ensure, Result};

/// Per-instruction performance labels (the model's prediction targets).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Labels {
    /// Cycles between this instruction's fetch and the previous retired
    /// instruction's fetch. After adjustment this *includes* squashed
    /// wrong-path time and stall bubbles (Figure 2's "10 → 18" example).
    pub fetch_latency: u32,
    /// Cycles from fetch to retire.
    pub exec_latency: u32,
    /// Conditional branch mispredicted?
    pub branch_mispred: bool,
    /// Data access service level.
    pub access_level: AccessLevel,
    /// L1I miss on fetch?
    pub icache_miss: bool,
    /// Data TLB miss?
    pub tlb_miss: bool,
}

/// One training sample: microarchitecture-agnostic input identity plus
/// microarchitecture-specific labels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// The functional-trace record (model input side).
    pub func: FuncRecord,
    /// The performance labels (model output side).
    pub labels: Labels,
}

/// An adjusted trace: functional instruction stream + per-instruction
/// labels, with squashed/nop records folded into latencies.
#[derive(Debug, Clone, Default)]
pub struct AdjustedTrace {
    /// Benchmark name.
    pub name: String,
    /// Microarchitecture name.
    pub uarch: String,
    /// Aligned samples in program order.
    pub samples: Vec<Sample>,
    /// Ground-truth total cycles of the source detailed trace.
    pub total_cycles: u64,
}

impl AdjustedTrace {
    /// Reconstruct total cycles from the labels alone. By construction
    /// this equals `total_cycles` (the Figure 2 "total cycles remain the
    /// same" invariant): the retire clock of the last instruction is the
    /// cumulative sum of fetch latencies plus its exec latency.
    pub fn reconstructed_cycles(&self) -> u64 {
        reconstruct_cycles(
            self.samples.iter().map(|s| s.labels.fetch_latency as f64),
            self.samples.iter().map(|s| s.labels.exec_latency as f64),
        )
    }
}

/// Total-cycle reconstruction used both for ground-truth labels and for
/// model predictions (paper §4.2: "retire clock is computed as current
/// clock + fetch latency + execution latency; the retire clock of the
/// last instruction determines the total cycles").
pub fn reconstruct_cycles(
    fetch_latencies: impl Iterator<Item = f64>,
    exec_latencies: impl Iterator<Item = f64>,
) -> u64 {
    let mut clock = 0.0f64;
    let mut last_retire = 0.0f64;
    for (f, e) in fetch_latencies.zip(exec_latencies) {
        clock += f;
        last_retire = clock + e;
    }
    last_retire.round().max(0.0) as u64
}

/// Run the §4.1 adjustment workflow over a detailed trace.
///
/// Squashed and nop records are dropped; their time shows up in the next
/// retired instruction's `fetch_latency` because latencies are defined as
/// fetch-clock deltas over the *retired-only* sequence.
pub fn adjust(detailed: &DetailedTrace) -> AdjustedTrace {
    let mut samples = Vec::with_capacity(detailed.retired_count());
    let mut prev_fetch = 0u64;
    for info in detailed.retired() {
        let fetch_latency = (info.fetch_clock - prev_fetch) as u32;
        let exec_latency = (info.retire_clock - info.fetch_clock) as u32;
        prev_fetch = info.fetch_clock;
        samples.push(Sample {
            func: info.func,
            labels: Labels {
                fetch_latency,
                exec_latency,
                branch_mispred: info.branch_mispred,
                access_level: info.access_level,
                icache_miss: info.icache_miss,
                tlb_miss: info.tlb_miss,
            },
        });
    }
    AdjustedTrace {
        name: detailed.name.clone(),
        uarch: detailed.uarch.clone(),
        samples,
        total_cycles: detailed.total_cycles,
    }
}

/// Per-instruction detailed-trace metrics for SimNet's µarch-specific
/// context input, `[N × 6]` in datagen label order: runs the detailed
/// simulator for `insts` instructions on `cfg` and flattens the
/// adjusted labels. Shared by the Figure 9 / Table 4 reports and the
/// serving layer's SimNet jobs (which is the paper's point — SimNet's
/// input itself costs a detailed simulation per target design).
pub fn simnet_ctx_metrics(
    program: &crate::isa::Program,
    cfg: &crate::uarch::UarchConfig,
    insts: u64,
) -> Vec<f32> {
    let (det, _) = crate::detailed::DetailedSim::new(program, cfg).run(insts);
    let adj = adjust(&det);
    let mut ctx = Vec::with_capacity(adj.samples.len() * 6);
    for s in &adj.samples {
        let l = &s.labels;
        ctx.extend_from_slice(&[
            l.fetch_latency as f32,
            l.exec_latency as f32,
            l.branch_mispred as u8 as f32,
            l.access_level.index() as f32,
            l.icache_miss as u8 as f32,
            l.tlb_miss as u8 as f32,
        ]);
    }
    ctx
}

/// Align an adjusted trace against the functional trace of the same
/// program: every instruction must match on PC, opcode and memory
/// address. Returns the verified training set.
///
/// Takes `adjusted` by value and truncates it in place — the caller is
/// done with the unaligned trace, so there is no reason to clone a
/// full-trace sample vector just to shorten it.
///
/// (Our detailed model commits exactly the functional stream by
/// construction; this check is the §4.1 alignment step and guards against
/// regressions in either simulator.)
pub fn align(functional: &FunctionalTrace, mut adjusted: AdjustedTrace) -> Result<AdjustedTrace> {
    let n = functional.records.len().min(adjusted.samples.len());
    ensure!(
        n > 0,
        "cannot align empty traces ({} functional, {} adjusted)",
        functional.records.len(),
        adjusted.samples.len()
    );
    align_chunk(&functional.records[..], &adjusted.samples[..n], 0)?;
    adjusted.samples.truncate(n);
    Ok(adjusted)
}

/// Verify one chunk of the §4.1 alignment: `samples[off]` must match the
/// functional record at global index `base + off` on PC, opcode and
/// memory address. [`align`] runs this over the whole trace at once; the
/// streaming datagen path calls it once per chunk so alignment never
/// needs the full sample vector and matrix resident together — the
/// functional side is consumed lazily through any [`RecordSource`].
pub fn align_chunk<S>(functional: &S, samples: &[Sample], base: usize) -> Result<()>
where
    S: RecordSource + ?Sized,
{
    ensure!(
        base + samples.len() <= functional.len(),
        "chunk [{base}, {}) overruns the {}-record functional trace",
        base + samples.len(),
        functional.len()
    );
    for (off, s) in samples.iter().enumerate() {
        let f = functional.get(base + off);
        let a = &s.func;
        ensure!(
            f.pc == a.pc && f.opcode == a.opcode && f.mem_addr == a.mem_addr,
            "trace mismatch at instruction {}: functional {:x}/{} vs detailed {:x}/{}",
            base + off,
            f.pc,
            f.opcode,
            a.pc,
            a.opcode
        );
    }
    Ok(())
}

/// Paper Table 1 row: instruction-count difference between detailed and
/// functional traces of the same run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceCounts {
    /// Total records in the detailed trace (retired + squashed + nops).
    pub detailed: u64,
    /// Records in the functional trace (committed only).
    pub functional: u64,
}

impl TraceCounts {
    /// Relative difference, in percent (Table 1 reports ~5%).
    pub fn diff_percent(&self) -> f64 {
        if self.functional == 0 {
            return 0.0;
        }
        (self.detailed as f64 - self.functional as f64) * 100.0 / self.functional as f64
    }
}

/// Count comparison for Table 1.
pub fn trace_counts(detailed: &DetailedTrace, functional: &FunctionalTrace) -> TraceCounts {
    TraceCounts {
        detailed: detailed.records.len() as u64,
        functional: functional.records.len() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detailed::DetailedSim;
    use crate::functional::FunctionalSim;
    use crate::uarch::UarchConfig;
    use crate::workloads;

    fn make_traces(bench: &str, n: u64) -> (FunctionalTrace, DetailedTrace) {
        let p = workloads::by_name(bench).unwrap().build(11);
        let func = FunctionalSim::new(&p).run(n);
        let (det, _) = DetailedSim::new(&p, &UarchConfig::uarch_a()).run(n);
        (func, det)
    }

    #[test]
    fn adjustment_preserves_total_cycles() {
        // The Figure 2 invariant, on real benchmark traces.
        for bench in ["dee", "mcf", "nab"] {
            let (_, det) = make_traces(bench, 5_000);
            let adj = adjust(&det);
            assert_eq!(
                adj.reconstructed_cycles(),
                det.total_cycles,
                "{bench}: reconstruction mismatch"
            );
        }
    }

    #[test]
    fn adjustment_drops_exactly_the_extra_records() {
        let (func, det) = make_traces("lee", 5_000);
        let adj = adjust(&det);
        assert_eq!(adj.samples.len(), det.retired_count());
        assert_eq!(adj.samples.len(), func.records.len());
        assert_eq!(
            det.records.len(),
            det.retired_count() + det.squashed_count() + det.nop_count()
        );
    }

    #[test]
    fn alignment_succeeds_on_matching_traces() {
        let (func, det) = make_traces("xal", 5_000);
        let adj = adjust(&det);
        let aligned = align(&func, adj).unwrap();
        assert_eq!(aligned.samples.len(), 5_000);
    }

    #[test]
    fn align_chunk_verifies_ranges_and_rejects_mismatches() {
        let (mut func, det) = make_traces("dee", 2_000);
        let adj = adjust(&det);
        // Any chunking of a matching pair verifies, at any base.
        for (base, len) in [(0usize, 500usize), (500, 1000), (1999, 1)] {
            align_chunk(&func.records[..], &adj.samples[base..base + len], base).unwrap();
        }
        // A chunk overrunning the functional trace is caught.
        assert!(align_chunk(&func.records[..], &adj.samples[1500..], 1501).is_err());
        // A corrupted record inside the chunk is caught; chunks that do
        // not cover it still pass.
        func.records[15].pc ^= 0x40;
        assert!(align_chunk(&func.records[..], &adj.samples[10..20], 10).is_err());
        align_chunk(&func.records[..], &adj.samples[16..30], 16).unwrap();
    }

    #[test]
    fn alignment_rejects_mismatched_traces() {
        let (mut func, det) = make_traces("dee", 1_000);
        let adj = adjust(&det);
        func.records[500].pc ^= 0x40;
        assert!(align(&func, adj).is_err());
    }

    #[test]
    fn fetch_latency_absorbs_squash_time() {
        // Instructions immediately after a mispredicted branch must carry
        // a larger-than-usual fetch latency (the Figure 2 "10 → 18"
        // re-attribution).
        let (_, det) = make_traces("lee", 20_000);
        let adj = adjust(&det);
        let mut after_mispred = Vec::new();
        let mut normal = Vec::new();
        let mut prev_mispred = false;
        for s in &adj.samples {
            if prev_mispred {
                after_mispred.push(s.labels.fetch_latency as f64);
            } else {
                normal.push(s.labels.fetch_latency as f64);
            }
            prev_mispred = s.labels.branch_mispred;
        }
        assert!(after_mispred.len() > 100, "too few mispredicts to test");
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            avg(&after_mispred) > avg(&normal) + 1.0,
            "after-mispredict fetch latency {} not above normal {}",
            avg(&after_mispred),
            avg(&normal)
        );
    }

    #[test]
    fn table1_counts_show_extra_instructions() {
        let (func, det) = make_traces("dee", 10_000);
        let c = trace_counts(&det, &func);
        assert!(c.detailed > c.functional);
        let d = c.diff_percent();
        assert!(d > 0.0 && d < 60.0, "diff% = {d}");
    }

    #[test]
    fn reconstruct_cycles_empty_is_zero() {
        assert_eq!(
            reconstruct_cycles(std::iter::empty(), std::iter::empty()),
            0
        );
    }

    #[test]
    fn reconstruct_cycles_simple_case() {
        // fetch deltas 1,2,3 ; exec 5,5,7 -> clock 6, retire 13
        let f = [1.0, 2.0, 3.0];
        let e = [5.0, 5.0, 7.0];
        assert_eq!(
            reconstruct_cycles(f.iter().copied(), e.iter().copied()),
            13
        );
    }

    /// Randomized property: for arbitrary synthetic detailed traces with
    /// interleaved squash/nop records, adjustment preserves total cycles
    /// and sample count equals retired count.
    #[test]
    fn property_adjustment_invariants_random_traces() {
        use crate::isa::Opcode;
        use crate::trace::{DetailedRecord, RetiredInfo};
        let mut rng = crate::util::Rng::new(0xDA7A);
        for _ in 0..200 {
            let n = 1 + rng.index(200);
            let mut records = Vec::new();
            let mut fetch = 0u64;
            let mut retire = 0u64;
            for i in 0..n {
                // Random interleaved extras.
                while rng.chance(0.2) {
                    if rng.chance(0.5) {
                        records.push(DetailedRecord::Squashed {
                            pc: 0x400000 + i as u64 * 4,
                            opcode: Opcode::Add,
                            fetch_clock: fetch,
                        });
                    } else {
                        records.push(DetailedRecord::NopStall { fetch_clock: fetch });
                    }
                    fetch += rng.gen_range(3);
                }
                fetch += rng.gen_range(5);
                let exec = 1 + rng.gen_range(20);
                retire = retire.max(fetch) + exec;
                records.push(DetailedRecord::Retired(RetiredInfo {
                    func: FuncRecord {
                        pc: 0x400000 + i as u64 * 4,
                        opcode: Opcode::Add,
                        reg_bitmap: 0,
                        mem_addr: 0,
                        mem_bytes: 0,
                        taken: false,
                    },
                    fetch_clock: fetch,
                    retire_clock: fetch + exec,
                    branch_mispred: false,
                    access_level: AccessLevel::None,
                    icache_miss: false,
                    tlb_miss: false,
                }));
            }
            let last_retire = records
                .iter()
                .filter_map(|r| r.retired())
                .last()
                .unwrap()
                .retire_clock;
            let det = DetailedTrace {
                name: "prop".into(),
                uarch: "x".into(),
                records,
                total_cycles: last_retire,
            };
            let adj = adjust(&det);
            assert_eq!(adj.samples.len(), det.retired_count());
            assert_eq!(adj.reconstructed_cycles(), det.total_cycles);
        }
    }
}
