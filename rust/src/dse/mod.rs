//! Design-space exploration support — Table 3 space, performance-vector
//! characterization and the §4.3 training-microarchitecture selection
//! (Mahalanobis vs Euclidean vs random, Figures 8 & 14).

pub mod space;

pub use space::DesignSpace;

use crate::util::Rng;

/// The four performance metrics §4.3 uses to characterize a design:
/// "CPI, L1 cache miss, L2 cache miss, and branch misprediction rate …
/// they capture the processor, cache, memory, and branch behaviors".
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PerfVector {
    /// Cycles per instruction.
    pub cpi: f64,
    /// L1D miss rate (misses / memory accesses).
    pub l1_miss_rate: f64,
    /// L2 miss rate on the data side.
    pub l2_miss_rate: f64,
    /// Conditional branch misprediction rate.
    pub mispredict_rate: f64,
}

impl PerfVector {
    /// As a fixed array for linear algebra.
    pub fn as_array(&self) -> [f64; 4] {
        [
            self.cpi,
            self.l1_miss_rate,
            self.l2_miss_rate,
            self.mispredict_rate,
        ]
    }
}

/// Selection strategies compared in Figure 14.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionStrategy {
    /// Pick the pair with maximum Mahalanobis distance (the paper's
    /// method).
    Mahalanobis,
    /// Pick the pair with maximum Euclidean distance.
    Euclidean,
    /// Pick a uniformly random pair.
    Random,
}

/// Mean of each metric column.
fn column_means(vs: &[PerfVector]) -> [f64; 4] {
    let mut m = [0.0; 4];
    for v in vs {
        let a = v.as_array();
        for i in 0..4 {
            m[i] += a[i];
        }
    }
    for x in m.iter_mut() {
        *x /= vs.len() as f64;
    }
    m
}

/// Sample covariance matrix of the performance metrics across designs
/// (the `S` in the Mahalanobis definition).
pub fn covariance(vs: &[PerfVector]) -> [[f64; 4]; 4] {
    let n = vs.len();
    assert!(n >= 2, "covariance needs at least 2 designs");
    let means = column_means(vs);
    let mut cov = [[0.0; 4]; 4];
    for v in vs {
        let a = v.as_array();
        for i in 0..4 {
            for j in 0..4 {
                cov[i][j] += (a[i] - means[i]) * (a[j] - means[j]);
            }
        }
    }
    for row in cov.iter_mut() {
        for x in row.iter_mut() {
            *x /= (n - 1) as f64;
        }
    }
    cov
}

/// Invert a 4×4 matrix by Gauss-Jordan with partial pivoting. Adds a tiny
/// ridge on singular input (possible when metrics are perfectly
/// correlated across the sampled designs).
pub fn invert4(m: &[[f64; 4]; 4]) -> [[f64; 4]; 4] {
    let mut a = *m;
    // Ridge to guarantee invertibility on degenerate samples.
    let trace: f64 = (0..4).map(|i| a[i][i]).sum();
    let ridge = (trace / 4.0).abs().max(1e-12) * 1e-9;
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += ridge;
    }
    let mut inv = [[0.0; 4]; 4];
    for (i, row) in inv.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for col in 0..4 {
        // Pivot.
        let pivot = (col..4)
            .max_by(|&r1, &r2| a[r1][col].abs().partial_cmp(&a[r2][col].abs()).unwrap())
            .unwrap();
        a.swap(col, pivot);
        inv.swap(col, pivot);
        let p = a[col][col];
        assert!(p.abs() > 0.0, "singular matrix even after ridge");
        for j in 0..4 {
            a[col][j] /= p;
            inv[col][j] /= p;
        }
        for row in 0..4 {
            if row != col {
                let f = a[row][col];
                for j in 0..4 {
                    a[row][j] -= f * a[col][j];
                    inv[row][j] -= f * inv[col][j];
                }
            }
        }
    }
    inv
}

/// Mahalanobis distance `sqrt((x−y)ᵀ S⁻¹ (x−y))`.
pub fn mahalanobis(x: &PerfVector, y: &PerfVector, inv_cov: &[[f64; 4]; 4]) -> f64 {
    let xa = x.as_array();
    let ya = y.as_array();
    let d: Vec<f64> = (0..4).map(|i| xa[i] - ya[i]).collect();
    let mut acc = 0.0;
    for i in 0..4 {
        for j in 0..4 {
            acc += d[i] * inv_cov[i][j] * d[j];
        }
    }
    acc.max(0.0).sqrt()
}

/// Euclidean distance between performance vectors.
pub fn euclidean(x: &PerfVector, y: &PerfVector) -> f64 {
    let xa = x.as_array();
    let ya = y.as_array();
    (0..4).map(|i| (xa[i] - ya[i]).powi(2)).sum::<f64>().sqrt()
}

/// Select the two training microarchitectures from characterized designs
/// (the Figure 8 workflow). Returns indices into `designs`.
pub fn select_pair(
    designs: &[PerfVector],
    strategy: SelectionStrategy,
    rng: &mut Rng,
) -> (usize, usize) {
    assert!(designs.len() >= 2, "need at least two designs");
    match strategy {
        SelectionStrategy::Random => {
            let idx = rng.sample_indices(designs.len(), 2);
            (idx[0], idx[1])
        }
        SelectionStrategy::Euclidean => argmax_pair(designs, |x, y| euclidean(x, y)),
        SelectionStrategy::Mahalanobis => {
            let inv = invert4(&covariance(designs));
            argmax_pair(designs, |x, y| mahalanobis(x, y, &inv))
        }
    }
}

fn argmax_pair(vs: &[PerfVector], d: impl Fn(&PerfVector, &PerfVector) -> f64) -> (usize, usize) {
    let mut best = (0, 1);
    let mut best_d = f64::MIN;
    for i in 0..vs.len() {
        for j in i + 1..vs.len() {
            let dist = d(&vs[i], &vs[j]);
            if dist > best_d {
                best_d = dist;
                best = (i, j);
            }
        }
    }
    best
}

/// Full pairwise distance matrix (for the Figure 8 report output).
pub fn distance_matrix(designs: &[PerfVector], strategy: SelectionStrategy) -> Vec<Vec<f64>> {
    let inv = if strategy == SelectionStrategy::Mahalanobis {
        Some(invert4(&covariance(designs)))
    } else {
        None
    };
    designs
        .iter()
        .map(|x| {
            designs
                .iter()
                .map(|y| match strategy {
                    SelectionStrategy::Mahalanobis => mahalanobis(x, y, inv.as_ref().unwrap()),
                    _ => euclidean(x, y),
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_designs() -> Vec<PerfVector> {
        vec![
            PerfVector { cpi: 1.23, l1_miss_rate: 0.34, l2_miss_rate: 0.21, mispredict_rate: 0.14 },
            PerfVector { cpi: 1.15, l1_miss_rate: 0.25, l2_miss_rate: 0.14, mispredict_rate: 0.12 },
            PerfVector { cpi: 1.11, l1_miss_rate: 0.23, l2_miss_rate: 0.12, mispredict_rate: 0.21 },
            PerfVector { cpi: 2.05, l1_miss_rate: 0.41, l2_miss_rate: 0.33, mispredict_rate: 0.05 },
            PerfVector { cpi: 0.78, l1_miss_rate: 0.05, l2_miss_rate: 0.02, mispredict_rate: 0.02 },
        ]
    }

    #[test]
    fn covariance_is_symmetric_psd_diagonal() {
        let cov = covariance(&sample_designs());
        for i in 0..4 {
            assert!(cov[i][i] >= 0.0);
            for j in 0..4 {
                assert!((cov[i][j] - cov[j][i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn invert4_identity() {
        let mut id = [[0.0; 4]; 4];
        for (i, row) in id.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        let inv = invert4(&id);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((inv[i][j] - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn invert4_times_original_is_identity() {
        let cov = covariance(&sample_designs());
        let inv = invert4(&cov);
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = 0.0;
                for k in 0..4 {
                    acc += cov[i][k] * inv[k][j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((acc - expect).abs() < 1e-4, "({i},{j}) = {acc}");
            }
        }
    }

    #[test]
    fn mahalanobis_properties() {
        let ds = sample_designs();
        let inv = invert4(&covariance(&ds));
        // Identity of indiscernibles + symmetry.
        assert!(mahalanobis(&ds[0], &ds[0], &inv) < 1e-9);
        let d01 = mahalanobis(&ds[0], &ds[1], &inv);
        let d10 = mahalanobis(&ds[1], &ds[0], &inv);
        assert!((d01 - d10).abs() < 1e-12);
        assert!(d01 > 0.0);
    }

    #[test]
    fn mahalanobis_downweights_correlated_large_scale_metric() {
        // Two designs differing only along a high-variance direction are
        // *closer* in Mahalanobis terms than an equal Euclidean step along
        // a low-variance direction — the property the paper cites for
        // preferring it.
        let mut rng = Rng::new(1);
        let mut ds = Vec::new();
        for _ in 0..40 {
            // cpi highly variable, mispredict_rate tight.
            ds.push(PerfVector {
                cpi: 1.0 + rng.gen_normal() * 1.0,
                l1_miss_rate: 0.2 + rng.gen_normal() * 0.02,
                l2_miss_rate: 0.1 + rng.gen_normal() * 0.02,
                mispredict_rate: 0.1 + rng.gen_normal() * 0.005,
            });
        }
        let inv = invert4(&covariance(&ds));
        let base = PerfVector {
            cpi: 1.0,
            l1_miss_rate: 0.2,
            l2_miss_rate: 0.1,
            mispredict_rate: 0.1,
        };
        let step_cpi = PerfVector { cpi: 1.5, ..base };
        let step_bp = PerfVector { mispredict_rate: 0.6, ..base };
        let d_cpi = mahalanobis(&base, &step_cpi, &inv);
        let d_bp = mahalanobis(&base, &step_bp, &inv);
        // Euclidean sees both steps as equal (0.5); Mahalanobis must see
        // the branch step as far larger.
        assert!((euclidean(&base, &step_cpi) - euclidean(&base, &step_bp)).abs() < 1e-9);
        assert!(d_bp > 5.0 * d_cpi, "d_bp={d_bp} d_cpi={d_cpi}");
    }

    #[test]
    fn select_pair_strategies() {
        let ds = sample_designs();
        let mut rng = Rng::new(3);
        let (i, j) = select_pair(&ds, SelectionStrategy::Euclidean, &mut rng);
        // Euclidean is dominated by CPI spread: designs 3 (2.05) and 4 (0.78).
        assert_eq!((i, j), (3, 4));
        let (i, j) = select_pair(&ds, SelectionStrategy::Mahalanobis, &mut rng);
        assert_ne!(i, j);
        let (i, j) = select_pair(&ds, SelectionStrategy::Random, &mut rng);
        assert_ne!(i, j);
    }

    #[test]
    fn distance_matrix_shape_and_diag() {
        let ds = sample_designs();
        for strat in [SelectionStrategy::Mahalanobis, SelectionStrategy::Euclidean] {
            let m = distance_matrix(&ds, strat);
            assert_eq!(m.len(), ds.len());
            for (i, row) in m.iter().enumerate() {
                assert_eq!(row.len(), ds.len());
                assert!(row[i].abs() < 1e-9);
            }
        }
    }
}
