//! The Table 3 design space: enumeration, indexing and sampling.

use crate::uarch::{CacheGeometry, PredictorKind, Timing, UarchConfig};
use crate::util::Rng;

/// Parameter ranges of Table 3.
pub struct DesignSpace {
    fetch_widths: Vec<u32>,
    rob_sizes: Vec<u32>,
    predictors: Vec<PredictorKind>,
    l1d_assoc: Vec<u32>,
    l1d_sizes: Vec<u64>,
    l1i_assoc: Vec<u32>,
    l1i_sizes: Vec<u64>,
    l2_assoc: Vec<u32>,
    l2_sizes: Vec<u64>,
}

impl Default for DesignSpace {
    fn default() -> Self {
        Self::table3()
    }
}

impl DesignSpace {
    /// Exactly the ranges of the paper's Table 3.
    pub fn table3() -> DesignSpace {
        DesignSpace {
            fetch_widths: vec![2, 3, 4],
            rob_sizes: vec![32, 64, 96, 128],
            predictors: PredictorKind::ALL.to_vec(),
            l1d_assoc: vec![2, 4, 6, 8],
            l1d_sizes: vec![16 << 10, 32 << 10, 64 << 10, 128 << 10],
            l1i_assoc: vec![2, 4, 6, 8],
            l1i_sizes: vec![8 << 10, 16 << 10, 32 << 10],
            l2_assoc: vec![2, 4, 6, 8],
            l2_sizes: vec![256 << 10, 512 << 10, 1 << 20, 2 << 20, 4 << 20],
        }
    }

    /// Number of design points (the paper quotes 184,320).
    pub fn count(&self) -> u64 {
        (self.fetch_widths.len()
            * self.rob_sizes.len()
            * self.predictors.len()
            * self.l1d_assoc.len()
            * self.l1d_sizes.len()
            * self.l1i_assoc.len()
            * self.l1i_sizes.len()
            * self.l2_assoc.len()
            * self.l2_sizes.len()) as u64
    }

    /// Decode design `index` (mixed-radix) into a configuration.
    pub fn design(&self, index: u64) -> UarchConfig {
        assert!(index < self.count(), "design index out of range");
        let mut i = index;
        let mut take = |n: usize| -> usize {
            let d = (i % n as u64) as usize;
            i /= n as u64;
            d
        };
        let fw = self.fetch_widths[take(self.fetch_widths.len())];
        let rob = self.rob_sizes[take(self.rob_sizes.len())];
        let bp = self.predictors[take(self.predictors.len())];
        let l1d_a = self.l1d_assoc[take(self.l1d_assoc.len())];
        let l1d_s = self.l1d_sizes[take(self.l1d_sizes.len())];
        let l1i_a = self.l1i_assoc[take(self.l1i_assoc.len())];
        let l1i_s = self.l1i_sizes[take(self.l1i_sizes.len())];
        let l2_a = self.l2_assoc[take(self.l2_assoc.len())];
        let l2_s = self.l2_sizes[take(self.l2_sizes.len())];
        UarchConfig {
            name: format!("design_{index}"),
            fetch_width: fw,
            rob_size: rob,
            predictor: bp,
            l1d: CacheGeometry { size_bytes: l1d_s, assoc: l1d_a },
            l1i: CacheGeometry { size_bytes: l1i_s, assoc: l1i_a },
            l2: CacheGeometry { size_bytes: l2_s, assoc: l2_a },
            timing: Timing::default(),
        }
    }

    /// Sample `n` distinct designs uniformly.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<UarchConfig> {
        assert!((n as u64) <= self.count());
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let idx = rng.gen_range(self.count());
            if seen.insert(idx) {
                out.push(self.design(idx));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_matches_paper() {
        // 3 × 4 × 4 × 4 × 4 × 4 × 3 × 4 × 5 = 184,320 (paper §5.5).
        assert_eq!(DesignSpace::table3().count(), 184_320);
    }

    #[test]
    fn design_decode_covers_extremes() {
        let s = DesignSpace::table3();
        let first = s.design(0);
        assert_eq!(first.fetch_width, 2);
        assert_eq!(first.rob_size, 32);
        let last = s.design(s.count() - 1);
        assert_eq!(last.fetch_width, 4);
        assert_eq!(last.l2.size_bytes, 4 << 20);
    }

    #[test]
    fn design_indices_are_unique() {
        let s = DesignSpace::table3();
        let a = s.design(12345);
        let b = s.design(12346);
        let a_body = a.summary().replace("design_12345", "");
        let b_body = b.summary().replace("design_12346", "");
        assert_ne!(a_body, b_body);
    }

    #[test]
    fn all_designs_have_power_of_two_sets() {
        // Spot-check a stride of designs: cache geometry must be valid
        // (power-of-two sets) for every point so the detailed simulator
        // can run any sampled design. Assoc 6 gives non-power-of-two sets,
        // which Cache::new pads — verify construction doesn't panic.
        let s = DesignSpace::table3();
        let mut rng = Rng::new(9);
        for cfg in s.sample(32, &mut rng) {
            // Constructing the simulator exercises Cache::new asserts.
            let p = crate::workloads::by_name("nab").unwrap().build(1);
            let (_, stats) = crate::detailed::DetailedSim::new(&p, &cfg)
                .stats_only()
                .run(200);
            assert!(stats.instructions > 0, "{}", cfg.summary());
        }
    }

    #[test]
    fn sample_returns_distinct_designs() {
        let s = DesignSpace::table3();
        let mut rng = Rng::new(4);
        let ds = s.sample(16, &mut rng);
        assert_eq!(ds.len(), 16);
        let names: std::collections::HashSet<&str> =
            ds.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names.len(), 16);
    }
}
