//! `tao simulate` — run the DL-based simulation end-to-end.
//!
//! Generates (or loads) a functional trace, streams it through the AOT
//! model via the engine, and reports predicted CPI/MPKIs, throughput in
//! MIPS, and — with `--truth` — the detailed-simulator ground truth and
//! the paper's simulation-error percentages. `--trace PATH` replays an
//! on-disk trace of either format (`tao trace` writes them) instead of
//! generating one; `--sample` adds phase-sampled replay, simulating only
//! the plan's representative slices and reconstructing whole-trace
//! metrics by weighted merge (see `docs/SAMPLING.md`).

use super::engine::{self, ParallelOptions};
use crate::cli::args::Args;
use crate::detailed::DetailedSim;
use crate::functional::FunctionalSim;
use crate::sampling::SamplingPlan;
use crate::stats::simulation_error_percent;
use crate::telemetry::{self, registry, Profile};
use crate::trace::{open_trace_source, TraceSource};
use crate::uarch::UarchConfig;
use crate::workloads;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Run the DL-based simulation from the command line.
pub fn cmd_simulate(mut args: Args) -> Result<()> {
    let model: PathBuf = args
        .opt_value("--model")?
        .context("--model artifacts/tao_<uarch>.hlo.txt required")?
        .into();
    let trace_path: Option<PathBuf> = args.opt_value("--trace")?.map(Into::into);
    let bench_flag = args.opt_value("--bench")?;
    let insts_flag: Option<u64> = args.opt_parse("--insts")?;
    let bench_name = bench_flag.clone().unwrap_or_else(|| "mcf".into());
    let insts: u64 = insts_flag.unwrap_or(100_000);
    let workers: usize = args.opt_parse("--workers")?.unwrap_or(1);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let defaults = ParallelOptions::default();
    let mut opts = ParallelOptions {
        chunk: args.opt_parse("--chunk")?.unwrap_or(defaults.chunk),
        warmup: args.opt_parse("--warmup")?.unwrap_or(defaults.warmup),
        // Double-buffered stage/execute workers; --no-pipeline runs the
        // single-threaded oracle staging for A/B timing and debugging.
        pipeline: !args.opt_flag("--no-pipeline"),
    };
    let truth_uarch = args.opt_value("--truth")?;
    let stream = args.opt_flag("--stream");
    let max_resident: usize = args.opt_parse("--max-resident")?.unwrap_or(1 << 20);
    let sample = args.opt_flag("--sample");
    let plan_path: Option<PathBuf> = args.opt_value("--plan")?.map(Into::into);
    let sample_slice_rows: Option<u64> = args.opt_parse("--slice-rows")?;
    let sample_max_phases: Option<usize> = args.opt_parse("--max-phases")?;
    let profile_flag = args.opt_flag("--profile");
    let profile_out: Option<PathBuf> = args.opt_value("--profile-out")?.map(Into::into);
    args.finish()?;
    anyhow::ensure!(
        profile_flag || profile_out.is_none(),
        "--profile-out names the --profile report; pass --profile"
    );
    // `--profile` arms the registry for this one-shot run (a fresh
    // slate, so stage attribution reflects exactly this invocation)
    // and times the sequential top-level phases; they tile the wall
    // clock by construction.
    let mut prof = if profile_flag {
        registry().reset();
        telemetry::arm();
        Some(Profile::start())
    } else {
        None
    };
    anyhow::ensure!(max_resident >= 1, "--max-resident must be positive");
    anyhow::ensure!(
        sample || (plan_path.is_none() && sample_slice_rows.is_none() && sample_max_phases.is_none()),
        "--plan/--slice-rows/--max-phases configure sampled replay; pass --sample"
    );

    if sample {
        // Phase-sampled replay: simulate only the plan's representative
        // slices (warmed by the preceding rows), then reconstruct
        // whole-trace metrics by weighted accumulator merge.
        let trace = trace_path.context(
            "--sample replays representative slices of a recorded trace; it requires --trace \
             (write one with `tao trace write`)",
        )?;
        anyhow::ensure!(
            !stream && bench_flag.is_none() && insts_flag.is_none() && truth_uarch.is_none(),
            "--sample cannot be combined with --stream, --bench, --insts, or --truth"
        );
        let plan = match &plan_path {
            Some(p) => {
                anyhow::ensure!(
                    sample_slice_rows.is_none() && sample_max_phases.is_none(),
                    "--plan loads a precomputed plan; --slice-rows/--max-phases only apply \
                     when the plan is computed here"
                );
                SamplingPlan::load(p)?
            }
            None => {
                let defaults = crate::sampling::SamplingOptions::default();
                let sopts = crate::sampling::SamplingOptions {
                    slice_rows: sample_slice_rows.unwrap_or(defaults.slice_rows),
                    max_phases: sample_max_phases.unwrap_or(defaults.max_phases),
                    seed,
                };
                anyhow::ensure!(sopts.slice_rows >= 1, "--slice-rows must be positive");
                anyhow::ensure!(sopts.max_phases >= 1, "--max-phases must be positive");
                eprintln!(
                    "simulate: computing sampling plan (slice-rows={}, max-phases={})...",
                    sopts.slice_rows, sopts.max_phases
                );
                crate::sampling::plan_trace(&trace, &sopts)?
            }
        };
        eprintln!(
            "simulate: sampled replay of {trace:?} — {} phases, {} of {} rows \
             ({:.1}% coverage), workers={workers}, chunk={}, warmup={}...",
            plan.phases.len(),
            plan.simulated_rows(),
            plan.total_rows,
            plan.coverage() * 100.0,
            opts.chunk,
            opts.warmup
        );
        let out = timed(&mut prof, "sampled_replay", || {
            engine::simulate_sampled(&model, &trace, &plan, workers, opts)
        })?;
        print_prediction(&plan.name, &out.result);
        println!("sampled rows       : {} (+{} warm-up)", out.simulated_rows, out.warmup_rows);
        println!(
            "sampled fraction   : {:.1}%",
            out.simulated_rows as f64 / out.total_rows.max(1) as f64 * 100.0
        );
        return finish_profile(prof, profile_out);
    }

    if let Some(trace) = trace_path {
        // Replay a recorded trace: format negotiated by magic sniffing,
        // decode riding the engine's prefetch/dispatch threads.
        anyhow::ensure!(
            !stream && bench_flag.is_none() && insts_flag.is_none() && truth_uarch.is_none(),
            "--trace replays a recorded trace; it cannot be combined with \
             --stream, --bench, --insts, or --truth (ground truth must \
             re-execute the program, which a trace does not carry)"
        );
        let mut source = open_trace_source(&trace)?;
        let bench = source.name().to_string();
        eprintln!(
            "simulate: replaying {trace:?} ({} trace of {bench}) with workers={workers}, \
             chunk={}, warmup={}...",
            source.format(),
            opts.chunk,
            opts.warmup
        );
        let result = timed(&mut prof, "trace_replay", || {
            engine::simulate_parallel_chunked(&model, &mut *source, workers, opts)
        })?;
        print_prediction(&bench, &result);
        return finish_profile(prof, profile_out);
    }

    let workload =
        workloads::by_name(&bench_name).with_context(|| format!("unknown benchmark {bench_name}"))?;
    let program = workload.build(seed);

    let result = if stream {
        // Pull-based pipeline: the functional simulator generates
        // records only as inference workers pull chunks, so the trace is
        // never resident. Peak buffering: each worker holds one
        // (chunk + warmup)-row item, the dispatch thread's bounded
        // prefetch channel holds up to `workers` more, plus one item in
        // dispatch limbo — (2·workers + 1) items total. Clamp the pull
        // grain so that whole budget honors --max-resident, and refuse
        // outright when the warm-up alone overflows it (a silent clamp
        // would both break the bound and burn a full warm-up re-run per
        // tiny chunk).
        let slots = 2 * workers.max(1) + 1;
        let per_item = max_resident / slots;
        anyhow::ensure!(
            per_item > opts.warmup,
            "--max-resident {max_resident} cannot hold {slots} prefetched/in-flight items \
             x (chunk + {} warmup) records; raise --max-resident or lower --warmup",
            opts.warmup
        );
        opts.chunk = opts.chunk.min(per_item - opts.warmup);
        eprintln!(
            "simulate: streaming {insts} insts of {bench_name} from the generator \
             (workers={workers}, chunk={}, warmup={}, max-resident={max_resident})...",
            opts.chunk, opts.warmup
        );
        let mut source = FunctionalSim::new(&program).into_chunks(insts);
        timed(&mut prof, "stream_inference", || {
            engine::simulate_parallel_chunked(&model, &mut source, workers, opts)
        })?
    } else {
        eprintln!("simulate: generating functional trace ({insts} insts of {bench_name})...");
        let cols = timed(&mut prof, "trace_gen", || {
            FunctionalSim::new(&program).run(insts).to_columns()
        });
        eprintln!(
            "simulate: loading {model:?} and running inference (workers={workers}, chunk={}, warmup={})...",
            opts.chunk, opts.warmup
        );
        timed(&mut prof, "inference", || {
            engine::simulate_parallel_opts(&model, &cols, workers, None, opts)
        })?
    };
    print_prediction(&bench_name, &result);

    if let Some(uarch_name) = truth_uarch {
        let cfg = UarchConfig::preset(&uarch_name)
            .with_context(|| format!("unknown uarch {uarch_name}"))?;
        eprintln!("simulate: running detailed ground truth on {}...", cfg.name);
        let (_, stats) = timed(&mut prof, "detailed_truth", || {
            DetailedSim::new(&program, &cfg).stats_only().run(insts)
        });
        println!("--- ground truth ({}) ---", cfg.name);
        println!("CPI truth          : {:.4}", stats.cpi());
        println!(
            "CPI error          : {:.2}%",
            simulation_error_percent(result.metrics.cpi(), stats.cpi())
        );
        println!("bMPKI truth        : {:.2}", stats.branch_mpki());
        println!("L1D MPKI truth     : {:.2}", stats.l1d_mpki());
    }
    finish_profile(prof, profile_out)
}

/// Run `f` under a named profile phase when profiling, plainly
/// otherwise.
fn timed<T>(prof: &mut Option<Profile>, name: &str, f: impl FnOnce() -> T) -> T {
    match prof.as_mut() {
        Some(p) => p.phase(name, f),
        None => f(),
    }
}

/// Print the `--profile` per-stage breakdown and write the JSON report
/// (`--profile-out`, default `profile.json`).
pub(crate) fn finish_profile(prof: Option<Profile>, out: Option<PathBuf>) -> Result<()> {
    let Some(prof) = prof else { return Ok(()) };
    eprint!("{}", prof.render_table());
    let path = out.unwrap_or_else(|| "profile.json".into());
    std::fs::write(&path, prof.to_json().render())
        .with_context(|| format!("write {path:?}"))?;
    eprintln!("profile: wrote {}", path.display());
    telemetry::disarm();
    Ok(())
}

/// Print the predicted-metrics block shared by every simulate path.
fn print_prediction(bench: &str, result: &engine::SimResult) {
    let m = &result.metrics;
    println!("benchmark          : {bench}");
    println!("instructions       : {}", m.instructions);
    println!("predicted CPI      : {:.4}", m.cpi());
    println!("predicted bMPKI    : {:.2}", m.branch_mpki());
    println!("predicted L1D MPKI : {:.2}", m.l1d_mpki());
    println!("predicted L1I MPKI : {:.2}", m.l1i_mpki());
    println!("predicted TLB MPKI : {:.2}", m.tlb_mpki());
    println!("batches            : {}", result.batches);
    println!("inference time     : {:.2}s", result.elapsed.as_secs_f64());
    println!("throughput         : {:.3} MIPS", result.mips());
}
