//! Reusable double-buffered stage/execute pipeline.
//!
//! PR 4 proved the shape on the serving path: a staging thread fills
//! one buffer set while a dedicated executor thread runs the model
//! from the other, the two sets rotating through a `sync_channel(1)`
//! and `Session::run_on` executing straight from caller buffers — no
//! hand-off copy. That machinery lived privately inside
//! `serve::scheduler`; this module is its engine-level extraction, so
//! the offline `simulate_parallel*` workers, the sequential chunked
//! path and the serving lanes all share one implementation (and the
//! datagen shard writer reuses the generic core for
//! featurize-while-write).
//!
//! Two layers:
//!
//! * [`StagePipeline`] — the generic core: N rotating buffer sets, a
//!   caller-side free list, a `sync_channel(1)` to a worker thread
//!   whose state is built *on* the thread (PJRT clients are not shared
//!   across threads), FIFO completion so the stager absorbs results in
//!   submission order, and occupancy counters (executor busy/idle,
//!   stager stall) for the bench reports.
//! * [`ExecPipeline`] — the model-execution specialization
//!   ([`ExecBuffers`] staging sets, `Session::run_on` as the step),
//!   generic over a per-batch routing payload: the serving lane tags
//!   batches with per-row job routes, the offline workers with the
//!   warm-up skip count.
//!
//! Ordering contract: the worker processes submissions FIFO and the
//! completion channel preserves that order, so a stager that absorbs
//! results as it receives them folds outputs in exactly the order a
//! single-threaded stage→execute loop would have — the bit-identity
//! the offline oracle tests assert.
//!
//! Panic isolation: the worker catches unwinds from both hooks. An
//! `init` panic surfaces as [`PipeMsg::InitFailed`]; a step panic
//! comes back as that batch's error value and the worker keeps
//! serving, so a poisoned batch can never wedge a scope join or take
//! down a serving lane's executor silently.

use crate::runtime::{ModelKind, ModelOutputs, Session};
use crate::telemetry::{registry, Counter};
use crate::util::fault::panic_message;
use anyhow::{bail, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------
// Occupancy counters
// ---------------------------------------------------------------------

#[derive(Debug)]
struct PipeCounters {
    batches: AtomicU64,
    stage_stall_ns: AtomicU64,
    exec_busy_ns: AtomicU64,
    exec_idle_ns: AtomicU64,
    // Process-wide telemetry mirrors (summed across every pipeline in
    // the process): inert single relaxed loads while telemetry is
    // disarmed, so offline runs pay nothing.
    tele_batches: Counter,
    tele_stage_stall_ns: Counter,
    tele_exec_busy_ns: Counter,
    tele_exec_idle_ns: Counter,
}

impl PipeCounters {
    fn new() -> PipeCounters {
        let reg = registry();
        PipeCounters {
            batches: AtomicU64::new(0),
            stage_stall_ns: AtomicU64::new(0),
            exec_busy_ns: AtomicU64::new(0),
            exec_idle_ns: AtomicU64::new(0),
            tele_batches: reg.counter(
                "tao_pipeline_batches_total",
                "Batches executed through stage/execute pipelines.",
                &[],
            ),
            tele_stage_stall_ns: reg.counter(
                "tao_pipeline_stage_stall_ns_total",
                "Nanoseconds the staging side blocked waiting for a free buffer set.",
                &[],
            ),
            tele_exec_busy_ns: reg.counter(
                "tao_pipeline_exec_busy_ns_total",
                "Nanoseconds pipeline executor threads spent running the step.",
                &[],
            ),
            tele_exec_idle_ns: reg.counter(
                "tao_pipeline_exec_idle_ns_total",
                "Nanoseconds pipeline executor threads spent waiting for a staged batch.",
                &[],
            ),
        }
    }
}

/// Snapshot of a pipeline's occupancy counters (exported into
/// `BENCH_coordinator.json` by the engine benches).
///
/// Reading the overlap: `exec_busy_fraction` near 1 means the pipeline
/// is **execute-bound** — the executor never waits, staging hides
/// entirely behind model time. High `stage_stall_ns` relative to wall
/// time means the stager kept waiting for a free buffer set (also
/// execute-bound); high `exec_idle_ns` means **stage-bound** — the
/// model finishes before the next batch is staged.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PipelineStats {
    /// Batches executed through the pipeline.
    pub batches: u64,
    /// Time the staging side spent blocked waiting on a completion
    /// (no free buffer set), nanoseconds.
    pub stage_stall_ns: u64,
    /// Time the executor thread spent running the step, nanoseconds.
    pub exec_busy_ns: u64,
    /// Time the executor thread spent waiting for a staged batch,
    /// nanoseconds.
    pub exec_idle_ns: u64,
}

impl PipelineStats {
    /// Fold another pipeline's counters in (cross-worker aggregation).
    pub fn absorb(&mut self, other: &PipelineStats) {
        self.batches += other.batches;
        self.stage_stall_ns += other.stage_stall_ns;
        self.exec_busy_ns += other.exec_busy_ns;
        self.exec_idle_ns += other.exec_idle_ns;
    }

    /// Fraction of executor wall time spent executing (vs waiting for
    /// the stager): ~1.0 = execute-bound, low = stage-bound.
    pub fn exec_busy_fraction(&self) -> f64 {
        let total = self.exec_busy_ns + self.exec_idle_ns;
        if total == 0 {
            0.0
        } else {
            self.exec_busy_ns as f64 / total as f64
        }
    }
}

// ---------------------------------------------------------------------
// Generic stage/execute pipeline
// ---------------------------------------------------------------------

/// A staged buffer on its way to the worker thread.
struct Staged<B, P> {
    buf: B,
    payload: P,
}

/// What comes back from the worker thread, in submission order.
pub enum PipeMsg<B, P, R> {
    /// One submission processed: the buffer (for reuse), its payload
    /// and the step's result. A step error is scoped to this payload —
    /// the worker keeps running.
    Done {
        /// The rotating buffer set, ready for reuse.
        buf: B,
        /// The payload submitted with the buffer.
        payload: P,
        /// The step's output, or its error message.
        result: Result<R, String>,
    },
    /// The worker's init hook failed; no submissions were processed
    /// and none ever will be.
    InitFailed {
        /// The init error.
        msg: String,
    },
}

/// Double-buffered stage/execute core: the caller stages into buffer
/// sets from the free list and [`StagePipeline::submit`]s them; a
/// dedicated worker thread (state built on-thread by the `init` hook)
/// runs the step over each and sends the result back FIFO.
pub struct StagePipeline<B, P, R> {
    to_exec: Option<SyncSender<Staged<B, P>>>,
    from_exec: Receiver<PipeMsg<B, P, R>>,
    handle: Option<std::thread::JoinHandle<()>>,
    free: Vec<B>,
    in_flight: usize,
    counters: Arc<PipeCounters>,
}

impl<B, P, R> StagePipeline<B, P, R>
where
    B: Send + 'static,
    P: Send + 'static,
    R: Send + 'static,
{
    /// Spawn the worker thread. `bufs` are the rotating buffer sets
    /// (two for classic double buffering); `init` runs **on the worker
    /// thread** and builds the step closure (e.g. compiles a PJRT
    /// session — clients are not shared across threads).
    pub fn spawn<I, S>(bufs: Vec<B>, init: I) -> StagePipeline<B, P, R>
    where
        I: FnOnce() -> Result<S> + Send + 'static,
        S: FnMut(&B, &P) -> Result<R> + 'static,
    {
        assert!(!bufs.is_empty(), "pipeline needs at least one buffer set");
        // sync_channel(1): the stager may queue one staged batch while
        // the worker runs another — bounded by the rotating buffer
        // sets. The completion channel holds every possible in-flight
        // result (≤ bufs) plus slack, so the worker never blocks on
        // send and shutdown joins cleanly.
        let (to_exec, rx_staged) = sync_channel::<Staged<B, P>>(1);
        let (tx_done, from_exec) = sync_channel::<PipeMsg<B, P, R>>(bufs.len() + 2);
        let counters = Arc::new(PipeCounters::new());
        let exec_counters = counters.clone();
        let handle = std::thread::spawn(move || {
            let mut step = match catch_unwind(AssertUnwindSafe(init)) {
                Ok(Ok(s)) => s,
                Ok(Err(e)) => {
                    let _ = tx_done.send(PipeMsg::InitFailed { msg: format!("{e:#}") });
                    return;
                }
                Err(p) => {
                    let _ = tx_done.send(PipeMsg::InitFailed {
                        msg: format!("init panicked: {}", panic_message(p.as_ref())),
                    });
                    return;
                }
            };
            loop {
                let idle = Instant::now();
                let staged = match rx_staged.recv() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                let idle_ns = idle.elapsed().as_nanos() as u64;
                exec_counters.exec_idle_ns.fetch_add(idle_ns, Ordering::Relaxed);
                exec_counters.tele_exec_idle_ns.inc_by(idle_ns);
                let busy = Instant::now();
                // A step panic is a batch-scoped error like any other:
                // the staged buffers are only borrowed, so they return
                // to rotation and the worker keeps serving.
                let result = catch_unwind(AssertUnwindSafe(|| {
                    step(&staged.buf, &staged.payload).map_err(|e| format!("{e:#}"))
                }))
                .unwrap_or_else(|p| Err(format!("step panicked: {}", panic_message(p.as_ref()))));
                let busy_ns = busy.elapsed().as_nanos() as u64;
                exec_counters.exec_busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
                exec_counters.tele_exec_busy_ns.inc_by(busy_ns);
                exec_counters.batches.fetch_add(1, Ordering::Relaxed);
                exec_counters.tele_batches.inc();
                let msg = PipeMsg::Done {
                    buf: staged.buf,
                    payload: staged.payload,
                    result,
                };
                if tx_done.send(msg).is_err() {
                    return;
                }
            }
        });
        StagePipeline {
            to_exec: Some(to_exec),
            from_exec,
            handle: Some(handle),
            free: bufs,
            in_flight: 0,
            counters,
        }
    }
}

impl<B, P, R> StagePipeline<B, P, R> {
    /// Take a free buffer set to stage into, if one is available. When
    /// `None`, block on [`StagePipeline::recv`] to get one back.
    pub fn take_buf(&mut self) -> Option<B> {
        self.free.pop()
    }

    /// Return a buffer set to the free list.
    pub fn release(&mut self, buf: B) {
        self.free.push(buf);
    }

    /// Submit a staged buffer for execution.
    pub fn submit(&mut self, buf: B, payload: P) -> Result<()> {
        let Some(tx) = &self.to_exec else {
            bail!("pipeline already shut down");
        };
        if tx.send(Staged { buf, payload }).is_err() {
            // The worker exited early — an InitFailed explains why.
            match self.from_exec.try_recv() {
                Ok(PipeMsg::InitFailed { msg }) => bail!("pipeline worker failed to start: {msg}"),
                _ => bail!("pipeline worker thread exited"),
            }
        }
        self.in_flight += 1;
        Ok(())
    }

    /// Completions not yet received.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Non-blocking poll for the next completion (FIFO).
    pub fn try_recv(&mut self) -> Result<Option<PipeMsg<B, P, R>>> {
        match self.from_exec.try_recv() {
            Ok(msg) => {
                if matches!(msg, PipeMsg::Done { .. }) {
                    self.in_flight -= 1;
                }
                Ok(Some(msg))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("pipeline worker thread exited"),
        }
    }

    /// Block for the next completion (FIFO). Wait time is recorded as
    /// staging stall in the occupancy counters.
    pub fn recv(&mut self) -> Result<PipeMsg<B, P, R>> {
        let t0 = Instant::now();
        let msg = self
            .from_exec
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline worker thread exited"))?;
        let stall_ns = t0.elapsed().as_nanos() as u64;
        self.counters.stage_stall_ns.fetch_add(stall_ns, Ordering::Relaxed);
        self.counters.tele_stage_stall_ns.inc_by(stall_ns);
        if matches!(msg, PipeMsg::Done { .. }) {
            self.in_flight -= 1;
        }
        Ok(msg)
    }

    /// Occupancy counter snapshot.
    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            batches: self.counters.batches.load(Ordering::Relaxed),
            stage_stall_ns: self.counters.stage_stall_ns.load(Ordering::Relaxed),
            exec_busy_ns: self.counters.exec_busy_ns.load(Ordering::Relaxed),
            exec_idle_ns: self.counters.exec_idle_ns.load(Ordering::Relaxed),
        }
    }

    /// Close the submission side and join the worker thread. Also runs
    /// on drop; callers that want the join to happen at a defined point
    /// (before reading files the worker wrote, say) call it explicitly.
    pub fn shutdown(&mut self) {
        self.to_exec.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<B, P, R> Drop for StagePipeline<B, P, R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Model-execution specialization
// ---------------------------------------------------------------------

/// One rotating staging buffer set for model execution: the `[B,T]`
/// opcodes, `[B,T,F]` features and (SimNet) `[B,T,6]` context metrics
/// the batchers materialize into and `Session::run_on` executes from.
pub struct ExecBuffers {
    /// `[B*T]` opcode staging.
    pub ops: Vec<i32>,
    /// `[B*T*F]` feature staging.
    pub feats: Vec<f32>,
    /// `[B*T*6]` SimNet context staging (empty for Tao artifacts).
    pub ctx: Vec<f32>,
}

impl ExecBuffers {
    /// Buffers sized for an artifact shape.
    pub fn new(b: usize, t: usize, f: usize, kind: ModelKind) -> ExecBuffers {
        ExecBuffers {
            ops: vec![0; b * t],
            feats: vec![0.0; b * t * f],
            ctx: match kind {
                ModelKind::SimNet => vec![0.0; b * t * crate::trace::CTX_WIDTH],
                ModelKind::Tao => Vec::new(),
            },
        }
    }
}

/// Per-batch execution request: how many staged windows are valid plus
/// a caller-defined routing tag (job routes for the serving lane, the
/// warm-up skip count for the offline workers).
pub struct ExecBatch<P> {
    /// Valid windows staged in the buffers.
    pub valid: usize,
    /// Caller routing tag, returned with the outputs.
    pub tag: P,
}

/// The model-execution pipeline: [`ExecBuffers`] through
/// `Session::run_on` on a dedicated executor thread.
pub type ExecPipeline<P> = StagePipeline<ExecBuffers, ExecBatch<P>, ModelOutputs>;

/// Spawn an [`ExecPipeline`] with `sets` rotating buffer sets (two for
/// double buffering). `open` runs on the executor thread and compiles
/// the session there — PJRT clients are not shared across threads.
pub fn spawn_exec_pipeline<P, F>(
    open: F,
    kind: ModelKind,
    b: usize,
    t: usize,
    f: usize,
    sets: usize,
) -> ExecPipeline<P>
where
    P: Send + 'static,
    F: FnOnce() -> Result<Session> + Send + 'static,
{
    let bufs = (0..sets.max(1)).map(|_| ExecBuffers::new(b, t, f, kind)).collect();
    StagePipeline::spawn(bufs, move || {
        let session = open()?;
        Ok(move |bufs: &ExecBuffers, batch: &ExecBatch<P>| {
            let ctx = match kind {
                ModelKind::SimNet => Some(&bufs.ctx[..]),
                ModelKind::Tao => None,
            };
            session.run_on(&bufs.ops, &bufs.feats, ctx, batch.valid)
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Doubling pipeline: results come back FIFO, buffers rotate, and
    /// the stats count every batch.
    #[test]
    fn stage_pipeline_runs_fifo_and_recycles_buffers() {
        let mut pipe: StagePipeline<Vec<u64>, u64, u64> = StagePipeline::spawn(
            vec![Vec::new(), Vec::new()],
            || Ok(|buf: &Vec<u64>, mul: &u64| Ok(buf.iter().sum::<u64>() * mul)),
        );
        let mut got = Vec::new();
        for k in 0..10u64 {
            let mut buf = match pipe.take_buf() {
                Some(b) => b,
                None => match pipe.recv().unwrap() {
                    PipeMsg::Done { buf, result, .. } => {
                        got.push(result.unwrap());
                        buf
                    }
                    PipeMsg::InitFailed { msg } => panic!("init failed: {msg}"),
                },
            };
            buf.clear();
            buf.extend([k, k + 1]);
            pipe.submit(buf, 10).unwrap();
        }
        while pipe.in_flight() > 0 {
            match pipe.recv().unwrap() {
                PipeMsg::Done { buf, result, .. } => {
                    got.push(result.unwrap());
                    pipe.release(buf);
                }
                PipeMsg::InitFailed { msg } => panic!("init failed: {msg}"),
            }
        }
        // FIFO: (k + k+1) * 10 in submission order.
        let want: Vec<u64> = (0..10).map(|k| (2 * k + 1) * 10).collect();
        assert_eq!(got, want);
        assert_eq!(pipe.stats().batches, 10);
        pipe.shutdown();
    }

    #[test]
    fn step_errors_are_scoped_to_their_batch() {
        let mut pipe: StagePipeline<u64, (), u64> = StagePipeline::spawn(
            vec![0u64],
            || {
                Ok(|buf: &u64, _: &()| {
                    if *buf == 3 {
                        anyhow::bail!("unlucky batch");
                    }
                    Ok(*buf)
                })
            },
        );
        for v in [1u64, 3, 5] {
            let _ = pipe.take_buf();
            pipe.submit(v, ()).unwrap();
            match pipe.recv().unwrap() {
                PipeMsg::Done { buf, result, .. } => {
                    if v == 3 {
                        assert!(result.unwrap_err().contains("unlucky"));
                    } else {
                        assert_eq!(result.unwrap(), v);
                    }
                    pipe.release(buf);
                }
                PipeMsg::InitFailed { msg } => panic!("init failed: {msg}"),
            }
        }
        // The worker survived the failed batch.
        assert_eq!(pipe.stats().batches, 3);
    }

    #[test]
    fn step_panics_become_batch_scoped_errors() {
        let mut pipe: StagePipeline<u64, (), u64> = StagePipeline::spawn(vec![0u64], || {
            Ok(|buf: &u64, _: &()| {
                if *buf == 3 {
                    panic!("executor blew up on {buf}");
                }
                Ok(*buf)
            })
        });
        for v in [1u64, 3, 5] {
            let _ = pipe.take_buf();
            pipe.submit(v, ()).unwrap();
            match pipe.recv().unwrap() {
                PipeMsg::Done { buf, result, .. } => {
                    if v == 3 {
                        let msg = result.unwrap_err();
                        assert!(msg.contains("step panicked"), "got {msg}");
                        assert!(msg.contains("blew up"), "got {msg}");
                    } else {
                        assert_eq!(result.unwrap(), v);
                    }
                    pipe.release(buf);
                }
                PipeMsg::InitFailed { msg } => panic!("init failed: {msg}"),
            }
        }
        // The worker survived the panicked batch and kept serving.
        assert_eq!(pipe.stats().batches, 3);
    }

    #[test]
    fn init_panic_surfaces_as_init_failure() {
        let mut pipe: StagePipeline<u64, (), u64> =
            StagePipeline::spawn(vec![0u64], || -> Result<fn(&u64, &()) -> Result<u64>> {
                panic!("device exploded during open")
            });
        match pipe.recv().unwrap() {
            PipeMsg::InitFailed { msg } => {
                assert!(msg.contains("init panicked"), "got {msg}");
                assert!(msg.contains("device exploded"), "got {msg}");
            }
            PipeMsg::Done { .. } => panic!("expected init failure"),
        }
        // Submitting after the panic reports failure instead of hanging.
        let buf = pipe.take_buf().unwrap();
        assert!(pipe.submit(buf, ()).is_err());
    }

    #[test]
    fn init_failure_surfaces_once() {
        let mut pipe: StagePipeline<u64, (), u64> = StagePipeline::spawn(vec![0u64], || {
            let fail: Result<fn(&u64, &()) -> Result<u64>> = Err(anyhow::anyhow!("no device"));
            fail
        });
        match pipe.recv().unwrap() {
            PipeMsg::InitFailed { msg } => assert!(msg.contains("no device")),
            PipeMsg::Done { .. } => panic!("expected init failure"),
        }
        // Submitting after the failure reports it instead of hanging.
        let buf = pipe.take_buf().unwrap();
        assert!(pipe.submit(buf, ()).is_err());
    }

    #[test]
    fn exec_buffers_shape_by_kind() {
        let tao = ExecBuffers::new(4, 8, 3, ModelKind::Tao);
        assert_eq!(tao.ops.len(), 32);
        assert_eq!(tao.feats.len(), 96);
        assert!(tao.ctx.is_empty());
        let sn = ExecBuffers::new(4, 8, 3, ModelKind::SimNet);
        assert_eq!(sn.ctx.len(), 4 * 8 * crate::trace::CTX_WIDTH);
    }
}
