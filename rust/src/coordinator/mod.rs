//! Simulation coordinator — Layer 3's request path (DESIGN.md S10).
//!
//! `engine` holds the parallel sharded inference pipeline (feature
//! extraction → window batching → PJRT execution → metric aggregation);
//! `pipeline` is the double-buffered stage/execute core the engine
//! workers and the serving lanes share; `cli` exposes the engine as
//! `tao simulate`.

pub mod cli;
pub mod engine;
pub mod pipeline;
