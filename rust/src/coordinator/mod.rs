//! Simulation coordinator — Layer 3's request path (DESIGN.md S10).
//!
//! `engine` holds the parallel sharded inference pipeline (feature
//! extraction → window batching → PJRT execution → metric aggregation);
//! `cli` exposes it as `tao simulate`.

pub mod cli;
pub mod engine;
