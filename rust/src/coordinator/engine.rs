//! The DL-simulation engine — Layer 3's request path.
//!
//! Mirrors the parallel-simulation design of Pandey et al. [59] that both
//! SimNet and Tao use: the committed instruction stream is split into
//! **chunks**; workers pull chunks from a shared work queue, each owning
//! a feature extractor, a window batcher and its own compiled PJRT
//! executable, and stream their chunks through the model; the collector
//! folds per-chunk accumulators (in any order — the fold is
//! order-independent) into the run-level metrics.
//!
//! Hot-path design (see PERFORMANCE.md):
//!
//! * **Zero-copy row staging** — the feature extractor writes each
//!   instruction's row directly into the batcher's rolling buffer
//!   ([`WindowBatcher::begin_row`]); no per-instruction scratch row.
//! * **Overlap-aware batching** — consecutive windows share `T-1` rows,
//!   so the batcher stores each row once and materializes the `[B,T,F]`
//!   model input with one contiguous memcpy per window at flush time,
//!   instead of the seed's `T` strided ring reads per *instruction*
//!   ([`NaiveWindowBatcher`], kept as the equivalence oracle).
//! * **Streamed sharding** — [`simulate_parallel`] feeds fixed-size
//!   chunks through a bounded work queue (at most one in-flight chunk
//!   per worker), and each chunk re-runs a warm-up overlap region whose
//!   predictions are discarded, so the cold-start approximation no
//!   longer sits inside the measured region at every shard boundary.
//! * **Double-buffered stage/execute** — each worker stages window
//!   batch k+1 while batch k executes on a dedicated executor thread
//!   (the shared [`crate::coordinator::pipeline::ExecPipeline`]); the
//!   chunked paths additionally prefetch the next chunk off the source
//!   on a bounded side thread. The single-threaded staging loop is
//!   kept (`ParallelOptions::pipeline = false`) as the bit-identity
//!   oracle.

use crate::coordinator::pipeline::{
    spawn_exec_pipeline, ExecBatch, ExecBuffers, ExecPipeline, PipeMsg, PipelineStats,
};
use crate::features::FeatureExtractor;
use crate::runtime::{ArtifactMeta, ModelKind, ModelOutputs, Session};
use crate::sampling::{PhasePlan, SamplingPlan};
use crate::stats::{Metrics, PhaseSeries};
use crate::trace::{
    open_trace_source, trace_header, ChunkBuf, ChunkPrefetcher, FuncRecord, TraceColumns,
    TraceSource, CTX_WIDTH,
};
use crate::util::fault::{panic_message, relock};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Record sources (AoS and SoA traces feed the same engine)
// ---------------------------------------------------------------------

// The traits live with the trace layer now (`trace::source`,
// `trace::chunk`) so datagen can stream off the same abstractions;
// re-exported here because the engine is their primary consumer and the
// historical home of the names.
pub use crate::trace::{ChunkSource, RecordSource};

// ---------------------------------------------------------------------
// Window batching
// ---------------------------------------------------------------------

/// Overlap-aware sliding-window batcher.
///
/// The window for instruction *i* covers `[i-T+1, i]` with
/// repeated-first-row padding during warm-up. Consecutive windows share
/// `T-1` rows, so instead of staging every window eagerly (`O(T·F)`
/// copied per instruction), the batcher keeps a rolling buffer of
/// `B + T - 1` rows in window order:
///
/// ```text
/// [ t-1 history rows | row of window 0 | row of window 1 | ... ]
/// ```
///
/// Each pushed instruction writes its row **exactly once** (amortized
/// `O(F)`); window `w` then occupies rows `[w, w+T)` — contiguous — so
/// [`WindowBatcher::materialize`] builds the `[B,T,F]` model input with
/// a single contiguous copy per window, and
/// [`WindowBatcher::clear_staged`] rolls the last `T-1` rows back to the
/// front to seed the next batch.
pub struct WindowBatcher {
    t: usize,
    f: usize,
    batch: usize,
    /// Rolling opcode rows, `batch + t - 1` entries.
    roll_ops: Vec<i32>,
    /// Rolling feature rows, `(batch + t - 1) * f` values.
    roll_feats: Vec<f32>,
    /// Whether the first row of the shard has seeded the warm-up padding.
    warmed: bool,
    /// Windows currently staged.
    pub staged: usize,
}

impl WindowBatcher {
    /// New batcher for the given artifact shape.
    pub fn new(t: usize, f: usize, batch: usize) -> WindowBatcher {
        assert!(t >= 1 && batch >= 1 && f >= 1, "degenerate batcher shape");
        let rows = batch + t - 1;
        WindowBatcher {
            t,
            f,
            batch,
            roll_ops: vec![0; rows],
            roll_feats: vec![0.0; rows * f],
            warmed: false,
            staged: 0,
        }
    }

    /// The rolling-buffer slot for the next instruction's feature row.
    /// The feature extractor writes into this slice in place
    /// (zero-copy); follow with [`WindowBatcher::commit_row`].
    #[inline]
    pub fn begin_row(&mut self) -> &mut [f32] {
        let idx = self.t - 1 + self.staged;
        &mut self.roll_feats[idx * self.f..(idx + 1) * self.f]
    }

    /// Commit the row written via [`WindowBatcher::begin_row`] along with
    /// its opcode. Returns `true` when the batch is full and must be
    /// flushed. The first committed row of a shard also seeds the `T-1`
    /// warm-up padding rows (repeated-first-row, matching the naive
    /// batcher byte for byte).
    #[inline]
    pub fn commit_row(&mut self, opcode: i32) -> bool {
        let idx = self.t - 1 + self.staged;
        self.roll_ops[idx] = opcode;
        if !self.warmed {
            for j in 0..self.t - 1 {
                self.roll_ops[j] = opcode;
                self.roll_feats
                    .copy_within(idx * self.f..(idx + 1) * self.f, j * self.f);
            }
            self.warmed = true;
        }
        self.staged += 1;
        self.staged == self.batch
    }

    /// Convenience push for callers that already have the row in a
    /// slice: copies it into the rolling buffer and commits.
    pub fn push(&mut self, opcode: i32, feats: &[f32]) -> bool {
        debug_assert_eq!(feats.len(), self.f);
        self.begin_row().copy_from_slice(feats);
        self.commit_row(opcode)
    }

    /// Materialize the staged windows into the session's `[B,T]` opcode
    /// and `[B,T,F]` feature staging buffers (one contiguous copy per
    /// window), returning the number of valid windows.
    pub fn materialize(&self, ops_buf: &mut [i32], feat_buf: &mut [f32]) -> usize {
        let (t, f) = (self.t, self.f);
        debug_assert!(ops_buf.len() >= self.batch * t);
        debug_assert!(feat_buf.len() >= self.batch * t * f);
        for w in 0..self.staged {
            ops_buf[w * t..(w + 1) * t].copy_from_slice(&self.roll_ops[w..w + t]);
            feat_buf[w * t * f..(w + 1) * t * f]
                .copy_from_slice(&self.roll_feats[w * f..(w + t) * f]);
        }
        self.staged
    }

    /// Roll the window history forward after a flush: the last `T-1`
    /// rows move to the front to back the next batch's first windows.
    pub fn clear_staged(&mut self) {
        if self.staged > 0 {
            let (t, f) = (self.t, self.f);
            self.roll_ops.copy_within(self.staged..self.staged + t - 1, 0);
            self.roll_feats
                .copy_within(self.staged * f..(self.staged + t - 1) * f, 0);
            self.staged = 0;
        }
    }

    /// Reset everything (new shard).
    pub fn reset(&mut self) {
        self.staged = 0;
        self.warmed = false;
    }
}

/// The seed's per-window ring-copy batcher, kept as the reference oracle
/// for the overlap-aware [`WindowBatcher`]: every push re-gathers the
/// whole `T×F` window out of a ring with modular indexing (`O(T·F)` per
/// instruction). Tests assert the two produce byte-identical staged
/// batches; `benches/coordinator.rs` measures the speedup.
pub struct NaiveWindowBatcher {
    t: usize,
    f: usize,
    batch: usize,
    ring_ops: Vec<i32>,
    ring_feats: Vec<f32>,
    filled: usize,
    head: usize,
    /// Windows currently staged.
    pub staged: usize,
}

impl NaiveWindowBatcher {
    /// New batcher for the given artifact shape.
    pub fn new(t: usize, f: usize, batch: usize) -> NaiveWindowBatcher {
        NaiveWindowBatcher {
            t,
            f,
            batch,
            ring_ops: vec![0; t],
            ring_feats: vec![0.0; t * f],
            filled: 0,
            head: 0,
            staged: 0,
        }
    }

    /// Push one instruction's features; stage its window into the batch
    /// buffers. Returns `true` when the batch is full.
    pub fn push(
        &mut self,
        opcode: i32,
        feats: &[f32],
        ops_buf: &mut [i32],
        feat_buf: &mut [f32],
    ) -> bool {
        debug_assert_eq!(feats.len(), self.f);
        self.ring_ops[self.head] = opcode;
        self.ring_feats[self.head * self.f..(self.head + 1) * self.f].copy_from_slice(feats);
        self.head = (self.head + 1) % self.t;
        self.filled = (self.filled + 1).min(self.t);

        let w = self.staged;
        let dst_ops = &mut ops_buf[w * self.t..(w + 1) * self.t];
        let dst_feats = &mut feat_buf[w * self.t * self.f..(w + 1) * self.t * self.f];
        for j in 0..self.t {
            let age = self.t - 1 - j; // newest = age 0
            let age = age.min(self.filled - 1);
            let idx = (self.head + self.t - 1 - age) % self.t;
            dst_ops[j] = self.ring_ops[idx];
            dst_feats[j * self.f..(j + 1) * self.f]
                .copy_from_slice(&self.ring_feats[idx * self.f..(idx + 1) * self.f]);
        }
        self.staged += 1;
        self.staged == self.batch
    }

    /// Reset staging (after a flush).
    pub fn clear_staged(&mut self) {
        self.staged = 0;
    }
}

/// Overlap-aware stager for SimNet's per-instruction context metrics.
///
/// The seed staged each instruction's context *window* eagerly — `T`
/// rows of [`CTX_WIDTH`] metrics gathered per instruction straight into
/// the session buffer, `O(T·6)` copied per push. Context windows overlap
/// exactly like feature windows, so this is [`WindowBatcher`]'s rolling
/// buffer specialised to the fixed-width ctx channel: each instruction's
/// 6 metrics are written **once**; [`CtxBatcher::materialize`] emits the
/// `[B,T,6]` staging buffer with one contiguous copy per window, zeroing
/// each window's own (newest) row — SimNet masks the current
/// instruction's metrics, which are what the model predicts.
///
/// Must be driven in lockstep with the feature [`WindowBatcher`] (one
/// `push` per `commit_row`, cleared/reset together) so the two stay on
/// the same window grid.
pub struct CtxBatcher {
    t: usize,
    batch: usize,
    /// Rolling ctx rows, `(batch + t - 1) * CTX_WIDTH` values.
    roll: Vec<f32>,
    warmed: bool,
    staged: usize,
}

impl CtxBatcher {
    /// New stager for the given artifact shape.
    pub fn new(t: usize, batch: usize) -> CtxBatcher {
        assert!(t >= 1 && batch >= 1, "degenerate ctx batcher shape");
        CtxBatcher {
            t,
            batch,
            roll: vec![0.0; (batch + t - 1) * CTX_WIDTH],
            warmed: false,
            staged: 0,
        }
    }

    /// Stage one instruction's context row. The first row of a shard
    /// also seeds the `T-1` repeat-pad warm-up rows, mirroring
    /// [`WindowBatcher::commit_row`].
    #[inline]
    pub fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), CTX_WIDTH);
        debug_assert!(self.staged < self.batch, "push past a full batch");
        let idx = self.t - 1 + self.staged;
        self.roll[idx * CTX_WIDTH..(idx + 1) * CTX_WIDTH].copy_from_slice(row);
        if !self.warmed {
            for j in 0..self.t - 1 {
                self.roll
                    .copy_within(idx * CTX_WIDTH..(idx + 1) * CTX_WIDTH, j * CTX_WIDTH);
            }
            self.warmed = true;
        }
        self.staged += 1;
    }

    /// Materialize the staged windows into the session's `[B,T,6]` ctx
    /// buffer (one contiguous copy per window, then the newest-row
    /// mask).
    pub fn materialize(&self, ctx_buf: &mut [f32]) {
        let (t, c) = (self.t, CTX_WIDTH);
        debug_assert!(ctx_buf.len() >= self.batch * t * c);
        for w in 0..self.staged {
            ctx_buf[w * t * c..(w + 1) * t * c]
                .copy_from_slice(&self.roll[w * c..(w + t) * c]);
            ctx_buf[(w * t + t - 1) * c..(w * t + t) * c].fill(0.0);
        }
    }

    /// Roll the last `T-1` rows to the front after a flush (window
    /// history for the next batch).
    pub fn clear_staged(&mut self) {
        if self.staged > 0 {
            let c = CTX_WIDTH;
            self.roll
                .copy_within(self.staged * c..(self.staged + self.t - 1) * c, 0);
            self.staged = 0;
        }
    }

    /// Reset everything (new shard).
    pub fn reset(&mut self) {
        self.staged = 0;
        self.warmed = false;
    }
}

/// Drive [`WindowBatcher`] and [`NaiveWindowBatcher`] over `n` seeded
/// random rows and panic unless they stage byte-identical batches,
/// flush for flush (including the final partial flush). Shared support
/// code for the unit tests, the 100k integration gate and
/// `benches/coordinator.rs` — one driver, three call sites.
pub fn check_batcher_equivalence(t: usize, f: usize, batch: usize, n: usize, seed: u64) {
    let mut rng = crate::util::Rng::new(seed);
    let mut naive = NaiveWindowBatcher::new(t, f, batch);
    let mut fast = WindowBatcher::new(t, f, batch);
    let mut n_ops = vec![0i32; batch * t];
    let mut n_feats = vec![0.0f32; batch * t * f];
    let mut x_ops = vec![0i32; batch * t];
    let mut x_feats = vec![0.0f32; batch * t * f];
    let mut row = vec![0.0f32; f];
    let mut flushes = 0u64;
    for i in 0..n {
        for v in row.iter_mut() {
            *v = rng.index(1 << 20) as f32 / (1 << 20) as f32;
        }
        let op = rng.index(39) as i32;
        let full_n = naive.push(op, &row, &mut n_ops, &mut n_feats);
        let full_x = fast.push(op, &row);
        assert_eq!(full_n, full_x, "full flag diverged at row {i}");
        if full_n || (i + 1 == n && fast.staged > 0) {
            let valid = fast.materialize(&mut x_ops, &mut x_feats);
            assert_eq!(valid, naive.staged, "staged count at flush {flushes}");
            assert_eq!(n_ops, x_ops, "opcode batch diverged at flush {flushes}");
            assert_eq!(n_feats, x_feats, "feature batch diverged at flush {flushes}");
            naive.clear_staged();
            fast.clear_staged();
            flushes += 1;
        }
    }
    assert_eq!(flushes, (n as u64).div_ceil(batch as u64), "flush count");
}

// ---------------------------------------------------------------------
// Window-level staging (cross-job batch packing)
// ---------------------------------------------------------------------

/// One stream's window producer, emitting windows *individually* so a
/// scheduler can pack windows from many concurrent streams into a
/// single fixed-`B` model batch (the serving layer's cross-job
/// packing). Internally this is the overlap-aware [`WindowBatcher`]
/// (and SimNet [`CtxBatcher`]) specialized to `batch = 1`: each record
/// writes its feature row once into the rolling buffer and the window
/// materializes with one contiguous copy into whatever batch slot the
/// caller chose — the same per-window copy cost as the whole-batch
/// flush path, byte for byte the same staging.
///
/// Two extra gears support the chunk-level prediction cache:
///
/// * [`WindowStager::advance_only`] — exact state-only fast-forward
///   (extractor history advances, no feature row is produced);
/// * [`WindowStager::roll_only`] — extract the row into the rolling
///   window history but emit no window.
///
/// A cache hit replays a chunk by `advance_only` over all but its last
/// `T-1` records and `roll_only` over those — after which the stager's
/// state is byte-identical to having staged every window, at feature
/// extraction cost for `T-1` rows and zero model cost.
pub struct WindowStager {
    fx: FeatureExtractor,
    batcher: WindowBatcher,
    ctx: CtxBatcher,
    kind: ModelKind,
    t: usize,
}

impl WindowStager {
    /// Stager sized for an artifact.
    pub fn new(meta: &ArtifactMeta) -> WindowStager {
        WindowStager {
            fx: FeatureExtractor::new(meta.features),
            batcher: WindowBatcher::new(meta.context, meta.feature_dim, 1),
            ctx: CtxBatcher::new(meta.context, 1),
            kind: meta.kind,
            t: meta.context,
        }
    }

    /// The context window length `T` (callers size batch slots off it).
    pub fn context(&self) -> usize {
        self.t
    }

    /// Records that must be [`WindowStager::roll_only`]-ed (not merely
    /// advanced) at the tail of a skipped region so the rolling window
    /// history stays exact: `T - 1`.
    pub fn history_rows(&self) -> usize {
        self.t - 1
    }

    /// Stage one record's window into the caller's batch slot:
    /// `ops_slot` is `[T]`, `feat_slot` is `[T*F]`, and for SimNet
    /// artifacts `ctx_slot` is `[T*6]` with `ctx_row` the record's 6
    /// context metrics. Slots receive exactly the bytes the whole-batch
    /// path would have staged for this window.
    pub fn stage_window(
        &mut self,
        rec: &FuncRecord,
        ctx_row: Option<&[f32]>,
        ops_slot: &mut [i32],
        feat_slot: &mut [f32],
        ctx_slot: Option<&mut [f32]>,
    ) {
        let row = self.batcher.begin_row();
        let opcode = self.fx.extract_into(rec, row);
        let full = self.batcher.commit_row(opcode);
        debug_assert!(full, "batch=1 stager must fill on every commit");
        self.batcher.materialize(ops_slot, feat_slot);
        if self.kind == ModelKind::SimNet {
            self.ctx.push(ctx_row.expect("SimNet stager requires a ctx row"));
            self.ctx
                .materialize(ctx_slot.expect("SimNet stager requires a ctx slot"));
        }
        self.batcher.clear_staged();
        self.ctx.clear_staged();
    }

    /// Extract the record into the rolling window history without
    /// emitting a window (cache-hit tail refill).
    pub fn roll_only(&mut self, rec: &FuncRecord, ctx_row: Option<&[f32]>) {
        let row = self.batcher.begin_row();
        let opcode = self.fx.extract_into(rec, row);
        self.batcher.commit_row(opcode);
        if self.kind == ModelKind::SimNet {
            self.ctx.push(ctx_row.expect("SimNet stager requires a ctx row"));
        }
        self.batcher.clear_staged();
        self.ctx.clear_staged();
    }

    /// Advance extractor state only (cache-hit fast-forward). The
    /// rolling window history goes stale; callers must follow with at
    /// least [`WindowStager::history_rows`] `roll_only`/`stage_window`
    /// calls before the next emitted window.
    pub fn advance_only(&mut self, rec: &FuncRecord) {
        self.fx.advance(rec);
    }

    /// Reset for a new stream.
    pub fn reset(&mut self) {
        self.fx.reset();
        self.batcher.reset();
        self.ctx.reset();
    }
}

// ---------------------------------------------------------------------
// Prediction accumulation
// ---------------------------------------------------------------------

/// Accumulated predictions over a stream.
///
/// Accumulators carry the **global ordinal** of the instruction that
/// produced their `last_exec` tail correction, so folding per-shard
/// accumulators is order-independent: [`PredAccum::merge`] keeps the
/// tail of whichever side saw the later instruction, not whichever
/// happened to be merged last.
#[derive(Debug, Clone, Default)]
pub struct PredAccum {
    /// Instructions accounted.
    pub instructions: u64,
    /// Σ predicted fetch latency (cycles).
    pub fetch_cycles: f64,
    /// Last window's predicted exec latency (tail correction).
    pub last_exec: f64,
    /// Global ordinal (1-based) of the instruction behind `last_exec`;
    /// 0 while empty.
    pub last_exec_at: u64,
    /// Σ P(mispredict).
    pub mispredicts: f64,
    /// Σ P(L1D miss) (= P(level ≥ L2)).
    pub l1d_misses: f64,
    /// Σ P(L1I miss).
    pub l1i_misses: f64,
    /// Σ P(TLB miss).
    pub tlb_misses: f64,
    /// Optional per-window phase series.
    pub phase: Option<PhaseSeries>,
    /// Next global ordinal to assign (base + absorbed count).
    ordinal: u64,
}

impl PredAccum {
    /// Accumulator whose first absorbed instruction has global index
    /// `base` (shard offset into the full trace).
    pub fn at_base(base: u64) -> PredAccum {
        PredAccum {
            ordinal: base,
            ..Default::default()
        }
    }

    /// With phase tracking at the given window size.
    pub fn with_phase(window: u64) -> PredAccum {
        PredAccum {
            phase: Some(PhaseSeries::new(window)),
            ..Default::default()
        }
    }

    /// Fold one model batch.
    pub fn absorb(&mut self, out: &ModelOutputs, kind: ModelKind) {
        self.absorb_range(out, kind, 0);
    }

    /// Fold one model batch, skipping the first `skip` rows (warm-up
    /// overlap predictions that belong to a neighbouring shard).
    pub fn absorb_range(&mut self, out: &ModelOutputs, kind: ModelKind, skip: usize) {
        for i in skip..out.fetch.len() {
            self.absorb_one(out, kind, i);
        }
    }

    /// Fold a single output row — the window-level demux surface. The
    /// serving scheduler packs windows from many jobs into one batch
    /// and routes each output row back to its job's accumulator with
    /// this call; a whole-batch [`PredAccum::absorb_range`] is just the
    /// loop over it, so the two paths share one fold body.
    pub fn absorb_one(&mut self, out: &ModelOutputs, kind: ModelKind, i: usize) {
        let fetch = out.fetch[i] as f64;
        let exec = out.exec[i] as f64;
        self.instructions += 1;
        self.ordinal += 1;
        self.fetch_cycles += fetch;
        self.last_exec = exec;
        self.last_exec_at = self.ordinal;
        let (mis, l1d, l1i, tlb) = match kind {
            ModelKind::Tao => (
                out.branch[i] as f64,
                (out.access[i * 4 + 2] + out.access[i * 4 + 3]) as f64,
                out.icache[i] as f64,
                out.tlb[i] as f64,
            ),
            ModelKind::SimNet => (0.0, 0.0, 0.0, 0.0),
        };
        self.mispredicts += mis;
        self.l1d_misses += l1d;
        self.l1i_misses += l1i;
        self.tlb_misses += tlb;
        if let Some(ph) = &mut self.phase {
            ph.push(fetch, mis > 0.5, l1d > 0.5, l1i > 0.5, tlb > 0.5);
        }
    }

    /// The sums + tail selection shared by [`PredAccum::merge`] and
    /// [`PredAccum::merge_from`]; everything except the absorb cursor.
    fn fold(&mut self, other: &PredAccum) {
        self.instructions += other.instructions;
        self.fetch_cycles += other.fetch_cycles;
        if other.last_exec_at > self.last_exec_at {
            self.last_exec = other.last_exec;
            self.last_exec_at = other.last_exec_at;
        }
        self.mispredicts += other.mispredicts;
        self.l1d_misses += other.l1d_misses;
        self.l1i_misses += other.l1i_misses;
        self.tlb_misses += other.tlb_misses;
    }

    /// Merge a **consecutive** shard's accumulator. Order-independent
    /// for the visible metrics: any fold order over a set of disjoint
    /// shards reconstructs the same run-level metrics (the tail
    /// correction follows the globally last instruction, not merge
    /// order). The internal absorb cursor advances by the merged
    /// instruction count, so a shard that directly follows this
    /// accumulator's absorbed region can be folded mid-stream and
    /// absorption can resume afterwards at the correct global ordinal —
    /// the serving cache replays chunk-level accumulators this way.
    pub fn merge(&mut self, other: &PredAccum) {
        self.fold(other);
        self.ordinal += other.instructions;
    }

    /// Merge a shard's accumulator **without assuming it follows the
    /// absorbed region**: the pipelined workers fold per-chunk tails in
    /// completion order, which is not global stream order, and
    /// [`PredAccum::merge`]'s cursor advance would mis-place a later
    /// absorb. `merge_from` instead jumps the cursor to the farthest
    /// shard end seen so far, so no ordinal is ever re-tagged: once the
    /// merged shards tile a prefix of the stream, absorption resumes at
    /// the correct global ordinal regardless of arrival order. Visible
    /// metrics are identical to [`PredAccum::merge`].
    pub fn merge_from(&mut self, other: &PredAccum) {
        self.fold(other);
        self.ordinal = self.ordinal.max(other.ordinal);
    }

    /// A copy with every additive statistic scaled by `w` — the
    /// phase-sampling expansion of one representative slice to the
    /// member rows it stands for. The `Σ` fields scale linearly; the
    /// tail correction does not (`last_exec` is one window's latency,
    /// not a sum), so it and its ordinal pass through unscaled.
    /// `instructions` rounds to the nearest integer, which recovers
    /// the exact member-row count for any `member_rows / rows` plan
    /// weight at trace scales. `w = 1.0` is a bit-exact identity
    /// (IEEE multiplication by 1.0 changes no finite value).
    pub fn scaled(&self, w: f64) -> PredAccum {
        PredAccum {
            instructions: (self.instructions as f64 * w).round() as u64,
            fetch_cycles: self.fetch_cycles * w,
            last_exec: self.last_exec,
            last_exec_at: self.last_exec_at,
            mispredicts: self.mispredicts * w,
            l1d_misses: self.l1d_misses * w,
            l1i_misses: self.l1i_misses * w,
            tlb_misses: self.tlb_misses * w,
            phase: None,
            ordinal: self.ordinal,
        }
    }

    /// Weighted order-independent merge: fold `other` scaled by `w`
    /// (see [`PredAccum::scaled`]), with [`PredAccum::merge_from`]'s
    /// cursor handling. This is the phase-sampling recombination —
    /// each representative slice's accumulator merges at its phase
    /// weight, reconstructing whole-trace metrics — and, like the
    /// unweighted merges, any fold order over a fixed set of
    /// (accumulator, weight) pairs produces the same metrics. With
    /// `w = 1.0` it is exactly [`PredAccum::merge_from`].
    pub fn merge_weighted(&mut self, other: &PredAccum, w: f64) {
        self.fold(&other.scaled(w));
        self.ordinal = self.ordinal.max(other.ordinal);
    }

    /// Total predicted cycles (§4.2 reconstruction).
    pub fn total_cycles(&self) -> f64 {
        self.fetch_cycles + self.last_exec
    }

    /// As run-level metrics.
    pub fn metrics(&self) -> Metrics {
        Metrics {
            instructions: self.instructions,
            cycles: self.total_cycles(),
            mispredicts: self.mispredicts,
            l1d_misses: self.l1d_misses,
            l1i_misses: self.l1i_misses,
            tlb_misses: self.tlb_misses,
        }
    }

    /// Size of the cache-journal encoding: the eight public scalars,
    /// 8 bytes each.
    pub const JOURNAL_BYTES: usize = 64;

    /// Serialize the visible accumulator state for the serving cache
    /// journal: the eight public scalars, little-endian, `f64` as raw
    /// bits so recovery is bit-exact. The private absorb cursor and
    /// the phase series are deliberately dropped — [`PredAccum::merge`]
    /// / [`PredAccum::merge_from`] never read the *other* side's
    /// cursor, and cached chunk deltas never carry phase — so a
    /// decoded accumulator folds exactly like the one encoded. The
    /// codec lives here (not in `serve`) because the private cursor
    /// keeps `PredAccum` unconstructible outside this module.
    pub fn encode_journal(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.instructions.to_le_bytes());
        out.extend_from_slice(&self.fetch_cycles.to_le_bytes());
        out.extend_from_slice(&self.last_exec.to_le_bytes());
        out.extend_from_slice(&self.last_exec_at.to_le_bytes());
        out.extend_from_slice(&self.mispredicts.to_le_bytes());
        out.extend_from_slice(&self.l1d_misses.to_le_bytes());
        out.extend_from_slice(&self.l1i_misses.to_le_bytes());
        out.extend_from_slice(&self.tlb_misses.to_le_bytes());
    }

    /// Inverse of [`PredAccum::encode_journal`].
    pub fn decode_journal(bytes: &[u8]) -> Result<PredAccum> {
        ensure!(
            bytes.len() == PredAccum::JOURNAL_BYTES,
            "journal accumulator record must be {} bytes, got {}",
            PredAccum::JOURNAL_BYTES,
            bytes.len()
        );
        let u = |i: usize| u64::from_le_bytes(bytes[i * 8..(i + 1) * 8].try_into().unwrap());
        let f = |i: usize| f64::from_bits(u(i));
        Ok(PredAccum {
            instructions: u(0),
            fetch_cycles: f(1),
            last_exec: f(2),
            last_exec_at: u(3),
            mispredicts: f(4),
            l1d_misses: f(5),
            l1i_misses: f(6),
            tlb_misses: f(7),
            phase: None,
            ordinal: 0,
        })
    }
}

// ---------------------------------------------------------------------
// Streaming simulation core
// ---------------------------------------------------------------------

/// Result of a simulation run.
#[derive(Debug)]
pub struct SimResult {
    /// Predicted metrics.
    pub metrics: Metrics,
    /// Wall-clock inference time (feature extraction + model execution).
    pub elapsed: Duration,
    /// Model batches executed.
    pub batches: u64,
    /// Optional phase series (single-shard runs).
    pub phase: Option<PhaseSeries>,
    /// Stage/execute occupancy counters, summed across workers
    /// (pipelined runs only; `None` on the serial paths).
    pub pipeline: Option<PipelineStats>,
}

impl SimResult {
    /// Simulation throughput in MIPS.
    pub fn mips(&self) -> f64 {
        crate::util::timer::mips(self.metrics.instructions, self.elapsed)
    }
}

/// Per-worker reusable state: one extractor, one feature batcher and
/// one ctx stager, reset per chunk so chunk streaming allocates nothing
/// on the hot path.
pub struct ShardScratch {
    fx: FeatureExtractor,
    batcher: WindowBatcher,
    ctx: CtxBatcher,
}

impl ShardScratch {
    /// Scratch sized for an artifact.
    pub fn new(meta: &ArtifactMeta) -> ShardScratch {
        ShardScratch {
            fx: FeatureExtractor::new(meta.features),
            batcher: WindowBatcher::new(meta.context, meta.feature_dim, meta.batch),
            ctx: CtxBatcher::new(meta.context, meta.batch),
        }
    }

    fn reset(&mut self) {
        self.fx.reset();
        self.batcher.reset();
        self.ctx.reset();
    }
}

/// Outcome of streaming one chunk: the accumulator plus batch count.
struct ShardRun {
    accum: PredAccum,
    batches: u64,
}

fn flush_batch(
    session: &mut Session,
    scratch: &mut ShardScratch,
    accum: &mut PredAccum,
    skip: &mut usize,
    batches: &mut u64,
    kind: ModelKind,
) -> Result<()> {
    let staged = scratch.batcher.staged;
    if staged == 0 {
        return Ok(());
    }
    {
        let _sp = crate::stage_span!("stage");
        {
            let (ops_buf, feat_buf) = session.buffers();
            scratch.batcher.materialize(ops_buf, feat_buf);
        }
        if kind == ModelKind::SimNet {
            scratch.ctx.materialize(session.ctx_buffer());
        }
    }
    let out = {
        let _sp = crate::stage_span!("execute");
        session.run(staged)?
    };
    let skip_now = (*skip).min(out.fetch.len());
    accum.absorb_range(&out, kind, skip_now);
    *skip -= skip_now;
    scratch.batcher.clear_staged();
    scratch.ctx.clear_staged();
    *batches += 1;
    Ok(())
}

/// Stage one record (and, for SimNet, its context row) into the
/// scratch's batchers and flush through the session when the batch
/// fills. The single per-record core shared by the resident
/// ([`simulate_stream`]) and pull-based ([`simulate_chunked`]) paths —
/// one body, so the byte-identity guarantees between them cannot drift.
/// SimNet callers must have validated ctx presence/length up front.
#[allow(clippy::too_many_arguments)]
#[inline]
fn stage_record(
    session: &mut Session,
    scratch: &mut ShardScratch,
    rec: &FuncRecord,
    ctx_row: Option<&[f32]>,
    accum: &mut PredAccum,
    skip: &mut usize,
    batches: &mut u64,
    kind: ModelKind,
) -> Result<()> {
    let row = scratch.batcher.begin_row();
    let opcode = scratch.fx.extract_into(rec, row);
    let full = scratch.batcher.commit_row(opcode);
    if kind == ModelKind::SimNet {
        // Stage the context row alongside the feature row: the rolling
        // CtxBatcher repeat-pads and masks at flush time,
        // byte-identical to the seed's per-instruction window copy.
        scratch
            .ctx
            .push(ctx_row.expect("SimNet ctx validated by the caller"));
    }
    if full {
        flush_batch(session, scratch, accum, skip, batches, kind)?;
    }
    Ok(())
}

/// Stream `source[start-warmup .. end]` through the session, absorbing
/// predictions only for `[start, end)`. The `warmup` prefix re-runs the
/// preceding instructions to warm the extractor/window state so the
/// chunk's first absorbed windows are not cold-started; its predictions
/// are discarded. `accum` must be positioned at global base `start`
/// (see [`PredAccum::at_base`]).
#[allow(clippy::too_many_arguments)]
fn simulate_stream<S: RecordSource + ?Sized>(
    session: &mut Session,
    scratch: &mut ShardScratch,
    source: &S,
    start: usize,
    end: usize,
    warmup: usize,
    ctx_metrics: Option<&[f32]>,
    mut accum: PredAccum,
) -> Result<ShardRun> {
    let kind = session.meta().kind;
    ensure!(start <= end && end <= source.len(), "bad stream range");
    ensure!(warmup <= start, "warm-up region precedes the trace");
    if kind == ModelKind::SimNet {
        ensure!(
            ctx_metrics.map(|c| c.len()) == Some(source.len() * CTX_WIDTH),
            "SimNet requires [N×6] context metrics"
        );
    }
    scratch.reset();
    let base = start - warmup;
    let mut skip = warmup;
    let mut batches = 0u64;

    for i in base..end {
        let rec = source.get(i);
        // Only sliced for SimNet, where the length check above holds;
        // Tao sessions ignore ctx entirely.
        let ctx_row = if kind == ModelKind::SimNet {
            ctx_metrics.map(|c| &c[i * CTX_WIDTH..(i + 1) * CTX_WIDTH])
        } else {
            None
        };
        stage_record(session, scratch, &rec, ctx_row, &mut accum, &mut skip, &mut batches, kind)?;
    }
    flush_batch(session, scratch, &mut accum, &mut skip, &mut batches, kind)?;
    if let Some(ph) = &mut accum.phase {
        ph.finish();
    }
    Ok(ShardRun { accum, batches })
}

/// Simulate a whole source through one session (one shard, one thread).
///
/// Stays zero-copy for resident sources — records are read straight off
/// the [`RecordSource`], no chunk staging. The pull-based
/// [`simulate_chunked`] shares the same per-record core
/// ([`stage_record`]), and the oracle tests assert the two paths
/// produce identical results.
pub fn simulate_source<S: RecordSource + ?Sized>(
    session: &mut Session,
    source: &S,
    ctx_metrics: Option<&[f32]>,
    phase_window: Option<u64>,
) -> Result<SimResult> {
    let accum = match phase_window {
        Some(w) => PredAccum::with_phase(w),
        None => PredAccum::default(),
    };
    let mut scratch = ShardScratch::new(session.meta());
    let start = Instant::now();
    let run = simulate_stream(
        session,
        &mut scratch,
        source,
        0,
        source.len(),
        0,
        ctx_metrics,
        accum,
    )?;
    let mut accum = run.accum;
    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start.elapsed(),
        batches: run.batches,
        phase: accum.phase.take(),
        pipeline: None,
    })
}

/// Stream a pull-based chunk source through one session, pulling at
/// most `chunk_rows` instructions at a time. Extractor, window-batcher
/// and ctx state roll across chunk boundaries — the warm-up handoff
/// between chunks is the state itself, not an approximate re-run — so
/// the metrics are identical to a fully resident pass over the same
/// records while peak trace buffering stays O(`chunk_rows`).
pub fn simulate_chunked<C: ChunkSource + ?Sized>(
    session: &mut Session,
    source: &mut C,
    chunk_rows: usize,
    phase_window: Option<u64>,
) -> Result<SimResult> {
    ensure!(chunk_rows >= 1, "chunk_rows must be positive");
    let kind = session.meta().kind;
    let mut scratch = ShardScratch::new(session.meta());
    let mut accum = match phase_window {
        Some(w) => PredAccum::with_phase(w),
        None => PredAccum::default(),
    };
    let start = Instant::now();
    let mut skip = 0usize;
    let mut batches = 0u64;
    let mut buf = ChunkBuf::new();
    loop {
        let n = {
            let _sp = crate::stage_span!("decode");
            source.next_chunk(&mut buf, chunk_rows)?
        };
        if n == 0 {
            break;
        }
        ensure!(
            buf.cols.len() == n,
            "chunk source reported {n} rows but buffered {}",
            buf.cols.len()
        );
        if kind == ModelKind::SimNet {
            ensure!(
                buf.ctx.len() == n * CTX_WIDTH,
                "SimNet requires [n×6] context metrics per chunk ({} for {n} records)",
                buf.ctx.len()
            );
        }
        let _sp = crate::stage_span!("extract");
        for i in 0..n {
            let rec = buf.cols.record(i);
            let ctx_row = (kind == ModelKind::SimNet)
                .then(|| &buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
            stage_record(session, &mut scratch, &rec, ctx_row, &mut accum, &mut skip, &mut batches, kind)?;
        }
    }
    flush_batch(session, &mut scratch, &mut accum, &mut skip, &mut batches, kind)?;
    if let Some(ph) = &mut accum.phase {
        ph.finish();
    }
    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start.elapsed(),
        batches,
        phase: accum.phase.take(),
        pipeline: None,
    })
}

/// Simulate a record stream through one session.
///
/// `ctx_metrics` (SimNet only): per-instruction detailed-trace metrics,
/// `[N × 6]` — the µarch-specific inputs SimNet requires.
pub fn simulate_records(
    session: &mut Session,
    records: &[FuncRecord],
    ctx_metrics: Option<&[f32]>,
    phase_window: Option<u64>,
) -> Result<SimResult> {
    simulate_source(session, records, ctx_metrics, phase_window)
}

/// Simulate a columnar trace through one session.
pub fn simulate_columns(
    session: &mut Session,
    cols: &TraceColumns,
    ctx_metrics: Option<&[f32]>,
    phase_window: Option<u64>,
) -> Result<SimResult> {
    simulate_source(session, cols, ctx_metrics, phase_window)
}

// ---------------------------------------------------------------------
// Pipelined execution (double-buffered stage/execute per worker)
// ---------------------------------------------------------------------

/// Routing tag the offline workers attach to each batch through the
/// [`ExecPipeline`]: how many leading output rows are warm-up overlap
/// whose predictions must be discarded.
struct BatchTag {
    skip: usize,
}

/// One shard whose model outputs have not fully come back yet. Batches
/// never span shards (each shard ends with its own partial flush), so
/// completions always fold into the front of the queue.
struct PendingShard {
    accum: PredAccum,
    /// Batch rows still expected; `None` for an open-ended stream
    /// (sequential chunked runs settle at finish, not per shard).
    remaining: Option<usize>,
}

/// A worker's folded output.
struct WorkerOut {
    accum: PredAccum,
    batches: u64,
    stats: Option<PipelineStats>,
}

/// The stage side of one offline worker: the extractor and batchers
/// run on the worker thread, filling one [`ExecBuffers`] set while the
/// [`ExecPipeline`]'s executor thread runs the model from the other —
/// the serving scheduler's double-buffering, extracted to the engine.
///
/// Completions arrive FIFO (submission order), so absorbing on receipt
/// folds outputs in exactly the order the single-threaded
/// [`simulate_stream`] loop would have — bit-identical accumulators,
/// oracle-tested.
struct PipelinedWorker {
    pipe: ExecPipeline<BatchTag>,
    scratch: ShardScratch,
    kind: ModelKind,
    pending: VecDeque<PendingShard>,
    folded: PredAccum,
    batches: u64,
    /// Warm-up rows of the current shard not yet attributed to a batch.
    skip: usize,
}

impl PipelinedWorker {
    /// Spawn the executor thread for `artifact` (the session compiles
    /// on that thread) and size the staging state off `meta`.
    fn new(artifact: &Path, meta: &ArtifactMeta) -> PipelinedWorker {
        let path = artifact.to_path_buf();
        let pipe = spawn_exec_pipeline(
            move || Session::load(&path).with_context(|| format!("load {path:?}")),
            meta.kind,
            meta.batch,
            meta.context,
            meta.feature_dim,
            2,
        );
        PipelinedWorker {
            pipe,
            scratch: ShardScratch::new(meta),
            kind: meta.kind,
            pending: VecDeque::new(),
            folded: PredAccum::default(),
            batches: 0,
            skip: 0,
        }
    }

    /// Open a new shard: reset the staging state (fresh extractor /
    /// window history) and queue its accumulator for in-order
    /// absorption. `rows` is the total batch rows the shard will stage
    /// (warm-up included); `None` marks an open-ended stream.
    fn begin_shard(&mut self, accum: PredAccum, rows: Option<usize>, warmup: usize) {
        debug_assert!(rows != Some(0), "empty shard");
        debug_assert_eq!(self.scratch.batcher.staged, 0, "shard began mid-batch");
        self.scratch.reset();
        self.skip = warmup;
        self.pending.push_back(PendingShard { accum, remaining: rows });
    }

    /// Fold one completion into the front shard; hands the buffer set
    /// back for restaging.
    fn absorb_msg(
        &mut self,
        msg: PipeMsg<ExecBuffers, ExecBatch<BatchTag>, ModelOutputs>,
    ) -> Result<ExecBuffers> {
        let (buf, payload, result) = match msg {
            PipeMsg::Done { buf, payload, result } => (buf, payload, result),
            PipeMsg::InitFailed { msg } => bail!("pipelined executor: {msg}"),
        };
        let out = result.map_err(|e| anyhow::anyhow!("pipelined executor: {e}"))?;
        let shard = self.pending.front_mut().expect("batch output with no open shard");
        shard.accum.absorb_range(&out, self.kind, payload.tag.skip);
        if let Some(remaining) = &mut shard.remaining {
            debug_assert!(*remaining >= payload.valid, "shard over-absorbed");
            *remaining -= payload.valid;
            if *remaining == 0 {
                let done = self.pending.pop_front().expect("front shard vanished");
                self.folded.merge_from(&done.accum);
            }
        }
        Ok(buf)
    }

    /// A free buffer set to stage into — from the free list, or by
    /// blocking on the oldest in-flight batch (the double-buffer
    /// rotation point).
    fn acquire(&mut self) -> Result<ExecBuffers> {
        if let Some(buf) = self.pipe.take_buf() {
            return Ok(buf);
        }
        let msg = self.pipe.recv()?;
        self.absorb_msg(msg)
    }

    /// Materialize the staged windows into a free buffer set and hand
    /// them to the executor thread. No-op when nothing is staged.
    fn flush(&mut self) -> Result<()> {
        let staged = self.scratch.batcher.staged;
        if staged == 0 {
            return Ok(());
        }
        let mut bufs = self.acquire()?;
        self.scratch.batcher.materialize(&mut bufs.ops, &mut bufs.feats);
        if self.kind == ModelKind::SimNet {
            self.scratch.ctx.materialize(&mut bufs.ctx);
        }
        self.scratch.batcher.clear_staged();
        self.scratch.ctx.clear_staged();
        let skip_now = self.skip.min(staged);
        self.skip -= skip_now;
        self.pipe
            .submit(bufs, ExecBatch { valid: staged, tag: BatchTag { skip: skip_now } })?;
        self.batches += 1;
        Ok(())
    }

    /// Stage one record (and, for SimNet, its context row); flushes
    /// through the pipeline when the batch fills. The pipelined twin of
    /// [`stage_record`] — same batchers, same flush grid.
    fn stage(&mut self, rec: &FuncRecord, ctx_row: Option<&[f32]>) -> Result<()> {
        let row = self.scratch.batcher.begin_row();
        let opcode = self.scratch.fx.extract_into(rec, row);
        let full = self.scratch.batcher.commit_row(opcode);
        if self.kind == ModelKind::SimNet {
            self.scratch
                .ctx
                .push(ctx_row.expect("SimNet ctx validated by the caller"));
        }
        if full {
            self.flush()?;
        }
        Ok(())
    }

    /// Flush the partial tail, drain every in-flight batch, join the
    /// executor. Returns the folded accumulator (or, for an open-ended
    /// stream, its single accumulator with phase tracking intact), the
    /// batch count and the occupancy counters.
    fn finish(mut self) -> Result<(PredAccum, u64, PipelineStats)> {
        self.flush()?;
        while self.pipe.in_flight() > 0 {
            let msg = self.pipe.recv()?;
            let buf = self.absorb_msg(msg)?;
            self.pipe.release(buf);
        }
        let stats = self.pipe.stats();
        self.pipe.shutdown();
        match self.pending.pop_front() {
            None => Ok((self.folded, self.batches, stats)),
            Some(open) if open.remaining.is_none() && self.pending.is_empty() => {
                Ok((open.accum, self.batches, stats))
            }
            Some(_) => bail!("pipelined worker finished with unabsorbed shards"),
        }
    }
}

/// Pipelined twin of [`simulate_stream`]: stage `source[start-warmup ..
/// end]` through the worker's pipeline, absorbing predictions only for
/// `[start, end)`. Same validation, same flush grid, same skip
/// accounting — the outputs fold in identical order.
fn run_shard_pipelined<S: RecordSource + ?Sized>(
    worker: &mut PipelinedWorker,
    source: &S,
    start: usize,
    end: usize,
    warmup: usize,
    ctx_metrics: Option<&[f32]>,
    accum: PredAccum,
) -> Result<()> {
    let kind = worker.kind;
    ensure!(start <= end && end <= source.len(), "bad stream range");
    ensure!(warmup <= start, "warm-up region precedes the trace");
    if kind == ModelKind::SimNet {
        ensure!(
            ctx_metrics.map(|c| c.len()) == Some(source.len() * CTX_WIDTH),
            "SimNet requires [N×6] context metrics"
        );
    }
    let base = start - warmup;
    if base == end {
        return Ok(());
    }
    worker.begin_shard(accum, Some(end - base), warmup);
    for i in base..end {
        let rec = source.get(i);
        let ctx_row = if kind == ModelKind::SimNet {
            ctx_metrics.map(|c| &c[i * CTX_WIDTH..(i + 1) * CTX_WIDTH])
        } else {
            None
        };
        worker.stage(&rec, ctx_row)?;
    }
    worker.flush()
}

/// Pipelined sequential fallback over a resident source: one worker,
/// one shard covering the whole range — identical staging and absorb
/// order to [`simulate_source`], with execution overlapped.
fn simulate_range_pipelined<S: RecordSource + ?Sized>(
    artifact: &Path,
    source: &S,
    ctx_metrics: Option<&[f32]>,
) -> Result<SimResult> {
    let meta = ArtifactMeta::load(artifact).with_context(|| format!("load {artifact:?}"))?;
    let start = Instant::now();
    let mut worker = PipelinedWorker::new(artifact, &meta);
    let accum = PredAccum::default();
    run_shard_pipelined(&mut worker, source, 0, source.len(), 0, ctx_metrics, accum)?;
    let (accum, batches, stats) = worker.finish()?;
    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start.elapsed(),
        batches,
        phase: None,
        pipeline: Some(stats),
    })
}

/// Pipelined twin of [`simulate_chunked`]: the same rolling-state
/// sequential pull (results identical to a fully resident pass), with
/// two overlaps added — batch staging overlaps model execution through
/// the [`ExecPipeline`], and the next chunk is prefetched off the
/// source on a bounded side thread ([`ChunkPrefetcher`]) so source I/O
/// (file reads / functional-sim generation) overlaps both. Peak trace
/// buffering stays O(`chunk_rows`) times the small fixed pool.
pub fn simulate_chunked_pipelined<C>(
    artifact: &Path,
    source: &mut C,
    chunk_rows: usize,
    phase_window: Option<u64>,
) -> Result<SimResult>
where
    C: ChunkSource + Send + ?Sized,
{
    ensure!(chunk_rows >= 1, "chunk_rows must be positive");
    let meta = ArtifactMeta::load(artifact).with_context(|| format!("load {artifact:?}"))?;
    let kind = meta.kind;
    let seed = match phase_window {
        Some(w) => PredAccum::with_phase(w),
        None => PredAccum::default(),
    };
    let start = Instant::now();
    let (mut accum, batches, stats) =
        std::thread::scope(|scope| -> Result<(PredAccum, u64, PipelineStats)> {
            let mut prefetch = ChunkPrefetcher::spawn(scope, source, chunk_rows, 2);
            let mut worker = PipelinedWorker::new(artifact, &meta);
            worker.begin_shard(seed, None, 0);
            while let Some(buf) = prefetch.next()? {
                let n = buf.len();
                if kind == ModelKind::SimNet {
                    ensure!(
                        buf.ctx.len() == n * CTX_WIDTH,
                        "SimNet requires [n×6] context metrics per chunk ({} for {n} records)",
                        buf.ctx.len()
                    );
                }
                for i in 0..n {
                    let rec = buf.cols.record(i);
                    let ctx_row = (kind == ModelKind::SimNet)
                        .then(|| &buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
                    worker.stage(&rec, ctx_row)?;
                }
                prefetch.recycle(buf);
            }
            worker.finish()
        })?;
    if let Some(ph) = &mut accum.phase {
        ph.finish();
    }
    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start.elapsed(),
        batches,
        phase: accum.phase.take(),
        pipeline: Some(stats),
    })
}

/// Join one parallel worker, converting a panic into an error value.
/// Re-panicking inside a `thread::scope` closure would abandon sibling
/// threads still blocked on channels mid-join — a panicked worker must
/// fail the run the same way an erroring worker does.
fn join_worker(h: std::thread::ScopedJoinHandle<'_, Result<WorkerOut>>) -> Result<WorkerOut> {
    h.join()
        .unwrap_or_else(|p| Err(anyhow!("worker panicked: {}", panic_message(p.as_ref()))))
}

/// Fold per-worker results into the run-level [`SimResult`].
fn collect_workers(results: Vec<Result<WorkerOut>>, start_wall: Instant) -> Result<SimResult> {
    let mut accum = PredAccum::default();
    let mut batches = 0u64;
    let mut stats: Option<PipelineStats> = None;
    for r in results {
        let out = r?;
        accum.merge_from(&out.accum);
        batches += out.batches;
        if let Some(s) = out.stats {
            stats.get_or_insert_with(PipelineStats::default).absorb(&s);
        }
    }
    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start_wall.elapsed(),
        batches,
        phase: None,
        pipeline: stats,
    })
}

// ---------------------------------------------------------------------
// Parallel streaming
// ---------------------------------------------------------------------

/// Chunking/warm-up knobs for [`simulate_parallel_opts`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelOptions {
    /// Instructions per work-queue chunk.
    pub chunk: usize,
    /// Warm-up overlap re-run before each chunk (predictions discarded).
    pub warmup: usize,
    /// Double-buffered stage/execute pipelining per worker (staging of
    /// batch k+1 overlaps model execution of batch k on a dedicated
    /// executor thread). `false` runs the original single-threaded
    /// stage→execute loop — kept as the bit-identity oracle.
    pub pipeline: bool,
}

impl Default for ParallelOptions {
    fn default() -> ParallelOptions {
        // 64k-instruction chunks keep tens of work items in flight per
        // worker at paper trace scales; 4k warm-up covers the context
        // window plus the memory/branch history depth (T + Nm + Nq·few).
        ParallelOptions {
            chunk: 65_536,
            warmup: 4_096,
            pipeline: true,
        }
    }
}

/// Parallel simulation with default chunking: workers stream fixed-size
/// chunks from a shared queue, each with its own PJRT session compiled
/// from `artifact`.
pub fn simulate_parallel(
    artifact: &Path,
    records: &[FuncRecord],
    workers: usize,
    ctx_metrics: Option<&[f32]>,
) -> Result<SimResult> {
    simulate_parallel_opts(artifact, records, workers, ctx_metrics, ParallelOptions::default())
}

/// [`simulate_parallel`] over a columnar trace.
pub fn simulate_parallel_columns(
    artifact: &Path,
    cols: &TraceColumns,
    workers: usize,
    ctx_metrics: Option<&[f32]>,
) -> Result<SimResult> {
    simulate_parallel_opts(artifact, cols, workers, ctx_metrics, ParallelOptions::default())
}

/// Parallel streaming simulation over any record source.
///
/// Chunks of `opts.chunk` instructions are handed out through a bounded
/// work queue (an atomic cursor: at most one in-flight chunk per worker,
/// pulled as workers free up — no one-shot full-slice partitioning), so
/// stragglers re-balance instead of serializing the join. Each chunk
/// re-runs `opts.warmup` preceding instructions to warm the history
/// state and discards their predictions, keeping the cold-start
/// approximation out of the measured region at chunk boundaries.
pub fn simulate_parallel_opts<S: RecordSource + Sync + ?Sized>(
    artifact: &Path,
    source: &S,
    workers: usize,
    ctx_metrics: Option<&[f32]>,
    opts: ParallelOptions,
) -> Result<SimResult> {
    ensure!(workers >= 1, "need at least one worker");
    ensure!(opts.chunk >= 1, "chunk must be positive");
    let n = source.len();
    if workers == 1 || n < workers * 1024 {
        // Sequential path: exact, no chunk boundaries at all. The
        // pipelined variant overlaps staging with execution; the serial
        // one is the single-threaded oracle.
        if opts.pipeline {
            return simulate_range_pipelined(artifact, source, ctx_metrics);
        }
        let mut session = Session::load(artifact)?;
        return simulate_source(&mut session, source, ctx_metrics, None);
    }
    // Honor the requested parallelism on small-to-medium traces: shrink
    // the chunk so every worker gets at least one, rather than leaving
    // workers idle behind a fixed 64k grain.
    let chunk = opts.chunk.min(n.div_ceil(workers)).max(1);
    let chunks = n.div_ceil(chunk);
    let start_wall = Instant::now();
    let cursor = AtomicUsize::new(0);
    let results: Vec<Result<WorkerOut>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers.min(chunks) {
            let cursor = &cursor;
            handles.push(scope.spawn(move || -> Result<WorkerOut> {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    if opts.pipeline {
                        slice_worker_pipelined(
                            artifact,
                            source,
                            ctx_metrics,
                            cursor,
                            chunks,
                            chunk,
                            n,
                            opts.warmup,
                            w,
                        )
                    } else {
                        slice_worker_serial(
                            artifact,
                            source,
                            ctx_metrics,
                            cursor,
                            chunks,
                            chunk,
                            n,
                            opts.warmup,
                            w,
                        )
                    }
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("worker {w} panicked: {}", panic_message(p.as_ref())))
                });
                if r.is_err() {
                    // Fast-forward the cursor: siblings stop pulling
                    // chunks for a run that is already doomed.
                    cursor.fetch_max(chunks, Ordering::Relaxed);
                }
                r
            }));
        }
        handles.into_iter().map(join_worker).collect()
    });
    collect_workers(results, start_wall)
}

/// One serial worker of [`simulate_parallel_opts`] (the oracle path):
/// stage→execute on a single thread per chunk pulled off the cursor.
#[allow(clippy::too_many_arguments)]
fn slice_worker_serial<S: RecordSource + Sync + ?Sized>(
    artifact: &Path,
    source: &S,
    ctx_metrics: Option<&[f32]>,
    cursor: &AtomicUsize,
    chunks: usize,
    chunk: usize,
    n: usize,
    warmup: usize,
    w: usize,
) -> Result<WorkerOut> {
    let mut session =
        Session::load(artifact).with_context(|| format!("worker {w}: load {artifact:?}"))?;
    let mut scratch = ShardScratch::new(session.meta());
    let mut folded = PredAccum::default();
    let mut batches = 0u64;
    loop {
        let c = cursor.fetch_add(1, Ordering::Relaxed);
        if c >= chunks {
            break;
        }
        let start = c * chunk;
        let end = (start + chunk).min(n);
        let warm = warmup.min(start);
        let run = simulate_stream(
            &mut session,
            &mut scratch,
            source,
            start,
            end,
            warm,
            ctx_metrics,
            PredAccum::at_base(start as u64),
        )?;
        folded.merge(&run.accum);
        batches += run.batches;
    }
    Ok(WorkerOut { accum: folded, batches, stats: None })
}

/// One pipelined worker of [`simulate_parallel_opts`]: same chunk
/// cursor, same warm-up grid, but the model executes from the other
/// buffer set while this thread stages the next batch — and because
/// staging state lives on this side, the worker rolls straight into
/// chunk k+1 while chunk k's tail batches are still executing.
#[allow(clippy::too_many_arguments)]
fn slice_worker_pipelined<S: RecordSource + Sync + ?Sized>(
    artifact: &Path,
    source: &S,
    ctx_metrics: Option<&[f32]>,
    cursor: &AtomicUsize,
    chunks: usize,
    chunk: usize,
    n: usize,
    warmup: usize,
    w: usize,
) -> Result<WorkerOut> {
    let meta =
        ArtifactMeta::load(artifact).with_context(|| format!("worker {w}: load {artifact:?}"))?;
    let mut worker = PipelinedWorker::new(artifact, &meta);
    loop {
        let c = cursor.fetch_add(1, Ordering::Relaxed);
        if c >= chunks {
            break;
        }
        let start = c * chunk;
        let end = (start + chunk).min(n);
        let warm = warmup.min(start);
        run_shard_pipelined(
            &mut worker,
            source,
            start,
            end,
            warm,
            ctx_metrics,
            PredAccum::at_base(start as u64),
        )?;
    }
    let (accum, batches, stats) = worker.finish()?;
    Ok(WorkerOut { accum, batches, stats: Some(stats) })
}

// ---------------------------------------------------------------------
// Parallel streaming over a pull-based source
// ---------------------------------------------------------------------

/// Work item dispensed to a parallel worker: an owned chunk whose first
/// `warmup` rows replay the tail of the previous chunk (the exact
/// warm-up state handoff); absorbed rows start at global ordinal `base`.
struct ChunkItem {
    cols: TraceColumns,
    ctx: Vec<f32>,
    warmup: usize,
    base: usize,
}

/// Pull side of [`simulate_parallel_chunked`], driven by its bounded
/// dispatch thread: the puller walks the (forward-only) source, keeps
/// the last `warmup` rows of each dispensed item and prepends them to
/// the next, reproducing exactly the overlap grid of the random-access
/// [`simulate_parallel_opts`] — chunk `k`'s warm-up is
/// `min(warmup, k·chunk)` rows in both.
struct ChunkPuller<'a, C: ?Sized> {
    source: &'a mut C,
    warmup: usize,
    carry_cols: TraceColumns,
    carry_ctx: Vec<f32>,
    buf: ChunkBuf,
    base: usize,
    done: bool,
}

impl<'a, C: ChunkSource + ?Sized> ChunkPuller<'a, C> {
    fn new(source: &'a mut C, warmup: usize) -> ChunkPuller<'a, C> {
        ChunkPuller {
            source,
            warmup,
            carry_cols: TraceColumns::new(),
            carry_ctx: Vec::new(),
            buf: ChunkBuf::new(),
            base: 0,
            done: false,
        }
    }

    fn next(&mut self, chunk: usize) -> Result<Option<ChunkItem>> {
        if self.done {
            return Ok(None);
        }
        let n = match self.source.next_chunk(&mut self.buf, chunk) {
            Ok(n) => n,
            Err(e) => {
                self.done = true;
                return Err(e);
            }
        };
        if n == 0 {
            self.done = true;
            return Ok(None);
        }
        let keep = self.carry_cols.len();
        let mut cols = TraceColumns::with_capacity(keep + n);
        cols.extend_from(&self.carry_cols, 0, keep);
        cols.extend_from(&self.buf.cols, 0, n);
        let mut ctx = Vec::new();
        if self.buf.has_ctx() {
            ctx.reserve(self.carry_ctx.len() + n * CTX_WIDTH);
            ctx.extend_from_slice(&self.carry_ctx);
            ctx.extend_from_slice(&self.buf.ctx);
        }
        let item = ChunkItem {
            warmup: keep,
            base: self.base,
            cols,
            ctx,
        };
        self.base += n;
        let total = item.cols.len();
        let next_keep = self.warmup.min(total);
        self.carry_cols.clear();
        self.carry_cols.extend_from(&item.cols, total - next_keep, total);
        self.carry_ctx.clear();
        if !item.ctx.is_empty() {
            self.carry_ctx
                .extend_from_slice(&item.ctx[(total - next_keep) * CTX_WIDTH..]);
        }
        Ok(Some(item))
    }
}

/// Parallel streaming simulation over any pull-based [`ChunkSource`] —
/// a live simulator, a trace file, or an in-memory adapter. A bounded
/// dispatch thread owns the [`ChunkPuller`] and prefetches up to
/// `workers` warm-up-carrying chunk items ahead of the consumers, so
/// source I/O (file reads / functional-sim stepping) overlaps worker
/// staging *and* model execution; each item re-runs its carried
/// `opts.warmup`-row prefix with discarded predictions. When the source
/// reports a length hint, the chunk grid and small-stream sequential
/// fallback adapt exactly like [`simulate_parallel_opts`] — for
/// exact-hint sources (the in-memory adapters, trace files) the two
/// paths absorb byte-identical windows; hint-less sources use
/// `opts.chunk` verbatim. Peak resident trace is bounded by
/// (2·workers + 1) items of (chunk + warmup) rows regardless of stream
/// length — one per worker, up to `workers` queued in the dispatch
/// channel, one in dispatch limbo (`tao simulate --max-resident`
/// clamps the pull grain off exactly this accounting).
pub fn simulate_parallel_chunked<C>(
    artifact: &Path,
    source: &mut C,
    workers: usize,
    opts: ParallelOptions,
) -> Result<SimResult>
where
    C: ChunkSource + Send + ?Sized,
{
    ensure!(workers >= 1, "need at least one worker");
    ensure!(opts.chunk >= 1, "chunk must be positive");
    let mut chunk = opts.chunk;
    let mut sequential = workers == 1;
    if let Some(n) = source.len_hint() {
        if workers == 1 || n < workers * 1024 {
            sequential = true;
        } else {
            // Mirror the slice path's grid adaptation: shrink the chunk
            // so every worker gets at least one on small-to-medium
            // streams.
            chunk = opts.chunk.min(n.div_ceil(workers)).max(1);
        }
    }
    if sequential {
        // Sequential pull: state rolls across chunks, so the result is
        // exact regardless of the pull grain — same as the slice path's
        // sequential fallback.
        if opts.pipeline {
            return simulate_chunked_pipelined(artifact, source, chunk, None);
        }
        let mut session = Session::load(artifact)?;
        return simulate_chunked(&mut session, source, chunk, None);
    }
    let start_wall = Instant::now();
    let cancelled = AtomicBool::new(false);
    let (item_tx, item_rx) = sync_channel::<Result<ChunkItem>>(workers);
    let item_rx = Mutex::new(item_rx);
    let results: Vec<Result<WorkerOut>> = std::thread::scope(|scope| {
        // Dispatch thread: owns the (forward-only) puller, prefetching
        // items into the bounded channel. `try_send` + cancellation
        // polling keeps it from wedging the scope join if every worker
        // bails early.
        {
            let src = &mut *source;
            let cancelled = &cancelled;
            scope.spawn(move || {
                let mut puller = ChunkPuller::new(src, opts.warmup);
                loop {
                    // Fail fast: a worker error dooms the run, so stop
                    // paying source I/O for it (also checked while the
                    // channel is full, below).
                    if cancelled.load(Ordering::Relaxed) {
                        return;
                    }
                    let (mut msg, stop) = match puller.next(chunk) {
                        Ok(Some(item)) => (Ok(item), false),
                        Ok(None) => return,
                        Err(e) => (Err(e), true),
                    };
                    loop {
                        match item_tx.try_send(msg) {
                            Ok(()) => break,
                            Err(TrySendError::Full(m)) => {
                                if cancelled.load(Ordering::Relaxed) {
                                    return;
                                }
                                msg = m;
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(TrySendError::Disconnected(_)) => return,
                        }
                    }
                    if stop {
                        return;
                    }
                }
            });
        }
        let mut handles = Vec::new();
        for w in 0..workers {
            let item_rx = &item_rx;
            let cancelled = &cancelled;
            handles.push(scope.spawn(move || -> Result<WorkerOut> {
                // A worker panic must also set `cancelled`: the
                // dispatch thread's try_send loop only exits on the
                // flag (the receiver outlives the scope), so an
                // unobserved panic in every worker would leave it
                // spinning against a full channel forever.
                let r = catch_unwind(AssertUnwindSafe(|| {
                    if opts.pipeline {
                        chunked_worker_pipelined(artifact, item_rx, cancelled, w)
                    } else {
                        chunked_worker_serial(artifact, item_rx, cancelled, w)
                    }
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow!("worker {w} panicked: {}", panic_message(p.as_ref())))
                });
                if r.is_err() {
                    cancelled.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles.into_iter().map(join_worker).collect()
    });
    collect_workers(results, start_wall)
}

/// Take the next dispatched chunk item; `None` once the dispatch
/// thread has exhausted the source and closed the channel.
fn next_chunk_item(rx: &Mutex<Receiver<Result<ChunkItem>>>) -> Result<Option<ChunkItem>> {
    match relock(rx).recv() {
        Ok(Ok(item)) => Ok(Some(item)),
        Ok(Err(e)) => Err(e),
        Err(_) => Ok(None),
    }
}

/// One serial worker of [`simulate_parallel_chunked`] (oracle path).
fn chunked_worker_serial(
    artifact: &Path,
    items: &Mutex<Receiver<Result<ChunkItem>>>,
    cancelled: &AtomicBool,
    w: usize,
) -> Result<WorkerOut> {
    let mut session =
        Session::load(artifact).with_context(|| format!("worker {w}: load {artifact:?}"))?;
    let mut scratch = ShardScratch::new(session.meta());
    let mut folded = PredAccum::default();
    let mut batches = 0u64;
    while let Some(item) = next_chunk_item(items)? {
        if cancelled.load(Ordering::Relaxed) {
            // A sibling already failed the run; stop consuming so the
            // first typed error surfaces promptly.
            break;
        }
        let ctx = (!item.ctx.is_empty()).then_some(&item.ctx[..]);
        let run = simulate_stream(
            &mut session,
            &mut scratch,
            &item.cols,
            item.warmup,
            item.cols.len(),
            item.warmup,
            ctx,
            PredAccum::at_base(item.base as u64),
        )?;
        folded.merge(&run.accum);
        batches += run.batches;
    }
    Ok(WorkerOut { accum: folded, batches, stats: None })
}

/// One pipelined worker of [`simulate_parallel_chunked`]: same items,
/// same warm-up grid, staging overlapped with execution.
fn chunked_worker_pipelined(
    artifact: &Path,
    items: &Mutex<Receiver<Result<ChunkItem>>>,
    cancelled: &AtomicBool,
    w: usize,
) -> Result<WorkerOut> {
    let meta =
        ArtifactMeta::load(artifact).with_context(|| format!("worker {w}: load {artifact:?}"))?;
    let mut worker = PipelinedWorker::new(artifact, &meta);
    while let Some(item) = next_chunk_item(items)? {
        if cancelled.load(Ordering::Relaxed) {
            break;
        }
        let ctx = (!item.ctx.is_empty()).then_some(&item.ctx[..]);
        run_shard_pipelined(
            &mut worker,
            &item.cols,
            item.warmup,
            item.cols.len(),
            item.warmup,
            ctx,
            PredAccum::at_base(item.base as u64),
        )?;
    }
    let (accum, batches, stats) = worker.finish()?;
    Ok(WorkerOut { accum, batches, stats: Some(stats) })
}

// ---------------------------------------------------------------------
// Sampled simulation (phase-sampling replay)
// ---------------------------------------------------------------------

/// Result of a sampled run: the whole-trace estimate plus the row
/// accounting behind it.
#[derive(Debug)]
pub struct SampledOutcome {
    /// Whole-trace metrics reconstructed by weighted merge.
    pub result: SimResult,
    /// Representative rows actually absorbed.
    pub simulated_rows: u64,
    /// Warm-up rows re-run with discarded predictions.
    pub warmup_rows: u64,
    /// Rows of the full trace the estimate stands for.
    pub total_rows: u64,
}

/// One phase's absorbed row range within a run, tagged with its plan
/// slot so outputs route to the right accumulator.
struct PhaseSpan {
    start: u64,
    end: u64,
    slot: usize,
}

/// A maximal group of contiguous phases, streamed as one shard: the
/// extractor/window state rolls across the internal phase boundaries
/// (no cold restart between adjacent representatives), and only the
/// run's leading `warm` rows are re-run with discarded predictions.
struct RunDesc {
    /// First absorbed row.
    start: u64,
    /// One past the last absorbed row.
    end: u64,
    /// Warm-up rows re-run before `start` (clamped at trace start).
    warm: u64,
    /// The phases tiling `[start, end)`, in row order.
    spans: Vec<PhaseSpan>,
}

impl RunDesc {
    /// Plan slot owning `row`; requires `start <= row < end`.
    fn slot_of(&self, row: u64) -> usize {
        let k = self.spans.partition_point(|s| s.end <= row);
        debug_assert!(k < self.spans.len() && self.spans[k].start <= row);
        self.spans[k].slot
    }
}

/// Coalesce a plan's (sorted, disjoint) phases into runs. An exhaustive
/// weight-1 plan collapses to a single run over the whole trace with no
/// warm-up — exactly the [`simulate_chunked`] stream, which is what
/// makes that configuration the bit-identity oracle.
fn build_runs(phases: &[PhasePlan], warmup: usize) -> Vec<RunDesc> {
    let mut runs: Vec<RunDesc> = Vec::new();
    for (slot, p) in phases.iter().enumerate() {
        let span = PhaseSpan { start: p.start_row, end: p.end_row(), slot };
        match runs.last_mut() {
            Some(run) if run.end == span.start => {
                run.end = span.end;
                run.spans.push(span);
            }
            _ => runs.push(RunDesc {
                start: span.start,
                end: span.end,
                warm: (warmup as u64).min(span.start),
                spans: vec![span],
            }),
        }
    }
    runs
}

/// Route one batch's outputs to the per-phase accumulators: output row
/// `i` is global trace row `first_row + i`; rows before the run's
/// absorbed region are warm-up and are discarded. Shared verbatim by
/// the serial and pipelined sampled paths so their absorb order cannot
/// drift.
fn route_sampled_outputs(
    out: &ModelOutputs,
    kind: ModelKind,
    first_row: u64,
    run: &RunDesc,
    accums: &mut [PredAccum],
) {
    for i in 0..out.fetch.len() {
        let row = first_row + i as u64;
        if row < run.start {
            continue;
        }
        accums[run.slot_of(row)].absorb_one(out, kind, i);
    }
}

/// Routing tag for sampled batches: the global trace row of the batch's
/// first staged window and the run it belongs to (batches never span
/// runs — each run ends with its own partial flush).
struct SampledTag {
    first_row: u64,
    run: usize,
}

/// Pipelined worker for sampled replay: the same double-buffered
/// stage/execute as [`PipelinedWorker`], but completions route per
/// *row* into per-phase accumulators instead of folding whole batches
/// into one shard accumulator. Tao-only (sampled replay reads trace
/// files, which carry no SimNet context channel), so no ctx staging.
struct SampledWorker<'r> {
    pipe: ExecPipeline<SampledTag>,
    scratch: ShardScratch,
    kind: ModelKind,
    runs: &'r [RunDesc],
    accums: Vec<PredAccum>,
    batches: u64,
    /// Run currently being staged.
    cur_run: usize,
    /// Global trace row of the next staged-but-unsubmitted row.
    next_row: u64,
}

impl<'r> SampledWorker<'r> {
    fn new(
        artifact: &Path,
        meta: &ArtifactMeta,
        runs: &'r [RunDesc],
        accums: Vec<PredAccum>,
    ) -> SampledWorker<'r> {
        let path = artifact.to_path_buf();
        let pipe = spawn_exec_pipeline(
            move || Session::load(&path).with_context(|| format!("load {path:?}")),
            meta.kind,
            meta.batch,
            meta.context,
            meta.feature_dim,
            2,
        );
        SampledWorker {
            pipe,
            scratch: ShardScratch::new(meta),
            kind: meta.kind,
            runs,
            accums,
            batches: 0,
            cur_run: 0,
            next_row: 0,
        }
    }

    /// Open a run: fresh extractor/window state, staging cursor at the
    /// run's warm-up start.
    fn begin_run(&mut self, run: usize) {
        debug_assert_eq!(self.scratch.batcher.staged, 0, "run began mid-batch");
        self.scratch.reset();
        self.cur_run = run;
        self.next_row = self.runs[run].start - self.runs[run].warm;
    }

    fn absorb_msg(
        &mut self,
        msg: PipeMsg<ExecBuffers, ExecBatch<SampledTag>, ModelOutputs>,
    ) -> Result<ExecBuffers> {
        let (buf, payload, result) = match msg {
            PipeMsg::Done { buf, payload, result } => (buf, payload, result),
            PipeMsg::InitFailed { msg } => bail!("sampled executor: {msg}"),
        };
        let out = result.map_err(|e| anyhow!("sampled executor: {e}"))?;
        route_sampled_outputs(
            &out,
            self.kind,
            payload.tag.first_row,
            &self.runs[payload.tag.run],
            &mut self.accums,
        );
        Ok(buf)
    }

    fn acquire(&mut self) -> Result<ExecBuffers> {
        if let Some(buf) = self.pipe.take_buf() {
            return Ok(buf);
        }
        let msg = self.pipe.recv()?;
        self.absorb_msg(msg)
    }

    fn flush(&mut self) -> Result<()> {
        let staged = self.scratch.batcher.staged;
        if staged == 0 {
            return Ok(());
        }
        let mut bufs = self.acquire()?;
        self.scratch.batcher.materialize(&mut bufs.ops, &mut bufs.feats);
        self.scratch.batcher.clear_staged();
        let tag = SampledTag { first_row: self.next_row, run: self.cur_run };
        self.next_row += staged as u64;
        self.pipe.submit(bufs, ExecBatch { valid: staged, tag })?;
        self.batches += 1;
        Ok(())
    }

    fn stage(&mut self, rec: &FuncRecord) -> Result<()> {
        let row = self.scratch.batcher.begin_row();
        let opcode = self.scratch.fx.extract_into(rec, row);
        let full = self.scratch.batcher.commit_row(opcode);
        if full {
            self.flush()?;
        }
        Ok(())
    }

    fn finish(mut self) -> Result<(Vec<PredAccum>, u64, PipelineStats)> {
        self.flush()?;
        while self.pipe.in_flight() > 0 {
            let msg = self.pipe.recv()?;
            let buf = self.absorb_msg(msg)?;
            self.pipe.release(buf);
        }
        let stats = self.pipe.stats();
        self.pipe.shutdown();
        Ok((self.accums, self.batches, stats))
    }
}

/// Pull one run's rows (warm-up included) from a seekable trace source
/// and hand each record to `stage`.
fn stream_run(
    source: &mut dyn TraceSource,
    run: &RunDesc,
    chunk_grain: usize,
    buf: &mut ChunkBuf,
    mut stage: impl FnMut(&FuncRecord) -> Result<()>,
) -> Result<()> {
    source.seek_to_row(run.start - run.warm)?;
    let mut remaining = run.end - (run.start - run.warm);
    while remaining > 0 {
        let want = remaining.min(chunk_grain as u64) as usize;
        let n = source.next_chunk(buf, want)?;
        ensure!(
            n > 0,
            "trace ended inside sampled run rows [{}, {})",
            run.start,
            run.end
        );
        for i in 0..n {
            stage(&buf.cols.record(i))?;
        }
        remaining -= n as u64;
    }
    Ok(())
}

/// Per-worker output of a sampled run: the per-phase accumulators (only
/// the slots of this worker's runs are touched), batch count, and
/// occupancy stats for pipelined workers.
type SampledWorkerOut = (Vec<PredAccum>, u64, Option<PipelineStats>);

/// One serial sampled worker (the oracle path): stage→execute on a
/// single thread, routing each output row to its phase accumulator.
fn sampled_worker_serial(
    artifact: &Path,
    trace: &Path,
    runs: &[RunDesc],
    mine: &[usize],
    mut accums: Vec<PredAccum>,
    chunk_grain: usize,
    w: usize,
) -> Result<SampledWorkerOut> {
    let mut session =
        Session::load(artifact).with_context(|| format!("worker {w}: load {artifact:?}"))?;
    let kind = session.meta().kind;
    let mut scratch = ShardScratch::new(session.meta());
    let mut source =
        open_trace_source(trace).with_context(|| format!("worker {w}: open {trace:?}"))?;
    let mut batches = 0u64;
    let mut buf = ChunkBuf::new();
    for &r in mine {
        let run = &runs[r];
        scratch.reset();
        let mut next_row = run.start - run.warm;
        stream_run(source.as_mut(), run, chunk_grain, &mut buf, |rec| {
            let row = scratch.batcher.begin_row();
            let opcode = scratch.fx.extract_into(rec, row);
            if scratch.batcher.commit_row(opcode) {
                flush_sampled_serial(
                    &mut session,
                    &mut scratch,
                    kind,
                    &mut next_row,
                    run,
                    &mut accums,
                    &mut batches,
                )?;
            }
            Ok(())
        })?;
        flush_sampled_serial(
            &mut session,
            &mut scratch,
            kind,
            &mut next_row,
            run,
            &mut accums,
            &mut batches,
        )?;
    }
    Ok((accums, batches, None))
}

/// Serial twin of [`SampledWorker::flush`]: materialize, execute
/// inline, route. `next_row` is the global trace row of the first
/// staged row and advances past the flushed batch.
fn flush_sampled_serial(
    session: &mut Session,
    scratch: &mut ShardScratch,
    kind: ModelKind,
    next_row: &mut u64,
    run: &RunDesc,
    accums: &mut [PredAccum],
    batches: &mut u64,
) -> Result<()> {
    let staged = scratch.batcher.staged;
    if staged == 0 {
        return Ok(());
    }
    {
        let (ops_buf, feat_buf) = session.buffers();
        scratch.batcher.materialize(ops_buf, feat_buf);
    }
    let out = session.run(staged)?;
    route_sampled_outputs(&out, kind, *next_row, run, accums);
    *next_row += staged as u64;
    scratch.batcher.clear_staged();
    *batches += 1;
    Ok(())
}

/// One pipelined sampled worker: same runs, same flush grid, staging
/// overlapped with execution through the [`ExecPipeline`].
fn sampled_worker_pipelined(
    artifact: &Path,
    meta: &ArtifactMeta,
    trace: &Path,
    runs: &[RunDesc],
    mine: &[usize],
    accums: Vec<PredAccum>,
    chunk_grain: usize,
    w: usize,
) -> Result<SampledWorkerOut> {
    let mut source =
        open_trace_source(trace).with_context(|| format!("worker {w}: open {trace:?}"))?;
    let mut worker = SampledWorker::new(artifact, meta, runs, accums);
    let mut buf = ChunkBuf::new();
    for &r in mine {
        worker.begin_run(r);
        stream_run(source.as_mut(), &runs[r], chunk_grain, &mut buf, |rec| {
            worker.stage(rec)
        })?;
        worker.flush()?;
    }
    let (accums, batches, stats) = worker.finish()?;
    Ok((accums, batches, Some(stats)))
}

/// Simulate only a plan's representative slices and weight-merge their
/// accumulators into whole-trace metrics.
///
/// Contiguous phases coalesce into runs ([`build_runs`]); each run
/// seeks to its warm-up start ([`TraceSource::seek_to_row`] — offset
/// math for v1, the chunk-offset index footer or a header scan for
/// v2), re-runs `opts.warmup` preceding rows with discarded
/// predictions, and streams its phases with state rolling across the
/// internal boundaries. Runs are strided across up to `workers`
/// pipelined workers, each with its own trace handle and PJRT session;
/// run staging is self-contained (reset at run start, flush at run
/// end), so the per-phase accumulators are identical whatever the
/// worker assignment — sampled results are deterministic and
/// independent of `workers`, and the exhaustive weight-1 plan
/// reproduces [`simulate_chunked`] bit-for-bit (the oracle test).
///
/// Tao artifacts only: trace files carry no per-instruction context
/// channel, so a SimNet artifact cannot be replayed from a bare trace.
pub fn simulate_sampled(
    artifact: &Path,
    trace: &Path,
    plan: &SamplingPlan,
    workers: usize,
    opts: ParallelOptions,
) -> Result<SampledOutcome> {
    ensure!(workers >= 1, "need at least one worker");
    ensure!(opts.chunk >= 1, "chunk must be positive");
    ensure!(!plan.phases.is_empty(), "sampling plan has no phases");
    let meta = ArtifactMeta::load(artifact).with_context(|| format!("load {artifact:?}"))?;
    ensure!(
        meta.kind == ModelKind::Tao,
        "sampled replay requires a Tao artifact: trace files carry no SimNet context metrics"
    );
    let (_, name, records) = trace_header(trace)?;
    plan.check_matches(&name, records)?;
    let runs = build_runs(&plan.phases, opts.warmup);
    let simulated_rows = plan.simulated_rows();
    let warmup_rows: u64 = runs.iter().map(|r| r.warm).sum();
    let accums: Vec<PredAccum> =
        plan.phases.iter().map(|p| PredAccum::at_base(p.start_row)).collect();
    let start_wall = Instant::now();
    let nworkers = workers.min(runs.len());
    let (accums, batches, stats) = if nworkers == 1 || (simulated_rows as usize) < nworkers * 1024
    {
        let all: Vec<usize> = (0..runs.len()).collect();
        if opts.pipeline {
            sampled_worker_pipelined(artifact, &meta, trace, &runs, &all, accums, opts.chunk, 0)?
        } else {
            sampled_worker_serial(artifact, trace, &runs, &all, accums, opts.chunk, 0)?
        }
    } else {
        let results: Vec<Result<SampledWorkerOut>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for w in 0..nworkers {
                let mine: Vec<usize> = (w..runs.len()).step_by(nworkers).collect();
                let accums = accums.clone();
                let runs = &runs;
                let meta = &meta;
                handles.push(scope.spawn(move || -> Result<SampledWorkerOut> {
                    catch_unwind(AssertUnwindSafe(|| {
                        if opts.pipeline {
                            sampled_worker_pipelined(
                                artifact, meta, trace, runs, &mine, accums, opts.chunk, w,
                            )
                        } else {
                            sampled_worker_serial(
                                artifact, trace, runs, &mine, accums, opts.chunk, w,
                            )
                        }
                    }))
                    .unwrap_or_else(|p| {
                        Err(anyhow!("worker {w} panicked: {}", panic_message(p.as_ref())))
                    })
                }));
            }
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|p| {
                        Err(anyhow!("worker panicked: {}", panic_message(p.as_ref())))
                    })
                })
                .collect()
        });
        let mut outs = Vec::with_capacity(nworkers);
        for r in results {
            outs.push(r?);
        }
        // Stitch: each phase's accumulator comes from the worker whose
        // stride owns its run; sum batches and occupancy across workers.
        let mut slot_owner = vec![0usize; plan.phases.len()];
        for (r, run) in runs.iter().enumerate() {
            for s in &run.spans {
                slot_owner[s.slot] = r % nworkers;
            }
        }
        let mut merged: Vec<PredAccum> =
            plan.phases.iter().map(|p| PredAccum::at_base(p.start_row)).collect();
        for (slot, &own) in slot_owner.iter().enumerate() {
            merged[slot] = outs[own].0[slot].clone();
        }
        let mut batches = 0u64;
        let mut stats: Option<PipelineStats> = None;
        for (_, b, s) in &outs {
            batches += b;
            if let Some(s) = s {
                stats.get_or_insert_with(PipelineStats::default).absorb(s);
            }
        }
        (merged, batches, stats)
    };
    let mut total = PredAccum::default();
    for (slot, phase) in plan.phases.iter().enumerate() {
        total.merge_weighted(&accums[slot], phase.weight);
    }
    Ok(SampledOutcome {
        result: SimResult {
            metrics: total.metrics(),
            elapsed: start_wall.elapsed(),
            batches,
            phase: None,
            pipeline: stats,
        },
        simulated_rows,
        warmup_rows,
        total_rows: plan.total_rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;
    use crate::trace::SliceChunkSource;
    use std::path::PathBuf;

    // --- window batcher ---

    #[test]
    fn window_batcher_stages_and_flags_full() {
        let (t, f, batch) = (4, 2, 3);
        let mut b = WindowBatcher::new(t, f, batch);
        let mut ops = vec![0i32; batch * t];
        let mut feats = vec![0.0f32; batch * t * f];
        assert!(!b.push(1, &[0.1, 0.2]));
        assert!(!b.push(2, &[0.3, 0.4]));
        assert!(b.push(3, &[0.5, 0.6]));
        assert_eq!(b.materialize(&mut ops, &mut feats), 3);
        // Window 0 (after 1 push): warm-up repeats opcode 1 everywhere.
        assert_eq!(&ops[0..4], &[1, 1, 1, 1]);
        // Window 2: [1,1,2,3] — newest last.
        assert_eq!(&ops[8..12], &[1, 1, 2, 3]);
        // Newest row's features land at the end of window 2.
        assert_eq!(&feats[(8 + 3) * f..(8 + 4) * f], &[0.5, 0.6]);
        // Warm-up padding rows carry the first row's features.
        assert_eq!(&feats[0..f], &[0.1, 0.2]);
    }

    #[test]
    fn window_batcher_slides_beyond_t() {
        let (t, f) = (3, 1);
        let mut b = WindowBatcher::new(t, f, 8);
        let mut ops = vec![0i32; 8 * t];
        let mut feats = vec![0.0f32; 8 * t];
        for i in 0..5 {
            b.push(i as i32 + 1, &[i as f32]);
        }
        b.materialize(&mut ops, &mut feats);
        // Window 4 = [3,4,5].
        assert_eq!(&ops[4 * t..5 * t], &[3, 4, 5]);
        assert_eq!(&feats[4 * t..5 * t], &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn window_batcher_warmup_padding_matches_naive() {
        // Fewer rows than T: every window is mostly padding.
        check_batcher_equivalence(8, 4, 4, 3, 0xA1);
    }

    #[test]
    fn window_batcher_wraparound_beyond_t_matches_naive() {
        // More pushes than T, spanning several flushes, T > batch and
        // T < batch both exercised.
        check_batcher_equivalence(4, 3, 16, 50, 0xB2);
        check_batcher_equivalence(16, 3, 4, 50, 0xB2);
        check_batcher_equivalence(1, 3, 5, 50, 0xB2);
    }

    #[test]
    fn window_batcher_equivalent_on_random_trace() {
        check_batcher_equivalence(12, 6, 32, 2_000, 0xC3);
    }

    #[test]
    fn window_batcher_reset_restarts_warmup() {
        let (t, f, batch) = (3, 1, 4);
        let mut b = WindowBatcher::new(t, f, batch);
        let mut ops = vec![0i32; batch * t];
        let mut feats = vec![0.0f32; batch * t];
        b.push(1, &[1.0]);
        b.push(2, &[2.0]);
        b.reset();
        b.push(9, &[9.0]);
        b.materialize(&mut ops, &mut feats);
        // Warm-up padding re-seeded from the new first row.
        assert_eq!(&ops[0..t], &[9, 9, 9]);
        assert_eq!(&feats[0..t], &[9.0, 9.0, 9.0]);
    }

    // --- ctx batcher ---

    /// The seed's per-instruction ctx staging: gather instruction `i`'s
    /// T-row context window (repeat-pad clamped at `base`), masking the
    /// newest row. The oracle [`CtxBatcher`] must reproduce byte for
    /// byte.
    fn stage_ctx_naive(ctx: &[f32], base: usize, i: usize, w: usize, t: usize, out: &mut [f32]) {
        for j in 0..t {
            let src = i.saturating_sub(t - 1 - j).max(base);
            let dst = &mut out[(w * t + j) * CTX_WIDTH..(w * t + j + 1) * CTX_WIDTH];
            if j + 1 == t {
                dst.fill(0.0);
            } else {
                dst.copy_from_slice(&ctx[src * CTX_WIDTH..(src + 1) * CTX_WIDTH]);
            }
        }
    }

    fn check_ctx_batcher_equivalence(t: usize, batch: usize, base: usize, end: usize, seed: u64) {
        let mut rng = crate::util::Rng::new(seed);
        let ctx: Vec<f32> = (0..end * CTX_WIDTH)
            .map(|_| rng.index(1000) as f32 / 1000.0)
            .collect();
        let mut fast = CtxBatcher::new(t, batch);
        let mut naive_buf = vec![0.0f32; batch * t * CTX_WIDTH];
        let mut fast_buf = vec![0.0f32; batch * t * CTX_WIDTH];
        let mut w = 0usize;
        for i in base..end {
            fast.push(&ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
            stage_ctx_naive(&ctx, base, i, w, t, &mut naive_buf);
            w += 1;
            if w == batch || i + 1 == end {
                fast.materialize(&mut fast_buf);
                assert_eq!(
                    &fast_buf[..w * t * CTX_WIDTH],
                    &naive_buf[..w * t * CTX_WIDTH],
                    "ctx staging diverged at flush ending {i} (t={t} batch={batch} base={base})"
                );
                fast.clear_staged();
                w = 0;
            }
        }
    }

    #[test]
    fn ctx_batcher_matches_naive_staging() {
        check_ctx_batcher_equivalence(8, 4, 0, 100, 0xC0);
        check_ctx_batcher_equivalence(4, 16, 0, 50, 0xC1);
        // Shard warm-up region starting past the trace head.
        check_ctx_batcher_equivalence(16, 3, 5, 40, 0xC2);
        // T = 1: every window is just its own masked row.
        check_ctx_batcher_equivalence(1, 5, 0, 23, 0xC3);
        check_ctx_batcher_equivalence(12, 32, 100, 2_000, 0xC4);
    }

    #[test]
    fn ctx_batcher_reset_restarts_warmup() {
        let mut b = CtxBatcher::new(3, 4);
        b.push(&[1.0; CTX_WIDTH]);
        b.push(&[2.0; CTX_WIDTH]);
        b.reset();
        b.push(&[9.0; CTX_WIDTH]);
        let mut buf = vec![0.0f32; 4 * 3 * CTX_WIDTH];
        b.materialize(&mut buf);
        // Warm-up pad rows re-seeded from the new first row; the
        // window's own (newest) row is masked to zero.
        assert_eq!(&buf[..CTX_WIDTH], &[9.0; CTX_WIDTH]);
        assert_eq!(&buf[CTX_WIDTH..2 * CTX_WIDTH], &[9.0; CTX_WIDTH]);
        assert_eq!(&buf[2 * CTX_WIDTH..3 * CTX_WIDTH], &[0.0; CTX_WIDTH]);
    }

    // --- accumulators ---

    #[test]
    fn pred_accum_totals() {
        let mut a = PredAccum::default();
        let out = ModelOutputs {
            fetch: vec![1.0, 2.0],
            exec: vec![5.0, 7.0],
            branch: vec![0.25, 0.75],
            access: vec![
                0.7, 0.2, 0.05, 0.05, // mostly none
                0.0, 0.1, 0.4, 0.5, // mostly miss
            ],
            icache: vec![0.0, 1.0],
            tlb: vec![0.5, 0.5],
        };
        a.absorb(&out, ModelKind::Tao);
        assert_eq!(a.instructions, 2);
        assert_eq!(a.last_exec_at, 2);
        assert!((a.total_cycles() - (3.0 + 7.0)).abs() < 1e-9);
        assert!((a.mispredicts - 1.0).abs() < 1e-9);
        assert!((a.l1d_misses - (0.1 + 0.9)).abs() < 1e-6);
        let m = a.metrics();
        assert!((m.branch_mpki() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn pred_accum_absorb_range_skips_warmup_rows() {
        let out = ModelOutputs {
            fetch: vec![10.0, 1.0, 2.0],
            exec: vec![99.0, 5.0, 7.0],
            branch: vec![1.0, 0.0, 0.0],
            access: vec![0.0; 12],
            icache: vec![0.0; 3],
            tlb: vec![0.0; 3],
        };
        let mut a = PredAccum::at_base(100);
        a.absorb_range(&out, ModelKind::Tao, 1);
        assert_eq!(a.instructions, 2);
        assert!((a.fetch_cycles - 3.0).abs() < 1e-12);
        assert!((a.last_exec - 7.0).abs() < 1e-12);
        assert_eq!(a.last_exec_at, 102);
        assert!((a.mispredicts - 0.0).abs() < 1e-12);
    }

    #[test]
    fn pred_accum_journal_codec_round_trips_bit_exactly() {
        let mut a = PredAccum::at_base(4_096);
        let out = ModelOutputs {
            fetch: vec![1.5, 2.25, 0.125],
            exec: vec![5.0, 7.75, 3.5],
            branch: vec![0.25, 0.75, 1.0 / 3.0],
            access: vec![0.7, 0.2, 0.05, 0.05, 0.0, 0.1, 0.4, 0.5, 0.25, 0.25, 0.25, 0.25],
            icache: vec![0.0, 1.0, 0.5],
            tlb: vec![0.5, 0.5, 0.1],
        };
        a.absorb(&out, ModelKind::Tao);
        let mut bytes = Vec::new();
        a.encode_journal(&mut bytes);
        assert_eq!(bytes.len(), PredAccum::JOURNAL_BYTES);
        let back = PredAccum::decode_journal(&bytes).unwrap();
        // Every visible scalar round-trips to the bit, so a recovered
        // cache entry folds exactly like the original did.
        assert_eq!(back.instructions, a.instructions);
        assert_eq!(back.fetch_cycles.to_bits(), a.fetch_cycles.to_bits());
        assert_eq!(back.last_exec.to_bits(), a.last_exec.to_bits());
        assert_eq!(back.last_exec_at, a.last_exec_at);
        assert_eq!(back.mispredicts.to_bits(), a.mispredicts.to_bits());
        assert_eq!(back.l1d_misses.to_bits(), a.l1d_misses.to_bits());
        assert_eq!(back.l1i_misses.to_bits(), a.l1i_misses.to_bits());
        assert_eq!(back.tlb_misses.to_bits(), a.tlb_misses.to_bits());
        // Folding the decoded delta mid-stream matches folding the
        // original (the serving cache's replay pattern).
        let mut via_orig = PredAccum::at_base(4_096);
        via_orig.merge(&a);
        let mut via_back = PredAccum::at_base(4_096);
        via_back.merge(&back);
        assert_eq!(via_orig.metrics().cycles.to_bits(), via_back.metrics().cycles.to_bits());
        assert_eq!(via_orig.ordinal, via_back.ordinal);
        // Wrong-length records are rejected.
        assert!(PredAccum::decode_journal(&bytes[..63]).is_err());
    }

    #[test]
    fn pred_accum_merge_takes_latest_tail() {
        let mut a = PredAccum {
            instructions: 10,
            fetch_cycles: 20.0,
            last_exec: 3.0,
            last_exec_at: 10,
            ..Default::default()
        };
        let b = PredAccum {
            instructions: 5,
            fetch_cycles: 10.0,
            last_exec: 9.0,
            last_exec_at: 15,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.instructions, 15);
        assert!((a.total_cycles() - 39.0).abs() < 1e-9);

        // Merging the *earlier* shard into the later one keeps the later
        // tail — the fold is order-independent.
        let mut c = PredAccum {
            instructions: 5,
            fetch_cycles: 10.0,
            last_exec: 9.0,
            last_exec_at: 15,
            ..Default::default()
        };
        let d = PredAccum {
            instructions: 10,
            fetch_cycles: 20.0,
            last_exec: 3.0,
            last_exec_at: 10,
            ..Default::default()
        };
        c.merge(&d);
        assert!((c.total_cycles() - 39.0).abs() < 1e-9);
    }

    #[test]
    fn shard_merge_is_associative_and_commutative() {
        // Integer-valued doubles make every fold order exactly equal, so
        // this checks the merge *logic* (tail selection, sums) under all
        // orders of a 4-shard fold.
        let shard = |base: u64, n: u64| {
            let mut a = PredAccum::at_base(base);
            let out = ModelOutputs {
                fetch: (0..n).map(|i| (i % 7) as f32 + 1.0).collect(),
                exec: (0..n).map(|i| (i % 5) as f32 + 2.0).collect(),
                branch: (0..n).map(|i| (i % 2) as f32).collect(),
                access: (0..n).flat_map(|i| [0.0, 0.0, (i % 3) as f32, 1.0]).collect(),
                icache: vec![0.0; n as usize],
                tlb: vec![1.0; n as usize],
            };
            a.absorb(&out, ModelKind::Tao);
            a
        };
        let shards = [shard(0, 16), shard(16, 16), shard(32, 16), shard(48, 7)];
        let fold = |order: &[usize]| {
            let mut acc = PredAccum::default();
            for &i in order {
                acc.merge(&shards[i]);
            }
            acc.metrics()
        };
        let reference = fold(&[0, 1, 2, 3]);
        for order in [
            [3, 2, 1, 0],
            [1, 3, 0, 2],
            [2, 0, 3, 1],
            [0, 2, 1, 3],
        ] {
            let m = fold(&order);
            assert_eq!(m.instructions, reference.instructions);
            assert_eq!(m.cycles, reference.cycles, "fold order {order:?}");
            assert_eq!(m.mispredicts, reference.mispredicts);
            assert_eq!(m.l1d_misses, reference.l1d_misses);
            assert_eq!(m.l1i_misses, reference.l1i_misses);
            assert_eq!(m.tlb_misses, reference.tlb_misses);
        }
        // Pairwise pre-folds (tree fold) also match the linear fold.
        let mut left = PredAccum::default();
        left.merge(&shards[0]);
        left.merge(&shards[1]);
        let mut right = PredAccum::default();
        right.merge(&shards[2]);
        right.merge(&shards[3]);
        let mut tree = PredAccum::default();
        tree.merge(&right);
        tree.merge(&left);
        assert_eq!(tree.metrics().cycles, reference.cycles);
        assert_eq!(tree.metrics().instructions, reference.instructions);
    }

    // --- end-to-end through the surrogate PJRT runtime ---

    fn fake_artifact(name: &str, batch: usize, context: usize) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tao-engine-{}", std::process::id()));
        crate::runtime::write_surrogate_artifact(&dir, name, batch, context).unwrap()
    }

    /// A trace with no branch/memory state: features are identical from
    /// the second instruction on, so chunked streaming with any warm-up
    /// ≥ 1 must reproduce the sequential run exactly.
    fn uniform_records(n: usize) -> Vec<FuncRecord> {
        (0..n)
            .map(|_| FuncRecord {
                pc: 0x400000,
                opcode: Opcode::Add,
                reg_bitmap: 0b11,
                mem_addr: 0,
                mem_bytes: 0,
                taken: false,
            })
            .collect()
    }

    #[test]
    fn simulate_records_counts_every_instruction() {
        let artifact = fake_artifact("count", 16, 8);
        let mut session = Session::load(&artifact).unwrap();
        let records = uniform_records(1000);
        let r = simulate_records(&mut session, &records, None, None).unwrap();
        assert_eq!(r.metrics.instructions, 1000);
        assert!(r.metrics.cpi().is_finite() && r.metrics.cpi() > 0.0);
        // 1000 instructions / batch 16 = 62.5 -> 63 flushes.
        assert_eq!(r.batches, 63);
    }

    #[test]
    fn columns_and_records_paths_agree() {
        let artifact = fake_artifact("cols", 8, 4);
        let p = crate::workloads::by_name("dee").unwrap().build(7);
        let trace = crate::functional::FunctionalSim::new(&p).run(3_000);
        let cols = trace.to_columns();
        let mut s1 = Session::load(&artifact).unwrap();
        let r1 = simulate_records(&mut s1, &trace.records, None, None).unwrap();
        let mut s2 = Session::load(&artifact).unwrap();
        let r2 = simulate_columns(&mut s2, &cols, None, None).unwrap();
        assert_eq!(r1.metrics.instructions, r2.metrics.instructions);
        assert_eq!(r1.metrics.cycles, r2.metrics.cycles);
        assert_eq!(r1.metrics.mispredicts, r2.metrics.mispredicts);
        assert_eq!(r1.batches, r2.batches);
        // A full-range ColumnsSlice view feeds the engine identically.
        let mut s3 = Session::load(&artifact).unwrap();
        let r3 = simulate_source(&mut s3, &cols.slice(0, cols.len()), None, None).unwrap();
        assert_eq!(r1.metrics.cycles, r3.metrics.cycles);
        assert_eq!(r1.metrics.instructions, r3.metrics.instructions);
    }

    #[test]
    fn chunked_parallel_matches_sequential_on_uniform_trace() {
        let artifact = fake_artifact("chunked", 16, 8);
        let records = uniform_records(20_000);
        let mut session = Session::load(&artifact).unwrap();
        let seq = simulate_records(&mut session, &records, None, None).unwrap();
        for workers in [2, 4] {
            let par = simulate_parallel_opts(
                &artifact,
                &records[..],
                workers,
                None,
                ParallelOptions {
                    chunk: 3_000,
                    warmup: 64,
                    pipeline: true,
                },
            )
            .unwrap();
            assert_eq!(par.metrics.instructions, seq.metrics.instructions);
            // Uniform trace + warm-up overlap => every absorbed window is
            // byte-identical to the sequential run's, so the totals are
            // exactly equal (f32 inputs sum exactly in f64 at this scale).
            assert_eq!(par.metrics.cycles, seq.metrics.cycles, "workers={workers}");
            assert_eq!(par.metrics.mispredicts, seq.metrics.mispredicts);
        }
    }

    #[test]
    fn chunked_parallel_real_trace_sane_and_deterministic() {
        let artifact = fake_artifact("real", 16, 8);
        let p = crate::workloads::by_name("mcf").unwrap().build(42);
        let trace = crate::functional::FunctionalSim::new(&p).run(12_000);
        let opts = ParallelOptions {
            chunk: 2_048,
            warmup: 512,
            pipeline: true,
        };
        let a = simulate_parallel_opts(&artifact, &trace.records[..], 3, None, opts).unwrap();
        let b = simulate_parallel_opts(&artifact, &trace.records[..], 3, None, opts).unwrap();
        assert_eq!(a.metrics.instructions, 12_000);
        assert!(a.metrics.cpi().is_finite() && a.metrics.cpi() > 0.0);
        // Work-queue scheduling order must not affect the result.
        assert_eq!(a.metrics.cycles, b.metrics.cycles);
        assert_eq!(a.metrics.mispredicts, b.metrics.mispredicts);
    }

    #[test]
    fn chunked_pull_matches_resident_source() {
        let artifact = fake_artifact("chunkeq", 8, 4);
        let p = crate::workloads::by_name("mcf").unwrap().build(5);
        let trace = crate::functional::FunctionalSim::new(&p).run(5_000);
        let cols = trace.to_columns();
        let mut s1 = Session::load(&artifact).unwrap();
        let r1 = simulate_columns(&mut s1, &cols, None, None).unwrap();
        // Odd-sized pulls over the same records: state rolls across the
        // chunk boundaries, so nothing changes.
        let mut s2 = Session::load(&artifact).unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let r2 = simulate_chunked(&mut s2, &mut src, 257, None).unwrap();
        assert_eq!(r1.metrics.instructions, r2.metrics.instructions);
        assert_eq!(r1.metrics.cycles, r2.metrics.cycles);
        assert_eq!(r1.metrics.mispredicts, r2.metrics.mispredicts);
        assert_eq!(r1.batches, r2.batches);
        // A generator-backed source commits the same stream, so the
        // metrics match without the trace ever being resident.
        let mut s3 = Session::load(&artifact).unwrap();
        let mut generated = crate::functional::FunctionalSim::new(&p).into_chunks(5_000);
        let r3 = simulate_chunked(&mut s3, &mut generated, 1_024, None).unwrap();
        assert_eq!(r1.metrics.cycles, r3.metrics.cycles);
        assert_eq!(r1.metrics.instructions, r3.metrics.instructions);
        assert_eq!(r1.batches, r3.batches);
    }

    #[test]
    fn parallel_chunked_matches_parallel_slices() {
        let artifact = fake_artifact("parchunk", 16, 8);
        let p = crate::workloads::by_name("dee").unwrap().build(11);
        let trace = crate::functional::FunctionalSim::new(&p).run(20_000);
        let opts = ParallelOptions {
            chunk: 2_048,
            warmup: 512,
            pipeline: true,
        };
        let by_slice =
            simulate_parallel_opts(&artifact, &trace.records[..], 3, None, opts).unwrap();
        let cols = trace.to_columns();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let by_pull = simulate_parallel_chunked(&artifact, &mut src, 3, opts).unwrap();
        assert_eq!(by_pull.metrics.instructions, by_slice.metrics.instructions);
        // Same chunk grid + warm-up overlap => identical absorbed
        // windows; the f32 outputs sum exactly in f64 at this scale, so
        // the totals are equal across any fold order.
        assert_eq!(by_pull.metrics.cycles, by_slice.metrics.cycles);
        assert_eq!(by_pull.metrics.mispredicts, by_slice.metrics.mispredicts);
        assert_eq!(by_pull.batches, by_slice.batches);

        // Default opts: chunk (64k) exceeds n/workers, so the slice path
        // shrinks its grid — the pull path must adapt identically off
        // the length hint.
        let defaults = ParallelOptions::default();
        let by_slice =
            simulate_parallel_opts(&artifact, &trace.records[..], 3, None, defaults).unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let by_pull = simulate_parallel_chunked(&artifact, &mut src, 3, defaults).unwrap();
        assert_eq!(by_pull.metrics.cycles, by_slice.metrics.cycles);
        assert_eq!(by_pull.batches, by_slice.batches);
    }

    #[test]
    fn parallel_chunked_single_worker_is_sequential_pull() {
        let artifact = fake_artifact("parone", 8, 4);
        let records = uniform_records(4_000);
        let mut session = Session::load(&artifact).unwrap();
        let seq = simulate_records(&mut session, &records, None, None).unwrap();
        let cols = TraceColumns::from_records(&records);
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let one = simulate_parallel_chunked(
            &artifact,
            &mut src,
            1,
            ParallelOptions {
                chunk: 777,
                warmup: 64,
                pipeline: true,
            },
        )
        .unwrap();
        assert_eq!(one.metrics.instructions, seq.metrics.instructions);
        assert_eq!(one.metrics.cycles, seq.metrics.cycles);
        assert_eq!(one.batches, seq.batches);
    }

    // --- window-level stager (cross-job packing surface) ---

    fn stager_meta(kind: ModelKind, batch: usize, context: usize) -> ArtifactMeta {
        let fc = crate::features::FeatureConfig::default();
        ArtifactMeta {
            kind,
            batch,
            context,
            feature_dim: fc.feature_dim(),
            num_opcodes: crate::isa::Opcode::COUNT,
            features: fc,
            outputs: vec![],
            vocab_hash: "test".into(),
            kernel: "test".into(),
        }
    }

    fn sample_records(n: u64, seed: u64) -> Vec<FuncRecord> {
        let p = crate::workloads::by_name("mcf").unwrap().build(seed);
        crate::functional::FunctionalSim::new(&p).run(n).records
    }

    #[test]
    fn window_stager_bytes_match_batch_staging() {
        let (b, t) = (16, 8);
        let meta = stager_meta(ModelKind::Tao, b, t);
        let f = meta.feature_dim;
        let records = sample_records(1_000, 9);

        // Reference: the whole-batch staging path.
        let mut fx = FeatureExtractor::new(meta.features);
        let mut batcher = WindowBatcher::new(t, f, b);
        let mut ref_ops = vec![0i32; b * t];
        let mut ref_feats = vec![0.0f32; b * t * f];

        // Stager: windows packed one slot at a time.
        let mut stager = WindowStager::new(&meta);
        let mut ops = vec![0i32; b * t];
        let mut feats = vec![0.0f32; b * t * f];
        let mut slot = 0usize;

        for (i, rec) in records.iter().enumerate() {
            let row = batcher.begin_row();
            let opcode = fx.extract_into(rec, row);
            let full = batcher.commit_row(opcode);
            stager.stage_window(
                rec,
                None,
                &mut ops[slot * t..(slot + 1) * t],
                &mut feats[slot * t * f..(slot + 1) * t * f],
                None,
            );
            slot += 1;
            if full || i + 1 == records.len() {
                let valid = batcher.materialize(&mut ref_ops, &mut ref_feats);
                assert_eq!(valid, slot, "staged count at record {i}");
                assert_eq!(ref_ops[..valid * t], ops[..valid * t], "opcodes at {i}");
                assert_eq!(
                    ref_feats[..valid * t * f],
                    feats[..valid * t * f],
                    "features at {i}"
                );
                batcher.clear_staged();
                slot = 0;
            }
        }
    }

    #[test]
    fn window_stager_fast_forward_is_exact() {
        let t = 8;
        let meta = stager_meta(ModelKind::Tao, 4, t);
        let f = meta.feature_dim;
        let records = sample_records(600, 3);

        // Reference: stage every record, keep every window.
        let mut full = WindowStager::new(&meta);
        let mut full_windows = Vec::new();
        for rec in &records {
            let mut ops = vec![0i32; t];
            let mut feats = vec![0.0f32; t * f];
            full.stage_window(rec, None, &mut ops, &mut feats, None);
            full_windows.push((ops, feats));
        }

        // Fast-forward path: skip the first k records the way a cache
        // hit does (advance-only, then roll the last T-1), then stage
        // the rest and compare windows byte for byte.
        for k in [0usize, 3, t - 1, t, 57, 300] {
            let mut ff = WindowStager::new(&meta);
            let hist = ff.history_rows();
            for (i, rec) in records[..k].iter().enumerate() {
                if i + hist < k {
                    ff.advance_only(rec);
                } else {
                    ff.roll_only(rec, None);
                }
            }
            for (i, rec) in records.iter().enumerate().skip(k) {
                let mut ops = vec![0i32; t];
                let mut feats = vec![0.0f32; t * f];
                ff.stage_window(rec, None, &mut ops, &mut feats, None);
                assert_eq!(full_windows[i].0, ops, "ops window {i} after skip {k}");
                assert_eq!(full_windows[i].1, feats, "feat window {i} after skip {k}");
            }
        }
    }

    #[test]
    fn window_stager_stages_simnet_ctx_with_mask() {
        let (b, t) = (4, 6);
        let meta = stager_meta(ModelKind::SimNet, b, t);
        let f = meta.feature_dim;
        let records = sample_records(100, 5);
        let ctx: Vec<f32> = (0..records.len() * CTX_WIDTH).map(|i| i as f32 * 0.5).collect();

        // Reference ctx staging: the whole-batch CtxBatcher.
        let mut ref_ctx = CtxBatcher::new(t, b);
        let mut ref_buf = vec![0.0f32; b * t * CTX_WIDTH];

        let mut stager = WindowStager::new(&meta);
        let mut ops = vec![0i32; t];
        let mut feats = vec![0.0f32; t * f];
        let mut got = vec![0.0f32; b * t * CTX_WIDTH];
        let mut slot = 0usize;
        for (i, rec) in records.iter().enumerate() {
            let row = &ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH];
            ref_ctx.push(row);
            let dst = &mut got[slot * t * CTX_WIDTH..(slot + 1) * t * CTX_WIDTH];
            stager.stage_window(rec, Some(row), &mut ops, &mut feats, Some(dst));
            slot += 1;
            if slot == b || i + 1 == records.len() {
                ref_ctx.materialize(&mut ref_buf);
                assert_eq!(
                    ref_buf[..slot * t * CTX_WIDTH],
                    got[..slot * t * CTX_WIDTH],
                    "ctx staging diverged at record {i}"
                );
                ref_ctx.clear_staged();
                slot = 0;
            }
        }
    }

    #[test]
    fn pred_accum_merge_advances_absorb_cursor() {
        // Absorb 2 rows, merge a 3-instruction consecutive shard, then
        // absorb again: the resumed ordinals must continue at 6, so the
        // tail correction tracks the true last instruction.
        let row = |v: f32| ModelOutputs {
            fetch: vec![v],
            exec: vec![v],
            branch: vec![0.0],
            access: vec![0.0; 4],
            icache: vec![0.0],
            tlb: vec![0.0],
        };
        let mut a = PredAccum::default();
        a.absorb(&row(1.0), ModelKind::Tao);
        a.absorb(&row(2.0), ModelKind::Tao);
        let mut mid = PredAccum::at_base(2);
        mid.absorb(&row(3.0), ModelKind::Tao);
        mid.absorb(&row(4.0), ModelKind::Tao);
        mid.absorb(&row(5.0), ModelKind::Tao);
        a.merge(&mid);
        a.absorb(&row(6.0), ModelKind::Tao);
        assert_eq!(a.instructions, 6);
        assert_eq!(a.last_exec_at, 6);
        assert!((a.last_exec - 6.0).abs() < 1e-12);
        assert!((a.total_cycles() - (21.0 + 6.0)).abs() < 1e-12);
    }

    #[test]
    fn warmup_clamps_at_trace_start() {
        let artifact = fake_artifact("clamp", 8, 4);
        let records = uniform_records(5_000);
        // warmup larger than the first chunk's start index: must clamp.
        let r = simulate_parallel_opts(
            &artifact,
            &records[..],
            2,
            None,
            ParallelOptions {
                chunk: 1_024,
                warmup: 100_000,
                pipeline: true,
            },
        )
        .unwrap();
        assert_eq!(r.metrics.instructions, 5_000);
    }

    // --- pipelined stage/execute vs the serial oracle ---

    #[test]
    fn pred_accum_merge_from_interleaved_absorb() {
        // Absorb rows 1-2, then fold shards [4,6) and [2,4) OUT OF
        // ORDER via merge_from, then absorb again: the cursor must sit
        // at the farthest merged end (6), never re-tagging an ordinal,
        // so the resumed absorb is instruction 7 and owns the tail.
        let row = |v: f32| ModelOutputs {
            fetch: vec![v],
            exec: vec![v],
            branch: vec![0.0],
            access: vec![0.0; 4],
            icache: vec![0.0],
            tlb: vec![0.0],
        };
        let mut a = PredAccum::default();
        a.absorb(&row(1.0), ModelKind::Tao);
        a.absorb(&row(2.0), ModelKind::Tao);
        let mut late = PredAccum::at_base(4);
        late.absorb(&row(5.0), ModelKind::Tao);
        late.absorb(&row(6.0), ModelKind::Tao);
        let mut early = PredAccum::at_base(2);
        early.absorb(&row(3.0), ModelKind::Tao);
        early.absorb(&row(4.0), ModelKind::Tao);
        // Out-of-order pipelined tails: the later shard completes first.
        a.merge_from(&late);
        assert_eq!(a.last_exec_at, 6, "tail must follow the latest ordinal");
        a.merge_from(&early);
        assert_eq!(a.instructions, 6);
        assert_eq!(a.last_exec_at, 6);
        assert!((a.last_exec - 6.0).abs() < 1e-12);
        // Resume absorption: instruction 7 takes over the tail.
        a.absorb(&row(7.0), ModelKind::Tao);
        assert_eq!(a.instructions, 7);
        assert_eq!(a.last_exec_at, 7);
        assert!((a.total_cycles() - (28.0 + 7.0)).abs() < 1e-12);
        // Plain merge on the same interleave would have mis-placed the
        // cursor after the first (out-of-order) fold.
        let mut b = PredAccum::default();
        b.absorb(&row(1.0), ModelKind::Tao);
        b.absorb(&row(2.0), ModelKind::Tao);
        b.merge(&late);
        b.absorb(&row(9.0), ModelKind::Tao);
        assert_eq!(b.last_exec_at, 5, "merge resumes at base+count, not the shard end");
    }

    #[test]
    fn pipelined_parallel_opts_matches_serial_oracle() {
        let artifact = fake_artifact("pipeq", 16, 8);
        let p = crate::workloads::by_name("mcf").unwrap().build(3);
        let trace = crate::functional::FunctionalSim::new(&p).run(16_000);
        let serial_opts = ParallelOptions {
            chunk: 2_048,
            warmup: 512,
            pipeline: false,
        };
        let piped_opts = ParallelOptions { pipeline: true, ..serial_opts };
        for workers in [2, 3] {
            let serial =
                simulate_parallel_opts(&artifact, &trace.records[..], workers, None, serial_opts)
                    .unwrap();
            let piped =
                simulate_parallel_opts(&artifact, &trace.records[..], workers, None, piped_opts)
                    .unwrap();
            assert_eq!(piped.metrics.instructions, serial.metrics.instructions);
            assert_eq!(piped.metrics.cycles, serial.metrics.cycles, "workers={workers}");
            assert_eq!(piped.metrics.mispredicts, serial.metrics.mispredicts);
            assert_eq!(piped.metrics.l1d_misses, serial.metrics.l1d_misses);
            assert_eq!(piped.batches, serial.batches);
            assert!(serial.pipeline.is_none());
            let stats = piped.pipeline.expect("pipelined run must report occupancy");
            assert_eq!(stats.batches, piped.batches, "every batch rode the pipeline");
        }
    }

    #[test]
    fn pipelined_sequential_fallback_matches_serial_oracle() {
        // n < workers*1024 forces the sequential fallback on both
        // sides: simulate_range_pipelined vs simulate_source.
        let artifact = fake_artifact("pipefall", 8, 4);
        let p = crate::workloads::by_name("dee").unwrap().build(7);
        let trace = crate::functional::FunctionalSim::new(&p).run(3_000);
        let serial = simulate_parallel_opts(
            &artifact,
            &trace.records[..],
            4,
            None,
            ParallelOptions { chunk: 1_024, warmup: 128, pipeline: false },
        )
        .unwrap();
        let piped = simulate_parallel_opts(
            &artifact,
            &trace.records[..],
            4,
            None,
            ParallelOptions { chunk: 1_024, warmup: 128, pipeline: true },
        )
        .unwrap();
        assert_eq!(piped.metrics.instructions, serial.metrics.instructions);
        assert_eq!(piped.metrics.cycles, serial.metrics.cycles);
        assert_eq!(piped.batches, serial.batches);
    }

    #[test]
    fn pipelined_chunked_sequential_matches_session_path() {
        // simulate_chunked_pipelined (prefetch + executor thread) must
        // reproduce simulate_chunked exactly, phase series included.
        let artifact = fake_artifact("pipechunk", 8, 4);
        let p = crate::workloads::by_name("xal").unwrap().build(2);
        let trace = crate::functional::FunctionalSim::new(&p).run(4_000);
        let cols = trace.to_columns();
        let mut session = Session::load(&artifact).unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let serial = simulate_chunked(&mut session, &mut src, 333, Some(256)).unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let piped = simulate_chunked_pipelined(&artifact, &mut src, 333, Some(256)).unwrap();
        assert_eq!(piped.metrics.instructions, serial.metrics.instructions);
        assert_eq!(piped.metrics.cycles, serial.metrics.cycles);
        assert_eq!(piped.metrics.mispredicts, serial.metrics.mispredicts);
        assert_eq!(piped.batches, serial.batches);
        let (sp, pp) = (serial.phase.unwrap(), piped.phase.unwrap());
        assert_eq!(sp.windows.len(), pp.windows.len());
        for (i, (a, b)) in sp.windows.iter().zip(&pp.windows).enumerate() {
            assert_eq!(a.instructions, b.instructions, "phase window {i}");
            assert_eq!(a.cycles, b.cycles, "phase window {i}");
            assert_eq!(a.mispredicts, b.mispredicts, "phase window {i}");
        }
    }

    #[test]
    fn pipelined_parallel_chunked_matches_serial_oracle_small() {
        let artifact = fake_artifact("pipepull", 16, 8);
        let p = crate::workloads::by_name("lee").unwrap().build(5);
        let trace = crate::functional::FunctionalSim::new(&p).run(12_000);
        let cols = trace.to_columns();
        let serial_opts = ParallelOptions {
            chunk: 2_048,
            warmup: 256,
            pipeline: false,
        };
        let piped_opts = ParallelOptions { pipeline: true, ..serial_opts };
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let serial = simulate_parallel_chunked(&artifact, &mut src, 3, serial_opts).unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let piped = simulate_parallel_chunked(&artifact, &mut src, 3, piped_opts).unwrap();
        assert_eq!(piped.metrics.instructions, serial.metrics.instructions);
        assert_eq!(piped.metrics.cycles, serial.metrics.cycles);
        assert_eq!(piped.metrics.mispredicts, serial.metrics.mispredicts);
        assert_eq!(piped.batches, serial.batches);
    }

    #[test]
    fn pipelined_run_propagates_bad_artifact_errors() {
        // A missing artifact must fail the run, not hang the pipeline.
        let missing = std::env::temp_dir().join("tao-engine-nope/absent.hlo.txt");
        let records = uniform_records(3_000);
        let r = simulate_parallel_opts(
            &missing,
            &records[..],
            2,
            None,
            ParallelOptions { chunk: 1_024, warmup: 0, pipeline: true },
        );
        assert!(r.is_err());
    }

    // --- phase-sampling replay ---

    fn sampled_shard(base: u64, n: u64) -> PredAccum {
        let mut a = PredAccum::at_base(base);
        let out = ModelOutputs {
            fetch: (0..n).map(|i| (i % 7) as f32 + 1.0).collect(),
            exec: (0..n).map(|i| (i % 5) as f32 + 2.0).collect(),
            branch: (0..n).map(|i| (i % 2) as f32).collect(),
            access: (0..n).flat_map(|i| [0.0, 0.0, (i % 3) as f32, 1.0]).collect(),
            icache: vec![0.0; n as usize],
            tlb: vec![1.0; n as usize],
        };
        a.absorb(&out, ModelKind::Tao);
        a
    }

    #[test]
    fn weighted_merge_is_order_independent_and_weight1_is_merge_from() {
        // Integer-valued doubles × integer weights: every fold order is
        // exactly equal, so this checks the weighted-merge logic (sum
        // scaling, unscaled tail, tail selection) under all orders.
        let shards = [
            sampled_shard(0, 16),
            sampled_shard(16, 16),
            sampled_shard(32, 16),
            sampled_shard(48, 7),
        ];
        let weights = [3.0, 1.0, 2.0, 5.0];
        let fold = |order: &[usize]| {
            let mut acc = PredAccum::default();
            for &i in order {
                acc.merge_weighted(&shards[i], weights[i]);
            }
            acc.metrics()
        };
        let reference = fold(&[0, 1, 2, 3]);
        for order in [[3, 2, 1, 0], [1, 3, 0, 2], [2, 0, 3, 1], [0, 2, 1, 3]] {
            let m = fold(&order);
            assert_eq!(m.instructions, reference.instructions, "fold order {order:?}");
            assert_eq!(m.cycles, reference.cycles, "fold order {order:?}");
            assert_eq!(m.mispredicts, reference.mispredicts);
            assert_eq!(m.l1d_misses, reference.l1d_misses);
            assert_eq!(m.tlb_misses, reference.tlb_misses);
        }
        // Weighted instruction expansion is exact.
        assert_eq!(reference.instructions, 3 * 16 + 16 + 2 * 16 + 5 * 7);
        // The tail correction is never scaled: cycles = Σ w·fetch plus
        // the (unweighted) exec latency of the globally last window.
        let weighted_fetch: f64 =
            shards.iter().zip(weights).map(|(s, w)| s.fetch_cycles * w).sum();
        assert_eq!(reference.cycles, weighted_fetch + shards[3].last_exec);
        // Weight 1.0 everywhere is exactly merge_from.
        let mut flat = PredAccum::default();
        let mut w1 = PredAccum::default();
        for s in &shards {
            flat.merge_from(s);
            w1.merge_weighted(s, 1.0);
        }
        assert_eq!(w1.metrics().cycles, flat.metrics().cycles);
        assert_eq!(w1.metrics().instructions, flat.metrics().instructions);
        assert_eq!(w1.metrics().mispredicts, flat.metrics().mispredicts);
        // Ratio weights round back to the exact member-row count.
        let s = sampled_shard(0, 7);
        let sc = s.scaled(3_500.0 / 7.0);
        assert_eq!(sc.instructions, 3_500);
        assert_eq!(sc.last_exec, s.last_exec);
        assert_eq!(sc.last_exec_at, s.last_exec_at);
    }

    fn write_trace_v2(tag: &str, name: &str, cols: &TraceColumns, chunk_rows: usize) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tao-engine-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(tag);
        crate::trace::TraceWriteOptions::new(crate::trace::TraceFormat::V2)
            .chunk_rows(chunk_rows)
            .write(&path, name, cols)
            .unwrap();
        path
    }

    #[test]
    fn sampled_exhaustive_weight1_matches_simulate_chunked_bit_exactly() {
        // The exactness oracle: an exhaustive plan (every slice its own
        // phase at weight 1) coalesces to a single warmup-free run over
        // the whole trace — same stream, same flush grid, same absorb
        // order as simulate_chunked, so the metrics are bit-identical.
        let artifact = fake_artifact("sampled-oracle", 16, 8);
        let p = crate::workloads::by_name("mcf").unwrap().build(5);
        let cols = crate::functional::FunctionalSim::new(&p).run(6_000).to_columns();
        let trace = write_trace_v2("sampled-oracle.trace", "mcf", &cols, 700);
        let mut session = Session::load(&artifact).unwrap();
        let mut src = crate::trace::open_trace_source(&trace).unwrap();
        let full = simulate_chunked(&mut session, &mut src, 777, None).unwrap();
        let plan = SamplingPlan::exhaustive("mcf", 6_000, 1_000);
        for pipeline in [false, true] {
            let out = simulate_sampled(
                &artifact,
                &trace,
                &plan,
                1,
                ParallelOptions { chunk: 777, warmup: 512, pipeline },
            )
            .unwrap();
            assert_eq!(out.result.metrics.instructions, full.metrics.instructions);
            assert_eq!(out.result.metrics.cycles, full.metrics.cycles, "pipeline={pipeline}");
            assert_eq!(out.result.metrics.mispredicts, full.metrics.mispredicts);
            assert_eq!(out.result.metrics.l1d_misses, full.metrics.l1d_misses);
            assert_eq!(out.result.metrics.l1i_misses, full.metrics.l1i_misses);
            assert_eq!(out.result.metrics.tlb_misses, full.metrics.tlb_misses);
            assert_eq!(out.result.batches, full.batches);
            assert_eq!(out.simulated_rows, 6_000);
            assert_eq!(out.warmup_rows, 0);
            assert_eq!(out.total_rows, 6_000);
        }
    }

    #[test]
    fn sampled_replay_is_deterministic_across_worker_counts() {
        let artifact = fake_artifact("sampled-par", 16, 8);
        // Round-robin slices from four workloads: known phase structure.
        let slices: Vec<TraceColumns> = ["dee", "mcf", "xal", "rom"]
            .iter()
            .map(|b| {
                let p = crate::workloads::by_name(b).unwrap().build(9);
                crate::functional::FunctionalSim::new(&p).run(1_500).to_columns()
            })
            .collect();
        let mut cols = TraceColumns::new();
        for i in 0..16 {
            let s = &slices[i % 4];
            cols.extend_from(s, 0, s.len());
        }
        let trace = write_trace_v2("sampled-par.trace", "mix4", &cols, 1_024);
        let plan = crate::sampling::plan_trace(
            &trace,
            &crate::sampling::SamplingOptions { slice_rows: 1_500, max_phases: 4, seed: 7 },
        )
        .unwrap();
        assert!(!plan.phases.is_empty() && plan.phases.len() <= 4);
        assert_eq!(plan.total_rows, 24_000);
        let opts =
            |pipeline| ParallelOptions { chunk: 640, warmup: 256, pipeline };
        // Run staging is self-contained (reset at run start, flush at
        // run end), so serial / pipelined / parallel all produce the
        // same per-phase accumulators — exact equality, any workers.
        let serial = simulate_sampled(&artifact, &trace, &plan, 1, opts(false)).unwrap();
        let piped = simulate_sampled(&artifact, &trace, &plan, 1, opts(true)).unwrap();
        let par = simulate_sampled(&artifact, &trace, &plan, 3, opts(true)).unwrap();
        for (tag, out) in [("piped", &piped), ("par", &par)] {
            assert_eq!(out.result.metrics.instructions, serial.result.metrics.instructions);
            assert_eq!(out.result.metrics.cycles, serial.result.metrics.cycles, "{tag}");
            assert_eq!(out.result.metrics.mispredicts, serial.result.metrics.mispredicts);
            assert_eq!(out.result.batches, serial.result.batches, "{tag}");
        }
        // Weighted expansion accounts every member row exactly.
        assert_eq!(serial.result.metrics.instructions, 24_000);
        assert_eq!(serial.simulated_rows, plan.simulated_rows());
        assert!(serial.simulated_rows <= 4 * 1_500);
        // A plan for a different trace is refused.
        let other = SamplingPlan::exhaustive("other", 24_000, 1_500);
        assert!(simulate_sampled(&artifact, &trace, &other, 1, opts(true)).is_err());
    }

    #[test]
    fn sampled_cpi_stays_within_guardrail_on_mixed_suite() {
        // Accuracy guardrail on the mixed scenario suite: every Table-2
        // workload contributes slices, and the sampled CPI must land
        // within the declared relative-error bound of the full run.
        // benches/coordinator.rs measures and publishes the same bound
        // (`sampled_error_bound_pct`) at bench scale.
        const BOUND: f64 = 0.15;
        let artifact = fake_artifact("sampled-acc", 16, 8);
        let mut cols = TraceColumns::new();
        for w in crate::workloads::suite() {
            let p = w.build(3);
            let t = crate::functional::FunctionalSim::new(&p).run(6_000).to_columns();
            cols.extend_from(&t, 0, t.len());
        }
        let n = cols.len() as u64;
        assert_eq!(n, 48_000);
        let trace = write_trace_v2("sampled-acc.trace", "mix", &cols, 1_024);
        let mut session = Session::load(&artifact).unwrap();
        let mut src = crate::trace::open_trace_source(&trace).unwrap();
        let full = simulate_chunked(&mut session, &mut src, 4_096, None).unwrap();
        let plan = crate::sampling::plan_trace(
            &trace,
            &crate::sampling::SamplingOptions { slice_rows: 2_000, max_phases: 8, seed: 42 },
        )
        .unwrap();
        assert!(plan.coverage() <= 8.0 * 2_000.0 / 48_000.0 + 1e-9);
        let out = simulate_sampled(
            &artifact,
            &trace,
            &plan,
            2,
            ParallelOptions { chunk: 2_048, warmup: 1_024, pipeline: true },
        )
        .unwrap();
        assert_eq!(out.result.metrics.instructions, n);
        let full_cpi = full.metrics.cpi();
        let cpi = out.result.metrics.cpi();
        let err = (cpi - full_cpi).abs() / full_cpi;
        assert!(
            err <= BOUND,
            "sampled CPI {cpi:.4} vs full {full_cpi:.4}: relative error {err:.4} > {BOUND}"
        );
    }
}
