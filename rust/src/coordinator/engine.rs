//! The DL-simulation engine — Layer 3's request path.
//!
//! Mirrors the parallel-simulation design of Pandey et al. [59] that both
//! SimNet and Tao use: the committed instruction stream is partitioned
//! into **shards**; each worker owns a feature extractor, a window
//! batcher and its own compiled PJRT executable, and streams its shard
//! through the model; the collector folds per-shard accumulators into the
//! run-level metrics. Shard boundaries cold-start the history state —
//! the same approximation the paper makes.

use crate::features::FeatureExtractor;
use crate::runtime::{ModelKind, ModelOutputs, Session};
use crate::stats::{Metrics, PhaseSeries};
use crate::trace::FuncRecord;
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Sliding-window batcher: collects per-instruction features into the
/// session's staging buffers, window by window, and reports when a full
/// batch is ready. The window for instruction *i* covers `[i-T+1, i]`
/// with repeated-first-row padding during warm-up.
pub struct WindowBatcher {
    t: usize,
    f: usize,
    batch: usize,
    /// Ring of the last `T` (opcode, features) rows.
    ring_ops: Vec<i32>,
    ring_feats: Vec<f32>,
    filled: usize,
    head: usize,
    /// Windows currently staged.
    pub staged: usize,
}

impl WindowBatcher {
    /// New batcher for the given artifact shape.
    pub fn new(t: usize, f: usize, batch: usize) -> WindowBatcher {
        WindowBatcher {
            t,
            f,
            batch,
            ring_ops: vec![0; t],
            ring_feats: vec![0.0; t * f],
            filled: 0,
            head: 0,
            staged: 0,
        }
    }

    /// Push one instruction's features; stage its window into the session
    /// buffers. Returns `true` when the batch is full and must be flushed.
    pub fn push(
        &mut self,
        opcode: i32,
        feats: &[f32],
        ops_buf: &mut [i32],
        feat_buf: &mut [f32],
    ) -> bool {
        debug_assert_eq!(feats.len(), self.f);
        // Insert into ring.
        self.ring_ops[self.head] = opcode;
        self.ring_feats[self.head * self.f..(self.head + 1) * self.f].copy_from_slice(feats);
        self.head = (self.head + 1) % self.t;
        self.filled = (self.filled + 1).min(self.t);

        // Stage the window ending at this instruction.
        let w = self.staged;
        let dst_ops = &mut ops_buf[w * self.t..(w + 1) * self.t];
        let dst_feats = &mut feat_buf[w * self.t * self.f..(w + 1) * self.t * self.f];
        for j in 0..self.t {
            // Window position j (oldest..newest). During warm-up, repeat
            // the oldest available row.
            let age = self.t - 1 - j; // newest = age 0
            let age = age.min(self.filled - 1);
            let idx = (self.head + self.t - 1 - age) % self.t;
            dst_ops[j] = self.ring_ops[idx];
            dst_feats[j * self.f..(j + 1) * self.f]
                .copy_from_slice(&self.ring_feats[idx * self.f..(idx + 1) * self.f]);
        }
        self.staged += 1;
        self.staged == self.batch
    }

    /// Reset staging (after a flush).
    pub fn clear_staged(&mut self) {
        self.staged = 0;
    }

    /// Reset everything (new shard).
    pub fn reset(&mut self) {
        self.filled = 0;
        self.head = 0;
        self.staged = 0;
    }
}

/// Accumulated predictions over a stream.
#[derive(Debug, Clone, Default)]
pub struct PredAccum {
    /// Instructions accounted.
    pub instructions: u64,
    /// Σ predicted fetch latency (cycles).
    pub fetch_cycles: f64,
    /// Last window's predicted exec latency (tail correction).
    pub last_exec: f64,
    /// Σ P(mispredict).
    pub mispredicts: f64,
    /// Σ P(L1D miss) (= P(level ≥ L2)).
    pub l1d_misses: f64,
    /// Σ P(L1I miss).
    pub l1i_misses: f64,
    /// Σ P(TLB miss).
    pub tlb_misses: f64,
    /// Optional per-window phase series.
    pub phase: Option<PhaseSeries>,
}

impl PredAccum {
    /// With phase tracking at the given window size.
    pub fn with_phase(window: u64) -> PredAccum {
        PredAccum {
            phase: Some(PhaseSeries::new(window)),
            ..Default::default()
        }
    }

    /// Fold one model batch.
    pub fn absorb(&mut self, out: &ModelOutputs, kind: ModelKind) {
        for i in 0..out.fetch.len() {
            let fetch = out.fetch[i] as f64;
            let exec = out.exec[i] as f64;
            self.instructions += 1;
            self.fetch_cycles += fetch;
            self.last_exec = exec;
            let (mis, l1d, l1i, tlb) = match kind {
                ModelKind::Tao => (
                    out.branch[i] as f64,
                    (out.access[i * 4 + 2] + out.access[i * 4 + 3]) as f64,
                    out.icache[i] as f64,
                    out.tlb[i] as f64,
                ),
                ModelKind::SimNet => (0.0, 0.0, 0.0, 0.0),
            };
            self.mispredicts += mis;
            self.l1d_misses += l1d;
            self.l1i_misses += l1i;
            self.tlb_misses += tlb;
            if let Some(ph) = &mut self.phase {
                ph.push(fetch, mis > 0.5, l1d > 0.5, l1i > 0.5, tlb > 0.5);
            }
        }
    }

    /// Merge another shard's accumulator (order: self then other).
    pub fn merge(&mut self, other: &PredAccum) {
        self.instructions += other.instructions;
        self.fetch_cycles += other.fetch_cycles;
        self.last_exec = other.last_exec;
        self.mispredicts += other.mispredicts;
        self.l1d_misses += other.l1d_misses;
        self.l1i_misses += other.l1i_misses;
        self.tlb_misses += other.tlb_misses;
    }

    /// Total predicted cycles (§4.2 reconstruction).
    pub fn total_cycles(&self) -> f64 {
        self.fetch_cycles + self.last_exec
    }

    /// As run-level metrics.
    pub fn metrics(&self) -> Metrics {
        Metrics {
            instructions: self.instructions,
            cycles: self.total_cycles(),
            mispredicts: self.mispredicts,
            l1d_misses: self.l1d_misses,
            l1i_misses: self.l1i_misses,
            tlb_misses: self.tlb_misses,
        }
    }
}

/// Result of a simulation run.
#[derive(Debug)]
pub struct SimResult {
    /// Predicted metrics.
    pub metrics: Metrics,
    /// Wall-clock inference time (feature extraction + model execution).
    pub elapsed: Duration,
    /// Model batches executed.
    pub batches: u64,
    /// Optional phase series (single-shard runs).
    pub phase: Option<PhaseSeries>,
}

impl SimResult {
    /// Simulation throughput in MIPS.
    pub fn mips(&self) -> f64 {
        crate::util::timer::mips(self.metrics.instructions, self.elapsed)
    }
}

/// Simulate a record stream through one session (one shard, one thread).
///
/// `ctx_metrics` (SimNet only): per-instruction detailed-trace metrics,
/// `[N × 6]` — the µarch-specific inputs SimNet requires.
pub fn simulate_records(
    session: &mut Session,
    records: &[FuncRecord],
    ctx_metrics: Option<&[f32]>,
    phase_window: Option<u64>,
) -> Result<SimResult> {
    let meta = session.meta().clone();
    if meta.kind == ModelKind::SimNet {
        ensure!(
            ctx_metrics.map(|c| c.len()) == Some(records.len() * 6),
            "SimNet requires [N×6] context metrics"
        );
    }
    let mut fx = FeatureExtractor::new(meta.features);
    let mut batcher = WindowBatcher::new(meta.context, meta.feature_dim, meta.batch);
    let mut accum = match phase_window {
        Some(w) => PredAccum::with_phase(w),
        None => PredAccum::default(),
    };
    let mut feat_row = vec![0.0f32; meta.feature_dim];
    let mut batches = 0u64;
    let start = Instant::now();

    let flush = |session: &mut Session,
                     batcher: &mut WindowBatcher,
                     accum: &mut PredAccum,
                     batches: &mut u64|
     -> Result<()> {
        let valid = batcher.staged;
        if valid == 0 {
            return Ok(());
        }
        let out = session.run(valid)?;
        accum.absorb(&out, meta.kind);
        batcher.clear_staged();
        *batches += 1;
        Ok(())
    };

    for (i, rec) in records.iter().enumerate() {
        let opcode = fx.extract(rec, &mut feat_row);
        let full = {
            let t = meta.context;
            let (ops_buf, feat_buf) = session.buffers();
            let full = batcher.push(opcode, &feat_row, ops_buf, feat_buf);
            // SimNet: stage the context-metric window alongside.
            if meta.kind == ModelKind::SimNet {
                let w = batcher.staged - 1;
                // Repeat-pad like the feature window; mask current row.
                let ctx = ctx_metrics.unwrap();
                // (split borrow: re-borrow ctx buffer after features)
                let _ = (&ctx, w, t);
                full
            } else {
                full
            }
        };
        if meta.kind == ModelKind::SimNet {
            let w = batcher.staged - 1;
            let t = meta.context;
            let ctx = ctx_metrics.unwrap();
            let ctx_buf = session.ctx_buffer();
            for j in 0..t {
                let src = i.saturating_sub(t - 1 - j);
                let dst = &mut ctx_buf[(w * t + j) * 6..(w * t + j + 1) * 6];
                if j + 1 == t {
                    dst.fill(0.0); // mask the current instruction's metrics
                } else {
                    dst.copy_from_slice(&ctx[src * 6..src * 6 + 6]);
                }
            }
        }
        if full {
            flush(session, &mut batcher, &mut accum, &mut batches)?;
        }
    }
    flush(session, &mut batcher, &mut accum, &mut batches)?;
    if let Some(ph) = &mut accum.phase {
        ph.finish();
    }

    Ok(SimResult {
        metrics: accum.metrics(),
        elapsed: start.elapsed(),
        batches,
        phase: accum.phase.take().map(|p| p),
    })
}

/// Parallel simulation: shard `records` across `workers` threads, each
/// with its own PJRT session compiled from `artifact`.
pub fn simulate_parallel(
    artifact: &Path,
    records: &[FuncRecord],
    workers: usize,
    ctx_metrics: Option<&[f32]>,
) -> Result<SimResult> {
    ensure!(workers >= 1, "need at least one worker");
    if workers == 1 || records.len() < workers * 1024 {
        let mut session = Session::load(artifact)?;
        return simulate_records(&mut session, records, ctx_metrics, None);
    }
    let shard_len = records.len().div_ceil(workers);
    let start = Instant::now();
    let artifact: PathBuf = artifact.to_path_buf();
    let results: Vec<Result<SimResult>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let lo = w * shard_len;
            let hi = ((w + 1) * shard_len).min(records.len());
            if lo >= hi {
                break;
            }
            let shard = &records[lo..hi];
            let ctx_shard = ctx_metrics.map(|c| &c[lo * 6..hi * 6]);
            let artifact = artifact.clone();
            handles.push(scope.spawn(move || -> Result<SimResult> {
                let mut session = Session::load(&artifact)
                    .with_context(|| format!("worker {w}: load {artifact:?}"))?;
                simulate_records(&mut session, shard, ctx_shard, None)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let mut metrics = Metrics::default();
    let mut batches = 0;
    for r in results {
        let r = r?;
        metrics.merge(&r.metrics);
        batches += r.batches;
    }
    Ok(SimResult {
        metrics,
        elapsed: start.elapsed(),
        batches,
        phase: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_batcher_stages_and_flags_full() {
        let t = 4;
        let f = 2;
        let batch = 3;
        let mut b = WindowBatcher::new(t, f, batch);
        let mut ops = vec![0i32; batch * t];
        let mut feats = vec![0.0f32; batch * t * f];
        assert!(!b.push(1, &[0.1, 0.2], &mut ops, &mut feats));
        assert!(!b.push(2, &[0.3, 0.4], &mut ops, &mut feats));
        assert!(b.push(3, &[0.5, 0.6], &mut ops, &mut feats));
        // Window 0 (after 1 push): warm-up repeats opcode 1 everywhere.
        assert_eq!(&ops[0..4], &[1, 1, 1, 1]);
        // Window 2: [1,1,2,3] — newest last.
        assert_eq!(&ops[8..12], &[1, 1, 2, 3]);
        // Newest row's features land at the end of window 2.
        assert_eq!(&feats[(8 + 3) * f..(8 + 4) * f], &[0.5, 0.6]);
    }

    #[test]
    fn window_batcher_slides_beyond_t() {
        let t = 3;
        let f = 1;
        let mut b = WindowBatcher::new(t, f, 8);
        let mut ops = vec![0i32; 8 * t];
        let mut feats = vec![0.0f32; 8 * t];
        for i in 0..5 {
            b.push(i as i32 + 1, &[i as f32], &mut ops, &mut feats);
        }
        // Window 4 = [3,4,5].
        assert_eq!(&ops[4 * t..5 * t], &[3, 4, 5]);
    }

    #[test]
    fn pred_accum_totals() {
        let mut a = PredAccum::default();
        let out = ModelOutputs {
            fetch: vec![1.0, 2.0],
            exec: vec![5.0, 7.0],
            branch: vec![0.25, 0.75],
            access: vec![
                0.7, 0.2, 0.05, 0.05, // mostly none
                0.0, 0.1, 0.4, 0.5, // mostly miss
            ],
            icache: vec![0.0, 1.0],
            tlb: vec![0.5, 0.5],
        };
        a.absorb(&out, ModelKind::Tao);
        assert_eq!(a.instructions, 2);
        assert!((a.total_cycles() - (3.0 + 7.0)).abs() < 1e-9);
        assert!((a.mispredicts - 1.0).abs() < 1e-9);
        assert!((a.l1d_misses - (0.1 + 0.9)).abs() < 1e-6);
        let m = a.metrics();
        assert!((m.branch_mpki() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn pred_accum_merge() {
        let mut a = PredAccum {
            instructions: 10,
            fetch_cycles: 20.0,
            last_exec: 3.0,
            ..Default::default()
        };
        let b = PredAccum {
            instructions: 5,
            fetch_cycles: 10.0,
            last_exec: 9.0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.instructions, 15);
        assert!((a.total_cycles() - 39.0).abs() < 1e-9);
    }
}
