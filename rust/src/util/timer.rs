//! Wall-clock measurement helper used by the report harnesses.

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating elapsed time across start/stop cycles.
#[derive(Debug)]
pub struct Stopwatch {
    started: Option<Instant>,
    accumulated: Duration,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// New, stopped stopwatch with zero accumulated time.
    pub fn new() -> Stopwatch {
        Stopwatch {
            started: None,
            accumulated: Duration::ZERO,
        }
    }

    /// Start (or restart) measuring.
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Stop measuring and fold the elapsed interval into the total.
    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.accumulated += t.elapsed();
        }
    }

    /// Total accumulated time (including a currently-running interval).
    pub fn elapsed(&self) -> Duration {
        let running = self.started.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        self.accumulated + running
    }

    /// Time a closure, returning its result and folding the elapsed time
    /// into the total.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Instructions-per-second helper: MIPS given an instruction count and a
/// duration.
pub fn mips(instructions: u64, elapsed: Duration) -> f64 {
    if elapsed.is_zero() {
        return f64::INFINITY;
    }
    instructions as f64 / elapsed.as_secs_f64() / 1.0e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_intervals() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        let first = sw.elapsed();
        assert!(first >= Duration::from_millis(5));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.elapsed() >= first + Duration::from_millis(5));
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn mips_math() {
        assert!((mips(2_000_000, Duration::from_secs(1)) - 2.0).abs() < 1e-9);
        assert!(mips(1, Duration::ZERO).is_infinite());
    }
}
