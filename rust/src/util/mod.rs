//! Small shared utilities: deterministic PRNG, timing helpers, bench
//! harness + trajectory gate, content hashing, fault injection.

pub mod benchgate;
pub mod benchkit;
pub mod fault;
pub mod hash;
pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
