//! Minimal benchmark harness (the vendored build has no criterion).
//!
//! `cargo bench` targets use [`Bench`] for warmup + repeated timed runs
//! with mean/min/max reporting, and [`BenchReport`] to persist the
//! numbers as JSON (e.g. `BENCH_coordinator.json`) so successive PRs
//! have a perf trajectory. Keep benchmarks deterministic: seed
//! everything through `crate::util::Rng`.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Shared bench-binary flags (`--smoke`, `--json <path>`), parsed from
/// `std::env::args`. Unknown flags (e.g. cargo's `--bench`) are
/// ignored; a `--json` with no value is ignored too.
#[derive(Debug, Default)]
pub struct BenchOpts {
    /// Reduced counts/iterations for CI smoke runs.
    pub smoke: bool,
    /// Write the [`BenchReport`] JSON here.
    pub json: Option<PathBuf>,
}

impl BenchOpts {
    /// Parse the process arguments.
    pub fn from_env() -> BenchOpts {
        let mut opts = BenchOpts::default();
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--smoke" => opts.smoke = true,
                "--json" => opts.json = args.next().map(PathBuf::from),
                _ => {}
            }
        }
        opts
    }
}

/// One benchmark case's measurements.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// `group/case` label.
    pub name: String,
    /// Logical items processed per iteration.
    pub items: u64,
    /// Mean wall-clock per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
    /// Slowest iteration, nanoseconds.
    pub max_ns: f64,
}

impl Measurement {
    /// Items per second at the mean iteration time.
    pub fn items_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            return f64::INFINITY;
        }
        self.items as f64 * 1e9 / self.mean_ns
    }
}

/// A named benchmark group printer.
pub struct Bench {
    name: String,
    warmup: u32,
    iters: u32,
}

impl Bench {
    /// New bench with defaults (1 warmup, 5 measured iterations).
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: 1,
            iters: 5,
        }
    }

    /// Set measured iterations.
    pub fn iters(mut self, n: u32) -> Self {
        self.iters = n;
        self
    }

    /// Run `f`, which processes `items` logical items per call; print
    /// mean latency + throughput and return the measurement.
    pub fn run<T>(&self, case: &str, items: u64, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let total: Duration = times.iter().sum();
        let mean = total / self.iters;
        let min = *times.iter().min().unwrap();
        let max = *times.iter().max().unwrap();
        let m = Measurement {
            name: format!("{}/{}", self.name, case),
            items,
            mean_ns: mean.as_nanos() as f64,
            min_ns: min.as_nanos() as f64,
            max_ns: max.as_nanos() as f64,
        };
        println!(
            "{:<44} {:>10.3?} /iter (min {:>9.3?}, max {:>9.3?})  {:>9.3} Mitems/s",
            m.name,
            mean,
            min,
            max,
            m.items_per_sec() / 1e6
        );
        m
    }
}

/// Collects measurements and scalar metrics and writes them as a flat
/// JSON document (hand-rolled — the build has no serde).
#[derive(Debug, Default)]
pub struct BenchReport {
    cases: Vec<Measurement>,
    metrics: Vec<(String, f64)>,
}

impl BenchReport {
    /// Empty report.
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Record a case measurement.
    pub fn push(&mut self, m: Measurement) {
        self.cases.push(m);
    }

    /// Record a derived scalar metric (speedups, latencies, ...).
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// Serialize to a JSON string.
    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                "null".to_string()
            }
        }
        let mut s = String::from("{\n  \"cases\": [\n");
        for (i, c) in self.cases.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"items\": {}, \"mean_ns\": {}, \"min_ns\": {}, \"max_ns\": {}, \"items_per_sec\": {}}}{}\n",
                c.name,
                c.items,
                num(c.mean_ns),
                num(c.min_ns),
                num(c.max_ns),
                num(c.items_per_sec()),
                if i + 1 < self.cases.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"metrics\": {\n");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                k,
                num(*v),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write the JSON document to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_throughput() {
        let m = Measurement {
            name: "g/c".into(),
            items: 1_000,
            mean_ns: 1e6, // 1 ms
            min_ns: 1e6,
            max_ns: 1e6,
        };
        assert!((m.items_per_sec() - 1e9).abs() < 1.0);
    }

    #[test]
    fn report_json_is_balanced_and_contains_cases() {
        let mut r = BenchReport::new();
        r.push(Measurement {
            name: "batcher/naive".into(),
            items: 10,
            mean_ns: 5.0,
            min_ns: 4.0,
            max_ns: 6.0,
        });
        r.push(Measurement {
            name: "batcher/overlap".into(),
            items: 10,
            mean_ns: 2.0,
            min_ns: 2.0,
            max_ns: 2.0,
        });
        r.metric("speedup", 2.5);
        let j = r.to_json();
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(j.contains("batcher/naive"));
        assert!(j.contains("\"speedup\": 2.500"));
        // The crate's own parser must accept it.
        let parsed = crate::util::json::Json::parse(&j).expect("self-parse");
        assert!(parsed.get("metrics").is_some());
    }

    #[test]
    fn report_round_trips_through_file() {
        let dir = std::env::temp_dir().join(format!("tao-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mut r = BenchReport::new();
        r.metric("x", 1.0);
        r.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"x\": 1.000"));
    }

    #[test]
    fn bench_run_returns_measurement() {
        let b = Bench::new("t").iters(1);
        let m = b.run("noop", 100, || 1 + 1);
        assert_eq!(m.items, 100);
        assert!(m.mean_ns >= 0.0);
    }
}
