//! Minimal benchmark harness (the vendored build has no criterion).
//!
//! `cargo bench` targets use [`Bench`] for warmup + repeated timed runs
//! with mean/min/max reporting. Keep benchmarks deterministic: seed
//! everything through `crate::util::Rng`.

use std::time::{Duration, Instant};

/// A named benchmark group printer.
pub struct Bench {
    name: String,
    warmup: u32,
    iters: u32,
}

impl Bench {
    /// New bench with defaults (1 warmup, 5 measured iterations).
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: 1,
            iters: 5,
        }
    }

    /// Set measured iterations.
    pub fn iters(mut self, n: u32) -> Self {
        self.iters = n;
        self
    }

    /// Run `f`, which processes `items` logical items per call, and print
    /// mean latency + throughput.
    pub fn run<T>(&self, case: &str, items: u64, mut f: impl FnMut() -> T) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let total: Duration = times.iter().sum();
        let mean = total / self.iters;
        let min = times.iter().min().unwrap();
        let max = times.iter().max().unwrap();
        let mips = items as f64 / mean.as_secs_f64() / 1e6;
        println!(
            "{:<44} {:>10.3?} /iter (min {:>9.3?}, max {:>9.3?})  {:>9.3} Mitems/s",
            format!("{}/{}", self.name, case),
            mean,
            min,
            max,
            mips
        );
    }
}
