//! Deterministic fault injection for the serving + pipeline stack.
//!
//! A process-global registry of named failure probes. Production code
//! asks [`should_fire`] at each injection point; when nothing is armed
//! that is a single relaxed atomic load returning `false`, so probes
//! can stay compiled into hot paths (see PERFORMANCE.md). Probes are
//! armed from tests ([`arm`]/[`arm_nth`]), from the CLI
//! (`tao serve --faults`), or from the `TAO_FAULTS` environment
//! variable, and fire **deterministically**: rate-armed probes hash
//! their per-probe check counter (no wall clock, no OS entropy), so a
//! given arming spec fires on the same check ordinals every run.
//!
//! The module also hosts the two panic-tolerance helpers the stack
//! shares: [`panic_message`] to render a `catch_unwind` payload, and
//! [`relock`] to keep shared mutexes usable after a peer thread
//! panicked while holding them (the guarded state is only ever read or
//! replaced whole, never left mid-update, so recovering the guard is
//! sound).

use anyhow::{ensure, Context, Result};
use std::any::Any;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::util::hash::{fnv1a64_u64, FNV_OFFSET};

/// The failure modes the serving + pipeline stack can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// A `ChunkSource::next_chunk` decode error inside a serving lane.
    ChunkDecode = 0,
    /// A panic inside the executor pipeline's step closure.
    ExecPanic = 1,
    /// An artifact/session load failure when a lane starts its executor.
    ArtifactLoad = 2,
    /// A bounded stall in the queue's consumer pop path.
    QueueStall = 3,
    /// A client that stalls mid-request (armed by `loadgen --chaos`).
    SlowClient = 4,
    /// A cache-journal append cut short mid-record (torn write).
    CacheTornWrite = 5,
}

/// Every probe, for iteration (stats dumps, disarm sweeps).
pub const PROBES: [Probe; 6] = [
    Probe::ChunkDecode,
    Probe::ExecPanic,
    Probe::ArtifactLoad,
    Probe::QueueStall,
    Probe::SlowClient,
    Probe::CacheTornWrite,
];

impl Probe {
    /// The spec-string name (`TAO_FAULTS=chunk_decode=0.01,...`).
    pub fn name(self) -> &'static str {
        match self {
            Probe::ChunkDecode => "chunk_decode",
            Probe::ExecPanic => "exec_panic",
            Probe::ArtifactLoad => "artifact_load",
            Probe::QueueStall => "queue_stall",
            Probe::SlowClient => "slow_client",
            Probe::CacheTornWrite => "cache_torn_write",
        }
    }

    /// Inverse of [`Probe::name`].
    pub fn from_name(name: &str) -> Option<Probe> {
        PROBES.iter().copied().find(|p| p.name() == name)
    }
}

/// Per-probe arming + accounting. `fire_at` is a one-shot check
/// ordinal (0 = none pending) and takes precedence over `rate_ppm`.
struct Slot {
    rate_ppm: AtomicU32,
    fire_at: AtomicU64,
    checks: AtomicU64,
    fires: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // repeat-initializer only
const SLOT_INIT: Slot = Slot {
    rate_ppm: AtomicU32::new(0),
    fire_at: AtomicU64::new(0),
    checks: AtomicU64::new(0),
    fires: AtomicU64::new(0),
};

/// Fast-path gate: `false` means no probe is armed anywhere and
/// [`should_fire`] returns immediately.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static SLOTS: [Slot; PROBES.len()] = [SLOT_INIT; PROBES.len()];

/// Should this injection point fail now? ~Zero cost while nothing is
/// armed: one relaxed atomic load.
#[inline]
pub fn should_fire(p: Probe) -> bool {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return false;
    }
    should_fire_armed(p)
}

#[cold]
fn should_fire_armed(p: Probe) -> bool {
    let slot = &SLOTS[p as usize];
    let n = slot.checks.fetch_add(1, Ordering::Relaxed) + 1;
    let at = slot.fire_at.load(Ordering::Relaxed);
    if at != 0 {
        // One-shot pending: fire on (or first past) the target check,
        // exactly once, then self-disarm. Suppresses rate mode so
        // `arm_nth` stays precise under concurrent rate arming.
        if n >= at
            && slot
                .fire_at
                .compare_exchange(at, 0, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            slot.fires.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        return false;
    }
    let ppm = slot.rate_ppm.load(Ordering::Relaxed) as u64;
    if ppm == 0 {
        return false;
    }
    // Deterministic "coin flip": hash (probe, check ordinal). The same
    // arming spec fires on the same ordinals in every run.
    let h = fnv1a64_u64(n, fnv1a64_u64(p as u64 + 1, FNV_OFFSET));
    if h % 1_000_000 < ppm {
        slot.fires.fetch_add(1, Ordering::Relaxed);
        return true;
    }
    false
}

/// Arm `p` to fire on a deterministic `rate_ppm`-per-million fraction
/// of checks (0 disarms the rate).
pub fn arm(p: Probe, rate_ppm: u32) {
    SLOTS[p as usize].rate_ppm.store(rate_ppm.min(1_000_000), Ordering::Relaxed);
    refresh_armed();
}

/// Arm `p` to fire exactly once, on the `nth` check from now (1 = the
/// very next check).
pub fn arm_nth(p: Probe, nth: u64) {
    let slot = &SLOTS[p as usize];
    let target = slot.checks.load(Ordering::Relaxed) + nth.max(1);
    slot.fire_at.store(target, Ordering::Relaxed);
    refresh_armed();
}

///// Arm probes from a spec string: comma-separated `name=probability`
/// pairs with probabilities in `[0, 1]`, e.g.
/// `chunk_decode=0.01,exec_panic=0.005`.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, prob) = part
            .split_once('=')
            .with_context(|| format!("fault spec {part:?} is not name=probability"))?;
        let probe = Probe::from_name(name.trim())
            .with_context(|| format!("unknown fault probe {:?}", name.trim()))?;
        let prob: f64 = prob
            .trim()
            .parse()
            .with_context(|| format!("bad fault probability in {part:?}"))?;
        ensure!(
            (0.0..=1.0).contains(&prob),
            "fault probability for {} must be in [0, 1], got {prob}",
            probe.name()
        );
        arm(probe, (prob * 1_000_000.0).round() as u32);
    }
    Ok(())
}

/// Arm probes from the `TAO_FAULTS` environment variable, if set and
/// non-empty. Returns whether anything was armed.
pub fn arm_from_env() -> Result<bool> {
    match std::env::var("TAO_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            arm_from_spec(&spec).context("parsing TAO_FAULTS")?;
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Disarm every probe (check/fire counters keep counting up).
pub fn disarm_all() {
    for slot in &SLOTS {
        slot.rate_ppm.store(0, Ordering::Relaxed);
        slot.fire_at.store(0, Ordering::Relaxed);
    }
    ANY_ARMED.store(false, Ordering::Relaxed);
}

fn refresh_armed() {
    let any = SLOTS.iter().any(|s| {
        s.rate_ppm.load(Ordering::Relaxed) != 0 || s.fire_at.load(Ordering::Relaxed) != 0
    });
    ANY_ARMED.store(any, Ordering::Relaxed);
}

/// Lifetime check/fire counts for one probe.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProbeStats {
    /// Times [`should_fire`] reached this probe's slot while armed.
    pub checks: u64,
    /// Times it returned `true`.
    pub fires: u64,
}

/// Lifetime stats for `p`.
pub fn stats(p: Probe) -> ProbeStats {
    let slot = &SLOTS[p as usize];
    ProbeStats {
        checks: slot.checks.load(Ordering::Relaxed),
        fires: slot.fires.load(Ordering::Relaxed),
    }
}

/// Render a `catch_unwind` payload as a message (panics carry `&str`
/// or `String` in practice).
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Lock `m`, recovering the guard if a peer thread panicked while
/// holding it. Use only where the guarded state is read or replaced
/// whole (never observably mid-update), so poison carries no extra
/// information — a panicked serving lane must not cascade-fail every
/// other lane through a poisoned cache or queue mutex.
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

///// Process-global serialization gate for tests that arm probes: probe
/// state is process-wide, so concurrently running tests must not arm
/// over each other. Hold the guard for the whole armed window and
/// [`disarm_all`] before dropping it.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    // All tests arm only `SlowClient`: no library code path checks it
    // (only `loadgen --chaos` does, in a separate process), so holding
    // `exclusive()` keeps these tests from interfering with anything.

    #[test]
    fn disarmed_probe_never_fires() {
        let _gate = exclusive();
        disarm_all();
        for _ in 0..1000 {
            assert!(!should_fire(Probe::SlowClient));
        }
    }

    #[test]
    fn rate_armed_probe_fires_deterministically() {
        let _gate = exclusive();
        disarm_all();
        arm(Probe::SlowClient, 1_000_000);
        assert!(should_fire(Probe::SlowClient), "rate 1.0 must always fire");
        let before = stats(Probe::SlowClient);
        arm(Probe::SlowClient, 250_000);
        let mut fired = 0;
        for _ in 0..4000 {
            if should_fire(Probe::SlowClient) {
                fired += 1;
            }
        }
        let after = stats(Probe::SlowClient);
        assert_eq!(after.checks - before.checks, 4000);
        assert_eq!(after.fires - before.fires, fired);
        // Deterministic hash ≈ uniform: expect ~1000 of 4000 at 25%.
        assert!((600..=1400).contains(&fired), "fired {fired} of 4000 at rate 0.25");
        disarm_all();
        assert!(!should_fire(Probe::SlowClient));
    }

    #[test]
    fn one_shot_fires_exactly_once_at_nth_check() {
        let _gate = exclusive();
        disarm_all();
        arm_nth(Probe::SlowClient, 3);
        assert!(!should_fire(Probe::SlowClient));
        assert!(!should_fire(Probe::SlowClient));
        assert!(should_fire(Probe::SlowClient), "must fire on the 3rd check");
        for _ in 0..100 {
            assert!(!should_fire(Probe::SlowClient), "one-shot must self-disarm");
        }
        disarm_all();
    }

    #[test]
    fn spec_parsing_arms_and_rejects() {
        let _gate = exclusive();
        disarm_all();
        arm_from_spec("slow_client=1.0").unwrap();
        assert!(should_fire(Probe::SlowClient));
        arm_from_spec(" slow_client = 0 ").unwrap();
        assert!(!should_fire(Probe::SlowClient));
        assert!(arm_from_spec("bogus_probe=0.5").is_err());
        assert!(arm_from_spec("slow_client=1.5").is_err());
        assert!(arm_from_spec("slow_client").is_err());
        assert!(arm_from_spec("slow_client=x").is_err());
        disarm_all();
    }

    #[test]
    fn probe_names_round_trip() {
        for p in PROBES {
            assert_eq!(Probe::from_name(p.name()), Some(p));
        }
        assert_eq!(Probe::from_name("nope"), None);
    }

    #[test]
    fn panic_messages_render() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 7");
        let p = std::panic::catch_unwind(|| panic!("static")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "static");
    }
}
