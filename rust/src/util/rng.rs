//! Deterministic, dependency-free PRNG (splitmix64 seeded xoshiro256**).
//!
//! Every stochastic component in the repository (workload generation,
//! design-space sampling, data shuffling on the Rust side) draws from this
//! generator so runs are exactly reproducible from a seed — a requirement
//! for the paper's experiments to be re-runnable bit-for-bit.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fork a child generator with an independent stream.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(42);
        for n in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_frequency_roughly_matches() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2_500..3_500).contains(&hits), "hits={hits}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
