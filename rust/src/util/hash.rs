//! Shared non-cryptographic hashing (64-bit FNV-1a).
//!
//! Used wherever the repo needs a stable, dependency-free content
//! fingerprint: artifact fingerprints in the runtime pool, chunk
//! content / warm-up prefix keys in the serving prediction cache. Not
//! collision-resistant against adversaries — these are correctness
//! *hints* keyed alongside exact lengths, not security boundaries.

/// The FNV-1a 64-bit offset basis (the canonical empty-input state).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into an FNV-1a state. Chain calls to hash multi-part
/// payloads: `fnv1a64(b, fnv1a64(a, FNV_OFFSET))` hashes `a ++ b`.
pub fn fnv1a64(bytes: &[u8], mut state: u64) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(FNV_PRIME);
    }
    state
}

/// Fold one `u64` (little-endian) into an FNV-1a state. Handy for
/// chaining hashes of hashes (e.g. the serving cache's rolling
/// warm-up-prefix key).
pub fn fnv1a64_u64(value: u64, state: u64) -> u64 {
    fnv1a64(&value.to_le_bytes(), state)
}

/// CRC-32 (IEEE 802.3: reflected, poly `0xEDB88320`, init + xor-out
/// `0xFFFFFFFF`). Frames the serving cache-journal records so a torn
/// tail from a crash is detected and truncated on recovery — unlike
/// FNV this catches short/zero-filled suffixes reliably.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xffff_ffff;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b"", FNV_OFFSET), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a", FNV_OFFSET), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar", FNV_OFFSET), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn chaining_equals_concatenation() {
        let whole = fnv1a64(b"hello world", FNV_OFFSET);
        let chained = fnv1a64(b" world", fnv1a64(b"hello", FNV_OFFSET));
        assert_eq!(whole, chained);
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // The canonical IEEE 802.3 check value, plus edges.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"\0"), crc32(b"\0\0"), "must detect appended zero bytes");
    }

    #[test]
    fn u64_fold_is_order_sensitive() {
        let a = fnv1a64_u64(2, fnv1a64_u64(1, FNV_OFFSET));
        let b = fnv1a64_u64(1, fnv1a64_u64(2, FNV_OFFSET));
        assert_ne!(a, b);
        assert_ne!(fnv1a64_u64(0, FNV_OFFSET), FNV_OFFSET);
    }
}
