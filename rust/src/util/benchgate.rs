//! Bench-trajectory gate: compare a fresh `BENCH_*.json` report (written
//! by [`crate::util::benchkit::BenchReport`]) against the committed
//! snapshots under `benches/baselines/` and fail on throughput
//! regressions once enough real data points exist.
//!
//! Policy (ROADMAP: "gate regressions once a few data points exist"):
//!
//! * Baselines are snapshots named `NNNN-BENCH_<bench>.json` (`make
//!   bench-baseline` copies the current reports in under the next
//!   sequence number).
//! * A snapshot whose `metrics.provisional` is 1 seeds the trajectory
//!   but never enforces — it marks a placeholder captured off the CI
//!   runner, so its absolute numbers are not comparable.
//! * With fewer than [`GateConfig::min_baselines`] enforcing snapshots,
//!   the gate reports would-be regressions but passes (warn-only).
//! * With enough, any case whose `items_per_sec` drops more than
//!   [`GateConfig::tolerance`] below the median of the baselines fails.
//!
//! Cases are matched by name, and smoke/full runs use different case
//! names (instruction counts are embedded), so smoke baselines never
//! gate full runs or vice versa.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Gate thresholds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GateConfig {
    /// Maximum tolerated fractional drop in `items_per_sec` (0.15 =
    /// fail when current < 85% of the baseline median).
    pub tolerance: f64,
    /// Enforcing snapshots required before the gate fails builds.
    pub min_baselines: usize,
}

impl Default for GateConfig {
    fn default() -> GateConfig {
        GateConfig {
            tolerance: 0.15,
            min_baselines: 3,
        }
    }
}

/// One case's throughput, pulled out of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseRate {
    /// `group/case` label.
    pub name: String,
    /// Items per second at the mean iteration time.
    pub items_per_sec: f64,
}

/// A parsed bench report: case rates plus the flags the gate cares
/// about.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// Per-case throughput.
    pub cases: Vec<CaseRate>,
    /// `metrics.provisional == 1`: placeholder numbers, never enforce.
    pub provisional: bool,
    /// All scalar metrics in the report (sorted keys), e.g. the
    /// `pipeline_*` occupancy/speedup numbers.
    pub metrics: Vec<(String, f64)>,
}

/// Parse a `BENCH_*.json` document.
pub fn parse_report(text: &str) -> Result<Report> {
    let j = Json::parse(text)?;
    let cases = j
        .get("cases")
        .and_then(|v| v.as_arr())
        .context("bench report missing cases")?
        .iter()
        .map(|c| {
            let name = c
                .get("name")
                .and_then(|v| v.as_str())
                .context("case missing name")?
                .to_string();
            let items_per_sec = c
                .get("items_per_sec")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("case {name} missing items_per_sec"))?;
            Ok(CaseRate {
                name,
                items_per_sec,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let metrics: Vec<(String, f64)> = match j.get("metrics") {
        Some(Json::Obj(map)) => map
            .iter()
            .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
            .collect(),
        _ => Vec::new(),
    };
    let provisional = metrics
        .iter()
        .any(|(k, v)| k == "provisional" && *v == 1.0);
    Ok(Report {
        cases,
        provisional,
        metrics,
    })
}

/// One regression (or would-be regression, when warn-only).
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Case label.
    pub case: String,
    /// Current items/sec.
    pub current: f64,
    /// Baseline-median items/sec.
    pub reference: f64,
}

impl Finding {
    /// Percent drop below the reference.
    pub fn drop_percent(&self) -> f64 {
        (1.0 - self.current / self.reference) * 100.0
    }
}

/// Outcome of gating one report against the baseline directory.
#[derive(Debug, Clone, PartialEq)]
pub struct GateOutcome {
    /// Report file name, e.g. `BENCH_coordinator.json`.
    pub bench: String,
    /// Enforcing (non-provisional) snapshots found.
    pub baselines: usize,
    /// Provisional snapshots found (trajectory seeds; never enforce).
    pub provisional: usize,
    /// Cases with at least one baseline data point.
    pub compared: usize,
    /// Cases below tolerance.
    pub regressions: Vec<Finding>,
    /// The report's `pipeline_*`, `sampled_*`, `telemetry_*` and
    /// `router_*` metrics (stage/execute speedups, occupancy counters,
    /// phase-sampling speedup and CPI error, router-tier scale-up),
    /// surfaced informationally so every trajectory is visible in each
    /// gate run.
    pub pipeline_metrics: Vec<(String, f64)>,
}

impl GateOutcome {
    /// True when the gate is past warn-only (enough real baselines).
    pub fn enforced(&self, cfg: &GateConfig) -> bool {
        self.baselines >= cfg.min_baselines
    }

    /// True when the build should fail.
    pub fn failed(&self, cfg: &GateConfig) -> bool {
        self.enforced(cfg) && !self.regressions.is_empty()
    }
}

/// Baseline snapshots for `bench` ("BENCH_x.json"), in sequence order.
pub fn baseline_paths(dir: &Path, bench: &str) -> Vec<PathBuf> {
    let suffix = format!("-{bench}");
    let mut paths: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Err(_) => Vec::new(), // no baselines yet — warn-only territory
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.ends_with(&suffix) && n.len() > suffix.len())
            })
            .collect(),
    };
    paths.sort();
    paths
}

fn median(mut v: Vec<f64>) -> Option<f64> {
    v.retain(|x| x.is_finite());
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    Some(v[v.len() / 2])
}

/// Gate one current report against the snapshots in `baselines_dir`.
pub fn check(current: &Path, baselines_dir: &Path, cfg: &GateConfig) -> Result<GateOutcome> {
    let bench = current
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("bad report path {current:?}"))?
        .to_string();
    let text =
        std::fs::read_to_string(current).with_context(|| format!("read report {current:?}"))?;
    let report = parse_report(&text).with_context(|| format!("parse {bench}"))?;

    let mut enforcing = 0usize;
    let mut provisional = 0usize;
    let mut history: Vec<Report> = Vec::new();
    for path in baseline_paths(baselines_dir, &bench) {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read baseline {path:?}"))?;
        let snap = parse_report(&text).with_context(|| format!("parse baseline {path:?}"))?;
        if snap.provisional {
            provisional += 1;
        } else {
            enforcing += 1;
            history.push(snap);
        }
    }

    let mut compared = 0usize;
    let mut regressions = Vec::new();
    for case in &report.cases {
        let rates: Vec<f64> = history
            .iter()
            .flat_map(|s| &s.cases)
            .filter(|c| c.name == case.name)
            .map(|c| c.items_per_sec)
            .collect();
        let Some(reference) = median(rates) else {
            continue;
        };
        compared += 1;
        if case.items_per_sec < reference * (1.0 - cfg.tolerance) {
            regressions.push(Finding {
                case: case.name.clone(),
                current: case.items_per_sec,
                reference,
            });
        }
    }
    let pipeline_metrics: Vec<(String, f64)> = report
        .metrics
        .iter()
        .filter(|(k, _)| {
            k.starts_with("pipeline_")
                || k.starts_with("sampled_")
                || k.starts_with("telemetry_")
                || k.starts_with("router_")
        })
        .cloned()
        .collect();
    Ok(GateOutcome {
        bench,
        baselines: enforcing,
        provisional,
        compared,
        regressions,
        pipeline_metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::benchkit::{BenchReport, Measurement};

    fn report_json(cases: &[(&str, f64)], provisional: bool) -> String {
        let mut r = BenchReport::new();
        for (name, ips) in cases {
            // mean_ns chosen so items_per_sec comes out at `ips`.
            r.push(Measurement {
                name: name.to_string(),
                items: 1_000_000,
                mean_ns: 1_000_000.0 * 1e9 / ips,
                min_ns: 1.0,
                max_ns: 2.0,
            });
        }
        r.metric("smoke", 1.0);
        if provisional {
            r.metric("provisional", 1.0);
        }
        r.to_json()
    }

    fn fixture(tag: &str) -> (PathBuf, PathBuf) {
        let root = std::env::temp_dir().join(format!("tao-gate-{tag}-{}", std::process::id()));
        let baselines = root.join("baselines");
        std::fs::create_dir_all(&baselines).unwrap();
        (root, baselines)
    }

    fn write_snap(dir: &Path, seq: usize, bench: &str, json: &str) {
        std::fs::write(dir.join(format!("{seq:04}-{bench}")), json).unwrap();
    }

    #[test]
    fn parses_benchkit_reports() {
        let r = parse_report(&report_json(&[("g/a", 100e6), ("g/b", 5e6)], false)).unwrap();
        assert_eq!(r.cases.len(), 2);
        assert_eq!(r.cases[0].name, "g/a");
        assert!((r.cases[0].items_per_sec - 100e6).abs() / 100e6 < 1e-3);
        assert!(!r.provisional);
        assert!(parse_report(&report_json(&[], true)).unwrap().provisional);
    }

    #[test]
    fn synthetic_regression_fails_once_enforced() {
        let (root, baselines) = fixture("fail");
        let bench = "BENCH_x.json";
        for seq in 1..=3 {
            write_snap(&baselines, seq, bench, &report_json(&[("g/a", 100e6)], false));
        }
        // 20% drop > 15% tolerance: regression, and 3 baselines enforce.
        let current = root.join(bench);
        std::fs::write(&current, report_json(&[("g/a", 80e6)], false)).unwrap();
        let cfg = GateConfig::default();
        let o = check(&current, &baselines, &cfg).unwrap();
        assert_eq!(o.baselines, 3);
        assert_eq!(o.compared, 1);
        assert_eq!(o.regressions.len(), 1);
        assert!(o.regressions[0].drop_percent() > 19.0);
        assert!(o.failed(&cfg), "a >15% regression with 3 baselines must fail");

        // A 10% drop stays inside tolerance.
        std::fs::write(&current, report_json(&[("g/a", 90e6)], false)).unwrap();
        let o = check(&current, &baselines, &cfg).unwrap();
        assert!(o.regressions.is_empty());
        assert!(!o.failed(&cfg));
    }

    #[test]
    fn warn_only_until_enough_real_baselines() {
        let (root, baselines) = fixture("warn");
        let bench = "BENCH_y.json";
        // Two real + three provisional snapshots: still warn-only.
        for seq in 1..=3 {
            write_snap(&baselines, seq, bench, &report_json(&[("g/a", 100e6)], true));
        }
        for seq in 4..=5 {
            write_snap(&baselines, seq, bench, &report_json(&[("g/a", 100e6)], false));
        }
        let current = root.join(bench);
        std::fs::write(&current, report_json(&[("g/a", 50e6)], false)).unwrap();
        let cfg = GateConfig::default();
        let o = check(&current, &baselines, &cfg).unwrap();
        assert_eq!(o.baselines, 2);
        assert_eq!(o.provisional, 3);
        // The halving is still *reported*...
        assert_eq!(o.regressions.len(), 1);
        // ...but does not fail the build yet.
        assert!(!o.failed(&cfg));
    }

    #[test]
    fn empty_or_missing_baseline_dir_is_warn_only() {
        let (root, baselines) = fixture("empty");
        let bench = "BENCH_z.json";
        let current = root.join(bench);
        std::fs::write(&current, report_json(&[("g/a", 1e6)], false)).unwrap();
        let cfg = GateConfig::default();
        let o = check(&current, &baselines, &cfg).unwrap();
        assert_eq!(o.baselines, 0);
        assert_eq!(o.compared, 0);
        assert!(!o.failed(&cfg));
        // A directory that does not exist at all behaves the same.
        let o = check(&current, &root.join("nope"), &cfg).unwrap();
        assert!(!o.failed(&cfg));
    }

    #[test]
    fn unknown_and_disjoint_cases_are_ignored() {
        let (root, baselines) = fixture("disjoint");
        let bench = "BENCH_w.json";
        for seq in 1..=3 {
            // Baselines carry a case the current run does not, and miss
            // one the current run has (e.g. smoke vs full names).
            write_snap(&baselines, seq, bench, &report_json(&[("g/old-200k", 9e6)], false));
        }
        let current = root.join(bench);
        std::fs::write(&current, report_json(&[("g/new-50k", 1e6)], false)).unwrap();
        let cfg = GateConfig::default();
        let o = check(&current, &baselines, &cfg).unwrap();
        assert_eq!(o.baselines, 3);
        assert_eq!(o.compared, 0);
        assert!(!o.failed(&cfg));
    }

    #[test]
    fn pipeline_metrics_surface_in_outcome() {
        let (root, baselines) = fixture("pipe");
        let bench = "BENCH_p.json";
        let mut r = BenchReport::new();
        r.push(Measurement {
            name: "e/w2".into(),
            items: 100,
            mean_ns: 1e6,
            min_ns: 1.0,
            max_ns: 2.0,
        });
        r.metric("pipeline_speedup_workers2", 1.25);
        r.metric("pipeline_exec_busy_frac", 0.9);
        r.metric("sampled_speedup", 5.0);
        r.metric("router_scaleup_2w", 1.9);
        r.metric("smoke", 1.0);
        let current = root.join(bench);
        std::fs::write(&current, r.to_json()).unwrap();
        let o = check(&current, &baselines, &GateConfig::default()).unwrap();
        assert_eq!(
            o.pipeline_metrics.len(),
            4,
            "only pipeline_*/sampled_*/telemetry_*/router_* metrics surface"
        );
        assert!(o
            .pipeline_metrics
            .iter()
            .any(|(k, v)| k == "pipeline_speedup_workers2" && (*v - 1.25).abs() < 1e-9));
        assert!(o
            .pipeline_metrics
            .iter()
            .any(|(k, v)| k == "sampled_speedup" && (*v - 5.0).abs() < 1e-9));
        assert!(o
            .pipeline_metrics
            .iter()
            .any(|(k, v)| k == "router_scaleup_2w" && (*v - 1.9).abs() < 1e-9));
    }

    #[test]
    fn median_is_robust_to_one_noisy_snapshot() {
        let (root, baselines) = fixture("median");
        let bench = "BENCH_m.json";
        write_snap(&baselines, 1, bench, &report_json(&[("g/a", 100e6)], false));
        write_snap(&baselines, 2, bench, &report_json(&[("g/a", 102e6)], false));
        // One wildly fast outlier must not move the reference much.
        write_snap(&baselines, 3, bench, &report_json(&[("g/a", 500e6)], false));
        let current = root.join(bench);
        std::fs::write(&current, report_json(&[("g/a", 95e6)], false)).unwrap();
        let cfg = GateConfig::default();
        let o = check(&current, &baselines, &cfg).unwrap();
        // Against median 102e6 a 95e6 run is a ~7% dip: clean.
        assert!(o.regressions.is_empty());
    }
}
