//! Minimal JSON parser + serializer (vendored-build friendly; no serde).
//!
//! Supports the subset the artifact metadata and the serving protocol
//! use: objects, arrays, strings (with escapes), numbers, booleans and
//! null. Strict enough to reject malformed input; small enough to
//! audit. Serialization goes through [`Json`]'s `Display` impl; object
//! keys render sorted (`BTreeMap`), so documents are deterministic and
//! diff-friendly. `f64` values round-trip exactly: Rust's shortest
//! round-trip `Display` feeds back through the parser's `str::parse`.

use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// any number (stored as f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (rejects non-integral numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers for loader code.
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field {key:?}"))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    /// Required finite number field.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {key:?}"))
    }

    /// Build an object from key/value pairs (serialization helper).
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// String value constructor.
    pub fn of_str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Number constructor (`u64` counters included — exact below 2^53,
    /// which covers every counter this repo emits).
    pub fn of_u64(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        self.to_string()
    }
}

fn escape_into(out: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    use std::fmt::Write;
    out.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\t' => out.write_str("\\t")?,
            '\r' => out.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => out.write_char(c)?,
        }
    }
    out.write_char('"')
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            // JSON has no NaN/Inf; emit null rather than invalid tokens.
            Json::Num(n) if !n.is_finite() => f.write_str("null"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => escape_into(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    escape_into(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad keyword at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(val)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", esc as char),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num_byte =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": 1, "b": [true, null, -2.5e1], "c": {"d": "x\ny"}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.req_u64("a").unwrap(), 1);
        let arr = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_f64().unwrap(), -25.0);
        assert_eq!(j.get("c").unwrap().req_str("d").unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse(r#""éé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "éé");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
        assert_eq!(Json::parse("-1.5").unwrap().as_f64().unwrap(), -1.5);
        assert!(Json::parse("1.5").unwrap().as_u64().is_none());
    }

    #[test]
    fn render_round_trips_through_parser() {
        let doc = Json::obj([
            ("name", Json::of_str("mcf \"quoted\"\n")),
            ("count", Json::of_u64(12345)),
            ("cycles", Json::Num(1234.56789)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("nested", Json::obj([("x", Json::Num(-2.5))])),
        ]);
        let text = doc.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn render_f64_is_bit_exact_round_trip() {
        // Serving equality checks compare f64 metric sums across the
        // HTTP boundary; the shortest round-trip Display + str::parse
        // pair must reproduce the exact bits.
        for v in [
            0.1f64 + 0.2,
            1.0 / 3.0,
            6.02214076e5,
            123456789.123456789,
            f64::MIN_POSITIVE,
        ] {
            let text = Json::Num(v).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{text}");
        }
        // Non-finite values degrade to null, not invalid JSON.
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn render_escapes_control_characters() {
        let j = Json::of_str("a\u{1}b");
        assert_eq!(j.render(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn round_trips_real_artifact_meta_shape() {
        let doc = r#"{
          "kind": "tao", "batch": 256, "context": 32,
          "feature_dim": 154, "outputs": ["fetch", "exec"],
          "feature_config": {"nb": 1024, "nq": 32, "nm": 64},
          "vocab_hash": "abc123", "kernel": "pallas"
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.req_u64("batch").unwrap(), 256);
        assert_eq!(j.req_str("kind").unwrap(), "tao");
        assert_eq!(j.get("feature_config").unwrap().req_u64("nm").unwrap(), 64);
        assert_eq!(j.get("outputs").unwrap().as_arr().unwrap().len(), 2);
    }
}
