//! The process-global metric registry: lock-free handles over a
//! BTreeMap-backed family table.
//!
//! Registration (naming a metric, resolving its label set) takes a
//! mutex and is meant to happen once per producer — at daemon boot, at
//! lane start, at a `OnceLock` call site — returning a cheap cloneable
//! handle ([`Counter`], [`Gauge`], [`Histogram`]) that updates shared
//! atomics with relaxed ordering. Lane respawns re-resolve the same
//! `(name, labels)` cell, which is what makes per-lane counters
//! cumulative across supervisor restarts: the cells outlive the lane
//! threads.
//!
//! Families and series render in deterministic order (both maps are
//! `BTreeMap`s), which the Prometheus exposition format test pins.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

// ---------------------------------------------------------------------
// Arming
// ---------------------------------------------------------------------

/// Fast-path gate: while `false`, every handle update returns after one
/// relaxed load (the `util::fault` disarmed bar).
static ARMED: AtomicBool = AtomicBool::new(false);

/// Is telemetry recording? One relaxed atomic load.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Start recording (serve boot, `--profile` runs, tests).
pub fn arm() {
    ARMED.store(true, Ordering::Relaxed);
}

/// Stop recording. Registered cells keep their values; [`MetricRegistry::reset`]
/// zeroes them.
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Histogram core
// ---------------------------------------------------------------------

/// Latency bucket count: upper bounds double from 1µs, so bucket `i`
/// covers values ≤ `1µs << i` and the last finite bound is ~33.6s.
/// One extra overflow bucket catches everything above.
pub const HIST_BUCKETS: usize = 26;

/// Upper bound of finite bucket `i`, nanoseconds.
#[inline]
pub fn bucket_bound_ns(i: usize) -> u64 {
    1_000u64 << i
}

#[allow(clippy::declare_interior_mutable_const)] // repeat-initializer only
const BUCKET_INIT: AtomicU64 = AtomicU64::new(0);

/// Shared histogram cell: per-bucket counts plus sum/count for means
/// and Prometheus `_sum`/`_count`.
#[derive(Debug)]
pub(crate) struct HistCore {
    buckets: [AtomicU64; HIST_BUCKETS + 1],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl HistCore {
    fn new() -> HistCore {
        HistCore {
            buckets: [BUCKET_INIT; HIST_BUCKETS + 1],
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn record(&self, ns: u64) {
        let mut idx = HIST_BUCKETS; // overflow unless a bound fits
        for i in 0..HIST_BUCKETS {
            if ns <= bucket_bound_ns(i) {
                idx = i;
                break;
            }
        }
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum_ns.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// A histogram read at one instant: per-bucket (non-cumulative) counts,
/// `buckets.len() == HIST_BUCKETS + 1` with the overflow bucket last.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket counts (not cumulative; overflow last).
    pub buckets: Vec<u64>,
    /// Σ recorded values, nanoseconds.
    pub sum_ns: u64,
    /// Recorded values.
    pub count: u64,
}

impl HistSnapshot {
    /// Quantile estimate in nanoseconds: walk the cumulative counts to
    /// the bucket holding rank `ceil(q·count)` and interpolate linearly
    /// inside it. Empty histograms answer 0; ranks landing in the
    /// overflow bucket answer the last finite bound (the histogram
    /// cannot see further).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen + n >= rank {
                if i >= HIST_BUCKETS {
                    return bucket_bound_ns(HIST_BUCKETS - 1) as f64;
                }
                let lo = if i == 0 { 0.0 } else { bucket_bound_ns(i - 1) as f64 };
                let hi = bucket_bound_ns(i) as f64;
                let frac = (rank - seen) as f64 / n as f64;
                return lo + frac * (hi - lo);
            }
            seen += n;
        }
        bucket_bound_ns(HIST_BUCKETS - 1) as f64 // unreachable if counts are consistent
    }

    /// [`HistSnapshot::quantile_ns`] in seconds.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.quantile_ns(q) / 1e9
    }

    /// Σ recorded values in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_ns as f64 / 1e9
    }
}

// ---------------------------------------------------------------------
// Handles
// ---------------------------------------------------------------------

/// Monotonic counter handle. Updates are relaxed atomics gated on
/// [`armed`]; [`Counter::mirror`] overwrites unconditionally, for
/// scrape-time mirroring of counters maintained elsewhere (fault probe
/// stats, cache stats).
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.inc_by(1);
    }

    /// Add `n` (no-op while disarmed).
    #[inline]
    pub fn inc_by(&self, n: u64) {
        if armed() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Overwrite with an externally-maintained cumulative value. Only
    /// for mirroring counters whose source of truth lives elsewhere
    /// (e.g. `util::fault` probe stats at `/metrics` scrape time).
    pub fn mirror(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge handle: a settable signed level (queue depth, active jobs,
/// cache entries).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Set the level (no-op while disarmed).
    #[inline]
    pub fn set(&self, v: i64) {
        if armed() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    /// Adjust the level by `d` (no-op while disarmed).
    #[inline]
    pub fn adjust(&self, d: i64) {
        if armed() {
            self.0.fetch_add(d, Ordering::Relaxed);
        }
    }

    /// Current level.
    pub fn value(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram handle (fixed doubling buckets, see
/// [`HIST_BUCKETS`]).
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    /// Record one value in nanoseconds (no-op while disarmed).
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        if armed() {
            self.0.record(ns);
        }
    }

    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Read the current state.
    pub fn snapshot(&self) -> HistSnapshot {
        self.0.snapshot()
    }
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// What a family holds (fixed at first registration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonic count.
    Counter,
    /// Settable level.
    Gauge,
    /// Fixed-bucket latency distribution.
    Histogram,
}

impl MetricKind {
    /// The Prometheus `# TYPE` keyword.
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

#[derive(Debug)]
enum SeriesCell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicI64>),
    Hist(Arc<HistCore>),
}

#[derive(Debug)]
struct Family {
    kind: MetricKind,
    help: String,
    series: BTreeMap<Vec<(String, String)>, SeriesCell>,
}

/// One series read at one instant.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesSnapshot {
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// The value.
    pub value: SeriesValue,
}

/// A snapshot value, by family kind.
#[derive(Debug, Clone, PartialEq)]
pub enum SeriesValue {
    /// Counter value.
    Counter(u64),
    /// Gauge level.
    Gauge(i64),
    /// Histogram state.
    Hist(HistSnapshot),
}

/// One family read at one instant (series in deterministic label
/// order).
#[derive(Debug, Clone, PartialEq)]
pub struct FamilySnapshot {
    /// Family name, e.g. `tao_cache_hits_total`.
    pub name: String,
    /// Family kind.
    pub kind: MetricKind,
    /// `# HELP` text.
    pub help: String,
    /// The series.
    pub series: Vec<SeriesSnapshot>,
}

/// The registry: families by name, series by sorted label set.
#[derive(Debug, Default)]
pub struct MetricRegistry {
    families: Mutex<BTreeMap<String, Family>>,
}

/// The process-global registry.
pub fn registry() -> &'static MetricRegistry {
    static REGISTRY: OnceLock<MetricRegistry> = OnceLock::new();
    REGISTRY.get_or_init(MetricRegistry::default)
}

fn label_key(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut key: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    key.sort();
    key
}

impl MetricRegistry {
    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, Family>> {
        // Registration only inserts or reads whole cells, never leaves
        // one mid-update, so recovering from a peer panic is sound.
        self.families.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn family<'a>(
        map: &'a mut BTreeMap<String, Family>,
        name: &str,
        kind: MetricKind,
        help: &str,
    ) -> &'a mut Family {
        let fam = map.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name} registered as {} and {}",
            fam.kind.as_str(),
            kind.as_str()
        );
        fam
    }

    /// Resolve (registering on first use) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let mut map = self.lock();
        let fam = Self::family(&mut map, name, MetricKind::Counter, help);
        let cell = fam
            .series
            .entry(label_key(labels))
            .or_insert_with(|| SeriesCell::Counter(Arc::new(AtomicU64::new(0))));
        match cell {
            SeriesCell::Counter(c) => Counter(c.clone()),
            _ => unreachable!("kind checked by family()"),
        }
    }

    /// Resolve (registering on first use) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let mut map = self.lock();
        let fam = Self::family(&mut map, name, MetricKind::Gauge, help);
        let cell = fam
            .series
            .entry(label_key(labels))
            .or_insert_with(|| SeriesCell::Gauge(Arc::new(AtomicI64::new(0))));
        match cell {
            SeriesCell::Gauge(g) => Gauge(g.clone()),
            _ => unreachable!("kind checked by family()"),
        }
    }

    /// Resolve (registering on first use) a histogram series.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Histogram {
        let mut map = self.lock();
        let fam = Self::family(&mut map, name, MetricKind::Histogram, help);
        let cell = fam
            .series
            .entry(label_key(labels))
            .or_insert_with(|| SeriesCell::Hist(Arc::new(HistCore::new())));
        match cell {
            SeriesCell::Hist(h) => Histogram(h.clone()),
            _ => unreachable!("kind checked by family()"),
        }
    }

    /// Read every family, in deterministic (name, label) order.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let map = self.lock();
        map.iter()
            .map(|(name, fam)| FamilySnapshot {
                name: name.clone(),
                kind: fam.kind,
                help: fam.help.clone(),
                series: fam
                    .series
                    .iter()
                    .map(|(labels, cell)| SeriesSnapshot {
                        labels: labels.clone(),
                        value: match cell {
                            SeriesCell::Counter(c) => {
                                SeriesValue::Counter(c.load(Ordering::Relaxed))
                            }
                            SeriesCell::Gauge(g) => SeriesValue::Gauge(g.load(Ordering::Relaxed)),
                            SeriesCell::Hist(h) => SeriesValue::Hist(h.snapshot()),
                        },
                    })
                    .collect(),
            })
            .collect()
    }

    /// Current value of one counter series, if registered. Sums across
    /// all series of the family when `labels` is `None` (label-agnostic
    /// totals for tests and the stats endpoint).
    pub fn counter_value(&self, name: &str, labels: Option<&[(&str, &str)]>) -> Option<u64> {
        let map = self.lock();
        let fam = map.get(name)?;
        let key = labels.map(label_key);
        let mut total = 0u64;
        let mut found = false;
        for (k, cell) in &fam.series {
            if key.as_ref().is_some_and(|want| want != k) {
                continue;
            }
            if let SeriesCell::Counter(c) = cell {
                total += c.load(Ordering::Relaxed);
                found = true;
            }
        }
        found.then_some(total)
    }

    /// Zero every registered value (registration survives). For tests
    /// and the armed-vs-disarmed bench, under [`crate::telemetry::exclusive`].
    pub fn reset(&self) {
        let map = self.lock();
        for fam in map.values() {
            for cell in fam.series.values() {
                match cell {
                    SeriesCell::Counter(c) => c.store(0, Ordering::Relaxed),
                    SeriesCell::Gauge(g) => g.store(0, Ordering::Relaxed),
                    SeriesCell::Hist(h) => h.reset(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::exclusive;

    #[test]
    fn bucket_boundaries_double_from_one_microsecond() {
        assert_eq!(bucket_bound_ns(0), 1_000);
        assert_eq!(bucket_bound_ns(1), 2_000);
        assert_eq!(bucket_bound_ns(10), 1_024_000);
        // Last finite bound ≈ 33.6s: wide enough for any request.
        assert!(bucket_bound_ns(HIST_BUCKETS - 1) > 30_000_000_000);
    }

    #[test]
    fn histogram_boundary_values_land_in_their_bucket() {
        let core = HistCore::new();
        // Exactly on a bound → that bucket (le semantics); one past → next.
        core.record(1_000);
        core.record(1_001);
        core.record(2_000);
        core.record(0);
        let s = core.snapshot();
        assert_eq!(s.buckets[0], 2); // 0 and 1000
        assert_eq!(s.buckets[1], 2); // 1001 and 2000
        assert_eq!(s.count, 4);
        assert_eq!(s.sum_ns, 4_001);
    }

    #[test]
    fn histogram_overflow_bucket_catches_the_tail() {
        let core = HistCore::new();
        core.record(u64::MAX / 2);
        let s = core.snapshot();
        assert_eq!(s.buckets[HIST_BUCKETS], 1);
        // A rank in the overflow bucket answers the last finite bound.
        assert_eq!(s.quantile_ns(0.99), bucket_bound_ns(HIST_BUCKETS - 1) as f64);
    }

    #[test]
    fn quantiles_on_empty_single_and_uniform_fills() {
        let core = HistCore::new();
        assert_eq!(core.snapshot().quantile_ns(0.99), 0.0);

        core.record(5_000); // single sample, bucket (4µs, 8µs]
        let s = core.snapshot();
        let p99 = s.quantile_ns(0.99);
        assert!(p99 > 4_000.0 && p99 <= 8_000.0, "p99 {p99}");
        // Every quantile of a single sample answers from its bucket.
        assert_eq!(s.quantile_ns(0.01), p99);

        // Uniform fill of one bucket: quantiles interpolate across it.
        let core = HistCore::new();
        for _ in 0..100 {
            core.record(3_000); // bucket (2µs, 4µs]
        }
        let s = core.snapshot();
        let p50 = s.quantile_ns(0.50);
        let p99 = s.quantile_ns(0.99);
        assert!(p50 > 2_000.0 && p50 <= 4_000.0);
        assert!(p99 > p50, "interpolation must order p99 {p99} above p50 {p50}");
        assert!((s.quantile_ns(1.0) - 4_000.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_across_buckets_follow_mass() {
        let core = HistCore::new();
        for _ in 0..90 {
            core.record(1_000); // bucket 0
        }
        for _ in 0..10 {
            core.record(1_000_000); // ~bucket 10
        }
        let s = core.snapshot();
        assert!(s.quantile_ns(0.50) <= 1_000.0);
        assert!(s.quantile_ns(0.95) > 500_000.0);
    }

    #[test]
    fn registry_concurrent_totals_are_exact() {
        let _gate = exclusive();
        registry().reset();
        arm();
        const THREADS: usize = 8;
        const METRICS: usize = 4;
        const PER: u64 = 10_000;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    // Each thread resolves its own handles, hammering
                    // registration and update concurrently.
                    let counters: Vec<Counter> = (0..METRICS)
                        .map(|m| {
                            let label = m.to_string();
                            registry().counter(
                                "tao_test_concurrency_total",
                                "test",
                                &[("m", label.as_str())],
                            )
                        })
                        .collect();
                    let h = registry().histogram("tao_test_concurrency_ns", "test", &[]);
                    for i in 0..PER {
                        counters[(i % METRICS as u64) as usize].inc();
                        h.record_ns(i);
                    }
                });
            }
        });
        let total = registry()
            .counter_value("tao_test_concurrency_total", None)
            .unwrap();
        assert_eq!(total, THREADS as u64 * PER);
        for m in 0..METRICS {
            let label = m.to_string();
            let v = registry()
                .counter_value("tao_test_concurrency_total", Some(&[("m", label.as_str())]))
                .unwrap();
            assert_eq!(v, THREADS as u64 * PER / METRICS as u64);
        }
        let h = registry().histogram("tao_test_concurrency_ns", "test", &[]);
        assert_eq!(h.snapshot().count, THREADS as u64 * PER);
        disarm();
        registry().reset();
    }

    #[test]
    fn disarmed_updates_are_dropped_and_reset_zeroes() {
        let _gate = exclusive();
        registry().reset();
        disarm();
        let c = registry().counter("tao_test_disarmed_total", "test", &[]);
        c.inc();
        assert_eq!(c.value(), 0, "disarmed increments must be dropped");
        arm();
        c.inc_by(3);
        assert_eq!(c.value(), 3);
        let g = registry().gauge("tao_test_disarmed_gauge", "test", &[]);
        g.set(7);
        g.adjust(-2);
        assert_eq!(g.value(), 5);
        registry().reset();
        assert_eq!(c.value(), 0);
        assert_eq!(g.value(), 0);
        disarm();
    }

    #[test]
    fn label_order_does_not_split_series() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let a = registry().counter("tao_test_labels_total", "t", &[("a", "1"), ("b", "2")]);
        let b = registry().counter("tao_test_labels_total", "t", &[("b", "2"), ("a", "1")]);
        a.inc();
        b.inc();
        assert_eq!(a.value(), 2, "permuted label order must resolve one cell");
        disarm();
        registry().reset();
    }
}
