//! Leveled structured JSON logging (`--log-json`).
//!
//! One line per event on stderr:
//!
//! ```text
//! {"ts_ms":1723100000123,"level":"info","event":"job_done","trace_id":"9f3c...","chunks":4}
//! ```
//!
//! Disabled (the default) an [`log_enabled`] check is one relaxed
//! atomic load, so emit sites stay compiled into the serving hot paths.
//! The daemon enables it from `tao serve --log-json [LEVEL]`; field
//! order is emission order, `ts_ms` is wall-clock Unix milliseconds.
//! Lines are JSON the crate's own `util::json` parser accepts (pinned
//! by test), so `grep trace_id log | tao`-side tooling can parse them.

use std::io::Write;
use std::sync::atomic::{AtomicI32, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// A failed request or lost lane.
    Error = 0,
    /// Degraded but serving (respawns, deadline expiries).
    Warn = 1,
    /// Lifecycle events (job admitted / done, lane up).
    Info = 2,
    /// Per-stage spans and cache traffic.
    Debug = 3,
}

impl Level {
    /// Wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Inverse of [`Level::as_str`].
    pub fn from_str(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// `-1` = disabled; otherwise the maximum emitted level.
static JSON_LEVEL: AtomicI32 = AtomicI32::new(-1);

/// Enable JSON logging up to and including `level`.
pub fn enable_json(level: Level) {
    JSON_LEVEL.store(level as i32, Ordering::Relaxed);
}

/// Disable JSON logging.
pub fn disable_json() {
    JSON_LEVEL.store(-1, Ordering::Relaxed);
}

/// Would an event at `level` be emitted? One relaxed atomic load.
#[inline]
pub fn log_enabled(level: Level) -> bool {
    level as i32 <= JSON_LEVEL.load(Ordering::Relaxed)
}

/// One event field value.
#[derive(Debug, Clone, Copy)]
pub enum Field<'a> {
    /// String value (JSON-escaped on emit).
    Str(&'a str),
    /// Unsigned integer.
    U64(u64),
    /// Signed integer.
    I64(i64),
    /// Float (non-finite renders as null).
    F64(f64),
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Render one event line (separate from [`emit`] so tests can pin the
/// format without capturing stderr).
pub fn render_line(ts_ms: u64, level: Level, event: &str, fields: &[(&str, Field)]) -> String {
    let mut line = String::with_capacity(64 + fields.len() * 16);
    line.push_str("{\"ts_ms\":");
    line.push_str(&ts_ms.to_string());
    line.push_str(",\"level\":\"");
    line.push_str(level.as_str());
    line.push_str("\",\"event\":\"");
    escape_into(&mut line, event);
    line.push('"');
    for (k, v) in fields {
        line.push_str(",\"");
        escape_into(&mut line, k);
        line.push_str("\":");
        match v {
            Field::Str(s) => {
                line.push('"');
                escape_into(&mut line, s);
                line.push('"');
            }
            Field::U64(n) => line.push_str(&n.to_string()),
            Field::I64(n) => line.push_str(&n.to_string()),
            Field::F64(x) if x.is_finite() => line.push_str(&format!("{x}")),
            Field::F64(_) => line.push_str("null"),
        }
    }
    line.push('}');
    line
}

/// Emit one event line to stderr if `level` is enabled.
pub fn emit(level: Level, event: &str, fields: &[(&str, Field)]) {
    if !log_enabled(level) {
        return;
    }
    let ts_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0);
    let line = render_line(ts_ms, level, event, fields);
    // One locked write per line keeps concurrent lanes' lines whole.
    let stderr = std::io::stderr();
    let mut w = stderr.lock();
    let _ = writeln!(w, "{line}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn lines_are_valid_json_with_ordered_fields() {
        let line = render_line(
            123,
            Level::Info,
            "job_done",
            &[
                ("trace_id", Field::Str("abc123")),
                ("chunks", Field::U64(4)),
                ("delta", Field::I64(-2)),
                ("cpi", Field::F64(1.25)),
                ("nan", Field::F64(f64::NAN)),
            ],
        );
        let j = Json::parse(&line).expect("log line must parse as JSON");
        assert_eq!(j.get("ts_ms").and_then(|v| v.as_u64()), Some(123));
        assert_eq!(j.get("level").and_then(|v| v.as_str()), Some("info"));
        assert_eq!(j.get("event").and_then(|v| v.as_str()), Some("job_done"));
        assert_eq!(j.get("trace_id").and_then(|v| v.as_str()), Some("abc123"));
        assert_eq!(j.get("chunks").and_then(|v| v.as_u64()), Some(4));
        assert_eq!(j.get("cpi").and_then(|v| v.as_f64()), Some(1.25));
        assert!(matches!(j.get("nan"), Some(Json::Null)));
    }

    #[test]
    fn escaping_survives_hostile_strings() {
        let line = render_line(
            1,
            Level::Error,
            "weird \"event\"\n",
            &[("msg", Field::Str("a\\b\"c\nd\te\u{1}"))],
        );
        let j = Json::parse(&line).expect("escaped line must parse");
        assert_eq!(
            j.get("msg").and_then(|v| v.as_str()),
            Some("a\\b\"c\nd\te\u{1}")
        );
    }

    #[test]
    fn level_gate_and_names_round_trip() {
        disable_json();
        assert!(!log_enabled(Level::Error));
        enable_json(Level::Info);
        assert!(log_enabled(Level::Error));
        assert!(log_enabled(Level::Info));
        assert!(!log_enabled(Level::Debug));
        disable_json();
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::from_str(l.as_str()), Some(l));
        }
        assert_eq!(Level::from_str("nope"), None);
    }
}
