//! Offline per-stage profiles for `tao simulate --profile` and
//! `tao datagen --profile`.
//!
//! A [`Profile`] times named phases on the main thread; phases run
//! sequentially and tile the wall clock, so their sum matches total
//! wall time by construction (the acceptance bar is sum within 5% — the
//! residual is only the untimed glue between phases). Registry stage
//! histograms (`tao_stage_seconds`) are attached as *attribution*
//! detail: for pipelined runs those spans run on worker threads and may
//! overlap each other and the phases, so they explain where time went
//! inside a phase but are not expected to tile.
//!
//! Output is a human table ([`Profile::render_table`]) plus a
//! machine-readable `profile.json` ([`Profile::to_json`]) rendered
//! through `util::json` (sorted keys, deterministic).

use super::registry::{registry, FamilySnapshot, SeriesValue};
use super::span::STAGE_FAMILY;
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One timed phase.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Phase name as passed to [`Profile::phase`].
    pub name: String,
    /// Phase wall-clock duration.
    pub elapsed: Duration,
}

/// Per-stage attribution pulled from the registry stage histograms.
#[derive(Debug, Clone)]
pub struct StageAttribution {
    /// The `stage` label value.
    pub stage: String,
    /// Recorded span count.
    pub count: u64,
    /// Σ span time, seconds.
    pub total_secs: f64,
    /// p50 span latency, seconds.
    pub p50_secs: f64,
    /// p95 span latency, seconds.
    pub p95_secs: f64,
    /// p99 span latency, seconds.
    pub p99_secs: f64,
}

/// A main-thread wall-clock profile: sequential named phases plus
/// registry stage attribution collected at report time.
#[derive(Debug)]
pub struct Profile {
    started: Instant,
    phases: Vec<Phase>,
}

impl Profile {
    /// Start the profile clock (also arms telemetry so stage spans
    /// record; callers disarm when done if they armed only for this).
    pub fn start() -> Profile {
        Profile {
            started: Instant::now(),
            phases: Vec::new(),
        }
    }

    /// Run `f` as a named phase, timing it.
    pub fn phase<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push(Phase {
            name: name.to_string(),
            elapsed: t0.elapsed(),
        });
        out
    }

    /// Record an externally-timed phase (for call sites that cannot
    /// wrap the work in a closure).
    pub fn record_phase(&mut self, name: &str, elapsed: Duration) {
        self.phases.push(Phase {
            name: name.to_string(),
            elapsed,
        });
    }

    /// Wall clock since [`Profile::start`].
    pub fn wall(&self) -> Duration {
        self.started.elapsed()
    }

    /// Timed phases in execution order.
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// Σ phase time, seconds.
    pub fn phase_sum_secs(&self) -> f64 {
        self.phases.iter().map(|p| p.elapsed.as_secs_f64()).sum()
    }

    /// Pull per-stage attribution from the registry's
    /// `tao_stage_seconds` family, ordered by total time descending.
    pub fn stage_attribution(&self) -> Vec<StageAttribution> {
        stage_attribution_from(&registry().snapshot())
    }

    /// Render the human-readable breakdown table.
    pub fn render_table(&self) -> String {
        let wall = self.wall().as_secs_f64();
        let mut out = String::new();
        out.push_str("profile: per-phase wall clock\n");
        out.push_str(&format!(
            "  {:<24} {:>12} {:>8}\n",
            "phase", "seconds", "% wall"
        ));
        for p in &self.phases {
            let secs = p.elapsed.as_secs_f64();
            let pct = if wall > 0.0 { 100.0 * secs / wall } else { 0.0 };
            out.push_str(&format!("  {:<24} {:>12.4} {:>7.1}%\n", p.name, secs, pct));
        }
        let sum = self.phase_sum_secs();
        let coverage = if wall > 0.0 { 100.0 * sum / wall } else { 0.0 };
        out.push_str(&format!(
            "  {:<24} {:>12.4} {:>7.1}%  (wall {:.4}s)\n",
            "total", sum, coverage, wall
        ));
        let stages = self.stage_attribution();
        if !stages.is_empty() {
            out.push_str("profile: stage attribution (spans; may overlap in pipelined runs)\n");
            out.push_str(&format!(
                "  {:<16} {:>9} {:>11} {:>10} {:>10} {:>10}\n",
                "stage", "count", "total s", "p50 s", "p95 s", "p99 s"
            ));
            for s in &stages {
                out.push_str(&format!(
                    "  {:<16} {:>9} {:>11.4} {:>10.6} {:>10.6} {:>10.6}\n",
                    s.stage, s.count, s.total_secs, s.p50_secs, s.p95_secs, s.p99_secs
                ));
            }
        }
        out
    }

    /// Serialize as the `profile.json` document (schema in
    /// `docs/OBSERVABILITY.md`).
    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                Json::obj([
                    ("name", Json::of_str(&p.name)),
                    ("seconds", Json::Num(p.elapsed.as_secs_f64())),
                ])
            })
            .collect();
        let stages: Vec<Json> = self
            .stage_attribution()
            .iter()
            .map(|s| {
                Json::obj([
                    ("stage", Json::of_str(&s.stage)),
                    ("count", Json::of_u64(s.count)),
                    ("total_seconds", Json::Num(s.total_secs)),
                    ("p50_seconds", Json::Num(s.p50_secs)),
                    ("p95_seconds", Json::Num(s.p95_secs)),
                    ("p99_seconds", Json::Num(s.p99_secs)),
                ])
            })
            .collect();
        Json::obj([
            ("wall_seconds", Json::Num(self.wall().as_secs_f64())),
            ("phase_sum_seconds", Json::Num(self.phase_sum_secs())),
            ("phases", Json::Arr(phases)),
            ("stages", Json::Arr(stages)),
        ])
    }
}

/// Extract stage attribution rows from a registry snapshot (separated
/// from [`Profile`] so tests can feed a synthetic snapshot).
pub fn stage_attribution_from(families: &[FamilySnapshot]) -> Vec<StageAttribution> {
    let mut rows = Vec::new();
    for fam in families {
        if fam.name != STAGE_FAMILY {
            continue;
        }
        for series in &fam.series {
            let SeriesValue::Hist(h) = &series.value else {
                continue;
            };
            let stage = series
                .labels
                .iter()
                .find(|(k, _)| k == "stage")
                .map(|(_, v)| v.clone())
                .unwrap_or_default();
            rows.push(StageAttribution {
                stage,
                count: h.count,
                total_secs: h.sum_secs(),
                p50_secs: h.quantile_secs(0.50),
                p95_secs: h.quantile_secs(0.95),
                p99_secs: h.quantile_secs(0.99),
            });
        }
    }
    rows.sort_by(|a, b| {
        b.total_secs
            .partial_cmp(&a.total_secs)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::exclusive;
    use crate::telemetry::registry::{arm, disarm};
    use crate::telemetry::span::Stage;

    #[test]
    fn phases_tile_the_wall_clock() {
        let mut prof = Profile::start();
        prof.phase("a", || std::thread::sleep(Duration::from_millis(5)));
        prof.phase("b", || std::thread::sleep(Duration::from_millis(5)));
        let wall = prof.wall().as_secs_f64();
        let sum = prof.phase_sum_secs();
        assert!(sum > 0.009, "phases must be timed, got {sum}");
        assert!(
            sum <= wall,
            "phase sum {sum} cannot exceed wall {wall} for sequential phases"
        );
        // Sequential phases tile the run: the untimed residual is glue.
        assert!(
            (wall - sum) / wall < 0.5,
            "phases should cover most of the wall clock (sum {sum}, wall {wall})"
        );
    }

    #[test]
    fn json_and_table_include_phases_and_stage_attribution() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let stage = Stage::new("profile_test_stage");
        {
            let _sp = stage.span();
            std::thread::sleep(Duration::from_micros(200));
        }
        let mut prof = Profile::start();
        prof.record_phase("simulate", Duration::from_millis(8));
        let j = prof.to_json();
        let rendered = j.render();
        let back = Json::parse(&rendered).expect("profile.json must parse");
        let phases = back.get("phases").and_then(Json::as_arr).expect("phases");
        assert_eq!(phases.len(), 1);
        assert_eq!(
            phases[0].get("name").and_then(Json::as_str),
            Some("simulate")
        );
        let stages = back.get("stages").and_then(Json::as_arr).expect("stages");
        assert!(
            stages
                .iter()
                .any(|s| s.get("stage").and_then(Json::as_str) == Some("profile_test_stage")),
            "stage attribution must surface recorded spans"
        );
        let table = prof.render_table();
        assert!(table.contains("simulate"));
        assert!(table.contains("profile_test_stage"));
        disarm();
        registry().reset();
    }

    #[test]
    fn attribution_sorts_by_total_time() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let slow = Stage::new("attr_slow");
        let fast = Stage::new("attr_fast");
        {
            let _sp = slow.span();
            std::thread::sleep(Duration::from_millis(5));
        }
        {
            let _sp = fast.span();
        }
        let rows = stage_attribution_from(&registry().snapshot());
        let slow_pos = rows.iter().position(|r| r.stage == "attr_slow").unwrap();
        let fast_pos = rows.iter().position(|r| r.stage == "attr_fast").unwrap();
        assert!(slow_pos < fast_pos, "attribution must sort by total desc");
        disarm();
        registry().reset();
    }
}
