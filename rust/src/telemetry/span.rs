//! RAII span timers over registry histograms, and trace-id minting.
//!
//! A [`Stage`] is a pre-resolved handle on one series of the
//! `tao_stage_seconds{stage=...}` family; [`Stage::span`] opens a timer
//! that records its elapsed time into the histogram when dropped. While
//! telemetry is disarmed a span site costs one relaxed atomic load —
//! no clock read, no record (the `util::fault` bar, asserted by the
//! armed-vs-disarmed bench). Hot paths intern their stage once with the
//! [`crate::stage_span!`] macro.
//!
//! Spans can carry a `trace_id`; with `--log-json` at debug level each
//! annotated span emits one structured line on close, so a job's
//! per-stage timeline is greppable by its id.

use super::log::{self, Field, Level};
use super::registry::{armed, registry, Histogram};
use crate::util::hash::{fnv1a64, fnv1a64_u64, FNV_OFFSET};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Help text for the shared per-stage latency family.
pub const STAGE_HELP: &str = "Per-stage wall-clock latency (seconds) by pipeline stage.";

/// The shared per-stage latency family name.
pub const STAGE_FAMILY: &str = "tao_stage_seconds";

/// A pre-resolved per-stage histogram handle.
#[derive(Debug, Clone)]
pub struct Stage {
    name: &'static str,
    hist: Histogram,
}

impl Stage {
    /// Resolve the `tao_stage_seconds{stage=name}` series (registers on
    /// first use; cheap to clone afterwards).
    pub fn new(name: &'static str) -> Stage {
        Stage {
            name,
            hist: registry().histogram(STAGE_FAMILY, STAGE_HELP, &[("stage", name)]),
        }
    }

    /// Stage name (the `stage` label value).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Open a span. Disarmed: returns an inert span after one relaxed
    /// load.
    #[inline]
    pub fn span(&self) -> Span<'_> {
        Span {
            stage: self,
            start: if armed() { Some(Instant::now()) } else { None },
            trace_id: None,
        }
    }

    /// Open a span annotated with a job's trace id (logged on close at
    /// debug level when `--log-json` is active).
    #[inline]
    pub fn span_traced<'a>(&'a self, trace_id: &'a str) -> Span<'a> {
        Span {
            stage: self,
            start: if armed() { Some(Instant::now()) } else { None },
            trace_id: Some(trace_id),
        }
    }
}

/// A running stage timer; records into the stage histogram on drop.
#[derive(Debug)]
pub struct Span<'a> {
    stage: &'a Stage,
    start: Option<Instant>,
    trace_id: Option<&'a str>,
}

impl Span<'_> {
    /// Close early (identical to dropping).
    pub fn finish(self) {}
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(start) = self.start.take() else {
            return;
        };
        let elapsed = start.elapsed();
        self.stage.hist.record(elapsed);
        if log::log_enabled(Level::Debug) {
            let mut fields = vec![
                ("stage", Field::Str(self.stage.name)),
                ("us", Field::U64(elapsed.as_micros().min(u64::MAX as u128) as u64)),
            ];
            if let Some(id) = self.trace_id {
                fields.push(("trace_id", Field::Str(id)));
            }
            log::emit(Level::Debug, "span", &fields);
        }
    }
}

/// Mint a fresh request trace id: 16 hex chars, unique per process via
/// an atomic sequence, distinct across processes via pid + boot-time
/// entropy folded through FNV-1a. (Uniqueness is what matters — the id
/// is a grep key, not a secret.)
pub fn fresh_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    static SALT: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    let salt = *SALT.get_or_init(|| {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        fnv1a64_u64(std::process::id() as u64, fnv1a64(&nanos.to_le_bytes(), FNV_OFFSET))
    });
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("{:016x}", fnv1a64_u64(n, salt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{arm, disarm};
    use crate::telemetry::exclusive;

    #[test]
    fn spans_record_into_the_stage_histogram_only_when_armed() {
        let _gate = exclusive();
        registry().reset();
        disarm();
        let stage = Stage::new("test_stage");
        stage.span().finish();
        assert_eq!(stage.hist.snapshot().count, 0, "disarmed span must not record");
        arm();
        {
            let _sp = stage.span();
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        let snap = stage.hist.snapshot();
        assert_eq!(snap.count, 1);
        assert!(snap.sum_ns >= 100_000, "span must measure elapsed time");
        disarm();
        registry().reset();
    }

    #[test]
    fn stage_span_macro_interns_per_site() {
        let _gate = exclusive();
        registry().reset();
        arm();
        for _ in 0..3 {
            let _sp = crate::stage_span!("macro_stage");
        }
        let stage = Stage::new("macro_stage");
        assert_eq!(stage.hist.snapshot().count, 3);
        disarm();
        registry().reset();
    }

    #[test]
    fn trace_ids_are_unique_and_hex() {
        let a = fresh_trace_id();
        let b = fresh_trace_id();
        assert_ne!(a, b);
        for id in [&a, &b] {
            assert_eq!(id.len(), 16);
            assert!(id.chars().all(|c| c.is_ascii_hexdigit()));
        }
    }
}
