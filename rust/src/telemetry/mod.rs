//! Unified telemetry: a process-global metric registry, RAII span
//! timers, structured JSON logging, Prometheus text exposition and
//! offline per-stage profiles — zero-dependency, in the same
//! hand-rolled idiom as the HTTP/JSON stack.
//!
//! Everything routes through one [`MetricRegistry`](registry::MetricRegistry):
//! atomic counters, gauges and fixed-bucket latency histograms with
//! p50/p95/p99 extraction. Producers pre-fetch cheap cloneable handles
//! ([`Counter`], [`Gauge`], [`Histogram`]) once and update them with
//! relaxed atomics on the hot path; consumers snapshot the registry for
//! the daemon's `GET /metrics` Prometheus endpoint, the `/v1/stats`
//! per-lane detail, and the `tao simulate --profile` breakdown.
//!
//! **Disarmed cost.** Telemetry follows the `util::fault` bar: while
//! [`armed`] is false every handle update and every [`Stage::span`]
//! site is a single relaxed atomic load returning immediately — no
//! clock reads, no stores. `tao serve` arms at boot; `--profile` arms
//! for the run; benches arm/disarm to measure the delta
//! (`telemetry_overhead_pct` in `BENCH_coordinator.json`, gated at 2%).
//!
//! **Tracing.** Each serve job carries a `trace_id` (client-supplied or
//! minted at admission) threaded from `serve::protocol` through the
//! queue, scheduler, pipeline and cache. With `--log-json` the daemon
//! emits one structured line per lifecycle event, so
//! `grep <trace_id>` reconstructs one job's life end-to-end. See
//! `docs/OBSERVABILITY.md` for the metric catalog and wire formats.
//!
//! Registry state is process-global (like `util::fault`): tests that
//! arm, reset or assert totals serialize on [`exclusive`] and reset
//! before measuring.

pub mod log;
pub mod profile;
pub mod prometheus;
pub mod registry;
pub mod span;

pub use log::{emit, log_enabled, Field, Level};
pub use profile::Profile;
pub use registry::{
    arm, armed, disarm, registry, Counter, FamilySnapshot, Gauge, HistSnapshot, Histogram,
    MetricKind, MetricRegistry, SeriesValue,
};
pub use span::{fresh_trace_id, Span, Stage};

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Process-global serialization gate for tests that arm the registry or
/// assert totals: registry state is process-wide, so concurrently
/// running tests must not reset over each other. Hold the guard for the
/// whole armed window and [`disarm`] + [`MetricRegistry::reset`] before
/// dropping it.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A stage span against a site-interned [`Stage`] handle: registers the
/// `tao_stage_seconds{stage=...}` series once per call site, then each
/// pass is one `OnceLock` load plus the armed check. Bind the result —
/// the span records its elapsed time into the histogram when dropped:
///
/// ```ignore
/// let out = {
///     let _sp = crate::stage_span!("execute");
///     session.run(staged)?
/// };
/// ```
#[macro_export]
macro_rules! stage_span {
    ($name:literal) => {{
        static STAGE: std::sync::OnceLock<$crate::telemetry::Stage> = std::sync::OnceLock::new();
        STAGE
            .get_or_init(|| $crate::telemetry::Stage::new($name))
            .span()
    }};
}
