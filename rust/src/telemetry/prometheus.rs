//! Prometheus text exposition (version 0.0.4): deterministic rendering
//! of a registry snapshot, plus a small parser for the same format so
//! `tao loadgen --progress-every` and the loopback tests can consume
//! `GET /metrics` without new dependencies.
//!
//! Rendering rules pinned by the unit tests here:
//!
//! * families in name order, series in sorted-label order (the registry
//!   snapshot already guarantees both);
//! * `# HELP` / `# TYPE` once per family;
//! * label values escaped (`\\`, `\"`, `\n`), help text escaped
//!   (`\\`, `\n`);
//! * histograms expose cumulative `_bucket{le="..."}` series with a
//!   final `le="+Inf"`, plus `_sum` (seconds) and `_count` — bucket
//!   bounds render in seconds.

use super::registry::{bucket_bound_ns, FamilySnapshot, SeriesValue};
use anyhow::{Context, Result};
use std::fmt::Write as _;

/// The `Content-Type` a Prometheus scraper expects from `/metrics`.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a label set (plus an optional trailing `le`) as
/// `{k="v",...}`, or nothing when empty.
fn render_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Bucket bound `i` in seconds, as it appears in `le="..."`.
fn le_of(i: usize) -> String {
    format!("{}", bucket_bound_ns(i) as f64 / 1e9)
}

/// Render a registry snapshot as the exposition text.
pub fn render(families: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    for fam in families {
        let _ = writeln!(out, "# HELP {} {}", fam.name, escape_help(&fam.help));
        let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.as_str());
        for s in &fam.series {
            match &s.value {
                SeriesValue::Counter(v) => {
                    let _ = writeln!(out, "{}{} {v}", fam.name, render_labels(&s.labels, None));
                }
                SeriesValue::Gauge(v) => {
                    let _ = writeln!(out, "{}{} {v}", fam.name, render_labels(&s.labels, None));
                }
                SeriesValue::Hist(h) => {
                    let mut cum = 0u64;
                    for (i, n) in h.buckets.iter().enumerate() {
                        cum += n;
                        let le = if i < h.buckets.len() - 1 {
                            le_of(i)
                        } else {
                            "+Inf".to_string()
                        };
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {cum}",
                            fam.name,
                            render_labels(&s.labels, Some(&le))
                        );
                    }
                    let _ = writeln!(
                        out,
                        "{}_sum{} {}",
                        fam.name,
                        render_labels(&s.labels, None),
                        h.sum_secs()
                    );
                    let _ = writeln!(
                        out,
                        "{}_count{} {}",
                        fam.name,
                        render_labels(&s.labels, None),
                        h.count
                    );
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// Parsing (client side)
// ---------------------------------------------------------------------

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Sample name as written (histogram series keep their `_bucket` /
    /// `_sum` / `_count` suffixes).
    pub name: String,
    /// Label pairs as written (including `le` on bucket lines).
    pub labels: Vec<(String, String)>,
    /// The value.
    pub value: f64,
}

fn unescape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    let mut chars = v.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some(other) => out.push(other),
            None => out.push('\\'),
        }
    }
    out
}

/// Split one `{k="v",...}` body into pairs. Quote-aware: commas inside
/// quoted values do not split.
fn parse_labels(body: &str) -> Result<Vec<(String, String)>> {
    let mut labels = Vec::new();
    let mut rest = body.trim();
    while !rest.is_empty() {
        let eq = rest.find('=').context("label missing '='")?;
        let key = rest[..eq].trim().to_string();
        let after = &rest[eq + 1..];
        let after = after.strip_prefix('"').context("label value missing opening quote")?;
        // Find the closing quote, skipping escaped ones.
        let mut end = None;
        let mut escaped = false;
        for (i, c) in after.char_indices() {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                end = Some(i);
                break;
            }
        }
        let end = end.context("label value missing closing quote")?;
        labels.push((key, unescape_label(&after[..end])));
        rest = after[end + 1..].trim_start_matches(',').trim();
    }
    Ok(labels)
}

/// Parse exposition text into samples; comment and blank lines are
/// skipped.
pub fn parse(text: &str) -> Result<Vec<Sample>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = line.rsplit_once(' ').context("sample line missing value")?;
        let value: f64 = value
            .parse()
            .or_else(|_| match value {
                "+Inf" => Ok(f64::INFINITY),
                "-Inf" => Ok(f64::NEG_INFINITY),
                _ => Err(anyhow::anyhow!("bad sample value {value:?}")),
            })?;
        let (name, labels) = match head.find('{') {
            Some(open) => {
                let close = head.rfind('}').context("unterminated label set")?;
                (head[..open].to_string(), parse_labels(&head[open + 1..close])?)
            }
            None => (head.trim().to_string(), Vec::new()),
        };
        out.push(Sample { name, labels, value });
    }
    Ok(out)
}

/// Sum every sample named `name` whose labels contain all of `want`
/// (extra labels are fine). `None` when nothing matched.
pub fn sample_value(samples: &[Sample], name: &str, want: &[(&str, &str)]) -> Option<f64> {
    let mut total = 0.0;
    let mut found = false;
    for s in samples {
        if s.name != name {
            continue;
        }
        if want
            .iter()
            .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
        {
            total += s.value;
            found = true;
        }
    }
    found.then_some(total)
}

/// Quantile (seconds) from a parsed histogram family's cumulative
/// `<name>_bucket` samples, with linear interpolation between bucket
/// bounds (the +Inf bucket answers the last finite bound). `None` when
/// no bucket samples exist; `Some(0.0)` when they exist but are empty.
pub fn histogram_quantile(samples: &[Sample], name: &str, q: f64) -> Option<f64> {
    let bucket_name = format!("{name}_bucket");
    let mut buckets: Vec<(f64, f64)> = samples
        .iter()
        .filter(|s| s.name == bucket_name)
        .filter_map(|s| {
            let le = s.labels.iter().find(|(k, _)| k == "le")?;
            let bound = if le.1 == "+Inf" {
                f64::INFINITY
            } else {
                le.1.parse().ok()?
            };
            Some((bound, s.value))
        })
        .collect();
    if buckets.is_empty() {
        return None;
    }
    buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total = buckets.last().map(|(_, c)| *c).unwrap_or(0.0);
    if total <= 0.0 {
        return Some(0.0);
    }
    let rank = (q.clamp(0.0, 1.0) * total).ceil().clamp(1.0, total);
    let mut prev_bound = 0.0;
    let mut prev_cum = 0.0;
    let mut last_finite = 0.0;
    for &(bound, cum) in &buckets {
        if bound.is_finite() {
            last_finite = bound;
        }
        if cum >= rank {
            if !bound.is_finite() {
                return Some(last_finite);
            }
            let in_bucket = cum - prev_cum;
            let frac = if in_bucket > 0.0 {
                (rank - prev_cum) / in_bucket
            } else {
                1.0
            };
            return Some(prev_bound + frac * (bound - prev_bound));
        }
        prev_bound = bound;
        prev_cum = cum;
    }
    Some(last_finite)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{arm, disarm, registry};
    use crate::telemetry::exclusive;

    #[test]
    fn renders_counter_gauge_and_histogram_families_in_order() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let c = registry().counter("tao_fmt_a_total", "counts things", &[("artifact", "x")]);
        c.inc_by(3);
        let g = registry().gauge("tao_fmt_b_depth", "a level", &[]);
        g.set(-2);
        let h = registry().histogram("tao_fmt_c_seconds", "a latency", &[]);
        h.record_ns(1_500); // bucket le=2µs
        let text = render(&registry().snapshot());
        // Families render in name order with HELP/TYPE headers.
        let a = text.find("# HELP tao_fmt_a_total counts things").unwrap();
        let b = text.find("# TYPE tao_fmt_b_depth gauge").unwrap();
        let cpos = text.find("# TYPE tao_fmt_c_seconds histogram").unwrap();
        assert!(a < b && b < cpos, "family ordering must be deterministic:\n{text}");
        assert!(text.contains("tao_fmt_a_total{artifact=\"x\"} 3"), "{text}");
        assert!(text.contains("tao_fmt_b_depth -2"), "{text}");
        // Cumulative buckets, +Inf, sum, count.
        assert!(text.contains("tao_fmt_c_seconds_bucket{le=\"0.000001\"} 0"), "{text}");
        assert!(text.contains("tao_fmt_c_seconds_bucket{le=\"0.000002\"} 1"), "{text}");
        assert!(text.contains("tao_fmt_c_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("tao_fmt_c_seconds_count 1"), "{text}");
        assert!(text.contains("tao_fmt_c_seconds_sum 0.0000015"), "{text}");
        disarm();
        registry().reset();
    }

    #[test]
    fn rendering_is_deterministic_across_snapshots() {
        let _gate = exclusive();
        registry().reset();
        arm();
        for (a, b) in [("x", "1"), ("y", "2")] {
            registry()
                .counter("tao_fmt_det_total", "det", &[("artifact", a), ("lane", b)])
                .inc();
        }
        let one = render(&registry().snapshot());
        let two = render(&registry().snapshot());
        assert_eq!(one, two);
        disarm();
        registry().reset();
    }

    #[test]
    fn label_values_escape_and_round_trip() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let tricky = "a\"b\\c\nd";
        registry()
            .counter("tao_fmt_escape_total", "esc", &[("artifact", tricky)])
            .inc_by(7);
        let text = render(&registry().snapshot());
        assert!(
            text.contains(r#"tao_fmt_escape_total{artifact="a\"b\\c\nd"} 7"#),
            "escaped rendering missing:\n{text}"
        );
        let samples = parse(&text).unwrap();
        let v = sample_value(&samples, "tao_fmt_escape_total", &[("artifact", tricky)]);
        assert_eq!(v, Some(7.0), "parse must invert escaping");
        disarm();
        registry().reset();
    }

    #[test]
    fn parse_reads_values_labels_and_skips_comments() {
        let text = "# HELP x y\n# TYPE x counter\nx{a=\"1\",b=\"two\"} 5\nplain 2.5\n\n";
        let samples = parse(text).unwrap();
        assert_eq!(samples.len(), 2);
        assert_eq!(sample_value(&samples, "x", &[("a", "1")]), Some(5.0));
        assert_eq!(sample_value(&samples, "x", &[("a", "2")]), None);
        assert_eq!(sample_value(&samples, "plain", &[]), Some(2.5));
        assert!(parse("broken_line_without_value\n").is_err());
    }

    #[test]
    fn histogram_quantile_from_parsed_buckets() {
        let text = "\
h_bucket{le=\"0.001\"} 90
h_bucket{le=\"0.01\"} 99
h_bucket{le=\"+Inf\"} 100
h_sum 1.0
h_count 100
";
        let samples = parse(text).unwrap();
        let p50 = histogram_quantile(&samples, "h", 0.50).unwrap();
        assert!(p50 <= 0.001, "p50 {p50}");
        let p95 = histogram_quantile(&samples, "h", 0.95).unwrap();
        assert!(p95 > 0.001 && p95 <= 0.01, "p95 {p95}");
        // Rank in +Inf answers the last finite bound.
        let p999 = histogram_quantile(&samples, "h", 0.9999).unwrap();
        assert!((p999 - 0.01).abs() < 1e-12, "p999 {p999}");
        assert_eq!(histogram_quantile(&samples, "missing", 0.5), None);
    }

    #[test]
    fn round_trip_registry_to_parsed_totals() {
        let _gate = exclusive();
        registry().reset();
        arm();
        let hits = registry().counter("tao_fmt_rt_hits_total", "rt", &[("artifact", "a")]);
        let misses = registry().counter("tao_fmt_rt_hits_total", "rt", &[("artifact", "b")]);
        hits.inc_by(4);
        misses.inc_by(6);
        let samples = parse(&render(&registry().snapshot())).unwrap();
        // Label-filtered and label-agnostic sums both reconcile.
        assert_eq!(sample_value(&samples, "tao_fmt_rt_hits_total", &[]), Some(10.0));
        assert_eq!(
            sample_value(&samples, "tao_fmt_rt_hits_total", &[("artifact", "a")]),
            Some(4.0)
        );
        disarm();
        registry().reset();
    }
}
