//! Simulation metrics, phase-level series and error measures.
//!
//! Defines the quantities every evaluation figure reports: CPI, MPKI for
//! branch mispredictions / L1D / L1I / TLB, windowed phase behaviour
//! (Figure 11), and the paper's simulation-error formula
//! `|CPI_pred − CPI_truth| / CPI_truth × 100%` (§5 "simulation study
//! criteria").

/// Aggregate metrics over a simulated instruction stream (predicted or
/// ground truth).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Metrics {
    /// Instructions accounted.
    pub instructions: u64,
    /// Total cycles.
    pub cycles: f64,
    /// Mispredicted conditional branches.
    pub mispredicts: f64,
    /// L1D misses (L2 hits + memory accesses).
    pub l1d_misses: f64,
    /// L1I misses.
    pub l1i_misses: f64,
    /// Data TLB misses.
    pub tlb_misses: f64,
}

impl Metrics {
    /// Cycles per instruction.
    pub fn cpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.cycles / self.instructions as f64
        }
    }

    /// Generic misses-per-kilo-instruction helper.
    fn mpki(&self, count: f64) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            count * 1000.0 / self.instructions as f64
        }
    }

    /// Branch misprediction MPKI.
    pub fn branch_mpki(&self) -> f64 {
        self.mpki(self.mispredicts)
    }

    /// L1D miss MPKI.
    pub fn l1d_mpki(&self) -> f64 {
        self.mpki(self.l1d_misses)
    }

    /// L1I miss MPKI.
    pub fn l1i_mpki(&self) -> f64 {
        self.mpki(self.l1i_misses)
    }

    /// Data-TLB miss MPKI.
    pub fn tlb_mpki(&self) -> f64 {
        self.mpki(self.tlb_misses)
    }

    /// Fold another window into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.instructions += other.instructions;
        self.cycles += other.cycles;
        self.mispredicts += other.mispredicts;
        self.l1d_misses += other.l1d_misses;
        self.l1i_misses += other.l1i_misses;
        self.tlb_misses += other.tlb_misses;
    }
}

/// The paper's simulation error: absolute relative CPI error in percent.
pub fn simulation_error_percent(cpi_pred: f64, cpi_truth: f64) -> f64 {
    if cpi_truth == 0.0 {
        return 0.0;
    }
    (cpi_pred - cpi_truth).abs() / cpi_truth * 100.0
}

/// Absolute relative error for any metric, in percent.
pub fn relative_error_percent(pred: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        return if pred == 0.0 { 0.0 } else { 100.0 };
    }
    (pred - truth).abs() / truth * 100.0
}

/// Phase-level series: per-window metrics over program execution
/// (Figure 11 plots CPI, L1D MPKI and branch MPKI per 10M-instruction
/// window; the window size scales with our instruction budgets).
#[derive(Debug, Clone, Default)]
pub struct PhaseSeries {
    /// Window size in instructions.
    pub window: u64,
    /// Completed windows.
    pub windows: Vec<Metrics>,
    current: Metrics,
}

impl PhaseSeries {
    /// New series with the given window size.
    pub fn new(window: u64) -> PhaseSeries {
        PhaseSeries {
            window,
            windows: Vec::new(),
            current: Metrics::default(),
        }
    }

    /// Account one instruction.
    pub fn push(
        &mut self,
        cycles: f64,
        mispred: bool,
        l1d_miss: bool,
        l1i_miss: bool,
        tlb_miss: bool,
    ) {
        self.current.instructions += 1;
        self.current.cycles += cycles;
        self.current.mispredicts += mispred as u8 as f64;
        self.current.l1d_misses += l1d_miss as u8 as f64;
        self.current.l1i_misses += l1i_miss as u8 as f64;
        self.current.tlb_misses += tlb_miss as u8 as f64;
        if self.current.instructions >= self.window {
            self.windows.push(self.current);
            self.current = Metrics::default();
        }
    }

    /// Close the series, flushing a final partial window.
    pub fn finish(&mut self) {
        if self.current.instructions > 0 {
            self.windows.push(self.current);
            self.current = Metrics::default();
        }
    }

    /// Totals across all windows.
    pub fn total(&self) -> Metrics {
        let mut m = Metrics::default();
        for w in &self.windows {
            m.merge(w);
        }
        m.merge(&self.current);
        m
    }
}

/// Mean of a slice (0.0 when empty) — used all over the report harness.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpi_and_mpki_math() {
        let m = Metrics {
            instructions: 2000,
            cycles: 3000.0,
            mispredicts: 10.0,
            l1d_misses: 40.0,
            l1i_misses: 2.0,
            tlb_misses: 1.0,
        };
        assert!((m.cpi() - 1.5).abs() < 1e-12);
        assert!((m.branch_mpki() - 5.0).abs() < 1e-12);
        assert!((m.l1d_mpki() - 20.0).abs() < 1e-12);
        assert!((m.l1i_mpki() - 1.0).abs() < 1e-12);
        assert!((m.tlb_mpki() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.cpi(), 0.0);
        assert_eq!(m.branch_mpki(), 0.0);
    }

    #[test]
    fn simulation_error_formula() {
        assert!((simulation_error_percent(1.1, 1.0) - 10.0).abs() < 1e-9);
        assert!((simulation_error_percent(0.9, 1.0) - 10.0).abs() < 1e-9);
        assert_eq!(simulation_error_percent(1.0, 0.0), 0.0);
    }

    #[test]
    fn relative_error_zero_truth() {
        assert_eq!(relative_error_percent(0.0, 0.0), 0.0);
        assert_eq!(relative_error_percent(1.0, 0.0), 100.0);
    }

    #[test]
    fn phase_series_windows() {
        let mut ps = PhaseSeries::new(10);
        for i in 0..25 {
            ps.push(2.0, i % 5 == 0, false, false, false);
        }
        ps.finish();
        assert_eq!(ps.windows.len(), 3);
        assert_eq!(ps.windows[0].instructions, 10);
        assert_eq!(ps.windows[2].instructions, 5);
        let t = ps.total();
        assert_eq!(t.instructions, 25);
        assert!((t.cpi() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics {
            instructions: 10,
            cycles: 20.0,
            ..Default::default()
        };
        let b = Metrics {
            instructions: 5,
            cycles: 5.0,
            mispredicts: 2.0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.instructions, 15);
        assert_eq!(a.cycles, 25.0);
        assert_eq!(a.mispredicts, 2.0);
    }

    #[test]
    fn mean_helper() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
