//! Synthetic benchmark suite — the SPEC CPU2017 stand-in (Table 2).
//!
//! Eight benchmarks, split exactly as the paper's Table 2: four training
//! (`dee`, `rom`, `nab`, `lee`) and four testing (`mcf`, `xal`, `wrf`,
//! `cac`). Each reproduces the microarchitectural character the paper
//! attributes to its SPEC namesake (see `bench` module docs and
//! DESIGN.md §1 for the substitution argument).

pub mod bench;
pub mod builder;
pub mod scenarios;

pub use builder::{Label, ProgramBuilder};
pub use scenarios::{mixed_scenarios, mixed_tenant_scenarios, ScenarioArtifact, ScenarioJob};

use crate::isa::Program;

/// Train/test membership (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Used to train DL models.
    Train,
    /// Held out for simulation-accuracy evaluation.
    Test,
}

/// A benchmark descriptor.
#[derive(Clone)]
pub struct Workload {
    /// Short name used everywhere ("mcf").
    pub name: &'static str,
    /// The SPEC CPU2017 benchmark it stands in for.
    pub spec_name: &'static str,
    /// Table 2 split.
    pub split: Split,
    /// One-line characterization.
    pub description: &'static str,
    build_fn: fn(u64) -> Program,
}

impl Workload {
    /// Build the program deterministically from `seed`.
    pub fn build(&self, seed: u64) -> Program {
        (self.build_fn)(seed)
    }
}

impl std::fmt::Debug for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workload")
            .field("name", &self.name)
            .field("spec", &self.spec_name)
            .field("split", &self.split)
            .finish()
    }
}

/// The full suite in Table 2 order (training first).
pub fn suite() -> Vec<Workload> {
    vec![
        Workload {
            name: "dee",
            spec_name: "531.deepsjeng_r",
            split: Split::Train,
            description: "chess search: int-heavy, branchy, hash probes, ~96KiB WSS",
            build_fn: bench::dee,
        },
        Workload {
            name: "rom",
            spec_name: "654.roms_s",
            split: Split::Train,
            description: "ocean stencil: FP streaming over 8MiB, predictable branches",
            build_fn: bench::rom,
        },
        Workload {
            name: "nab",
            spec_name: "544.nab_r",
            split: Split::Train,
            description: "molecular dynamics: FP compute, small WSS, few branches",
            build_fn: bench::nab,
        },
        Workload {
            name: "lee",
            spec_name: "641.leela_s",
            split: Split::Train,
            description: "Go MCTS: random tree walk, 50/50 branches, 512KiB WSS",
            build_fn: bench::lee,
        },
        Workload {
            name: "mcf",
            spec_name: "605.mcf_s",
            split: Split::Test,
            description: "network simplex: 8MiB pointer chase, memory bound",
            build_fn: bench::mcf,
        },
        Workload {
            name: "xal",
            spec_name: "523.xalancbmk_r",
            split: Split::Test,
            description: "XML transform: byte scan + dispatch chain + calls",
            build_fn: bench::xal,
        },
        Workload {
            name: "wrf",
            spec_name: "621.wrf_s",
            split: Split::Test,
            description: "weather stencil: row-strided FP, TLB pressure, fdiv",
            build_fn: bench::wrf,
        },
        Workload {
            name: "cac",
            spec_name: "507.cactuBSSN_r",
            split: Split::Test,
            description: "relativity PDE: store-heavy FP, very few branches",
            build_fn: bench::cac,
        },
    ]
}

/// Training benchmarks (Table 2 row 1).
pub fn training() -> Vec<Workload> {
    suite().into_iter().filter(|w| w.split == Split::Train).collect()
}

/// Testing benchmarks (Table 2 row 2).
pub fn testing() -> Vec<Workload> {
    suite().into_iter().filter(|w| w.split == Split::Test).collect()
}

/// Look up a benchmark by short name.
pub fn by_name(name: &str) -> Option<Workload> {
    suite().into_iter().find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detailed::DetailedSim;
    use crate::functional::FunctionalSim;
    use crate::isa::OpcodeClass;
    use crate::uarch::UarchConfig;

    #[test]
    fn table2_split() {
        let names: Vec<&str> = training().iter().map(|w| w.name).collect();
        assert_eq!(names, vec!["dee", "rom", "nab", "lee"]);
        let names: Vec<&str> = testing().iter().map(|w| w.name).collect();
        assert_eq!(names, vec!["mcf", "xal", "wrf", "cac"]);
    }

    #[test]
    fn all_programs_valid_and_run_forever() {
        for w in suite() {
            let p = w.build(42);
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            let n = 20_000;
            let t = FunctionalSim::new(&p).run(n);
            assert_eq!(
                t.records.len() as u64, n,
                "{} halted after {} insts",
                w.name,
                t.records.len()
            );
        }
    }

    #[test]
    fn deterministic_in_seed() {
        for w in suite() {
            let a = FunctionalSim::new(&w.build(7)).run(5_000);
            let b = FunctionalSim::new(&w.build(7)).run(5_000);
            assert_eq!(a.records, b.records, "{} not deterministic", w.name);
        }
    }

    fn mix(records: &[crate::trace::FuncRecord]) -> (f64, f64, f64, f64) {
        let n = records.len() as f64;
        let loads = records.iter().filter(|r| r.opcode.is_load()).count() as f64 / n;
        let stores = records.iter().filter(|r| r.opcode.is_store()).count() as f64 / n;
        let branches = records.iter().filter(|r| r.opcode.is_cond_branch()).count() as f64 / n;
        let fp = records
            .iter()
            .filter(|r| {
                matches!(
                    r.opcode.class(),
                    OpcodeClass::FpAlu | OpcodeClass::FpMul | OpcodeClass::FpDiv
                )
            })
            .count() as f64
            / n;
        (loads, stores, branches, fp)
    }

    #[test]
    fn cac_is_store_heavy_and_branch_light() {
        let t = FunctionalSim::new(&by_name("cac").unwrap().build(1)).run(30_000);
        let (_, stores, branches, _) = mix(&t.records);
        assert!(stores > 0.2, "cac stores={stores}");
        assert!(branches < 0.12, "cac branches={branches}");
    }

    #[test]
    fn nab_is_fp_heavy() {
        let t = FunctionalSim::new(&by_name("nab").unwrap().build(1)).run(30_000);
        let (_, _, _, fp) = mix(&t.records);
        assert!(fp > 0.25, "nab fp={fp}");
    }

    #[test]
    fn dee_and_xal_are_branchy() {
        for name in ["dee", "xal"] {
            let t = FunctionalSim::new(&by_name(name).unwrap().build(1)).run(30_000);
            let (_, _, branches, _) = mix(&t.records);
            assert!(branches > 0.15, "{name} branches={branches}");
        }
    }

    #[test]
    fn mcf_is_memory_bound_on_small_cache() {
        let p = by_name("mcf").unwrap().build(3);
        let (_, stats) = DetailedSim::new(&p, &UarchConfig::uarch_a())
            .stats_only()
            .run(30_000);
        assert!(stats.l1d_mpki() > 50.0, "mcf l1d mpki={}", stats.l1d_mpki());
        assert!(stats.cpi() > 3.0, "mcf cpi={}", stats.cpi());
    }

    #[test]
    fn nab_has_low_cpi_relative_to_mcf() {
        let cfg = UarchConfig::uarch_b();
        let (_, s_nab) = DetailedSim::new(&by_name("nab").unwrap().build(3), &cfg)
            .stats_only()
            .run(30_000);
        let (_, s_mcf) = DetailedSim::new(&by_name("mcf").unwrap().build(3), &cfg)
            .stats_only()
            .run(30_000);
        assert!(
            s_nab.cpi() < s_mcf.cpi(),
            "nab {} !< mcf {}",
            s_nab.cpi(),
            s_mcf.cpi()
        );
    }

    #[test]
    fn lee_mispredicts_more_than_rom() {
        let cfg = UarchConfig::uarch_b();
        let (_, s_lee) = DetailedSim::new(&by_name("lee").unwrap().build(3), &cfg)
            .stats_only()
            .run(30_000);
        let (_, s_rom) = DetailedSim::new(&by_name("rom").unwrap().build(3), &cfg)
            .stats_only()
            .run(30_000);
        assert!(
            s_lee.branch_mpki() > 2.0 * s_rom.branch_mpki().max(0.05),
            "lee {} vs rom {}",
            s_lee.branch_mpki(),
            s_rom.branch_mpki()
        );
    }

    #[test]
    fn benchmarks_have_distinct_cpi_profiles() {
        // The suite must spread across the CPI spectrum for the DL model
        // to see diverse behaviour (paper's benchmark-selection argument).
        let cfg = UarchConfig::uarch_a();
        let mut cpis = Vec::new();
        for w in suite() {
            let (_, s) = DetailedSim::new(&w.build(3), &cfg).stats_only().run(20_000);
            cpis.push((w.name, s.cpi()));
        }
        let min = cpis.iter().map(|(_, c)| *c).fold(f64::MAX, f64::min);
        let max = cpis.iter().map(|(_, c)| *c).fold(0.0, f64::max);
        assert!(max / min > 2.0, "CPI spread too small: {cpis:?}");
    }
}
