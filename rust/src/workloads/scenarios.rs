//! Serving scenario mixes for `tao loadgen`.
//!
//! A scenario is one simulation request: a benchmark trace (bench ×
//! seed × length) against an artifact, with a Table-3 detailed design
//! attached when the artifact is a SimNet baseline (its µarch-specific
//! context input). Mixes are deterministic in the seed so phases can
//! be replayed exactly — the warm-cache phase replays the cold phase's
//! scenarios verbatim, and disjoint seed bases keep phases from
//! cross-warming each other.

/// One loadgen job, serving-layer agnostic (the loadgen client maps it
/// onto the wire protocol's `JobSpec`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioJob {
    /// Benchmark short name.
    pub bench: String,
    /// Trace length.
    pub insts: u64,
    /// Trace seed.
    pub seed: u64,
    /// Artifact registry name.
    pub artifact: String,
    /// Context design for SimNet artifacts.
    pub ctx_uarch: Option<String>,
}

/// An artifact available for scenario building.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioArtifact {
    /// Registry name.
    pub name: String,
    /// Needs a `ctx_uarch` (SimNet baseline).
    pub simnet: bool,
}

/// Context designs rotated across SimNet scenarios: the three preset
/// µarchs plus sampled Table 3 design points (`dse::DesignSpace`
/// indices), so a mix genuinely sweeps the design space.
pub const CTX_DESIGNS: [&str; 5] = ["a", "b", "c", "design:12345", "design:67890"];

/// Build `jobs` mixed scenarios: benches cycle in Table 2 suite order,
/// trace lengths rotate through three deliberately batch-misaligned
/// sizes around `base_insts` (tail-heavy small requests are where
/// cross-job packing pays), artifacts round-robin, and each job gets a
/// distinct trace seed derived from `seed_base`.
pub fn mixed_scenarios(
    artifacts: &[ScenarioArtifact],
    jobs: usize,
    base_insts: u64,
    seed_base: u64,
) -> Vec<ScenarioJob> {
    assert!(!artifacts.is_empty(), "scenario mix needs at least one artifact");
    assert!(base_insts >= 2, "scenario traces must be non-trivial");
    let suite = super::suite();
    // Four sizes against the usual three-artifact sets: coprime cycle
    // lengths, so sizes and artifacts cross fully instead of pairing.
    let sizes = [
        base_insts,
        base_insts / 2 + 1,
        base_insts + base_insts / 2 + 3,
        base_insts / 3 + 2,
    ];
    (0..jobs)
        .map(|i| {
            let art = &artifacts[i % artifacts.len()];
            ScenarioJob {
                bench: suite[i % suite.len()].name.to_string(),
                insts: sizes[i % sizes.len()],
                seed: seed_base + i as u64,
                artifact: art.name.clone(),
                ctx_uarch: art
                    .simnet
                    .then(|| CTX_DESIGNS[i % CTX_DESIGNS.len()].to_string()),
            }
        })
        .collect()
}

/// Build a **tenant-skewed** mix for multi-tenant cache experiments:
/// three of every four jobs hammer the `hot` artifact (a design sweep
/// monopolizing the fleet), the rest round-robin across the remaining
/// tenants. Per-artifact cache quotas exist exactly so the hot
/// tenant's churn cannot evict the minority tenants' working sets —
/// this mix is the workload that demonstrates it, and the router bench
/// uses it so one shard sees realistic tenant imbalance.
///
/// Deterministic in `seed_base`, disjoint from [`mixed_scenarios`]
/// seeds at the same base (offset by `1 << 20`).
pub fn mixed_tenant_scenarios(
    artifacts: &[ScenarioArtifact],
    jobs: usize,
    base_insts: u64,
    seed_base: u64,
    hot: usize,
) -> Vec<ScenarioJob> {
    assert!(!artifacts.is_empty(), "scenario mix needs at least one artifact");
    assert!(hot < artifacts.len(), "hot tenant index out of range");
    assert!(base_insts >= 2, "scenario traces must be non-trivial");
    let suite = super::suite();
    let sizes = [base_insts, base_insts / 2 + 1, base_insts + base_insts / 2 + 3];
    let cold: Vec<usize> = (0..artifacts.len()).filter(|&i| i != hot).collect();
    (0..jobs)
        .map(|i| {
            let art = if i % 4 != 3 || cold.is_empty() {
                &artifacts[hot]
            } else {
                &artifacts[cold[(i / 4) % cold.len()]]
            };
            ScenarioJob {
                bench: suite[i % suite.len()].name.to_string(),
                insts: sizes[i % sizes.len()],
                seed: seed_base + (1 << 20) + i as u64,
                artifact: art.name.clone(),
                ctx_uarch: art
                    .simnet
                    .then(|| CTX_DESIGNS[i % CTX_DESIGNS.len()].to_string()),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arts() -> Vec<ScenarioArtifact> {
        vec![
            ScenarioArtifact { name: "tao_x".into(), simnet: false },
            ScenarioArtifact { name: "tao_y".into(), simnet: false },
            ScenarioArtifact { name: "simnet_x".into(), simnet: true },
        ]
    }

    #[test]
    fn mix_is_deterministic_and_covers_artifacts() {
        let a = mixed_scenarios(&arts(), 24, 150, 1000);
        let b = mixed_scenarios(&arts(), 24, 150, 1000);
        assert_eq!(a, b);
        assert_eq!(a.len(), 24);
        for art in arts() {
            assert!(a.iter().any(|j| j.artifact == art.name), "{} unused", art.name);
        }
        // Every SimNet job carries a context design; Tao jobs none.
        for j in &a {
            assert_eq!(j.ctx_uarch.is_some(), j.artifact == "simnet_x");
        }
        // All seeds distinct (no accidental intra-phase cache hits).
        let mut seeds: Vec<u64> = a.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 24);
        // Disjoint seed bases don't collide.
        let c = mixed_scenarios(&arts(), 24, 150, 5000);
        assert!(a.iter().zip(&c).all(|(x, y)| x.seed != y.seed));
    }

    #[test]
    fn mix_rotates_table3_designs() {
        let sim_only = vec![ScenarioArtifact { name: "sn".into(), simnet: true }];
        let jobs = mixed_scenarios(&sim_only, 10, 100, 0);
        let designs: std::collections::HashSet<_> =
            jobs.iter().filter_map(|j| j.ctx_uarch.clone()).collect();
        assert_eq!(designs.len(), CTX_DESIGNS.len());
        assert!(designs.contains("design:12345"));
    }

    #[test]
    fn tenant_mix_skews_hot_and_keeps_cold_tenants_alive() {
        let a = mixed_tenant_scenarios(&arts(), 24, 150, 1000, 0);
        assert_eq!(a, mixed_tenant_scenarios(&arts(), 24, 150, 1000, 0));
        assert_eq!(a.len(), 24);
        let hot = a.iter().filter(|j| j.artifact == "tao_x").count();
        assert_eq!(hot, 18, "3 of 4 jobs go to the hot tenant");
        // Both cold tenants still appear (the quota satellite needs
        // minority working sets to protect).
        assert!(a.iter().any(|j| j.artifact == "tao_y"));
        assert!(a.iter().any(|j| j.artifact == "simnet_x"));
        // Seeds are disjoint from mixed_scenarios at the same base.
        let plain = mixed_scenarios(&arts(), 24, 150, 1000);
        for j in &a {
            assert!(plain.iter().all(|p| p.seed != j.seed));
        }
        // A single-tenant fleet degenerates gracefully.
        let solo = vec![ScenarioArtifact { name: "only".into(), simnet: false }];
        let b = mixed_tenant_scenarios(&solo, 8, 100, 0, 0);
        assert!(b.iter().all(|j| j.artifact == "only"));
    }

    #[test]
    fn benches_cycle_suite_order() {
        let jobs = mixed_scenarios(&arts(), 9, 100, 0);
        assert_eq!(jobs[0].bench, "dee");
        assert_eq!(jobs[8].bench, "dee");
        assert_eq!(jobs[4].bench, "mcf");
    }
}
