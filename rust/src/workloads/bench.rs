//! The eight synthetic SPEC CPU2017 stand-in benchmarks (Table 2).
//!
//! Each generator reproduces the *microarchitectural character* the paper
//! attributes to its SPEC counterpart — instruction mix, branch
//! predictability, and memory locality — rather than its semantics (the DL
//! pipeline only ever observes the trace shape; see DESIGN.md §1). All
//! generators are deterministic in `seed`.

use super::builder::ProgramBuilder;
use crate::isa::{Condition, Opcode, Program, Reg};
use crate::util::Rng;

// Register conventions used by every benchmark:
//   x1      outer-loop counter          x10..x15  base addresses / pointers
//   x2..x9  scratch                     x20..x25  long-lived accumulators
//   x28     LCG state                   x30       link register
//   f0..f7  FP scratch

const LCG_MUL: i64 = 6364136223846793005;
const LCG_ADD: i64 = 1442695040888963407;

/// Emit `x28 = x28 * LCG_MUL + LCG_ADD; dst = (x28 >> 59) & mask`.
fn lcg_bits(b: &mut ProgramBuilder, dst: Reg, mask: i64) {
    b.movi(Reg::x(9), LCG_MUL);
    b.alu(Opcode::Mul, Reg::x(28), Reg::x(28), Reg::x(9));
    b.alui(Opcode::Add, Reg::x(28), Reg::x(28), LCG_ADD);
    b.alui(Opcode::Lsr, dst, Reg::x(28), 59);
    b.alui(Opcode::And, dst, dst, mask);
}


/// `dst = base << ((lcg >> 59) & sel_mask)` — draws a power-of-two
/// parameter from the program's LCG. Training benchmarks use this to
/// sweep a *family* of regimes (stride × footprint × branch bias) across
/// outer iterations, mirroring the internal phase diversity of real SPEC
/// programs. Without the sweep the DL model only ever sees a few point
/// modes and cannot interpolate to the test benchmarks' parameters.
fn lcg_pow2(b: &mut ProgramBuilder, dst: Reg, base: i64, sel_mask: i64) {
    lcg_bits(b, Reg::x(25), sel_mask);
    b.movi(dst, base);
    b.alu(Opcode::Lsl, dst, dst, Reg::x(25));
}

/// `dst = (base << k) - 1` — a swept power-of-two mask.
fn lcg_pow2_mask(b: &mut ProgramBuilder, dst: Reg, base: i64, sel_mask: i64) {
    lcg_pow2(b, dst, base, sel_mask);
    b.alui(Opcode::Sub, dst, dst, 1);
}

/// `531.deepsjeng_r` stand-in — chess alpha-beta search: integer-heavy,
/// branchy, hash-table probes over a small working set (~96 KiB).
pub fn dee(seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0xdee);
    let mut b = ProgramBuilder::new("dee");
    let board_words: u64 = 8192; // 64 KiB
    let hash_words: u64 = 4096; // 32 KiB
    let board = b.alloc(board_words * 8);
    let hash = b.alloc(hash_words * 8);
    for i in 0..board_words {
        b.init_word(board + i * 8, rng.next_u64());
    }
    for i in 0..hash_words {
        b.init_word(hash + i * 8, rng.next_u64() & 0xFF);
    }

    b.movi(Reg::x(10), board as i64);
    b.movi(Reg::x(11), hash as i64);
    b.movi(Reg::x(28), seed as i64 | 1);
    let outer = b.here();
    // Swept phases: hash-table locality (mask 63..4095 words) and
    // cutoff-branch bias ({1,3,7} -> 50%..12.5% taken).
    lcg_pow2_mask(&mut b, Reg::x(15), 64, 6);
    lcg_pow2_mask(&mut b, Reg::x(17), 2, 2);
    b.movi(Reg::x(1), board_words as i64); // position counter
    b.movi(Reg::x(2), 0); // board offset

    let pos_loop = b.here();
    // v = board[off]
    b.ldr_idx(Reg::x(3), Reg::x(10), Reg::x(2), 0);
    // zobrist-ish hash: h = (v ^ (v >> 13)) * M
    b.alui(Opcode::Lsr, Reg::x(4), Reg::x(3), 13);
    b.alu(Opcode::Eor, Reg::x(4), Reg::x(3), Reg::x(4));
    b.movi(Reg::x(9), 0x9E3779B97F4A7C15u64 as i64);
    b.alu(Opcode::Mul, Reg::x(4), Reg::x(4), Reg::x(9));
    // probe: e = hash[(h & mask) * 8]
    b.alui(Opcode::Lsr, Reg::x(5), Reg::x(4), 20);
    b.alu(Opcode::And, Reg::x(5), Reg::x(5), Reg::x(15));
    b.alui(Opcode::Lsl, Reg::x(5), Reg::x(5), 3);
    b.ldr_idx(Reg::x(6), Reg::x(11), Reg::x(5), 0);
    // hash hit? (biased: values are 0..255, compare to v&0xFF)
    let miss = b.label();
    b.alui(Opcode::And, Reg::x(7), Reg::x(3), 0xFF);
    b.bcond(Condition::Ne, Reg::x(6), Reg::x(7), miss);
    // hit path: bump score
    b.alui(Opcode::Add, Reg::x(20), Reg::x(20), 3);
    b.place(miss);
    // store updated entry (write traffic into hash table)
    b.str_idx(Reg::x(7), Reg::x(11), Reg::x(5), 0);
    // inner "move generation" loop: trips = v & 7 (data-dependent)
    b.alui(Opcode::And, Reg::x(8), Reg::x(3), 7);
    let moves_done = b.label();
    b.cbz(Reg::x(8), moves_done);
    let moves = b.here();
    b.alu(Opcode::Eor, Reg::x(21), Reg::x(21), Reg::x(8));
    b.alui(Opcode::Lsl, Reg::x(22), Reg::x(21), 1);
    b.alui(Opcode::Subs, Reg::x(8), Reg::x(8), 1);
    b.cbnz(Reg::x(8), moves);
    b.place(moves_done);
    // unpredictable alpha-beta cutoff: ~50/50 from data bit 17
    let no_cut = b.label();
    b.alui(Opcode::Lsr, Reg::x(7), Reg::x(3), 17);
    b.alu(Opcode::And, Reg::x(7), Reg::x(7), Reg::x(17));
    b.cbz(Reg::x(7), no_cut);
    b.alui(Opcode::Add, Reg::x(23), Reg::x(23), 1);
    b.place(no_cut);
    // next position
    b.alui(Opcode::Add, Reg::x(2), Reg::x(2), 8);
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), pos_loop);
    b.movi(Reg::x(2), 0);
    b.b(outer);
    b.build()
}

/// `641.leela_s` stand-in — Go MCTS: random tree walk over ~512 KiB of
/// nodes (spilling the smaller L2s, like leela's tree exceeds cache),
/// 50/50 data-dependent branches, occasional FP win-rate updates.
pub fn lee(seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0x1ee);
    let mut b = ProgramBuilder::new("lee");
    let node_words: u64 = 1_048_576; // 8 MiB pool; phases walk sub-regions
    let nodes = b.alloc(node_words * 8);
    for i in 0..node_words {
        b.init_word(nodes + i * 8, rng.next_u64());
    }

    b.movi(Reg::x(10), nodes as i64);
    b.movi(Reg::x(28), seed as i64 | 1);
    b.movi(Reg::x(2), 0); // node index (words)
    let outer = b.here();
    // Swept phases: walk region 64KiB..8MiB (8192..1M words) — from
    // cache-resident to memory-bound dependent chasing — and explore
    // branch bias {1,3,7,15} (50%..6% taken).
    lcg_pow2_mask(&mut b, Reg::x(15), 8_192, 7);
    lcg_pow2_mask(&mut b, Reg::x(17), 2, 3);
    b.movi(Reg::x(1), 4096); // playout steps

    let walk = b.here();
    // v = nodes[idx]
    b.alui(Opcode::Lsl, Reg::x(3), Reg::x(2), 3);
    b.ldr_idx(Reg::x(4), Reg::x(10), Reg::x(3), 0);
    // unpredictable expand/exploit decision on value parity
    let exploit = b.label();
    let merged = b.label();
    b.alu(Opcode::And, Reg::x(5), Reg::x(4), Reg::x(17));
    b.cbz(Reg::x(5), exploit);
    // explore: idx = (idx*5 + (v>>32)) & mask
    b.alui(Opcode::Lsr, Reg::x(6), Reg::x(4), 32);
    b.movi(Reg::x(9), 5);
    b.alu(Opcode::Mul, Reg::x(2), Reg::x(2), Reg::x(9));
    b.alu(Opcode::Add, Reg::x(2), Reg::x(2), Reg::x(6));
    b.b(merged);
    b.place(exploit);
    // exploit: idx = idx + (v & 63) + 1
    b.alui(Opcode::And, Reg::x(6), Reg::x(4), 63);
    b.alu(Opcode::Add, Reg::x(2), Reg::x(2), Reg::x(6));
    b.alui(Opcode::Add, Reg::x(2), Reg::x(2), 1);
    b.place(merged);
    b.alu(Opcode::And, Reg::x(2), Reg::x(2), Reg::x(15));
    // every 16th step: FP win-rate update
    let no_fp = b.label();
    b.alui(Opcode::And, Reg::x(7), Reg::x(1), 15);
    b.cbnz(Reg::x(7), no_fp);
    b.push(crate::isa::Instruction::new(Opcode::Fcvt).dst(Reg::f(0)).src1(Reg::x(4)));
    b.push(
        crate::isa::Instruction::new(Opcode::Fmul)
            .dst(Reg::f(1))
            .src1(Reg::f(1))
            .src2(Reg::f(0)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fadd)
            .dst(Reg::f(2))
            .src1(Reg::f(2))
            .src2(Reg::f(1)),
    );
    b.place(no_fp);
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), walk);
    b.b(outer);
    b.build()
}

/// `544.nab_r` stand-in — molecular dynamics: FP-dominant compute over a
/// small (~96 KiB) working set, highly predictable branches.
pub fn nab(seed: u64) -> Program {
    let mut b = ProgramBuilder::new("nab");
    let n: u64 = 32_768; // doubles per array (256 KiB); phases sweep sub-footprints
    let a = b.alloc(n * 8);
    let bb = b.alloc(n * 8);
    let c = b.alloc(n * 8);
    for i in 0..n {
        let va = (i as f64).mul_add(0.001, 1.0) + (seed % 97) as f64 * 1e-4;
        let vb = (i as f64).mul_add(-0.0005, 2.0);
        b.init_word(a + i * 8, va.to_bits());
        b.init_word(bb + i * 8, vb.to_bits());
    }

    b.movi(Reg::x(10), a as i64);
    b.movi(Reg::x(11), bb as i64);
    b.movi(Reg::x(12), c as i64);
    let outer = b.here();
    // Swept phases: stride 8..64 B, footprint 8..256 KiB.
    lcg_pow2(&mut b, Reg::x(14), 8, 3);
    lcg_pow2_mask(&mut b, Reg::x(15), 8 << 10, 5);
    b.movi(Reg::x(1), 4096);
    b.movi(Reg::x(2), 0); // byte offset

    let body = b.here();
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(0))
            .src1(Reg::x(10))
            .src2(Reg::x(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(1))
            .src1(Reg::x(11))
            .src2(Reg::x(2)),
    );
    // force field: f2 = f0*f1 + f2 ; f3 = f2*f0 + f3 ; f4 = sqrt(|f3|)
    b.push(
        crate::isa::Instruction::new(Opcode::Fmadd)
            .dst(Reg::f(2))
            .src1(Reg::f(0))
            .src2(Reg::f(1))
            .src3(Reg::f(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fmadd)
            .dst(Reg::f(3))
            .src1(Reg::f(2))
            .src2(Reg::f(0))
            .src3(Reg::f(3)),
    );
    // every 8th iteration: sqrt + store to c
    let light = b.label();
    b.alui(Opcode::And, Reg::x(4), Reg::x(1), 7);
    b.cbnz(Reg::x(4), light);
    b.push(crate::isa::Instruction::new(Opcode::Fsqrt).dst(Reg::f(4)).src1(Reg::f(3)));
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(12))
            .src2(Reg::x(2))
            .src3(Reg::f(4)),
    );
    b.place(light);
    b.push(
        crate::isa::Instruction::new(Opcode::Fadd)
            .dst(Reg::f(5))
            .src1(Reg::f(5))
            .src2(Reg::f(2)),
    );
    b.alu(Opcode::Add, Reg::x(2), Reg::x(2), Reg::x(14));
    b.alu(Opcode::And, Reg::x(2), Reg::x(2), Reg::x(15));
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), body);
    b.b(outer);
    b.build()
}

/// `654.roms_s` stand-in — ocean-model stencil: FP streaming over an
/// 8 MiB grid (SPEC's roms_s streams a working set far beyond any L2,
/// so the training data covers memory-level accesses and TLB misses),
/// near-perfectly predictable branches, sequential locality.
pub fn rom(_seed: u64) -> Program {
    let mut b = ProgramBuilder::new("rom");
    let words: u64 = 1_048_576; // 8 MiB
    let grid = b.alloc(words * 8);

    b.movi(Reg::x(10), grid as i64);
    let outer = b.here();
    // Swept phases: stride 8 B..1 KiB (sequential to TLB-pressuring
    // strided) over regions 64 KiB..8 MiB (L1-resident to
    // memory-streaming).
    lcg_pow2(&mut b, Reg::x(14), 8, 7);
    lcg_pow2_mask(&mut b, Reg::x(15), 64 << 10, 7);
    b.movi(Reg::x(1), 16_384); // iterations per phase pass
    b.movi(Reg::x(2), 8); // byte offset, start at word 1

    let body = b.here();
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(0))
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(-8),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(1))
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(8),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fadd)
            .dst(Reg::f(2))
            .src1(Reg::f(0))
            .src2(Reg::f(1)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fmul)
            .dst(Reg::f(2))
            .src1(Reg::f(2))
            .imm(1), // ×1.0 — keeps the FP unit busy, values bounded
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .src3(Reg::f(2)),
    );
    b.alu(Opcode::Add, Reg::x(2), Reg::x(2), Reg::x(14));
    b.alu(Opcode::And, Reg::x(2), Reg::x(2), Reg::x(15));
    b.alui(Opcode::Orr, Reg::x(2), Reg::x(2), 8); // keep off >= 8 for the ±8 stencil
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), body);
    b.b(outer);
    b.build()
}

/// `605.mcf_s` stand-in — network simplex: pointer chasing across an
/// 8 MiB node pool (every hop a cache+TLB hazard), branches decided by
/// loaded node payloads (effectively random).
pub fn mcf(seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0xc0f);
    let mut b = ProgramBuilder::new("mcf");
    let node_count: u64 = 131_072; // × 64 B = 8 MiB
    let stride: u64 = 64;
    let pool = b.alloc(node_count * stride);

    // Random cyclic permutation (Sattolo) so the chase visits every node.
    let mut next: Vec<u64> = (0..node_count).collect();
    {
        let mut i = node_count as usize - 1;
        while i > 0 {
            let j = rng.index(i);
            next.swap(i, j);
            i -= 1;
        }
    }
    // node[i].next (word 0) and node[i].payload (word 1)
    for i in 0..node_count as usize {
        let addr = pool + i as u64 * stride;
        b.init_word(addr, pool + next[i] * stride);
        b.init_word(addr + 8, rng.next_u64());
    }

    b.movi(Reg::x(10), pool as i64);
    let outer = b.here();
    // ptr = pool
    b.push(crate::isa::Instruction::new(Opcode::Mov).dst(Reg::x(11)).src1(Reg::x(10)));
    b.movi(Reg::x(1), node_count as i64);

    let chase = b.here();
    b.ldr(Reg::x(12), Reg::x(11), 0); // next ptr (serialized dependency)
    b.ldr(Reg::x(13), Reg::x(11), 8); // payload
    // cost test: unpredictable branch on payload bit
    let cheap = b.label();
    b.alui(Opcode::And, Reg::x(4), Reg::x(13), 1);
    b.cbz(Reg::x(4), cheap);
    b.alui(Opcode::Add, Reg::x(20), Reg::x(20), 1);
    b.alui(Opcode::Lsr, Reg::x(5), Reg::x(13), 8);
    b.alu(Opcode::Eor, Reg::x(21), Reg::x(21), Reg::x(5));
    b.place(cheap);
    b.push(crate::isa::Instruction::new(Opcode::Mov).dst(Reg::x(11)).src1(Reg::x(12)));
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), chase);
    b.b(outer);
    b.build()
}

/// `523.xalancbmk_r` stand-in — XML transform: byte scanning with table
/// lookups, a dispatch chain of data-dependent branches, and call-heavy
/// control flow over a 256 KiB text buffer.
pub fn xal(seed: u64) -> Program {
    let mut rng = Rng::new(seed ^ 0xa1);
    let mut b = ProgramBuilder::new("xal");
    let text_bytes: u64 = 256 << 10;
    let table_words: u64 = 256;
    let text = b.alloc(text_bytes);
    let table = b.alloc(table_words * 8);
    for i in 0..text_bytes / 8 {
        b.init_word(text + i * 8, rng.next_u64());
    }
    for i in 0..table_words {
        b.init_word(table + i * 8, rng.gen_range(4));
    }

    // Handlers (subroutines).
    let h0 = b.label();
    let h1 = b.label();
    let start = b.label();
    b.b(start);
    b.place(h0); // element handler: hash-ish update
    b.alui(Opcode::Lsl, Reg::x(20), Reg::x(20), 1);
    b.alu(Opcode::Eor, Reg::x(20), Reg::x(20), Reg::x(3));
    b.alui(Opcode::Add, Reg::x(21), Reg::x(21), 1);
    b.ret();
    b.place(h1); // attribute handler: counter + table write-back
    b.alui(Opcode::Add, Reg::x(22), Reg::x(22), 1);
    b.alui(Opcode::And, Reg::x(6), Reg::x(3), table_words as i64 - 1);
    b.alui(Opcode::Lsl, Reg::x(6), Reg::x(6), 3);
    b.str_idx(Reg::x(22), Reg::x(11), Reg::x(6), 0);
    b.ret();

    b.place(start);
    b.movi(Reg::x(10), text as i64);
    b.movi(Reg::x(11), table as i64);
    let outer = b.here();
    b.movi(Reg::x(1), 16_384); // characters per pass
    b.movi(Reg::x(2), 0); // cursor

    let scan = b.here();
    // c = text[cursor]; cls = table[c]
    b.ldrb(Reg::x(3), Reg::x(10), Reg::x(2), 0);
    b.alui(Opcode::Lsl, Reg::x(4), Reg::x(3), 3);
    b.ldr_idx(Reg::x(5), Reg::x(11), Reg::x(4), 0);
    // dispatch chain on class (data-dependent, mixed predictability)
    let try1 = b.label();
    let try2 = b.label();
    let advance = b.label();
    b.bcondi(Condition::Ne, Reg::x(5), 0, try1);
    b.bl(h0);
    b.b(advance);
    b.place(try1);
    b.bcondi(Condition::Ne, Reg::x(5), 1, try2);
    b.bl(h1);
    b.b(advance);
    b.place(try2);
    // classes 2-3 inline: escape scan (short data-dependent inner loop)
    b.alui(Opcode::And, Reg::x(7), Reg::x(3), 3);
    let esc_done = b.label();
    b.cbz(Reg::x(7), esc_done);
    let esc = b.here();
    b.alui(Opcode::Add, Reg::x(23), Reg::x(23), 7);
    b.alui(Opcode::Subs, Reg::x(7), Reg::x(7), 1);
    b.cbnz(Reg::x(7), esc);
    b.place(esc_done);
    b.place(advance);
    // cursor += (c & 7) + 1 (variable stride through the buffer)
    b.alui(Opcode::And, Reg::x(8), Reg::x(3), 7);
    b.alu(Opcode::Add, Reg::x(2), Reg::x(2), Reg::x(8));
    b.alui(Opcode::Add, Reg::x(2), Reg::x(2), 1);
    b.alui(Opcode::And, Reg::x(2), Reg::x(2), text_bytes as i64 - 1);
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), scan);
    b.b(outer);
    b.build()
}

/// `621.wrf_s` stand-in — weather model: 2-D FP stencil with a 4 KiB row
/// stride (TLB pressure), mostly-predictable physics branches, periodic
/// expensive `fdiv`.
pub fn wrf(seed: u64) -> Program {
    let mut b = ProgramBuilder::new("wrf");
    let words: u64 = 131_072; // 1 MiB
    let row_words: u64 = 512; // 4 KiB rows
    let grid = b.alloc(words * 8);
    for i in (0..words).step_by(8) {
        let v = 1.0 + (i % 1024) as f64 * 1e-3;
        b.init_word(grid + i * 8, v.to_bits());
    }

    b.movi(Reg::x(10), grid as i64);
    b.movi(Reg::x(28), seed as i64 | 1);
    let outer = b.here();
    b.movi(Reg::x(1), (words - 2 * row_words) as i64);
    b.movi(Reg::x(2), (row_words * 8) as i64); // start at row 1

    let body = b.here();
    // u = g[p]; n = g[p+row]; s = g[p-row]
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(0))
            .src1(Reg::x(10))
            .src2(Reg::x(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(1))
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(row_words as i64 * 8),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(2))
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(-(row_words as i64) * 8),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fadd)
            .dst(Reg::f(3))
            .src1(Reg::f(1))
            .src2(Reg::f(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fmadd)
            .dst(Reg::f(4))
            .src1(Reg::f(3))
            .src2(Reg::f(0))
            .src3(Reg::f(4)),
    );
    // physics branch: ~94% taken (cheap path)
    let cheap = b.label();
    lcg_bits(&mut b, Reg::x(4), 15);
    b.cbnz(Reg::x(4), cheap);
    b.push(
        crate::isa::Instruction::new(Opcode::Fdiv)
            .dst(Reg::f(5))
            .src1(Reg::f(4))
            .src2(Reg::f(0)),
    );
    b.place(cheap);
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .src3(Reg::f(4)),
    );
    b.alui(Opcode::Add, Reg::x(2), Reg::x(2), 8);
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), body);
    b.b(outer);
    b.build()
}

/// `507.cactuBSSN_r` stand-in — numerical relativity: store-dominant FP
/// kernel over a 4 MiB region with very few branches (the paper singles
/// out cac's store-heavy, branch-light profile).
pub fn cac(_seed: u64) -> Program {
    let mut b = ProgramBuilder::new("cac");
    let words: u64 = 524_288; // 4 MiB
    let grid = b.alloc(words * 8);

    b.movi(Reg::x(10), grid as i64);
    let outer = b.here();
    b.movi(Reg::x(1), (words / 4 - 2) as i64);
    b.movi(Reg::x(2), 0);

    let body = b.here();
    // Load two neighbours, compute, store THREE results (store-heavy mix).
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(0))
            .src1(Reg::x(10))
            .src2(Reg::x(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Ldr)
            .dst(Reg::f(1))
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(8),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fmadd)
            .dst(Reg::f(2))
            .src1(Reg::f(0))
            .src2(Reg::f(1))
            .src3(Reg::f(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Fadd)
            .dst(Reg::f(3))
            .src1(Reg::f(2))
            .src2(Reg::f(0)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(8)
            .src3(Reg::f(2)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(16)
            .src3(Reg::f(3)),
    );
    b.push(
        crate::isa::Instruction::new(Opcode::Str)
            .src1(Reg::x(10))
            .src2(Reg::x(2))
            .imm(24)
            .src3(Reg::f(0)),
    );
    b.alui(Opcode::Add, Reg::x(2), Reg::x(2), 32);
    b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
    b.cbnz(Reg::x(1), body);
    b.b(outer);
    b.build()
}
