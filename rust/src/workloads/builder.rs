//! `ProgramBuilder` — a tiny assembler for authoring synthetic benchmarks.
//!
//! Provides labels with forward references, a bump allocator for the data
//! segment, and convenience emitters for common instruction shapes. Every
//! benchmark in `crate::workloads::bench` is written against this.

use crate::isa::inst::DATA_BASE;
use crate::isa::{Condition, Instruction, Opcode, Program, Reg};

/// A branch label (forward references allowed until `build`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Label(usize);

/// Builder state.
pub struct ProgramBuilder {
    name: String,
    insts: Vec<Instruction>,
    labels: Vec<Option<usize>>,
    fixups: Vec<(usize, Label)>,
    data_cursor: u64,
    init_words: Vec<(u64, u64)>,
    init_regs: Vec<(Reg, u64)>,
}

impl ProgramBuilder {
    /// Start a program named `name`.
    pub fn new(name: &str) -> ProgramBuilder {
        ProgramBuilder {
            name: name.to_string(),
            insts: Vec::new(),
            labels: Vec::new(),
            fixups: Vec::new(),
            data_cursor: 0,
            init_words: Vec::new(),
            init_regs: Vec::new(),
        }
    }

    /// Create an unplaced label.
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind `label` to the current instruction position.
    pub fn place(&mut self, label: Label) {
        assert!(self.labels[label.0].is_none(), "label placed twice");
        self.labels[label.0] = Some(self.insts.len());
    }

    /// Create a label bound to the current position.
    pub fn here(&mut self) -> Label {
        let l = self.label();
        self.place(l);
        l
    }

    /// Append a raw instruction; returns its index.
    pub fn push(&mut self, inst: Instruction) -> usize {
        self.insts.push(inst);
        self.insts.len() - 1
    }

    /// Allocate `bytes` in the data segment (8-byte aligned); returns the
    /// absolute virtual address of the allocation.
    pub fn alloc(&mut self, bytes: u64) -> u64 {
        let addr = DATA_BASE + self.data_cursor;
        self.data_cursor += bytes.div_ceil(8) * 8;
        addr
    }

    /// Set an initial 8-byte word at absolute address `addr`.
    pub fn init_word(&mut self, addr: u64, value: u64) {
        assert!(addr >= DATA_BASE);
        self.init_words.push((addr - DATA_BASE, value));
    }

    /// Set an initial register value.
    pub fn init_reg(&mut self, r: Reg, value: u64) {
        self.init_regs.push((r, value));
    }

    // ---- convenience emitters ----

    /// `dst = imm` (also used to materialize addresses).
    pub fn movi(&mut self, dst: Reg, imm: i64) {
        self.push(Instruction::new(Opcode::Movi).dst(dst).imm(imm));
    }

    /// Three-register ALU op `dst = op(a, b)`.
    pub fn alu(&mut self, op: Opcode, dst: Reg, a: Reg, b: Reg) {
        self.push(Instruction::new(op).dst(dst).src1(a).src2(b));
    }

    /// Immediate ALU op `dst = op(a, imm)`.
    pub fn alui(&mut self, op: Opcode, dst: Reg, a: Reg, imm: i64) {
        self.push(Instruction::new(op).dst(dst).src1(a).imm(imm));
    }

    /// `dst = mem[base + off]` (8 bytes).
    pub fn ldr(&mut self, dst: Reg, base: Reg, off: i64) {
        self.push(Instruction::new(Opcode::Ldr).dst(dst).src1(base).imm(off));
    }

    /// `dst = mem[base + idx + off]` (8 bytes).
    pub fn ldr_idx(&mut self, dst: Reg, base: Reg, idx: Reg, off: i64) {
        self.push(
            Instruction::new(Opcode::Ldr)
                .dst(dst)
                .src1(base)
                .src2(idx)
                .imm(off),
        );
    }

    /// Byte load.
    pub fn ldrb(&mut self, dst: Reg, base: Reg, idx: Reg, off: i64) {
        self.push(
            Instruction::new(Opcode::Ldrb)
                .dst(dst)
                .src1(base)
                .src2(idx)
                .imm(off),
        );
    }

    /// `mem[base + off] = data` (8 bytes).
    pub fn str_(&mut self, data: Reg, base: Reg, off: i64) {
        self.push(Instruction::new(Opcode::Str).src1(base).imm(off).src3(data));
    }

    /// `mem[base + idx + off] = data` (8 bytes).
    pub fn str_idx(&mut self, data: Reg, base: Reg, idx: Reg, off: i64) {
        self.push(
            Instruction::new(Opcode::Str)
                .src1(base)
                .src2(idx)
                .imm(off)
                .src3(data),
        );
    }

    /// Unconditional branch.
    pub fn b(&mut self, label: Label) {
        let i = self.push(Instruction::new(Opcode::B).target(usize::MAX));
        self.fixups.push((i, label));
    }

    /// Call: link register `x30`.
    pub fn bl(&mut self, label: Label) {
        let i = self.push(
            Instruction::new(Opcode::Bl)
                .dst(Reg::x(30))
                .target(usize::MAX),
        );
        self.fixups.push((i, label));
    }

    /// Return through `x30`.
    pub fn ret(&mut self) {
        self.push(Instruction::new(Opcode::Ret).src1(Reg::x(30)));
    }

    /// Conditional branch comparing `a` to `b`.
    pub fn bcond(&mut self, cond: Condition, a: Reg, b: Reg, label: Label) {
        let i = self.push(
            Instruction::new(Opcode::Bcond)
                .src1(a)
                .src2(b)
                .cond(cond)
                .target(usize::MAX),
        );
        self.fixups.push((i, label));
    }

    /// Conditional branch comparing `a` to an immediate.
    pub fn bcondi(&mut self, cond: Condition, a: Reg, imm: i64, label: Label) {
        let i = self.push(
            Instruction::new(Opcode::Bcond)
                .src1(a)
                .imm(imm)
                .cond(cond)
                .target(usize::MAX),
        );
        self.fixups.push((i, label));
    }

    /// Branch if `r != 0`.
    pub fn cbnz(&mut self, r: Reg, label: Label) {
        let i = self.push(Instruction::new(Opcode::Cbnz).src1(r).target(usize::MAX));
        self.fixups.push((i, label));
    }

    /// Branch if `r == 0`.
    pub fn cbz(&mut self, r: Reg, label: Label) {
        let i = self.push(Instruction::new(Opcode::Cbz).src1(r).target(usize::MAX));
        self.fixups.push((i, label));
    }

    /// Nop.
    pub fn nop(&mut self) {
        self.push(Instruction::new(Opcode::Nop));
    }

    /// Finalize: patch label fixups, validate, return the program.
    pub fn build(mut self) -> Program {
        for (inst_idx, label) in &self.fixups {
            let target = self.labels[label.0]
                .unwrap_or_else(|| panic!("label {} never placed", label.0));
            self.insts[*inst_idx].target = Some(target);
        }
        let program = Program {
            name: self.name,
            insts: self.insts,
            data_size: self.data_cursor.max(8),
            init_words: self.init_words,
            init_regs: self.init_regs,
        };
        program
            .validate()
            .unwrap_or_else(|e| panic!("generated program invalid: {e}"));
        program
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut b = ProgramBuilder::new("t");
        b.movi(Reg::x(1), 3);
        let top = b.here();
        let done = b.label();
        b.alui(Opcode::Subs, Reg::x(1), Reg::x(1), 1);
        b.cbz(Reg::x(1), done);
        b.b(top);
        b.place(done);
        b.nop();
        let p = b.build();
        p.validate().unwrap();
        let t = FunctionalSim::new(&p).run(100);
        // movi + 3*(subs,cbz) + 2*b + nop = 1 + 6 + 2 + 1
        assert_eq!(t.records.len(), 10);
    }

    #[test]
    fn alloc_is_aligned_and_disjoint() {
        let mut b = ProgramBuilder::new("t");
        let a1 = b.alloc(100);
        let a2 = b.alloc(8);
        assert_eq!(a1 % 8, 0);
        assert!(a2 >= a1 + 100);
        b.nop();
        let p = b.build();
        assert!(p.data_size >= 112);
    }

    #[test]
    fn init_words_offsets_relative() {
        let mut b = ProgramBuilder::new("t");
        let a = b.alloc(16);
        b.init_word(a + 8, 77);
        b.nop();
        let p = b.build();
        assert_eq!(p.init_words, vec![(8, 77)]);
    }

    #[test]
    #[should_panic(expected = "never placed")]
    fn unplaced_label_panics() {
        let mut b = ProgramBuilder::new("t");
        let l = b.label();
        b.b(l);
        let _ = b.build();
    }

    #[test]
    #[should_panic(expected = "placed twice")]
    fn double_place_panics() {
        let mut b = ProgramBuilder::new("t");
        let l = b.label();
        b.place(l);
        b.place(l);
    }

    #[test]
    fn call_ret_works_end_to_end() {
        let mut b = ProgramBuilder::new("t");
        let sub = b.label();
        let end = b.label();
        b.bl(sub);
        b.b(end);
        b.place(sub);
        b.movi(Reg::x(5), 42);
        b.ret();
        b.place(end);
        b.nop();
        let p = b.build();
        let t = FunctionalSim::new(&p).run(100);
        assert_eq!(t.records.len(), 5);
    }
}
