//! The out-of-order pipeline timing model.

use super::cache::{Cache, DataHierarchy, InstHierarchy};
use super::predictor::{self, BranchPredictor};
use crate::functional::Machine;
use crate::isa::{Opcode, OpcodeClass, Program};
use crate::trace::{AccessLevel, DetailedRecord, DetailedTrace, RetiredInfo};
use crate::uarch::{CacheGeometry, UarchConfig};
use std::collections::VecDeque;

/// Execution latency (cycles in the functional unit) per opcode class.
fn exec_latency(class: OpcodeClass) -> u64 {
    match class {
        OpcodeClass::IntAlu => 1,
        OpcodeClass::IntMul => 3,
        OpcodeClass::IntDiv => 12,
        OpcodeClass::FpAlu => 2,
        OpcodeClass::FpMul => 4,
        OpcodeClass::FpDiv => 12,
        OpcodeClass::Load => 0,  // memory latency added separately
        OpcodeClass::Store => 1, // retires via store buffer
        OpcodeClass::Branch => 1,
        OpcodeClass::Nop => 1,
    }
}

/// Run-level statistics the detailed simulator reports directly — the
/// "gem5 ground truth" column of every evaluation figure.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimStats {
    /// Committed instructions.
    pub instructions: u64,
    /// Total cycles (retire clock of the last instruction).
    pub cycles: u64,
    /// Conditional branches committed.
    pub cond_branches: u64,
    /// Mispredicted conditional branches.
    pub mispredicts: u64,
    /// Committed loads+stores.
    pub mem_ops: u64,
    /// L1D misses (served by L2 or memory).
    pub l1d_misses: u64,
    /// L2 misses on the data side (served by memory).
    pub l2d_misses: u64,
    /// L1I misses.
    pub l1i_misses: u64,
    /// Data TLB misses.
    pub dtlb_misses: u64,
    /// Squashed wrong-path instructions fetched.
    pub squashed: u64,
    /// Pipeline-stall nop bubbles recorded.
    pub nops: u64,
}

impl SimStats {
    /// Cycles per instruction.
    pub fn cpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.cycles as f64 / self.instructions as f64
        }
    }

    /// Branch mispredictions per kilo-instruction.
    pub fn branch_mpki(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.mispredicts as f64 * 1000.0 / self.instructions as f64
        }
    }

    /// L1D misses per kilo-instruction.
    pub fn l1d_mpki(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.l1d_misses as f64 * 1000.0 / self.instructions as f64
        }
    }

    /// L2 (data) misses per kilo-instruction.
    pub fn l2d_mpki(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.l2d_misses as f64 * 1000.0 / self.instructions as f64
        }
    }

    /// Conditional-branch misprediction rate in [0,1].
    pub fn mispredict_rate(&self) -> f64 {
        if self.cond_branches == 0 {
            0.0
        } else {
            self.mispredicts as f64 / self.cond_branches as f64
        }
    }
}

/// The detailed out-of-order simulator.
pub struct DetailedSim {
    config: UarchConfig,
    machine: Machine,
    predictor: Box<dyn BranchPredictor + Send>,
    iside: InstHierarchy,
    dside: DataHierarchy,
    l2: Cache,
    /// Register scoreboard: cycle at which each architectural register's
    /// value is available (full forwarding).
    reg_ready: [u64; crate::isa::NUM_REGS],
    /// Retire clocks of in-flight (dispatched, not yet retired relative
    /// to fetch time) instructions — models ROB occupancy.
    rob: VecDeque<u64>,
    fetch_cycle: u64,
    fetched_in_cycle: u32,
    last_fetch_line: u64,
    last_retire_cycle: u64,
    retired_in_cycle: u32,
    stats: SimStats,
    /// Whether to emit wrong-path/nop records (dataset construction needs
    /// them; pure-stats runs can skip the allocation traffic).
    emit_records: bool,
}

impl DetailedSim {
    /// Build a simulator for `program` on design point `config`.
    pub fn new(program: &Program, config: &UarchConfig) -> DetailedSim {
        DetailedSim {
            config: config.clone(),
            machine: Machine::new(program),
            predictor: predictor::build(config.predictor),
            iside: InstHierarchy::new(config.l1i, config.timing),
            dside: DataHierarchy::new(config.l1d, config.timing),
            l2: Cache::new(config.l2),
            reg_ready: [0; crate::isa::NUM_REGS],
            rob: VecDeque::new(),
            fetch_cycle: 1,
            fetched_in_cycle: 0,
            last_fetch_line: u64::MAX,
            last_retire_cycle: 0,
            retired_in_cycle: 0,
            stats: SimStats::default(),
            emit_records: true,
        }
    }

    /// Disable trace-record emission (statistics only, used by DSE sweeps
    /// where only `SimStats` is consumed).
    pub fn stats_only(mut self) -> Self {
        self.emit_records = false;
        self
    }

    /// Run up to `max_insts` committed instructions; returns the detailed
    /// trace (empty `records` if `stats_only`) and the statistics.
    pub fn run(mut self, max_insts: u64) -> (DetailedTrace, SimStats) {
        let mut records: Vec<DetailedRecord> = Vec::new();
        if self.emit_records {
            records.reserve(max_insts.min(1 << 22) as usize + 1024);
        }
        while self.stats.instructions < max_insts {
            let emit = self.emit_records.then_some(&mut records);
            if self.step_commit(emit).is_none() {
                break;
            }
        }
        let trace = DetailedTrace {
            name: self.machine.program_name().to_string(),
            uarch: self.config.name.clone(),
            records,
            total_cycles: self.stats.cycles,
        };
        (trace, self.stats)
    }

    /// Ground-truth cycles so far (the retire clock of the last
    /// committed instruction). After a bounded run this is the trace's
    /// `total_cycles`.
    pub fn total_cycles(&self) -> u64 {
        self.stats.cycles
    }

    /// The statistics accumulated so far.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Advance the pipeline until the next instruction commits and
    /// return its retired record, or `None` once the program halts.
    ///
    /// This is the resumable core [`DetailedSim::run`] loops over, and
    /// the pull surface behind the streaming datagen source
    /// (`datagen::SimPairSource`): callers that only need the retired
    /// stream pass `emit: None` and no record vector ever exists. With
    /// `emit: Some(v)`, the squashed / nop-stall records produced along
    /// the way are appended to `v` in fetch order, the retired record
    /// included — exactly the batch trace layout.
    pub fn step_commit(
        &mut self,
        mut emit: Option<&mut Vec<DetailedRecord>>,
    ) -> Option<RetiredInfo> {
        let line_mask = !(CacheGeometry::LINE_BYTES - 1);
        let Some(exec) = self.machine.step() else {
            return None;
        };
        {
            let rec = exec.record;
            let inst_index = exec.index;
            let opcode = rec.opcode;

            // ---- ROB capacity: stall fetch until the oldest retires ----
            while self.rob.len() >= self.config.rob_size as usize {
                let oldest = *self.rob.front().unwrap();
                self.rob.pop_front();
                if oldest > self.fetch_cycle {
                    // Pipeline bubble (§4.1 "stall instructions"): record
                    // one nop per *significant* stall event (short
                    // single-cycle hiccups are absorbed into fetch-clock
                    // deltas, matching gem5's sparse nop insertion),
                    // advance fetch to the blocking retire cycle.
                    if oldest - self.fetch_cycle >= 4 {
                        if let Some(v) = emit.as_mut() {
                            v.push(DetailedRecord::NopStall {
                                fetch_clock: self.fetch_cycle,
                            });
                        }
                        self.stats.nops += 1;
                    }
                    self.fetch_cycle = oldest;
                    self.fetched_in_cycle = 0;
                }
            }

            // ---- ICache ----
            let line = rec.pc & line_mask;
            let mut icache_miss = false;
            if line != self.last_fetch_line {
                let f = self.iside.fetch(rec.pc, &mut self.l2);
                icache_miss = f.miss;
                if f.miss {
                    self.stats.l1i_misses += 1;
                    self.fetch_cycle += f.penalty;
                    self.fetched_in_cycle = 0;
                }
                self.last_fetch_line = line;
            }

            // ---- Fetch slot ----
            let fetch_clock = self.fetch_cycle;
            self.fetched_in_cycle += 1;
            if self.fetched_in_cycle >= self.config.fetch_width {
                self.fetch_cycle += 1;
                self.fetched_in_cycle = 0;
            }

            // ---- Issue: wait for operands ----
            let mut issue = fetch_clock + self.config.timing.decode_lat;
            let inst = self.machine.program().insts[inst_index];
            for src in inst.sources() {
                issue = issue.max(self.reg_ready[src.index()]);
            }

            // ---- Execute ----
            let mut latency = exec_latency(opcode.class());
            let mut access_level = AccessLevel::None;
            let mut tlb_miss = false;
            if rec.is_mem() {
                self.stats.mem_ops += 1;
                let a = self.dside.access(rec.mem_addr, &mut self.l2);
                access_level = a.level;
                tlb_miss = a.tlb_miss;
                if a.tlb_miss {
                    self.stats.dtlb_misses += 1;
                }
                match a.level {
                    AccessLevel::L2 => self.stats.l1d_misses += 1,
                    AccessLevel::Mem => {
                        self.stats.l1d_misses += 1;
                        self.stats.l2d_misses += 1;
                    }
                    _ => {}
                }
                if opcode.is_load() {
                    latency += a.latency;
                } else {
                    // Stores retire via the store buffer; the hierarchy
                    // state is updated but commit does not wait for it.
                    latency += 1;
                }
            }
            let complete = issue + latency;
            if let Some(d) = inst.dst {
                self.reg_ready[d.index()] = complete;
            }

            // ---- Branch prediction ----
            let mut mispred = false;
            if opcode.is_cond_branch() {
                self.stats.cond_branches += 1;
                let pred = self.predictor.predict(rec.pc);
                mispred = pred != rec.taken;
                self.predictor.update(rec.pc, rec.taken);
            }

            // ---- Commit (in order, fetch_width per cycle) ----
            let mut retire = complete.max(self.last_retire_cycle);
            if retire == self.last_retire_cycle {
                self.retired_in_cycle += 1;
                if self.retired_in_cycle >= self.config.fetch_width {
                    retire += 1;
                    self.retired_in_cycle = 0;
                }
            } else {
                self.retired_in_cycle = 1;
            }
            self.last_retire_cycle = retire;
            self.rob.push_back(retire);

            self.stats.instructions += 1;
            if mispred {
                self.stats.mispredicts += 1;
            }
            self.stats.cycles = retire;

            let info = RetiredInfo {
                func: rec,
                fetch_clock,
                retire_clock: retire,
                branch_mispred: mispred,
                access_level,
                icache_miss,
                tlb_miss,
            };
            if let Some(v) = emit.as_mut() {
                v.push(DetailedRecord::Retired(info));
            }

            // ---- Misprediction: wrong path + redirect ----
            if mispred {
                let resolve = complete;
                // Wrong-path fetch: from the *not* taken direction.
                let wrong_start = if rec.taken {
                    inst_index + 1 // predicted not-taken, fell through
                } else {
                    inst.target.unwrap_or(inst_index + 1)
                };
                // Wrong-path fetch stops when the front-end queue fills,
                // long before a slow (e.g. load-dependent) branch
                // resolves: cap at a fetch-queue's worth of instructions,
                // not the full resolve window.
                let budget_cycles = resolve
                    .saturating_sub(fetch_clock)
                    .max(1)
                    .min(2 * self.config.timing.mispredict_penalty);
                let max_wrong = (budget_cycles * self.config.fetch_width as u64)
                    .min(self.config.rob_size as u64)
                    .min(16);
                let program = self.machine.program();
                let mut wp_cycle = fetch_clock + 1;
                let mut wp_in_cycle = 0u32;
                let mut idx = wrong_start;
                for _ in 0..max_wrong {
                    if idx >= program.insts.len() {
                        break;
                    }
                    let wp_inst = &program.insts[idx];
                    if let Some(v) = emit.as_mut() {
                        v.push(DetailedRecord::Squashed {
                            pc: Program::pc_of(idx),
                            opcode: wp_inst.opcode,
                            fetch_clock: wp_cycle,
                        });
                    }
                    self.stats.squashed += 1;
                    wp_in_cycle += 1;
                    if wp_in_cycle >= self.config.fetch_width {
                        wp_cycle += 1;
                        wp_in_cycle = 0;
                    }
                    // Wrong-path control flow: follow unconditional
                    // branches, assume conditionals fall through.
                    idx = match wp_inst.opcode {
                        Opcode::B | Opcode::Bl => wp_inst.target.unwrap_or(idx + 1),
                        _ => idx + 1,
                    };
                }
                // Redirect: fetch restarts after resolution + penalty.
                self.fetch_cycle = resolve + self.config.timing.mispredict_penalty;
                self.fetched_in_cycle = 0;
                self.last_fetch_line = u64::MAX; // refetch the line
            }

            Some(info)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Condition, Instruction, Opcode, Program, Reg};
    use crate::uarch::UarchConfig;

    /// Tight countdown loop with a data array walk.
    fn loop_program(iters: i64, stride: i64, footprint: u64) -> Program {
        Program {
            name: "loop".into(),
            insts: vec![
                // x1 = iters; x2 = DATA_BASE; x3 = 0 (accumulator)
                Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(iters),
                Instruction::new(Opcode::Movi)
                    .dst(Reg::x(2))
                    .imm(crate::isa::inst::DATA_BASE as i64),
                // loop: x4 = [x2]; x3 += x4; x2 += stride; x1 -= 1; cbnz
                Instruction::new(Opcode::Ldr).dst(Reg::x(4)).src1(Reg::x(2)),
                Instruction::new(Opcode::Add)
                    .dst(Reg::x(3))
                    .src1(Reg::x(3))
                    .src2(Reg::x(4)),
                Instruction::new(Opcode::Add)
                    .dst(Reg::x(2))
                    .src1(Reg::x(2))
                    .imm(stride),
                Instruction::new(Opcode::Subs)
                    .dst(Reg::x(1))
                    .src1(Reg::x(1))
                    .imm(1),
                Instruction::new(Opcode::Cbnz).src1(Reg::x(1)).target(2),
            ],
            data_size: footprint,
            init_words: vec![],
            init_regs: vec![],
        }
    }

    fn run(p: &Program, cfg: &UarchConfig, n: u64) -> (DetailedTrace, SimStats) {
        DetailedSim::new(p, cfg).run(n)
    }

    #[test]
    fn cpi_at_least_inverse_width() {
        let p = loop_program(1000, 8, 1 << 16);
        let cfg = UarchConfig::uarch_c();
        let (_, stats) = run(&p, &cfg, 5000);
        assert!(stats.instructions > 4000);
        assert!(
            stats.cpi() >= 1.0 / cfg.fetch_width as f64,
            "cpi={} below ideal",
            stats.cpi()
        );
    }

    #[test]
    fn retire_clocks_monotone_and_total_matches() {
        let p = loop_program(200, 64, 1 << 16);
        let (trace, stats) = run(&p, &UarchConfig::uarch_a(), 1000);
        let mut prev = 0;
        for r in trace.retired() {
            assert!(r.retire_clock >= prev, "retire clock went backwards");
            assert!(r.fetch_clock <= r.retire_clock);
            prev = r.retire_clock;
        }
        assert_eq!(stats.cycles, prev);
        assert_eq!(trace.total_cycles, prev);
    }

    #[test]
    fn fetch_clocks_monotone_across_all_records() {
        let p = loop_program(300, 4096, 1 << 22);
        let (trace, _) = run(&p, &UarchConfig::uarch_a(), 2000);
        let mut prev = 0;
        for r in &trace.records {
            assert!(
                r.fetch_clock() >= prev,
                "fetch clock regressed: {} < {prev}",
                r.fetch_clock()
            );
            prev = r.fetch_clock();
        }
    }

    #[test]
    fn streaming_large_footprint_misses_more_than_small() {
        let small = loop_program(5000, 8, 1 << 14); // revisits few lines
        let large = loop_program(5000, 64, 8 << 20); // new line every iter
        let cfg = UarchConfig::uarch_a();
        let (_, s_small) = run(&small, &cfg, 20_000);
        let (_, s_large) = run(&large, &cfg, 20_000);
        assert!(
            s_large.l1d_mpki() > 5.0 * s_small.l1d_mpki().max(0.1),
            "large {} vs small {}",
            s_large.l1d_mpki(),
            s_small.l1d_mpki()
        );
        assert!(s_large.cpi() > s_small.cpi());
    }

    #[test]
    fn bigger_caches_reduce_misses() {
        let p = loop_program(20_000, 64, 512 << 10); // 512KB working set
        let (_, sa) = run(&p, &UarchConfig::uarch_a(), 50_000); // 256KB L2
        let (_, sc) = run(&p, &UarchConfig::uarch_c(), 50_000); // 4MB L2
        assert!(
            sc.l2d_mpki() < sa.l2d_mpki(),
            "C {} !< A {}",
            sc.l2d_mpki(),
            sa.l2d_mpki()
        );
        assert!(sc.cpi() < sa.cpi());
    }

    /// Program with a hard-to-predict data-dependent branch.
    fn branchy_program() -> Program {
        Program {
            name: "branchy".into(),
            insts: vec![
                // x1 = counter; x2 = DATA_BASE; x5 = lcg state
                Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(100_000),
                Instruction::new(Opcode::Movi).dst(Reg::x(5)).imm(12345),
                // loop: lcg: x5 = x5*6364136223846793005 + 1442695040888963407
                Instruction::new(Opcode::Movi).dst(Reg::x(6)).imm(6364136223846793005),
                Instruction::new(Opcode::Mul)
                    .dst(Reg::x(5))
                    .src1(Reg::x(5))
                    .src2(Reg::x(6)),
                Instruction::new(Opcode::Add)
                    .dst(Reg::x(5))
                    .src1(Reg::x(5))
                    .imm(1442695040888963407),
                // x7 = (x5 >> 60) & 1
                Instruction::new(Opcode::Lsr).dst(Reg::x(7)).src1(Reg::x(5)).imm(60),
                Instruction::new(Opcode::And).dst(Reg::x(7)).src1(Reg::x(7)).imm(1),
                // if x7 != 0 skip the add
                Instruction::new(Opcode::Bcond)
                    .src1(Reg::x(7))
                    .imm(0)
                    .cond(Condition::Ne)
                    .target(9),
                Instruction::new(Opcode::Add).dst(Reg::x(8)).src1(Reg::x(8)).imm(1),
                // x1 -= 1; loop
                Instruction::new(Opcode::Subs).dst(Reg::x(1)).src1(Reg::x(1)).imm(1),
                Instruction::new(Opcode::Cbnz).src1(Reg::x(1)).target(2),
            ],
            data_size: 64,
            init_words: vec![],
            init_regs: vec![],
        }
    }

    #[test]
    fn random_branches_mispredict_and_squash() {
        let p = branchy_program();
        let (trace, stats) = run(&p, &UarchConfig::uarch_a(), 30_000);
        assert!(stats.cond_branches > 5_000);
        // ~50% unpredictable branch, 1-in-9 instructions => mispredict
        // rate over conditionals should be substantial.
        assert!(
            stats.mispredict_rate() > 0.10,
            "rate={}",
            stats.mispredict_rate()
        );
        assert!(stats.squashed > 0);
        assert_eq!(trace.squashed_count() as u64, stats.squashed);
    }

    #[test]
    fn better_predictor_reduces_mispredicts_on_loop() {
        // Loop branch with fixed trip count: TAGE's loop predictor should
        // beat Local decisively.
        let p = loop_program(20_000, 8, 1 << 14);
        let mut cfg_local = UarchConfig::uarch_a();
        cfg_local.predictor = crate::uarch::PredictorKind::Local;
        let mut cfg_tage = UarchConfig::uarch_a();
        cfg_tage.predictor = crate::uarch::PredictorKind::TageScL;
        let (_, s_local) = run(&p, &cfg_local, 50_000);
        let (_, s_tage) = run(&p, &cfg_tage, 50_000);
        assert!(s_tage.mispredicts <= s_local.mispredicts);
    }

    #[test]
    fn stats_match_trace_counts() {
        let p = branchy_program();
        let (trace, stats) = run(&p, &UarchConfig::uarch_b(), 10_000);
        assert_eq!(trace.retired_count() as u64, stats.instructions);
        assert_eq!(trace.squashed_count() as u64, stats.squashed);
        assert_eq!(trace.nop_count() as u64, stats.nops);
        let mispred_in_trace = trace.retired().filter(|r| r.branch_mispred).count() as u64;
        assert_eq!(mispred_in_trace, stats.mispredicts);
        let l1d_miss_in_trace = trace
            .retired()
            .filter(|r| r.access_level.is_l1_miss())
            .count() as u64;
        assert_eq!(l1d_miss_in_trace, stats.l1d_misses);
    }

    #[test]
    fn step_commit_matches_batch_run() {
        let p = branchy_program();
        let (trace, stats) = run(&p, &UarchConfig::uarch_a(), 3_000);
        let mut sim = DetailedSim::new(&p, &UarchConfig::uarch_a());
        let mut records = Vec::new();
        let mut retired = Vec::new();
        while (retired.len() as u64) < 3_000 {
            let Some(info) = sim.step_commit(Some(&mut records)) else {
                break;
            };
            retired.push(info);
        }
        // Pull-based stepping reproduces the batch run record for
        // record, stat for stat.
        assert_eq!(records, trace.records);
        assert_eq!(sim.total_cycles(), stats.cycles);
        assert_eq!(sim.stats(), &stats);
        let from_trace: Vec<RetiredInfo> = trace.retired().copied().collect();
        assert_eq!(retired, from_trace);
        // emit: None yields the same retired stream with no record
        // vector at all.
        let mut lean = DetailedSim::new(&p, &UarchConfig::uarch_a());
        for want in &from_trace {
            assert_eq!(lean.step_commit(None).as_ref(), Some(want));
        }
    }

    #[test]
    fn stats_only_emits_no_records() {
        let p = loop_program(100, 8, 1 << 12);
        let (trace, stats) = DetailedSim::new(&p, &UarchConfig::uarch_a())
            .stats_only()
            .run(500);
        assert!(trace.records.is_empty());
        assert!(stats.instructions > 0);
    }

    #[test]
    fn detailed_commits_same_stream_as_functional() {
        let p = branchy_program();
        let functional = crate::functional::FunctionalSim::new(&p).run(5_000);
        let (trace, _) = run(&p, &UarchConfig::uarch_c(), 5_000);
        let committed: Vec<_> = trace.retired().map(|r| r.func).collect();
        assert_eq!(committed.len(), functional.records.len());
        for (a, b) in committed.iter().zip(&functional.records) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let p = branchy_program();
        let (t1, s1) = run(&p, &UarchConfig::uarch_b(), 3_000);
        let (t2, s2) = run(&p, &UarchConfig::uarch_b(), 3_000);
        assert_eq!(s1, s2);
        assert_eq!(t1.records.len(), t2.records.len());
    }
}
