//! Conditional branch predictors (Table 3's four algorithms).
//!
//! These follow the gem5 implementations the paper simulates with, scaled
//! to the same default table sizes gem5's ARM configs use. The detailed
//! model consults the predictor at fetch and trains it at resolution; the
//! predictor choice is one of the strongest performance axes in the design
//! space, which is exactly what Figure 15(b) explores.

use crate::uarch::PredictorKind;

/// Direction predictor interface.
pub trait BranchPredictor {
    /// Predict the direction of the conditional branch at `pc`.
    fn predict(&mut self, pc: u64) -> bool;
    /// Train with the resolved outcome.
    fn update(&mut self, pc: u64, taken: bool);
    /// Name for reports.
    fn name(&self) -> &'static str;
}

/// Build the predictor selected by a [`PredictorKind`].
pub fn build(kind: PredictorKind) -> Box<dyn BranchPredictor + Send> {
    match kind {
        PredictorKind::Local => Box::new(LocalBp::new(2048)),
        PredictorKind::BiMode => Box::new(BiMode::new(4096, 12)),
        PredictorKind::TageScL => Box::new(TageScL::new()),
        PredictorKind::Tournament => Box::new(Tournament::new()),
    }
}

/// 2-bit saturating counter.
#[derive(Debug, Clone, Copy, Default)]
pub struct Counter2(u8);

impl Counter2 {
    /// Weakly-taken initial state.
    pub fn weakly_taken() -> Counter2 {
        Counter2(2)
    }

    /// Predicted direction.
    pub fn taken(self) -> bool {
        self.0 >= 2
    }

    /// Saturating update toward the outcome.
    pub fn update(&mut self, taken: bool) {
        if taken {
            self.0 = (self.0 + 1).min(3);
        } else {
            self.0 = self.0.saturating_sub(1);
        }
    }
}

/// Saturating n-bit signed counter (for TAGE tagged entries).
#[derive(Debug, Clone, Copy, Default)]
struct SCounter {
    v: i8,
    bits: u8,
}

impl SCounter {
    fn new(bits: u8) -> SCounter {
        SCounter { v: 0, bits }
    }
    fn max(&self) -> i8 {
        (1 << (self.bits - 1)) - 1
    }
    fn min(&self) -> i8 {
        -(1 << (self.bits - 1))
    }
    fn taken(&self) -> bool {
        self.v >= 0
    }
    fn update(&mut self, taken: bool) {
        if taken {
            self.v = (self.v + 1).min(self.max());
        } else {
            self.v = (self.v - 1).max(self.min());
        }
    }
    fn is_weak(&self) -> bool {
        self.v == 0 || self.v == -1
    }
}

fn pc_hash(pc: u64) -> u64 {
    // Drop the instruction alignment bits, then mix.
    let x = pc >> 2;
    x ^ (x >> 13) ^ (x >> 29)
}

/// gem5 `LocalBP`: a PC-indexed table of 2-bit counters.
pub struct LocalBp {
    table: Vec<Counter2>,
}

impl LocalBp {
    /// `entries` must be a power of two.
    pub fn new(entries: usize) -> LocalBp {
        assert!(entries.is_power_of_two());
        LocalBp {
            table: vec![Counter2::weakly_taken(); entries],
        }
    }

    fn idx(&self, pc: u64) -> usize {
        (pc_hash(pc) as usize) & (self.table.len() - 1)
    }
}

impl BranchPredictor for LocalBp {
    fn predict(&mut self, pc: u64) -> bool {
        let i = self.idx(pc);
        self.table[i].taken()
    }

    fn update(&mut self, pc: u64, taken: bool) {
        let i = self.idx(pc);
        self.table[i].update(taken);
    }

    fn name(&self) -> &'static str {
        "Local"
    }
}

/// Bi-Mode predictor: global-history-indexed *taken-biased* and
/// *not-taken-biased* PHTs, with a PC-indexed choice PHT selecting which
/// bank to believe. Separating the banks reduces destructive aliasing
/// between opposite-biased branches.
pub struct BiMode {
    taken_pht: Vec<Counter2>,
    not_taken_pht: Vec<Counter2>,
    choice: Vec<Counter2>,
    ghist: u64,
    hist_bits: u32,
}

impl BiMode {
    /// `entries` per bank (power of two); `hist_bits` of global history.
    pub fn new(entries: usize, hist_bits: u32) -> BiMode {
        assert!(entries.is_power_of_two());
        let mut taken_pht = vec![Counter2::weakly_taken(); entries];
        let mut not_taken_pht = vec![Counter2::weakly_taken(); entries];
        // Bias the banks as the design intends.
        for c in taken_pht.iter_mut() {
            c.update(true);
        }
        for c in not_taken_pht.iter_mut() {
            c.update(false);
            c.update(false);
        }
        BiMode {
            taken_pht,
            not_taken_pht,
            choice: vec![Counter2::weakly_taken(); entries],
            ghist: 0,
            hist_bits,
        }
    }

    fn direction_idx(&self, pc: u64) -> usize {
        let mask = self.taken_pht.len() - 1;
        ((pc_hash(pc) ^ self.ghist) as usize) & mask
    }

    fn choice_idx(&self, pc: u64) -> usize {
        (pc_hash(pc) as usize) & (self.choice.len() - 1)
    }
}

impl BranchPredictor for BiMode {
    fn predict(&mut self, pc: u64) -> bool {
        let di = self.direction_idx(pc);
        if self.choice[self.choice_idx(pc)].taken() {
            self.taken_pht[di].taken()
        } else {
            self.not_taken_pht[di].taken()
        }
    }

    fn update(&mut self, pc: u64, taken: bool) {
        let di = self.direction_idx(pc);
        let ci = self.choice_idx(pc);
        let chose_taken_bank = self.choice[ci].taken();
        let bank_pred = if chose_taken_bank {
            self.taken_pht[di].taken()
        } else {
            self.not_taken_pht[di].taken()
        };
        // Choice PHT trains unless the selected bank was correct while the
        // choice pointed the other way (standard Bi-Mode partial update).
        if !(bank_pred == taken && chose_taken_bank != taken) {
            self.choice[ci].update(taken);
        }
        // Only the selected direction bank trains.
        if chose_taken_bank {
            self.taken_pht[di].update(taken);
        } else {
            self.not_taken_pht[di].update(taken);
        }
        self.ghist = ((self.ghist << 1) | taken as u64) & ((1 << self.hist_bits) - 1);
    }

    fn name(&self) -> &'static str {
        "BiMode"
    }
}

/// Alpha 21264-style tournament predictor: a local predictor (per-PC
/// history → local PHT), a global predictor (global history → PHT) and a
/// global-history-indexed chooser.
pub struct Tournament {
    local_hist: Vec<u16>,
    local_pht: Vec<Counter2>,
    global_pht: Vec<Counter2>,
    chooser: Vec<Counter2>,
    ghist: u64,
    local_hist_bits: u32,
    ghist_bits: u32,
}

impl Tournament {
    /// gem5-like default geometry.
    pub fn new() -> Tournament {
        let local_hist_bits = 11;
        let ghist_bits = 12;
        Tournament {
            local_hist: vec![0; 1024],
            local_pht: vec![Counter2::weakly_taken(); 1 << local_hist_bits],
            global_pht: vec![Counter2::weakly_taken(); 1 << ghist_bits],
            chooser: vec![Counter2::weakly_taken(); 1 << ghist_bits],
            ghist: 0,
            local_hist_bits,
            ghist_bits,
        }
    }

    fn local_idx(&self, pc: u64) -> usize {
        (pc_hash(pc) as usize) & (self.local_hist.len() - 1)
    }
}

impl Default for Tournament {
    fn default() -> Self {
        Self::new()
    }
}

impl BranchPredictor for Tournament {
    fn predict(&mut self, pc: u64) -> bool {
        let lh = self.local_hist[self.local_idx(pc)] as usize & ((1 << self.local_hist_bits) - 1);
        let local_pred = self.local_pht[lh].taken();
        let gi = (self.ghist as usize) & ((1 << self.ghist_bits) - 1);
        let global_pred = self.global_pht[gi].taken();
        if self.chooser[gi].taken() {
            global_pred
        } else {
            local_pred
        }
    }

    fn update(&mut self, pc: u64, taken: bool) {
        let li = self.local_idx(pc);
        let lh = self.local_hist[li] as usize & ((1 << self.local_hist_bits) - 1);
        let local_pred = self.local_pht[lh].taken();
        let gi = (self.ghist as usize) & ((1 << self.ghist_bits) - 1);
        let global_pred = self.global_pht[gi].taken();
        // Chooser trains toward whichever component was right (when they
        // disagree).
        if local_pred != global_pred {
            self.chooser[gi].update(global_pred == taken);
        }
        self.local_pht[lh].update(taken);
        self.global_pht[gi].update(taken);
        self.local_hist[li] =
            ((self.local_hist[li] << 1) | taken as u16) & ((1 << self.local_hist_bits) - 1);
        self.ghist = ((self.ghist << 1) | taken as u64) & ((1 << self.ghist_bits) - 1);
    }

    fn name(&self) -> &'static str {
        "Tournament"
    }
}

/// One tagged TAGE component.
struct TageTable {
    tags: Vec<u16>,
    ctrs: Vec<SCounter>,
    useful: Vec<u8>,
    hist_len: u32,
    idx_bits: u32,
}

impl TageTable {
    fn new(idx_bits: u32, hist_len: u32) -> TageTable {
        let n = 1usize << idx_bits;
        TageTable {
            tags: vec![0; n],
            ctrs: vec![SCounter::new(3); n],
            useful: vec![0; n],
            hist_len,
            idx_bits,
        }
    }

    fn fold(hist: u128, len: u32, bits: u32) -> u64 {
        // Fold `len` history bits down to `bits` by xor.
        let mut h = hist & ((1u128 << len) - 1);
        let mut out = 0u64;
        while h != 0 {
            out ^= (h as u64) & ((1 << bits) - 1);
            h >>= bits;
        }
        out
    }

    fn index(&self, pc: u64, hist: u128) -> usize {
        let folded = Self::fold(hist, self.hist_len, self.idx_bits);
        ((pc_hash(pc) ^ folded) as usize) & ((1 << self.idx_bits) - 1)
    }

    fn tag(&self, pc: u64, hist: u128) -> u16 {
        let folded = Self::fold(hist, self.hist_len, 8);
        (((pc_hash(pc) >> 4) ^ folded ^ (folded << 1)) & 0xFF) as u16 | 0x100
    }
}

/// TAGE-SC-L, reduced: a bimodal base predictor plus four tagged tables
/// with geometrically increasing history lengths, usefulness counters and
/// the standard provider/alternate allocation policy, plus a small loop
/// predictor (the "L" component). The statistical corrector is folded
/// into a confidence threshold on the provider counter — a common
/// simplification that keeps the accuracy ordering (TAGE > Tournament >
/// BiMode > Local) the paper's Figure 15(b) relies on.
pub struct TageScL {
    base: Vec<Counter2>,
    tables: Vec<TageTable>,
    ghist: u128,
    /// Loop predictor: PC-indexed entries tracking (trip count, current
    /// iteration, confidence).
    loops: Vec<LoopEntry>,
    tick: u64,
}

#[derive(Debug, Clone, Copy, Default)]
struct LoopEntry {
    tag: u16,
    trip: u16,
    current: u16,
    conf: u8,
}

impl TageScL {
    /// Default geometry: 4 tagged tables, histories 8/16/32/64.
    pub fn new() -> TageScL {
        TageScL {
            base: vec![Counter2::weakly_taken(); 4096],
            tables: vec![
                TageTable::new(10, 8),
                TageTable::new(10, 16),
                TageTable::new(10, 32),
                TageTable::new(10, 64),
            ],
            ghist: 0,
            loops: vec![LoopEntry::default(); 256],
            tick: 0,
        }
    }

    fn base_idx(&self, pc: u64) -> usize {
        (pc_hash(pc) as usize) & (self.base.len() - 1)
    }

    fn loop_idx(pc: u64) -> usize {
        (pc_hash(pc) as usize) & 255
    }

    fn loop_tag(pc: u64) -> u16 {
        ((pc_hash(pc) >> 8) & 0x3FF) as u16 | 0x400
    }

    /// (provider table index or None=base, prediction)
    fn provider(&self, pc: u64) -> (Option<usize>, bool) {
        for (ti, t) in self.tables.iter().enumerate().rev() {
            let i = t.index(pc, self.ghist);
            if t.tags[i] == t.tag(pc, self.ghist) {
                return (Some(ti), t.ctrs[i].taken());
            }
        }
        (None, self.base[self.base_idx(pc)].taken())
    }
}

impl Default for TageScL {
    fn default() -> Self {
        Self::new()
    }
}

impl BranchPredictor for TageScL {
    fn predict(&mut self, pc: u64) -> bool {
        // Loop predictor overrides when confident.
        let le = &self.loops[Self::loop_idx(pc)];
        if le.tag == Self::loop_tag(pc) && le.conf >= 3 && le.trip > 0 {
            return le.current + 1 != le.trip;
        }
        self.provider(pc).1
    }

    fn update(&mut self, pc: u64, taken: bool) {
        // --- loop predictor training ---
        let li = Self::loop_idx(pc);
        let ltag = Self::loop_tag(pc);
        {
            let le = &mut self.loops[li];
            if le.tag != ltag {
                // (Re)allocate on a not-taken outcome (loop exit candidate).
                if !taken {
                    *le = LoopEntry {
                        tag: ltag,
                        trip: 0,
                        current: 0,
                        conf: 0,
                    };
                }
            } else if taken {
                le.current = le.current.saturating_add(1);
            } else {
                let observed = le.current + 1;
                if le.trip == observed {
                    le.conf = (le.conf + 1).min(7);
                } else {
                    le.trip = observed;
                    le.conf = 0;
                }
                le.current = 0;
            }
        }

        // --- TAGE training ---
        let (provider, pred) = self.provider(pc);
        match provider {
            Some(ti) => {
                let i = self.tables[ti].index(pc, self.ghist);
                self.tables[ti].ctrs[i].update(taken);
                if pred == taken {
                    self.tables[ti].useful[i] = (self.tables[ti].useful[i] + 1).min(3);
                } else {
                    self.tables[ti].useful[i] = self.tables[ti].useful[i].saturating_sub(1);
                }
            }
            None => {
                let i = self.base_idx(pc);
                self.base[i].update(taken);
            }
        }

        // Allocate a longer-history entry on misprediction.
        if pred != taken {
            let start = provider.map(|p| p + 1).unwrap_or(0);
            let mut allocated = false;
            for ti in start..self.tables.len() {
                let i = self.tables[ti].index(pc, self.ghist);
                if self.tables[ti].useful[i] == 0 {
                    let tag = self.tables[ti].tag(pc, self.ghist);
                    self.tables[ti].tags[i] = tag;
                    self.tables[ti].ctrs[i] = SCounter::new(3);
                    self.tables[ti].ctrs[i].update(taken);
                    allocated = true;
                    break;
                }
            }
            if !allocated {
                // Global usefulness decay when allocation keeps failing.
                self.tick += 1;
                if self.tick.is_multiple_of(256) {
                    for t in self.tables.iter_mut() {
                        for u in t.useful.iter_mut() {
                            *u = u.saturating_sub(1);
                        }
                    }
                }
            }
        } else if let Some(ti) = provider {
            // Weak-correct providers occasionally refresh usefulness.
            let i = self.tables[ti].index(pc, self.ghist);
            if self.tables[ti].ctrs[i].is_weak() {
                self.tables[ti].useful[i] = self.tables[ti].useful[i].saturating_sub(0);
            }
        }

        self.ghist = (self.ghist << 1) | taken as u128;
    }

    fn name(&self) -> &'static str {
        "TAGE_SC_L"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train(bp: &mut dyn BranchPredictor, pattern: &[bool], reps: usize, pc: u64) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..reps {
            for &t in pattern {
                if bp.predict(pc) == t {
                    correct += 1;
                }
                bp.update(pc, t);
                total += 1;
            }
        }
        correct as f64 / total as f64
    }

    #[test]
    fn all_predictors_learn_always_taken() {
        for kind in PredictorKind::ALL {
            let mut bp = build(kind);
            let acc = train(bp.as_mut(), &[true], 500, 0x400100);
            assert!(acc > 0.95, "{} acc={acc}", bp.name());
        }
    }

    #[test]
    fn all_predictors_learn_always_not_taken() {
        for kind in PredictorKind::ALL {
            let mut bp = build(kind);
            let acc = train(bp.as_mut(), &[false], 500, 0x400100);
            assert!(acc > 0.95, "{} acc={acc}", bp.name());
        }
    }

    #[test]
    fn history_predictors_learn_alternating_pattern() {
        // T,N,T,N is impossible for LocalBp (2-bit counter flaps) but easy
        // for anything with history.
        let pattern = [true, false];
        for kind in [
            PredictorKind::BiMode,
            PredictorKind::Tournament,
            PredictorKind::TageScL,
        ] {
            let mut bp = build(kind);
            let acc = train(bp.as_mut(), &pattern, 600, 0x400200);
            assert!(acc > 0.8, "{} acc={acc}", bp.name());
        }
        let mut local = build(PredictorKind::Local);
        let acc = train(local.as_mut(), &pattern, 600, 0x400200);
        assert!(acc < 0.8, "Local should not learn alternation, acc={acc}");
    }

    #[test]
    fn tage_learns_long_loop_pattern() {
        // 15 taken, 1 not-taken — a loop with trip count 16.
        let mut pattern = vec![true; 15];
        pattern.push(false);
        let mut tage = TageScL::new();
        let acc = train(&mut tage, &pattern, 400, 0x400300);
        assert!(acc > 0.97, "tage loop acc={acc}");
        // Local predictor mispredicts every loop exit.
        let mut local = LocalBp::new(2048);
        let acc_local = train(&mut local, &pattern, 400, 0x400300);
        assert!(acc_local < 0.96, "local loop acc={acc_local}");
    }

    #[test]
    fn counter2_saturates() {
        let mut c = Counter2::weakly_taken();
        for _ in 0..10 {
            c.update(true);
        }
        assert!(c.taken());
        for _ in 0..2 {
            c.update(false);
        }
        // From saturated-taken(3), two not-taken steps land at 1 => not taken.
        assert!(!c.taken());
    }

    #[test]
    fn predictors_separate_pcs() {
        // Two branches with opposite bias must not destructively alias.
        for kind in PredictorKind::ALL {
            let mut bp = build(kind);
            let mut correct = 0;
            let mut total = 0;
            for _ in 0..500 {
                for (pc, t) in [(0x400400u64, true), (0x400480u64, false)] {
                    if bp.predict(pc) == t {
                        correct += 1;
                    }
                    bp.update(pc, t);
                    total += 1;
                }
            }
            let acc = correct as f64 / total as f64;
            assert!(acc > 0.9, "{} acc={acc}", bp.name());
        }
    }

    #[test]
    fn accuracy_ordering_on_mixed_workload() {
        // A synthetic mix: loop branches + correlated branches + biased
        // branches. The paper's Figure 15(b) depends on the ordering
        // TAGE >= Tournament >= BiMode >= Local holding broadly.
        let mut accs = Vec::new();
        for kind in [
            PredictorKind::Local,
            PredictorKind::BiMode,
            PredictorKind::Tournament,
            PredictorKind::TageScL,
        ] {
            let mut bp = build(kind);
            let mut correct = 0usize;
            let mut total = 0usize;
            let mut ghist = 0u64;
            let mut rng = crate::util::Rng::new(7);
            for i in 0..30_000u64 {
                // loop branch, trip 8
                let pc1 = 0x401000;
                let t1 = !(i).is_multiple_of(8);
                // correlated branch: taken iff last loop branch taken
                let pc2 = 0x401100;
                let t2 = ghist & 1 == 1;
                // biased branch: 90% taken
                let pc3 = 0x401200;
                let t3 = rng.chance(0.9);
                for (pc, t) in [(pc1, t1), (pc2, t2), (pc3, t3)] {
                    if bp.predict(pc) == t {
                        correct += 1;
                    }
                    bp.update(pc, t);
                    total += 1;
                }
                ghist = (ghist << 1) | t1 as u64;
            }
            accs.push((kind, correct as f64 / total as f64));
        }
        let get = |k: PredictorKind| accs.iter().find(|(kk, _)| *kk == k).unwrap().1;
        assert!(
            get(PredictorKind::TageScL) >= get(PredictorKind::Local),
            "TAGE {:.3} < Local {:.3}",
            get(PredictorKind::TageScL),
            get(PredictorKind::Local)
        );
        assert!(
            get(PredictorKind::Tournament) >= get(PredictorKind::Local),
            "Tournament < Local"
        );
    }
}
