//! Set-associative caches and the two-level data/instruction hierarchy.
//!
//! Geometry comes from the Table 3 design space (`crate::uarch`); the
//! replacement policy is true-LRU with write-allocate, matching gem5's
//! classic cache defaults. The hierarchy reports the *service level* of
//! every access — the label space of Tao's data-access-level prediction
//! head — plus hit/miss statistics for the MPKI ground truth.

use crate::trace::AccessLevel;
use crate::uarch::{CacheGeometry, Timing};

/// One set-associative cache with true LRU replacement.
pub struct Cache {
    sets: u64,
    assoc: usize,
    line_shift: u32,
    /// `tags[set * assoc + way]`; `u64::MAX` = invalid.
    tags: Vec<u64>,
    /// LRU stamp per way (higher = more recent).
    stamps: Vec<u64>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl Cache {
    /// Build from a geometry. Set count need not be a power of two
    /// (Table 3 includes associativity 6): indexing is modulo and tags
    /// store the full line number.
    pub fn new(geom: CacheGeometry) -> Cache {
        let sets = geom.sets().max(1);
        let assoc = geom.assoc as usize;
        Cache {
            sets,
            assoc,
            line_shift: CacheGeometry::LINE_BYTES.trailing_zeros(),
            tags: vec![u64::MAX; (sets as usize) * assoc],
            stamps: vec![0; (sets as usize) * assoc],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    fn set_of(&self, addr: u64) -> usize {
        ((addr >> self.line_shift) % self.sets) as usize
    }

    fn tag_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    /// Access the line containing `addr`. Returns `true` on hit; on miss
    /// the line is filled (write-allocate / fetch-on-miss), evicting LRU.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.assoc;
        let ways = &mut self.tags[base..base + self.assoc];
        if let Some(w) = ways.iter().position(|&t| t == tag) {
            self.stamps[base + w] = self.clock;
            self.hits += 1;
            return true;
        }
        // Miss: fill into LRU way.
        let lru = (0..self.assoc)
            .min_by_key(|&w| self.stamps[base + w])
            .unwrap();
        self.tags[base + lru] = tag;
        self.stamps[base + lru] = self.clock;
        self.misses += 1;
        false
    }

    /// Probe without filling or updating LRU (used by tests and warm-up
    /// checks).
    pub fn probe(&self, addr: u64) -> bool {
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.assoc;
        self.tags[base..base + self.assoc].iter().any(|&t| t == tag)
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Number of sets.
    pub fn num_sets(&self) -> u64 {
        self.sets
    }
}

/// Fully-associative LRU TLB over 4 KiB pages.
pub struct Tlb {
    entries: Vec<u64>,
    stamps: Vec<u64>,
    clock: u64,
    hits: u64,
    misses: u64,
}

/// Page size covered by one TLB entry.
pub const PAGE_BYTES: u64 = 4096;

impl Tlb {
    /// TLB with `n` entries.
    pub fn new(n: usize) -> Tlb {
        Tlb {
            entries: vec![u64::MAX; n],
            stamps: vec![0; n],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access the page of `addr`; true on hit, fills on miss.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let page = addr / PAGE_BYTES;
        if let Some(i) = self.entries.iter().position(|&p| p == page) {
            self.stamps[i] = self.clock;
            self.hits += 1;
            return true;
        }
        let lru = (0..self.entries.len())
            .min_by_key(|&i| self.stamps[i])
            .unwrap();
        self.entries[lru] = page;
        self.stamps[lru] = self.clock;
        self.misses += 1;
        false
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Result of a data-side access through the hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataAccess {
    /// Which level served the access.
    pub level: AccessLevel,
    /// Total latency in cycles (including TLB penalty).
    pub latency: u64,
    /// Whether the TLB missed.
    pub tlb_miss: bool,
}

/// The data-side hierarchy: DTLB → L1D → (shared) L2 → memory.
pub struct DataHierarchy {
    l1d: Cache,
    tlb: Tlb,
    timing: Timing,
}

/// Result of an instruction fetch through the hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FetchAccess {
    /// L1I miss?
    pub miss: bool,
    /// Extra cycles beyond the pipelined fetch (0 on L1I hit).
    pub penalty: u64,
}

/// The instruction-side hierarchy: L1I → (shared) L2 → memory.
pub struct InstHierarchy {
    l1i: Cache,
    timing: Timing,
}

impl DataHierarchy {
    /// Build from geometries + timing.
    pub fn new(l1d: CacheGeometry, timing: Timing) -> DataHierarchy {
        DataHierarchy {
            l1d: Cache::new(l1d),
            tlb: Tlb::new(timing.dtlb_entries),
            timing,
        }
    }

    /// Perform a data access; the shared L2 is passed in so the I-side
    /// can contend for the same capacity.
    pub fn access(&mut self, addr: u64, l2: &mut Cache) -> DataAccess {
        let tlb_hit = self.tlb.access(addr);
        let mut latency = if tlb_hit { 0 } else { self.timing.tlb_miss_lat };
        let level;
        if self.l1d.access(addr) {
            level = AccessLevel::L1;
            latency += self.timing.l1_lat;
        } else if l2.access(addr) {
            level = AccessLevel::L2;
            latency += self.timing.l1_lat + self.timing.l2_lat;
        } else {
            level = AccessLevel::Mem;
            latency += self.timing.l1_lat + self.timing.l2_lat + self.timing.mem_lat;
        }
        DataAccess {
            level,
            latency,
            tlb_miss: !tlb_hit,
        }
    }

    /// (l1d hits, l1d misses).
    pub fn l1d_stats(&self) -> (u64, u64) {
        self.l1d.stats()
    }

    /// (tlb hits, tlb misses).
    pub fn tlb_stats(&self) -> (u64, u64) {
        self.tlb.stats()
    }
}

impl InstHierarchy {
    /// Build from geometry + timing.
    pub fn new(l1i: CacheGeometry, timing: Timing) -> InstHierarchy {
        InstHierarchy {
            l1i: Cache::new(l1i),
            timing,
        }
    }

    /// Fetch the line containing `pc`.
    pub fn fetch(&mut self, pc: u64, l2: &mut Cache) -> FetchAccess {
        if self.l1i.access(pc) {
            FetchAccess {
                miss: false,
                penalty: 0,
            }
        } else if l2.access(pc) {
            FetchAccess {
                miss: true,
                penalty: self.timing.l2_lat,
            }
        } else {
            FetchAccess {
                miss: true,
                penalty: self.timing.l2_lat + self.timing.mem_lat,
            }
        }
    }

    /// (l1i hits, l1i misses).
    pub fn l1i_stats(&self) -> (u64, u64) {
        self.l1i.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(size: u64, assoc: u32) -> CacheGeometry {
        CacheGeometry {
            size_bytes: size,
            assoc,
        }
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(geom(16 << 10, 2));
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1008)); // same line
        assert_eq!(c.stats(), (2, 1));
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2-way: fill a set with 2 lines, touch the first, insert a third
        // conflicting line — the *second* must be evicted.
        let mut c = Cache::new(geom(16 << 10, 2));
        let sets = c.num_sets();
        let stride = sets * CacheGeometry::LINE_BYTES;
        let a = 0u64;
        let b = stride;
        let d = 2 * stride;
        c.access(a);
        c.access(b);
        c.access(a); // a now MRU
        c.access(d); // evicts b
        assert!(c.probe(a));
        assert!(!c.probe(b));
        assert!(c.probe(d));
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = Cache::new(geom(1 << 10, 2)); // 1KB = 16 lines
        // Stream 64 lines twice: second pass still misses everything.
        for pass in 0..2 {
            for i in 0..64u64 {
                let hit = c.access(i * 64);
                if pass == 1 {
                    assert!(!hit, "line {i} unexpectedly survived");
                }
            }
        }
    }

    #[test]
    fn working_set_within_cache_all_hits_after_warmup() {
        let mut c = Cache::new(geom(4 << 10, 4)); // 64 lines
        for i in 0..32u64 {
            c.access(i * 64);
        }
        for i in 0..32u64 {
            assert!(c.access(i * 64), "line {i} should hit");
        }
    }

    #[test]
    fn higher_associativity_resolves_conflicts() {
        // 4 lines mapping to the same set thrash a 2-way but fit an 8-way.
        let g2 = geom(16 << 10, 2);
        let g8 = geom(16 << 10, 8);
        let mut c2 = Cache::new(g2);
        let mut c8 = Cache::new(g8);
        let stride2 = c2.num_sets() * CacheGeometry::LINE_BYTES;
        let stride8 = c8.num_sets() * CacheGeometry::LINE_BYTES;
        for _ in 0..4 {
            for i in 0..4u64 {
                c2.access(i * stride2);
                c8.access(i * stride8);
            }
        }
        let (h2, _) = c2.stats();
        let (h8, _) = c8.stats();
        assert!(h8 > h2, "8-way hits {h8} <= 2-way hits {h2}");
    }

    #[test]
    fn tlb_hit_and_miss() {
        let mut t = Tlb::new(2);
        assert!(!t.access(0x0000));
        assert!(t.access(0x0FFF)); // same 4K page
        assert!(!t.access(0x1000));
        assert!(!t.access(0x2000)); // evicts page 0 (LRU)
        assert!(!t.access(0x0000));
        assert_eq!(t.stats().1, 4);
    }

    #[test]
    fn data_hierarchy_levels_and_latency() {
        let timing = Timing::default();
        let mut l2 = Cache::new(geom(256 << 10, 2));
        let mut dh = DataHierarchy::new(geom(16 << 10, 2), timing);
        // Cold: memory access + TLB miss.
        let a = dh.access(0x10000000, &mut l2);
        assert_eq!(a.level, AccessLevel::Mem);
        assert!(a.tlb_miss);
        assert_eq!(
            a.latency,
            timing.tlb_miss_lat + timing.l1_lat + timing.l2_lat + timing.mem_lat
        );
        // Warm: L1 hit, TLB hit.
        let b = dh.access(0x10000000, &mut l2);
        assert_eq!(b.level, AccessLevel::L1);
        assert_eq!(b.latency, timing.l1_lat);
        assert!(!b.tlb_miss);
    }

    #[test]
    fn l2_serves_l1_conflict_victims() {
        let timing = Timing::default();
        let mut l2 = Cache::new(geom(256 << 10, 8));
        let mut dh = DataHierarchy::new(geom(1 << 10, 2), timing); // tiny L1
        // Stream 64 lines: all cold misses to memory.
        for i in 0..64u64 {
            dh.access(0x10000000 + i * 64, &mut l2);
        }
        // Second pass: L1 thrashes but L2 holds everything.
        let mut l2_hits = 0;
        for i in 0..64u64 {
            let a = dh.access(0x10000000 + i * 64, &mut l2);
            if a.level == AccessLevel::L2 {
                l2_hits += 1;
            }
        }
        assert!(l2_hits > 48, "only {l2_hits} L2 hits");
    }

    #[test]
    fn inst_hierarchy_penalties() {
        let timing = Timing::default();
        let mut l2 = Cache::new(geom(256 << 10, 2));
        let mut ih = InstHierarchy::new(geom(8 << 10, 2), timing);
        let cold = ih.fetch(0x400000, &mut l2);
        assert!(cold.miss);
        assert_eq!(cold.penalty, timing.l2_lat + timing.mem_lat);
        let warm = ih.fetch(0x400000, &mut l2);
        assert!(!warm.miss);
        assert_eq!(warm.penalty, 0);
        // L2 now holds the line: a conflicting L1I re-fetch pays L2 only.
        let mut ih2 = InstHierarchy::new(geom(8 << 10, 2), timing);
        let via_l2 = ih2.fetch(0x400000, &mut l2);
        assert!(via_l2.miss);
        assert_eq!(via_l2.penalty, timing.l2_lat);
    }
}
