//! Detailed out-of-order simulation — the `O3CPU` equivalent.
//!
//! An instruction-driven cycle-accounting model of a single-core
//! superscalar out-of-order processor, parameterized by the full Table 3
//! design space (`crate::uarch::UarchConfig`): fetch width, ROB size,
//! branch predictor algorithm, and L1I/L1D/L2 geometry, plus a data TLB.
//!
//! The model reuses the functional `Machine` for correct-path semantics
//! (so detailed and functional traces commit the same stream, §4.1's
//! alignment invariant) and wraps timing around it:
//!
//! * **Fetch** — `fetch_width` per cycle, stalling on L1I misses and
//!   redirecting on branch mispredictions (wrong-path instructions are
//!   fetched and later emitted as `Squashed` records).
//! * **Dispatch/ROB** — fetch blocks when the ROB is full; each blocked
//!   event emits a `NopStall` bubble record (§4.1 "stall instructions").
//! * **Issue/execute** — register scoreboard (full forwarding); per-class
//!   execution latencies; loads/stores walk DTLB → L1D → L2 → memory.
//! * **Commit** — in-order, `fetch_width` per cycle.

pub mod cache;
pub mod pipeline;
pub mod predictor;

pub use pipeline::{DetailedSim, SimStats};
