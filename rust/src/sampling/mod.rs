//! SimPoint-style phase sampling: simulate only representative trace
//! slices, weight-merge their metrics into whole-trace results.
//!
//! Long traces are mostly phase repetition. This module computes a
//! cheap BBV-style signature per fixed-size slice of the functional
//! trace (opcode histogram + branch/memory-stride features — static
//! properties only, so signatures and plans are microarchitecture
//! *agnostic* like the trace itself), clusters the signatures with a
//! deterministic seeded k-means, and picks one representative slice per
//! phase plus a weight (phase rows / representative rows). The result
//! is a [`SamplingPlan`]: a small sidecar file, computed once per
//! trace, reusable across every microarchitecture config simulated
//! against that trace.
//!
//! Replay-side machinery lives where the replaying happens:
//! `coordinator::engine::simulate_sampled` seeks to each
//! representative (warming up with the true preceding rows) and
//! weight-merges the per-phase `PredAccum`s; `tao serve` streams
//! representatives through [`SampledTraceSource`] so its prediction
//! cache keys per representative slice.
//!
//! Plan sidecar layout (all integers little-endian):
//!
//! ```text
//! magic "TAOPLAN1"
//! name         u64 length + bytes   (trace name, must match the trace)
//! total_rows   u64
//! slice_rows   u64
//! seed         u64
//! phase_count  u64
//! per phase:   rep_slice u64 | start_row u64 | rows u64 |
//!              member_rows u64 | weight f64-bits |
//!              entropy f32-bits | branch_ratio f32-bits
//! crc32        u32 over everything above
//! ```

use crate::isa::Opcode;
use crate::trace::{ChunkBuf, ChunkSource, TraceSource};
use crate::util::hash::crc32;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Magic opening a sampling-plan sidecar file.
pub const MAGIC_PLAN: &[u8; 8] = b"TAOPLAN1";

/// log2-ish memory-stride histogram buckets in a signature.
pub const SIG_STRIDE_BUCKETS: usize = 8;

/// Signature vector width: normalized opcode histogram + branch /
/// taken / memory ratios + normalized stride histogram.
pub const SIG_DIM: usize = Opcode::COUNT + 3 + SIG_STRIDE_BUCKETS;

/// Iteration cap for the k-means loop (it usually converges far
/// earlier; the cap bounds worst-case plan time).
const MAX_KMEANS_ITERS: usize = 25;

// ---------------------------------------------------------------------
// Slice signatures
// ---------------------------------------------------------------------

/// The BBV-style signature of one fixed-size trace slice.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceSignature {
    /// Slice ordinal (slice `i` covers rows `[i*slice_rows, ...)`).
    pub slice: usize,
    /// First trace row of the slice.
    pub start_row: u64,
    /// Rows in the slice (== `slice_rows` except for the final slice).
    pub rows: u64,
    /// The [`SIG_DIM`]-wide feature vector k-means clusters on.
    pub vec: Vec<f32>,
    /// Opcode-histogram entropy in bits (0 = single opcode).
    pub entropy: f32,
    /// Branch instructions / slice rows.
    pub branch_ratio: f32,
}

/// Streaming accumulator for one slice's signature.
struct SigAccum {
    opcode_counts: Vec<u64>,
    branches: u64,
    taken: u64,
    mems: u64,
    strides: [u64; SIG_STRIDE_BUCKETS],
    last_mem_addr: Option<u64>,
    rows: u64,
}

/// Bucket a memory stride (absolute byte distance between consecutive
/// memory accesses) into a coarse log2 range: 0 = repeat address,
/// then same-line through page-local up to effectively-random.
fn stride_bucket(stride: u64) -> usize {
    if stride == 0 {
        return 0;
    }
    let bits = 64 - stride.leading_zeros() as usize;
    match bits {
        1..=3 => 1,   // < 8 B
        4..=6 => 2,   // < 64 B: cache-line local
        7..=9 => 3,   // < 512 B
        10..=12 => 4, // < 4 KiB: page local
        13..=16 => 5, // < 64 KiB
        17..=24 => 6, // < 16 MiB
        _ => 7,
    }
}

impl SigAccum {
    fn new() -> SigAccum {
        SigAccum {
            opcode_counts: vec![0u64; Opcode::COUNT],
            branches: 0,
            taken: 0,
            mems: 0,
            strides: [0u64; SIG_STRIDE_BUCKETS],
            last_mem_addr: None,
            rows: 0,
        }
    }

    fn absorb(&mut self, buf: &ChunkBuf, lo: usize, hi: usize) {
        let cols = &buf.cols;
        for i in lo..hi {
            let op = Opcode::from_index(cols.opcode[i] as usize);
            self.opcode_counts[cols.opcode[i] as usize] += 1;
            if op.is_branch() {
                self.branches += 1;
                self.taken += cols.taken[i] as u64;
            }
            if op.is_mem() {
                self.mems += 1;
                let addr = cols.mem_addr[i];
                if let Some(prev) = self.last_mem_addr {
                    self.strides[stride_bucket(addr.abs_diff(prev))] += 1;
                }
                self.last_mem_addr = Some(addr);
            }
        }
        self.rows += (hi - lo) as u64;
    }

    fn finish(self, slice: usize, start_row: u64) -> SliceSignature {
        let rows = self.rows.max(1) as f32;
        let mut vec = Vec::with_capacity(SIG_DIM);
        let mut entropy = 0.0f32;
        for &c in &self.opcode_counts {
            let p = c as f32 / rows;
            vec.push(p);
            if p > 0.0 {
                entropy -= p * p.log2();
            }
        }
        let branch_ratio = self.branches as f32 / rows;
        vec.push(branch_ratio);
        vec.push(if self.branches > 0 {
            self.taken as f32 / self.branches as f32
        } else {
            0.0
        });
        vec.push(self.mems as f32 / rows);
        let stride_total = self.strides.iter().sum::<u64>().max(1) as f32;
        for &s in &self.strides {
            vec.push(s as f32 / stride_total);
        }
        debug_assert_eq!(vec.len(), SIG_DIM);
        SliceSignature {
            slice,
            start_row,
            rows: self.rows,
            vec,
            entropy,
            branch_ratio,
        }
    }
}

/// Compute per-slice signatures over any chunk stream in one cheap
/// forward pass — no model, no feature extraction, O(slice) memory.
/// The final slice may be short; every row lands in exactly one slice.
pub fn compute_signatures<S: ChunkSource + ?Sized>(
    source: &mut S,
    slice_rows: u64,
) -> Result<Vec<SliceSignature>> {
    ensure!(slice_rows >= 1, "slice_rows must be >= 1");
    let grain = slice_rows.min(1 << 16) as usize;
    let mut sigs = Vec::new();
    let mut accum = SigAccum::new();
    let mut buf = ChunkBuf::new();
    let mut row = 0u64;
    loop {
        let in_slice = slice_rows - accum.rows;
        let n = source.next_chunk(&mut buf, grain.min(in_slice as usize))?;
        if n == 0 {
            break;
        }
        accum.absorb(&buf, 0, n);
        row += n as u64;
        if accum.rows == slice_rows {
            let done = std::mem::replace(&mut accum, SigAccum::new());
            sigs.push(done.finish(sigs.len(), row - slice_rows));
        }
    }
    if accum.rows > 0 {
        let tail_rows = accum.rows;
        sigs.push(accum.finish(sigs.len(), row - tail_rows));
    }
    Ok(sigs)
}

// ---------------------------------------------------------------------
// Deterministic k-means
// ---------------------------------------------------------------------

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

/// Seeded k-means++ then Lloyd iterations, capped at
/// [`MAX_KMEANS_ITERS`]. Fully deterministic for a given (signatures,
/// k, seed): ties in assignment go to the lowest centroid index, and a
/// cluster that empties keeps its old centroid (it is skipped at plan
/// extraction). Returns the per-slice cluster assignment.
fn kmeans(sigs: &[SliceSignature], k: usize, seed: u64) -> Vec<usize> {
    let n = sigs.len();
    let mut rng = Rng::new(seed);
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(sigs[rng.index(n)].vec.clone());
    let mut best = vec![f64::INFINITY; n];
    while centroids.len() < k {
        let last = centroids.last().unwrap();
        for (b, s) in best.iter_mut().zip(sigs) {
            *b = b.min(dist2(&s.vec, last));
        }
        let total: f64 = best.iter().sum();
        let next = if total <= 0.0 {
            // Every point coincides with a centroid already; further
            // seeds are arbitrary but must stay deterministic.
            rng.index(n)
        } else {
            let mut target = rng.gen_f64() * total;
            let mut pick = n - 1;
            for (i, &d) in best.iter().enumerate() {
                if target < d {
                    pick = i;
                    break;
                }
                target -= d;
            }
            pick
        };
        centroids.push(sigs[next].vec.clone());
    }

    let mut assign = vec![usize::MAX; n];
    for _ in 0..MAX_KMEANS_ITERS {
        let mut changed = false;
        for (i, s) in sigs.iter().enumerate() {
            let mut best_c = 0usize;
            let mut best_d = f64::INFINITY;
            for (c, cen) in centroids.iter().enumerate() {
                let d = dist2(&s.vec, cen);
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            if assign[i] != best_c {
                assign[i] = best_c;
                changed = true;
            }
        }
        if !changed {
            break;
        }
        let mut sums = vec![[0f64; SIG_DIM]; k];
        let mut counts = vec![0usize; k];
        for (i, s) in sigs.iter().enumerate() {
            counts[assign[i]] += 1;
            for (acc, &v) in sums[assign[i]].iter_mut().zip(&s.vec) {
                *acc += v as f64;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                centroids[c] = sums[c].iter().map(|&v| (v / counts[c] as f64) as f32).collect();
            }
        }
    }
    assign
}

// ---------------------------------------------------------------------
// The sampling plan
// ---------------------------------------------------------------------

/// One phase: a representative slice plus the weight scaling its
/// metrics up to everything it stands for.
#[derive(Debug, Clone, PartialEq)]
pub struct PhasePlan {
    /// Ordinal of the representative slice.
    pub rep_slice: u64,
    /// First trace row of the representative slice.
    pub start_row: u64,
    /// Rows in the representative slice.
    pub rows: u64,
    /// Total rows across every member slice of the phase.
    pub member_rows: u64,
    /// `member_rows / rows`: the factor the representative's
    /// `PredAccum` is scaled by at merge time.
    pub weight: f64,
    /// Representative's opcode entropy (diagnostics).
    pub entropy: f32,
    /// Representative's branch ratio (diagnostics).
    pub branch_ratio: f32,
}

impl PhasePlan {
    /// One-past-the-last trace row of the representative slice.
    pub fn end_row(&self) -> u64 {
        self.start_row + self.rows
    }
}

/// Knobs for plan construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingOptions {
    /// Rows per signature slice.
    pub slice_rows: u64,
    /// Cluster-count cap (actual phases may be fewer).
    pub max_phases: usize,
    /// k-means seed.
    pub seed: u64,
}

impl Default for SamplingOptions {
    fn default() -> SamplingOptions {
        SamplingOptions {
            slice_rows: 50_000,
            max_phases: 5,
            seed: 42,
        }
    }
}

/// A microarchitecture-agnostic sampling plan for one trace: which
/// slices to simulate and how to weight them. Persisted as a small
/// CRC-guarded sidecar, computed once, reused across every uarch
/// config simulated against the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingPlan {
    /// Trace name from the trace header (replay refuses a mismatch).
    pub name: String,
    /// Trace rows the plan was computed over (ditto).
    pub total_rows: u64,
    /// Rows per signature slice.
    pub slice_rows: u64,
    /// k-means seed the plan was built with.
    pub seed: u64,
    /// Phases, sorted by `start_row`, pairwise non-overlapping.
    pub phases: Vec<PhasePlan>,
}

impl SamplingPlan {
    /// Build a plan from precomputed signatures.
    pub fn from_signatures(
        name: &str,
        sigs: &[SliceSignature],
        opts: &SamplingOptions,
    ) -> Result<SamplingPlan> {
        ensure!(opts.max_phases >= 1, "max_phases must be >= 1");
        ensure!(opts.slice_rows >= 1, "slice_rows must be >= 1");
        let total_rows: u64 = sigs.iter().map(|s| s.rows).sum();
        let mut phases = Vec::new();
        if !sigs.is_empty() {
            let k = opts.max_phases.min(sigs.len());
            let assign = kmeans(sigs, k, opts.seed);
            for c in 0..k {
                let members: Vec<usize> =
                    (0..sigs.len()).filter(|&i| assign[i] == c).collect();
                if members.is_empty() {
                    continue;
                }
                let mut centroid = [0f64; SIG_DIM];
                for &m in &members {
                    for (acc, &v) in centroid.iter_mut().zip(&sigs[m].vec) {
                        *acc += v as f64;
                    }
                }
                let centroid: Vec<f32> = centroid
                    .iter()
                    .map(|&v| (v / members.len() as f64) as f32)
                    .collect();
                // Representative: the member closest to the centroid;
                // ties break to the lowest slice index (strict <).
                let mut rep = members[0];
                let mut rep_d = f64::INFINITY;
                for &m in &members {
                    let d = dist2(&sigs[m].vec, &centroid);
                    if d < rep_d {
                        rep_d = d;
                        rep = m;
                    }
                }
                let member_rows: u64 = members.iter().map(|&m| sigs[m].rows).sum();
                let r = &sigs[rep];
                phases.push(PhasePlan {
                    rep_slice: rep as u64,
                    start_row: r.start_row,
                    rows: r.rows,
                    member_rows,
                    weight: member_rows as f64 / r.rows as f64,
                    entropy: r.entropy,
                    branch_ratio: r.branch_ratio,
                });
            }
            phases.sort_by_key(|p| p.start_row);
        }
        Ok(SamplingPlan {
            name: name.to_string(),
            total_rows,
            slice_rows: opts.slice_rows,
            seed: opts.seed,
            phases,
        })
    }

    /// The exhaustive plan: every slice is its own phase at weight 1 —
    /// sampled replay covers every row, and is the bit-identity oracle
    /// against full simulation.
    pub fn exhaustive(name: &str, total_rows: u64, slice_rows: u64) -> SamplingPlan {
        assert!(slice_rows >= 1, "slice_rows must be >= 1");
        let mut phases = Vec::new();
        let mut start = 0u64;
        let mut slice = 0u64;
        while start < total_rows {
            let rows = slice_rows.min(total_rows - start);
            phases.push(PhasePlan {
                rep_slice: slice,
                start_row: start,
                rows,
                member_rows: rows,
                weight: 1.0,
                entropy: 0.0,
                branch_ratio: 0.0,
            });
            start += rows;
            slice += 1;
        }
        SamplingPlan {
            name: name.to_string(),
            total_rows,
            slice_rows,
            seed: 0,
            phases,
        }
    }

    /// Rows the plan actually simulates (excluding warm-up).
    pub fn simulated_rows(&self) -> u64 {
        self.phases.iter().map(|p| p.rows).sum()
    }

    /// Simulated fraction of the trace, in `[0, 1]`.
    pub fn coverage(&self) -> f64 {
        if self.total_rows == 0 {
            1.0
        } else {
            self.simulated_rows() as f64 / self.total_rows as f64
        }
    }

    /// Refuse replay against a trace the plan was not computed for.
    pub fn check_matches(&self, trace_name: &str, trace_rows: u64) -> Result<()> {
        ensure!(
            self.name == trace_name && self.total_rows == trace_rows,
            "sampling plan is for trace {:?} ({} rows), not {:?} ({} rows)",
            self.name,
            self.total_rows,
            trace_name,
            trace_rows
        );
        Ok(())
    }

    /// Serialize to the `TAOPLAN1` sidecar format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + self.phases.len() * 48);
        buf.extend_from_slice(MAGIC_PLAN);
        put_u64(&mut buf, self.name.len() as u64);
        buf.extend_from_slice(self.name.as_bytes());
        put_u64(&mut buf, self.total_rows);
        put_u64(&mut buf, self.slice_rows);
        put_u64(&mut buf, self.seed);
        put_u64(&mut buf, self.phases.len() as u64);
        for p in &self.phases {
            put_u64(&mut buf, p.rep_slice);
            put_u64(&mut buf, p.start_row);
            put_u64(&mut buf, p.rows);
            put_u64(&mut buf, p.member_rows);
            put_u64(&mut buf, p.weight.to_bits());
            buf.extend_from_slice(&p.entropy.to_bits().to_le_bytes());
            buf.extend_from_slice(&p.branch_ratio.to_bits().to_le_bytes());
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse and validate a `TAOPLAN1` sidecar.
    pub fn from_bytes(bytes: &[u8]) -> Result<SamplingPlan> {
        ensure!(
            bytes.len() >= 8 && &bytes[..8] == MAGIC_PLAN,
            "not a tao sampling plan (bad magic)"
        );
        ensure!(bytes.len() >= 12, "truncated sampling plan");
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let computed = crc32(body);
        ensure!(
            stored == computed,
            "corrupt sampling plan (CRC stored {stored:#010x}, computed {computed:#010x})"
        );
        let mut pos = 8usize;
        let name_len = get_u64(body, &mut pos)? as usize;
        ensure!(
            name_len <= 4096 && pos + name_len <= body.len(),
            "unreasonable plan name length {name_len}"
        );
        let name = std::str::from_utf8(&body[pos..pos + name_len])
            .context("plan name is not UTF-8")?
            .to_string();
        pos += name_len;
        let total_rows = get_u64(body, &mut pos)?;
        let slice_rows = get_u64(body, &mut pos)?;
        let seed = get_u64(body, &mut pos)?;
        let count = get_u64(body, &mut pos)? as usize;
        ensure!(slice_rows >= 1, "plan slice_rows must be >= 1");
        ensure!(
            count <= total_rows.div_ceil(slice_rows) as usize,
            "{count} phases for {total_rows} rows of {slice_rows}-row slices"
        );
        let mut phases = Vec::with_capacity(count);
        let mut prev_end = 0u64;
        for i in 0..count {
            let rep_slice = get_u64(body, &mut pos)?;
            let start_row = get_u64(body, &mut pos)?;
            let rows = get_u64(body, &mut pos)?;
            let member_rows = get_u64(body, &mut pos)?;
            let weight = f64::from_bits(get_u64(body, &mut pos)?);
            let entropy = f32::from_bits(get_u32(body, &mut pos)?);
            let branch_ratio = f32::from_bits(get_u32(body, &mut pos)?);
            ensure!(
                rows >= 1 && rows <= slice_rows,
                "phase {i}: {rows} rows in a {slice_rows}-row-slice plan"
            );
            ensure!(
                start_row == rep_slice * slice_rows,
                "phase {i}: start row {start_row} disagrees with slice {rep_slice}"
            );
            ensure!(
                start_row >= prev_end && start_row + rows <= total_rows,
                "phase {i}: rows [{start_row}, {}) out of order or out of range",
                start_row + rows
            );
            ensure!(
                weight.is_finite() && weight > 0.0,
                "phase {i}: weight {weight} is not a positive finite number"
            );
            prev_end = start_row + rows;
            phases.push(PhasePlan {
                rep_slice,
                start_row,
                rows,
                member_rows,
                weight,
                entropy,
                branch_ratio,
            });
        }
        ensure!(
            pos == body.len(),
            "{} trailing bytes in sampling plan",
            body.len() - pos
        );
        Ok(SamplingPlan {
            name,
            total_rows,
            slice_rows,
            seed,
            phases,
        })
    }

    /// Write the sidecar to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes()).with_context(|| format!("write {path:?}"))
    }

    /// Load and validate a sidecar from `path`.
    pub fn load(path: &Path) -> Result<SamplingPlan> {
        let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        SamplingPlan::from_bytes(&bytes).with_context(|| format!("parse {path:?}"))
    }
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    ensure!(*pos + 8 <= buf.len(), "truncated sampling plan");
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(*pos + 4 <= buf.len(), "truncated sampling plan");
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

/// Compute a [`SamplingPlan`] for a trace file: one streaming
/// signature pass, then clustering. The plan is independent of any
/// model artifact or uarch config.
pub fn plan_trace(path: &Path, opts: &SamplingOptions) -> Result<SamplingPlan> {
    let mut src = crate::trace::open_trace_source(path)?;
    let name = src.name().to_string();
    let sigs = compute_signatures(&mut src, opts.slice_rows)?;
    SamplingPlan::from_signatures(&name, &sigs, opts)
}

// ---------------------------------------------------------------------
// Sampled replay source
// ---------------------------------------------------------------------

/// Streams only a plan's representative slices, in trace order, by
/// seeking the underlying [`TraceSource`] between phases. Each
/// `next_chunk` serves rows from a single phase (a pull never straddles
/// a phase boundary), so a consumer pulling `slice_rows`-sized chunks
/// gets exactly one chunk per phase — the alignment `tao serve` relies
/// on to key its prediction cache per representative slice.
pub struct SampledTraceSource {
    src: Box<dyn TraceSource>,
    plan: SamplingPlan,
    phase: usize,
    /// Rows already delivered from the current phase.
    delivered: u64,
    /// Whether `src` is positioned inside the current phase.
    positioned: bool,
}

impl SampledTraceSource {
    /// Wrap a seekable trace source; refuses a plan computed for a
    /// different trace.
    pub fn new(src: Box<dyn TraceSource>, plan: SamplingPlan) -> Result<SampledTraceSource> {
        let rows = match src.len_hint() {
            Some(n) => n as u64,
            None => bail!("sampled replay needs a length-aware trace source"),
        };
        plan.check_matches(src.name(), rows)?;
        Ok(SampledTraceSource {
            src,
            plan,
            phase: 0,
            delivered: 0,
            positioned: false,
        })
    }

    /// The plan being replayed.
    pub fn plan(&self) -> &SamplingPlan {
        &self.plan
    }

    /// Per-phase merge weights, in stream (phase) order.
    pub fn weights(&self) -> Vec<f64> {
        self.plan.phases.iter().map(|p| p.weight).collect()
    }
}

impl ChunkSource for SampledTraceSource {
    fn len_hint(&self) -> Option<usize> {
        let rest: u64 = self.plan.phases[self.phase..]
            .iter()
            .map(|p| p.rows)
            .sum::<u64>()
            - self.delivered;
        usize::try_from(rest).ok()
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        loop {
            let Some(phase) = self.plan.phases.get(self.phase) else {
                buf.clear();
                return Ok(0);
            };
            if self.delivered == phase.rows {
                self.phase += 1;
                self.delivered = 0;
                self.positioned = false;
                continue;
            }
            if !self.positioned {
                self.src.seek_to_row(phase.start_row)?;
                self.positioned = true;
            }
            let want = (phase.rows - self.delivered).min(max_rows as u64) as usize;
            let n = self.src.next_chunk(buf, want)?;
            ensure!(
                n > 0,
                "trace ended inside phase rows [{}, {})",
                phase.start_row,
                phase.end_row()
            );
            self.delivered += n as u64;
            return Ok(n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::trace::{OwnedChunkSource, TraceColumns, TraceFormat, TraceWriteOptions};
    use crate::workloads;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-sampling-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(tag.to_string())
    }

    fn bench_cols(bench: &str, n: u64) -> TraceColumns {
        let p = workloads::by_name(bench).unwrap().build(9);
        FunctionalSim::new(&p).run(n).to_columns()
    }

    /// Alternating-phase trace: slices drawn alternately from two
    /// different workloads, so the phase structure is known a priori.
    fn alternating_cols(slice: u64, slices: usize) -> TraceColumns {
        let a = bench_cols("dee", slice);
        let b = bench_cols("mcf", slice);
        let mut cols = TraceColumns::new();
        for i in 0..slices {
            let src = if i % 2 == 0 { &a } else { &b };
            cols.extend_from(src, 0, src.len());
        }
        cols
    }

    #[test]
    fn signatures_cover_every_row_and_are_deterministic() {
        let cols = bench_cols("dee", 3_500);
        let mut src = OwnedChunkSource::new(cols.clone(), None).unwrap();
        let sigs = compute_signatures(&mut src, 1_000).unwrap();
        assert_eq!(sigs.len(), 4);
        assert_eq!(sigs.iter().map(|s| s.rows).sum::<u64>(), 3_500);
        assert_eq!(sigs[3].rows, 500);
        for (i, s) in sigs.iter().enumerate() {
            assert_eq!(s.slice, i);
            assert_eq!(s.start_row, i as u64 * 1_000);
            assert_eq!(s.vec.len(), SIG_DIM);
            // Histogram parts are probabilities.
            assert!(s.vec.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(s.entropy >= 0.0);
        }
        // A second pass is bit-identical, regardless of pull grain.
        let mut src = OwnedChunkSource::new(cols, None).unwrap();
        let again = compute_signatures(&mut src, 1_000).unwrap();
        assert_eq!(sigs, again);
    }

    #[test]
    fn clustering_separates_known_phases() {
        let cols = alternating_cols(1_000, 8);
        let mut src = OwnedChunkSource::new(cols, None).unwrap();
        let sigs = compute_signatures(&mut src, 1_000).unwrap();
        let opts = SamplingOptions {
            slice_rows: 1_000,
            max_phases: 2,
            seed: 7,
        };
        let plan = SamplingPlan::from_signatures("alt", &sigs, &opts).unwrap();
        assert_eq!(plan.phases.len(), 2);
        // Every row is accounted for exactly once across phase members.
        assert_eq!(
            plan.phases.iter().map(|p| p.member_rows).sum::<u64>(),
            plan.total_rows
        );
        // The two representatives come from opposite parities (the two
        // interleaved workloads).
        assert_ne!(
            plan.phases[0].rep_slice % 2,
            plan.phases[1].rep_slice % 2
        );
        // Each phase holds the 4 slices of its parity.
        for p in &plan.phases {
            assert_eq!(p.member_rows, 4_000);
            assert!((p.weight - 4.0).abs() < 1e-12);
        }
        assert!(plan.coverage() <= 0.25 + 1e-12);

        // Same inputs, same seed: bit-identical plan.
        let again = SamplingPlan::from_signatures("alt", &sigs, &opts).unwrap();
        assert_eq!(plan, again);
    }

    #[test]
    fn exhaustive_plan_covers_everything_at_weight_one() {
        let plan = SamplingPlan::exhaustive("x", 2_500, 1_000);
        assert_eq!(plan.phases.len(), 3);
        assert_eq!(plan.simulated_rows(), 2_500);
        assert_eq!(plan.coverage(), 1.0);
        assert!(plan.phases.iter().all(|p| p.weight == 1.0));
        assert_eq!(plan.phases[2].rows, 500);
        plan.check_matches("x", 2_500).unwrap();
        plan.check_matches("y", 2_500).unwrap_err();
        plan.check_matches("x", 2_400).unwrap_err();
    }

    #[test]
    fn plan_sidecar_round_trips_and_fails_typed_when_corrupt() {
        let cols = alternating_cols(500, 6);
        let mut src = OwnedChunkSource::new(cols, None).unwrap();
        let sigs = compute_signatures(&mut src, 500).unwrap();
        let plan = SamplingPlan::from_signatures(
            "alt",
            &sigs,
            &SamplingOptions {
                slice_rows: 500,
                max_phases: 3,
                seed: 11,
            },
        )
        .unwrap();
        let path = tmp("plan.tsp");
        plan.save(&path).unwrap();
        let back = SamplingPlan::load(&path).unwrap();
        assert_eq!(plan, back);

        // Foreign bytes are refused by magic.
        let foreign = tmp("foreign.tsp");
        std::fs::write(&foreign, b"NOTAPLAN_AT_ALL!").unwrap();
        let err = SamplingPlan::load(&foreign).unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");

        // A flipped body byte fails the CRC.
        let mut bytes = plan.to_bytes();
        bytes[20] ^= 0x01;
        let bad = tmp("bad.tsp");
        std::fs::write(&bad, &bytes).unwrap();
        let err = SamplingPlan::load(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
    }

    #[test]
    fn sampled_source_streams_representatives_in_order() {
        let cols = alternating_cols(1_000, 8);
        let trace = tmp("sampled.trace");
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(1_000)
            .write(&trace, "alt", &cols)
            .unwrap();
        let plan = plan_trace(
            &trace,
            &SamplingOptions {
                slice_rows: 1_000,
                max_phases: 2,
                seed: 7,
            },
        )
        .unwrap();
        assert_eq!(plan.phases.len(), 2);

        let src = crate::trace::open_trace_source(&trace).unwrap();
        let mut sampled = SampledTraceSource::new(src, plan.clone()).unwrap();
        assert_eq!(sampled.len_hint(), Some(2_000));
        assert_eq!(sampled.weights(), vec![4.0, 4.0]);
        let mut buf = ChunkBuf::new();
        // Chunk pulls at slice size: exactly one pull per phase, and
        // the rows are byte-identical to the slice in the full trace.
        for p in &plan.phases {
            let n = sampled.next_chunk(&mut buf, 1_000).unwrap();
            assert_eq!(n as u64, p.rows);
            let mut want = TraceColumns::new();
            want.extend_from(&cols, p.start_row as usize, p.end_row() as usize);
            assert_eq!(buf.cols, want);
        }
        assert_eq!(sampled.next_chunk(&mut buf, 1_000).unwrap(), 0);
        assert_eq!(sampled.len_hint(), Some(0));

        // Misaligned pulls still never straddle a phase boundary.
        let src = crate::trace::open_trace_source(&trace).unwrap();
        let mut sampled = SampledTraceSource::new(src, plan.clone()).unwrap();
        let mut total = 0u64;
        let mut pulls = 0usize;
        loop {
            let n = sampled.next_chunk(&mut buf, 300).unwrap();
            if n == 0 {
                break;
            }
            total += n as u64;
            pulls += 1;
        }
        assert_eq!(total, 2_000);
        // ceil(1000/300) = 4 pulls per phase.
        assert_eq!(pulls, 8);

        // A plan for a different trace is refused.
        let src = crate::trace::open_trace_source(&trace).unwrap();
        let mut other = plan;
        other.name = "other".to_string();
        assert!(SampledTraceSource::new(src, other).is_err());
    }
}
