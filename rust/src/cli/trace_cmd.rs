//! `tao trace` — inspect, convert, and generate on-disk functional traces.
//!
//! Thin CLI over [`crate::trace::format`]: `inspect` runs the full
//! validating walk and prints header/chunk/size statistics, `convert`
//! transcodes v1 <-> v2 with bounded memory (one pull chunk resident),
//! and `write` streams a freshly generated functional trace straight to
//! disk in either format — the producer CI uses to stage large v2
//! traces without materializing them.

use crate::cli::args::Args;
use crate::functional::FunctionalSim;
use crate::trace::{
    convert_trace, inspect_trace, open_trace_source, section_names, ChunkBuf, ChunkSource,
    TraceFormat, TraceWriteOptions,
};
use crate::workloads;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Usage string for the `trace` subcommand family.
pub const TRACE_USAGE: &str = "\
USAGE:
  tao trace inspect PATH [--signatures] [--slice-rows N]
  tao trace convert IN OUT [--format v1|v2] [--chunk-rows N] [--level 0|1|2]
  tao trace write   OUT --bench B [--insts N] [--seed S]
                    [--format v1|v2] [--chunk-rows N] [--level 0|1|2]
";

/// Dispatch `tao trace <action>`.
pub fn cmd_trace(mut args: Args) -> Result<()> {
    let Some(action) = args.next_positional() else {
        println!("{TRACE_USAGE}");
        return Ok(());
    };
    match action.as_str() {
        "inspect" => cmd_inspect(args),
        "convert" => cmd_convert(args),
        "write" => cmd_write(args),
        "help" => {
            println!("{TRACE_USAGE}");
            Ok(())
        }
        other => bail!("unknown trace action {other:?}\n{TRACE_USAGE}"),
    }
}

/// Consume the shared `--format/--chunk-rows/--level` writer flags.
fn parse_write_options(args: &mut Args, default_format: TraceFormat) -> Result<TraceWriteOptions> {
    let mut opts = TraceWriteOptions::new(default_format);
    if let Some(fmt) = args.opt_value("--format")? {
        opts = opts.format(TraceFormat::parse(&fmt)?);
    }
    if let Some(rows) = args.opt_parse::<usize>("--chunk-rows")? {
        opts = opts.chunk_rows(rows);
    }
    if let Some(level) = args.opt_parse::<u8>("--level")? {
        opts = opts.level(level);
    }
    Ok(opts)
}

fn cmd_inspect(mut args: Args) -> Result<()> {
    let signatures = args.opt_flag("--signatures");
    let slice_rows: u64 = args.opt_parse("--slice-rows")?.unwrap_or(50_000);
    let path: PathBuf = args
        .next_positional()
        .context("trace inspect: PATH required")?
        .into();
    args.finish()?;
    anyhow::ensure!(slice_rows >= 1, "--slice-rows must be positive");
    let info = inspect_trace(&path)?;
    println!("file               : {}", path.display());
    println!("format             : {}", info.format);
    println!("name               : {}", info.name);
    println!("records            : {}", info.records);
    println!("file bytes         : {}", info.file_bytes);
    println!("bytes/instruction  : {:.3}", info.bytes_per_inst());
    if let (Some(chunk_rows), Some(chunks)) = (info.chunk_rows, info.chunks) {
        println!("chunk rows         : {chunk_rows}");
        println!("chunks             : {chunks}");
    }
    if let Some(index) = info.index {
        println!(
            "chunk-offset index : {}",
            if index { "present (O(1) seeks)" } else { "absent (seeks scan frame headers)" }
        );
    }
    if let Some(section_bytes) = info.section_bytes {
        for (name, bytes) in section_names().iter().zip(section_bytes.iter()) {
            let per_inst = if info.records == 0 {
                0.0
            } else {
                *bytes as f64 / info.records as f64
            };
            println!("section {name:<11}: {bytes} bytes ({per_inst:.3} B/inst)");
        }
    }
    if signatures {
        // Per-slice phase signatures — the same pass `tao sample
        // compute` clusters, printed as a behaviour profile over time.
        let mut src = open_trace_source(&path)?;
        let sigs = crate::sampling::compute_signatures(&mut *src, slice_rows)?;
        println!("slices             : {} x {slice_rows} rows", sigs.len());
        println!("slice  start_row  rows      entropy  branch%");
        for s in &sigs {
            println!(
                "{:<5}  {:<9}  {:<8}  {:<7.3}  {:.1}",
                s.slice,
                s.start_row,
                s.rows,
                s.entropy,
                s.branch_ratio * 100.0
            );
        }
    }
    Ok(())
}

fn cmd_convert(mut args: Args) -> Result<()> {
    let input: PathBuf = args
        .next_positional()
        .context("trace convert: IN path required")?
        .into();
    let output: PathBuf = args
        .next_positional()
        .context("trace convert: OUT path required")?
        .into();
    let opts = parse_write_options(&mut args, TraceFormat::V2)?;
    args.finish()?;
    eprintln!(
        "trace: converting {} -> {} ({})...",
        input.display(),
        output.display(),
        opts.format
    );
    let records = convert_trace(&input, &output, &opts)?;
    let info = inspect_trace(&output)?;
    println!("records            : {records}");
    println!("output bytes       : {}", info.file_bytes);
    println!("bytes/instruction  : {:.3}", info.bytes_per_inst());
    Ok(())
}

fn cmd_write(mut args: Args) -> Result<()> {
    let bench_name = args
        .opt_value("--bench")?
        .context("trace write: --bench required")?;
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(100_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let opts = parse_write_options(&mut args, TraceFormat::V2)?;
    let out: PathBuf = args
        .next_positional()
        .context("trace write: OUT path required")?
        .into();
    args.finish()?;

    let workload = workloads::by_name(&bench_name)
        .with_context(|| format!("unknown benchmark {bench_name}"))?;
    let program = workload.build(seed);
    eprintln!(
        "trace: writing {insts} insts of {bench_name} to {} ({})...",
        out.display(),
        opts.format
    );
    // Pull-based: the machine steps only as the writer drains chunks, so
    // peak memory is one chunk of columns regardless of --insts.
    let mut src = FunctionalSim::new(&program).into_chunks(insts);
    let mut w = opts.writer(&out, src.name())?;
    let mut buf = ChunkBuf::new();
    loop {
        let n = src.next_chunk(&mut buf, 1 << 16)?;
        if n == 0 {
            break;
        }
        w.append(&buf.cols)?;
    }
    let written = w.finish()?;
    let info = inspect_trace(&out)?;
    println!("records            : {written}");
    println!("output bytes       : {}", info.file_bytes);
    println!("bytes/instruction  : {:.3}", info.bytes_per_inst());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceColumns;
    use std::path::Path;

    fn args(s: &[&str]) -> Args {
        Args::new(s.iter().map(|s| s.to_string()).collect())
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-cli-trace-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.trace"))
    }

    #[test]
    fn write_inspect_convert_round_trip() {
        let v2 = tmp("t_v2");
        let v1 = tmp("t_v1");

        cmd_trace(args(&[
            "write",
            "--bench",
            "dee",
            "--insts",
            "3000",
            "--seed",
            "7",
            "--chunk-rows",
            "512",
            v2.to_str().unwrap(),
        ]))
        .unwrap();
        let info = inspect_trace(&v2).unwrap();
        assert_eq!(info.format, TraceFormat::V2);
        assert_eq!(info.records, 3000);

        cmd_trace(args(&[
            "convert",
            v2.to_str().unwrap(),
            v1.to_str().unwrap(),
            "--format",
            "v1",
        ]))
        .unwrap();
        let info = inspect_trace(&v1).unwrap();
        assert_eq!(info.format, TraceFormat::V1);
        assert_eq!(info.records, 3000);

        // The transcoded v1 decodes to the same columns as the v2 source.
        let drain = |p: &Path| -> TraceColumns {
            let mut src = open_trace_source(p).unwrap();
            let mut buf = ChunkBuf::new();
            let mut all = TraceColumns::default();
            while src.next_chunk(&mut buf, 701).unwrap() > 0 {
                all.extend_from(&buf.cols, 0, buf.cols.len());
            }
            all
        };
        assert_eq!(drain(&v2), drain(&v1));

        cmd_trace(args(&["inspect", v2.to_str().unwrap()])).unwrap();
        // Per-slice signature summaries ride the same walk.
        cmd_trace(args(&[
            "inspect",
            v2.to_str().unwrap(),
            "--signatures",
            "--slice-rows",
            "1000",
        ]))
        .unwrap();
    }

    #[test]
    fn bad_action_and_missing_args_fail() {
        assert!(cmd_trace(args(&["frobnicate"])).is_err());
        assert!(cmd_trace(args(&["inspect"])).is_err());
        assert!(cmd_trace(args(&["convert", "only-one"])).is_err());
        assert!(cmd_trace(args(&["write", "--bench", "dee"])).is_err());
    }
}
