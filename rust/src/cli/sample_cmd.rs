//! `tao sample` — compute and inspect phase-sampling plans.
//!
//! Thin CLI over [`crate::sampling`]: `compute` streams a recorded
//! trace through the signature pass + k-means and persists the
//! resulting `TAOPLAN1` sidecar, `inspect` prints a saved plan's
//! phase table. Plans are microarchitecture-agnostic — one plan per
//! trace serves every model artifact (`tao simulate --sample`,
//! `tao serve` jobs with a `plan` field).

use crate::cli::args::Args;
use crate::sampling::{SamplingOptions, SamplingPlan};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Usage string for the `sample` subcommand family.
pub const SAMPLE_USAGE: &str = "\
USAGE:
  tao sample compute --trace PATH --out PLAN
                     [--slice-rows N] [--max-phases K] [--seed S]
  tao sample inspect PLAN
";

/// Dispatch `tao sample <action>`.
pub fn cmd_sample(mut args: Args) -> Result<()> {
    let Some(action) = args.next_positional() else {
        println!("{SAMPLE_USAGE}");
        return Ok(());
    };
    match action.as_str() {
        "compute" => cmd_compute(args),
        "inspect" => cmd_inspect(args),
        "help" => {
            println!("{SAMPLE_USAGE}");
            Ok(())
        }
        other => bail!("unknown sample action {other:?}\n{SAMPLE_USAGE}"),
    }
}

/// Consume the shared `--slice-rows/--max-phases/--seed` plan knobs.
pub fn parse_sampling_options(args: &mut Args) -> Result<SamplingOptions> {
    let defaults = SamplingOptions::default();
    let opts = SamplingOptions {
        slice_rows: args.opt_parse("--slice-rows")?.unwrap_or(defaults.slice_rows),
        max_phases: args.opt_parse("--max-phases")?.unwrap_or(defaults.max_phases),
        seed: args.opt_parse("--seed")?.unwrap_or(defaults.seed),
    };
    anyhow::ensure!(opts.slice_rows >= 1, "--slice-rows must be positive");
    anyhow::ensure!(opts.max_phases >= 1, "--max-phases must be positive");
    Ok(opts)
}

fn cmd_compute(mut args: Args) -> Result<()> {
    let trace: PathBuf = args
        .opt_value("--trace")?
        .context("sample compute: --trace PATH required")?
        .into();
    let out: PathBuf = args
        .opt_value("--out")?
        .context("sample compute: --out PLAN required")?
        .into();
    let opts = parse_sampling_options(&mut args)?;
    args.finish()?;
    eprintln!(
        "sample: computing signatures over {} (slice-rows={}, max-phases={}, seed={})...",
        trace.display(),
        opts.slice_rows,
        opts.max_phases,
        opts.seed
    );
    let plan = crate::sampling::plan_trace(&trace, &opts)?;
    plan.save(&out)?;
    print_plan(&plan);
    println!("plan               : {}", out.display());
    Ok(())
}

fn cmd_inspect(mut args: Args) -> Result<()> {
    let path: PathBuf = args
        .next_positional()
        .context("sample inspect: PLAN path required")?
        .into();
    args.finish()?;
    let plan = SamplingPlan::load(&path)?;
    print_plan(&plan);
    Ok(())
}

/// Print a plan's summary + phase table (shared by compute/inspect).
fn print_plan(plan: &SamplingPlan) {
    println!("trace              : {}", plan.name);
    println!("total rows         : {}", plan.total_rows);
    println!("slice rows         : {}", plan.slice_rows);
    println!("seed               : {}", plan.seed);
    println!("phases             : {}", plan.phases.len());
    println!(
        "simulated rows     : {} ({:.1}% coverage)",
        plan.simulated_rows(),
        plan.coverage() * 100.0
    );
    println!("phase  rep_slice  start_row  rows      weight    entropy  branch%");
    for (i, p) in plan.phases.iter().enumerate() {
        println!(
            "{i:<5}  {:<9}  {:<9}  {:<8}  {:<8.2}  {:<7.3}  {:.1}",
            p.rep_slice,
            p.start_row,
            p.rows,
            p.weight,
            p.entropy,
            p.branch_ratio * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::trace::{TraceFormat, TraceWriteOptions};
    use crate::workloads;

    fn args(s: &[&str]) -> Args {
        Args::new(s.iter().map(|s| s.to_string()).collect())
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-cli-sample-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(tag)
    }

    #[test]
    fn compute_then_inspect_round_trip() {
        let trace = tmp("mix.trace");
        let plan_path = tmp("mix.plan");
        let p = workloads::by_name("dee").unwrap().build(7);
        let cols = FunctionalSim::new(&p).run(6_000).to_columns();
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(1_024)
            .write(&trace, "dee", &cols)
            .unwrap();

        cmd_sample(args(&[
            "compute",
            "--trace",
            trace.to_str().unwrap(),
            "--out",
            plan_path.to_str().unwrap(),
            "--slice-rows",
            "1000",
            "--max-phases",
            "3",
            "--seed",
            "9",
        ]))
        .unwrap();

        let plan = SamplingPlan::load(&plan_path).unwrap();
        assert_eq!(plan.name, "dee");
        assert_eq!(plan.total_rows, 6_000);
        assert_eq!(plan.slice_rows, 1_000);
        assert!(!plan.phases.is_empty() && plan.phases.len() <= 3);

        cmd_sample(args(&["inspect", plan_path.to_str().unwrap()])).unwrap();
    }

    #[test]
    fn bad_action_and_missing_args_fail() {
        assert!(cmd_sample(args(&["frobnicate"])).is_err());
        assert!(cmd_sample(args(&["compute", "--out", "x"])).is_err());
        assert!(cmd_sample(args(&["inspect"])).is_err());
        let mut a = args(&["--slice-rows", "0"]);
        assert!(parse_sampling_options(&mut a).is_err());
    }
}
