//! Tiny flag parser: positionals + `--flag value` pairs, with typed
//! accessors and an unknown-flag check at the end.

use anyhow::{bail, Context, Result};

/// Argument cursor.
pub struct Args {
    argv: Vec<Option<String>>,
}

impl Args {
    /// Wrap an argv (excluding the program name).
    pub fn new(argv: Vec<String>) -> Args {
        Args {
            argv: argv.into_iter().map(Some).collect(),
        }
    }

    /// Take the next unconsumed positional argument.
    pub fn next_positional(&mut self) -> Option<String> {
        for slot in self.argv.iter_mut() {
            if let Some(v) = slot {
                if !v.starts_with("--") {
                    return slot.take();
                } else {
                    return None; // positionals come before flags
                }
            }
        }
        None
    }

    /// Take `--flag value`, if present.
    pub fn opt_value(&mut self, flag: &str) -> Result<Option<String>> {
        for i in 0..self.argv.len() {
            if self.argv[i].as_deref() == Some(flag) {
                self.argv[i] = None;
                let v = self
                    .argv
                    .get_mut(i + 1)
                    .and_then(|s| s.take())
                    .with_context(|| format!("flag {flag} requires a value"))?;
                if v.starts_with("--") {
                    bail!("flag {flag} requires a value, got {v}");
                }
                return Ok(Some(v));
            }
        }
        Ok(None)
    }

    /// Take `--flag value` parsed into `T`.
    pub fn opt_parse<T: std::str::FromStr>(&mut self, flag: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_value(flag)? {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("bad value for {flag}: {e}"),
            },
        }
    }

    /// Take a boolean `--flag` (no value).
    pub fn opt_flag(&mut self, flag: &str) -> bool {
        for slot in self.argv.iter_mut() {
            if slot.as_deref() == Some(flag) {
                *slot = None;
                return true;
            }
        }
        false
    }

    /// Error on any unconsumed argument.
    pub fn finish(self) -> Result<()> {
        let leftovers: Vec<String> = self.argv.into_iter().flatten().collect();
        if !leftovers.is_empty() {
            bail!("unrecognized arguments: {}", leftovers.join(" "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn positional_then_flags() {
        let mut a = args("report --out x");
        assert_eq!(a.next_positional().unwrap(), "report");
        assert_eq!(a.opt_value("--out").unwrap().unwrap(), "x");
        a.finish().unwrap();
    }

    #[test]
    fn typed_parse() {
        let mut a = args("--n 42");
        let n: u64 = a.opt_parse("--n").unwrap().unwrap();
        assert_eq!(n, 42);
        let mut a = args("--n forty");
        assert!(a.opt_parse::<u64>("--n").is_err());
    }

    #[test]
    fn missing_flag_is_none() {
        let mut a = args("--x 1");
        assert!(a.opt_value("--y").unwrap().is_none());
    }

    #[test]
    fn missing_value_is_error() {
        let mut a = args("--x");
        assert!(a.opt_value("--x").is_err());
        let mut a = args("--x --y");
        assert!(a.opt_value("--x").is_err());
    }

    #[test]
    fn bool_flag() {
        let mut a = args("--fast");
        assert!(a.opt_flag("--fast"));
        assert!(!a.opt_flag("--fast"));
        a.finish().unwrap();
    }

    #[test]
    fn leftovers_rejected() {
        let a = args("--mystery 1");
        assert!(a.finish().is_err());
    }
}
