//! Command-line interface for the `tao` launcher.
//!
//! Hand-rolled argument parsing (the build is fully offline/vendored; no
//! clap). Subcommands:
//!
//! * `tao datagen`   — generate traces + training datasets (`data/`);
//! * `tao simulate`  — run the DL-based simulation on a benchmark;
//! * `tao serve`     — the concurrent simulation service daemon;
//! * `tao router`    — consistent-hash routing tier over serve workers;
//! * `tao loadgen`   — replay mixed scenarios against a daemon;
//! * `tao router-bench` — measure router-tier throughput scale-up;
//! * `tao report`    — regenerate a paper table/figure (see DESIGN.md §3);
//! * `tao dse`       — sample + characterize designs, select train pair;
//! * `tao trace`     — inspect/convert/generate on-disk functional traces;
//! * `tao sample`    — compute/inspect phase-sampling plans for traces.

pub mod args;
pub mod sample_cmd;
pub mod trace_cmd;

use crate::datagen::{self, DatagenOptions, StreamOptions};
use crate::features::FeatureConfig;
use crate::uarch::UarchConfig;
use crate::workloads;
use anyhow::{bail, Context, Result};
use args::Args;
use std::path::PathBuf;

/// Top-level usage string.
pub const USAGE: &str = "\
tao — Tao DL-based microarchitecture simulation (SIGMETRICS '24 reproduction)

USAGE:
  tao datagen  [--out DIR] [--insts N] [--uarchs a,b,c] [--split train|test|all]
               [--seed S] [--nb N] [--nq N] [--nm N]
               [--chunk-size N] [--shards K] [--keep-shards] [--stream]
               [--from-trace PATH]   (replay a recorded trace, either format)
               [--profile [--profile-out F]]   (per-stage latency breakdown)
  tao simulate --model artifacts/tao_uarch_a.hlo.txt --bench mcf
               [--insts N] [--workers W] [--seed S] [--truth a|b|c]
               [--chunk N] [--warmup N] [--stream] [--max-resident N]
               [--trace PATH]   (replay a recorded trace, either format)
               [--sample [--plan PLAN | --slice-rows N --max-phases K]]
                                (phase-sampled replay; requires --trace)
               [--profile [--profile-out F]]   (per-stage latency breakdown)
  tao serve    --model A.hlo.txt [--model B.hlo.txt ...] | --surrogate-dir DIR
               [--addr H:P | --port P] [--port-file F] [--queue-depth N]
               [--max-active N] [--cache-entries N] [--max-insts N]
               [--admission-wait-ms N] [--no-pipeline] [--stats-out F]
               [--cache-journal F] [--default-deadline-ms N]
               [--read-timeout-ms N] [--write-timeout-ms N]
               [--faults probe=prob,...]   (also: TAO_FAULTS env var)
               [--log-json] [--log-level error|warn|info|debug]
               [--peers H:P,...] [--peer-timeout-ms N]   (ring-sibling caches)
               [--cache-quota NAME=BYTES]... [--warm-journal F]...
               (GET /metrics serves the Prometheus exposition)
  tao router   --worker H:P[=WEIGHT] [--worker ...] | --workers H:P,H:P,...
               [--addr H:P | --port P] [--port-file F] [--replica-walk N]
               [--max-attempts N] [--hop-cap-ms N] [--default-deadline-ms N]
               [--health-interval-ms N] [--health-timeout-ms N]
               [--read-timeout-ms N] [--write-timeout-ms N]
               [--log-json] [--log-level L]
               [--print-peers]   (emit each worker's --peers wiring and exit)
  tao loadgen  --addr H:P | --port-file F  [--jobs N] [--threads K]
               [--solo-jobs N] [--insts N] [--seed S] [--chunk N]
               [--json BENCH_serve.json] [--verify-models DIR]
               [--assert-occupancy] [--shutdown] [--wait-secs N] [--chaos]
               [--targets H:P,...] [--assert-balance]   (per-worker spread)
               [--progress-every SECS]   (periodic /metrics summary)
  tao router-bench [--fleets 1,2,4] [--jobs N] [--threads K] [--insts N]
               [--seed S] [--chunk N] [--cache-entries N]
               [--work-dir DIR] [--json BENCH_serve.json]
  tao report   <table1|figure2|figure9|figure10a|figure10b|figure11|figure12a|
                figure12b|figure14|table4|table6|figure15> [opts]
  tao dse      [--designs N] [--insts N] [--seed S]
  tao trace    inspect PATH [--signatures] [--slice-rows N]
               | convert IN OUT [--format v1|v2] [--chunk-rows N] [--level 0|1|2]
               | write OUT --bench B [--insts N] [--seed S]
                 [--format v1|v2] [--chunk-rows N] [--level 0|1|2]
  tao sample   compute --trace PATH --out PLAN
               [--slice-rows N] [--max-phases K] [--seed S]
               | inspect PLAN
  tao help
";

/// Entry point called by `main`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let mut args = Args::new(argv);
    let Some(cmd) = args.next_positional() else {
        println!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "datagen" => cmd_datagen(args),
        "simulate" => crate::coordinator::cli::cmd_simulate(args),
        "serve" => crate::serve::cli::cmd_serve(args),
        "router" => crate::serve::cli::cmd_router(args),
        "loadgen" => crate::serve::cli::cmd_loadgen(args),
        "router-bench" => crate::serve::cli::cmd_router_bench(args),
        "report" => crate::reports::cmd_report(args),
        "dse" => crate::reports::cmd_dse(args),
        "trace" => trace_cmd::cmd_trace(args),
        "sample" => sample_cmd::cmd_sample(args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// Parse `--uarchs a,b,c` into configs.
pub fn parse_uarchs(spec: &str) -> Result<Vec<UarchConfig>> {
    spec.split(',')
        .map(|s| UarchConfig::preset(s.trim()).with_context(|| format!("unknown uarch {s:?}")))
        .collect()
}

/// Parse a workload split selector.
pub fn parse_split(spec: &str) -> Result<Vec<workloads::Workload>> {
    Ok(match spec {
        "train" => workloads::training(),
        "test" => workloads::testing(),
        "all" => workloads::suite(),
        name => {
            let w = workloads::by_name(name);
            vec![w.with_context(|| format!("unknown benchmark {name:?}"))?]
        }
    })
}

fn cmd_datagen(mut args: Args) -> Result<()> {
    let out: PathBuf = args.opt_value("--out")?.unwrap_or_else(|| "data".into()).into();
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(20_000);
    let uarch_spec = args.opt_value("--uarchs")?.unwrap_or_else(|| "a,b,c".into());
    let split = args.opt_value("--split")?.unwrap_or_else(|| "all".into());
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let nb: usize = args.opt_parse("--nb")?.unwrap_or(1024);
    let nq: usize = args.opt_parse("--nq")?.unwrap_or(32);
    let nm: usize = args.opt_parse("--nm")?.unwrap_or(64);
    let default_stream = StreamOptions::default();
    let chunk_size: usize = args
        .opt_parse("--chunk-size")?
        .unwrap_or(default_stream.chunk_size);
    let shards: usize = args.opt_parse("--shards")?.unwrap_or(default_stream.shards);
    let keep_shards = args.opt_flag("--keep-shards");
    let from_generator = args.opt_flag("--stream");
    let from_trace: Option<PathBuf> = args.opt_value("--from-trace")?.map(Into::into);
    let profile_flag = args.opt_flag("--profile");
    let profile_out: Option<PathBuf> = args.opt_value("--profile-out")?.map(Into::into);
    args.finish()?;
    anyhow::ensure!(
        profile_flag || profile_out.is_none(),
        "--profile-out names the --profile report; pass --profile"
    );
    anyhow::ensure!(chunk_size >= 1, "--chunk-size must be at least 1");
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");

    let uarchs = parse_uarchs(&uarch_spec)?;
    let wls = parse_split(&split)?;
    if from_trace.is_some() {
        anyhow::ensure!(
            wls.len() == 1,
            "--from-trace replays one recorded benchmark; pass --split <bench> \
             (a single workload), not a suite"
        );
        anyhow::ensure!(
            !from_generator,
            "--from-trace and --stream are exclusive (the trace replaces the generator)"
        );
    }
    let opts = DatagenOptions {
        instructions: insts,
        features: FeatureConfig { nb, nq, nm },
        seed,
        stream: StreamOptions {
            chunk_size,
            shards,
            keep_shards,
        },
        from_generator,
        from_trace,
    };
    if !profile_flag {
        return datagen::run(&out, &wls, &uarchs, &opts);
    }
    // `--profile`: arm the registry on a fresh slate so the per-stage
    // attribution (functional / detailed / extract_write / merge spans
    // inside datagen) covers exactly this run.
    crate::telemetry::registry().reset();
    crate::telemetry::arm();
    let mut prof = crate::telemetry::Profile::start();
    prof.phase("generate", || datagen::run(&out, &wls, &uarchs, &opts))?;
    crate::coordinator::cli::finish_profile(Some(prof), profile_out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_uarchs_presets() {
        let u = parse_uarchs("a,b").unwrap();
        assert_eq!(u.len(), 2);
        assert_eq!(u[0].name, "uarch_a");
        assert!(parse_uarchs("a,zz").is_err());
    }

    #[test]
    fn parse_split_selectors() {
        assert_eq!(parse_split("train").unwrap().len(), 4);
        assert_eq!(parse_split("test").unwrap().len(), 4);
        assert_eq!(parse_split("all").unwrap().len(), 8);
        assert_eq!(parse_split("mcf").unwrap().len(), 1);
        assert!(parse_split("bogus").is_err());
    }
}
