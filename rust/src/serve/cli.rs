//! `tao serve` / `tao loadgen` command-line entry points.

use super::loadgen::{run_loadgen, LoadgenOptions};
use super::server::{Server, ServeConfig};
use crate::cli::args::Args;
use crate::runtime::{
    write_surrogate_artifact, write_surrogate_artifact_kind, ArtifactPool, ModelKind,
};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Process-wide drain request flag, set by SIGINT/SIGTERM.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    // Only async-signal-safe work here: flip the atomic.
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Route SIGINT/SIGTERM into [`SIGNALLED`] (zero-dep: straight libc
/// `signal(2)`, which std already links).
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler: extern "C" fn(i32) = on_signal;
    let handler = handler as usize;
    unsafe {
        signal(2, handler); // SIGINT
        signal(15, handler); // SIGTERM
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Write the dev/CI surrogate artifact set under `dir`: two Tao models
/// and one SimNet baseline, all `B = 64`, `T = 16` — small jobs leave
/// tail-heavy batches, which is exactly the traffic shape cross-job
/// packing exists for. Returns the `.hlo.txt` paths.
pub fn write_surrogate_set(dir: &std::path::Path) -> Result<Vec<PathBuf>> {
    Ok(vec![
        write_surrogate_artifact(dir, "serve_tao_a", 64, 16)?,
        write_surrogate_artifact(dir, "serve_tao_b", 64, 16)?,
        write_surrogate_artifact_kind(dir, "serve_simnet_a", ModelKind::SimNet, 64, 16)?,
    ])
}

/// `tao serve` — run the simulation service daemon.
pub fn cmd_serve(mut args: Args) -> Result<()> {
    let mut models: Vec<PathBuf> = Vec::new();
    while let Some(m) = args.opt_value("--model")? {
        models.push(m.into());
    }
    let surrogate_dir: Option<PathBuf> = args.opt_value("--surrogate-dir")?.map(Into::into);
    let defaults = ServeConfig::default();
    let addr_flag = args.opt_value("--addr")?;
    let port: Option<u16> = args.opt_parse("--port")?;
    let cfg = ServeConfig {
        addr: addr_flag.unwrap_or_else(|| format!("127.0.0.1:{}", port.unwrap_or(0))),
        queue_depth: args.opt_parse("--queue-depth")?.unwrap_or(defaults.queue_depth),
        max_active: args.opt_parse("--max-active")?.unwrap_or(defaults.max_active),
        cache_entries: args.opt_parse("--cache-entries")?.unwrap_or(defaults.cache_entries),
        max_insts: args.opt_parse("--max-insts")?.unwrap_or(defaults.max_insts),
        pipeline: !args.opt_flag("--no-pipeline"),
        admission_wait_ms: args
            .opt_parse("--admission-wait-ms")?
            .unwrap_or(defaults.admission_wait_ms),
        prep_depth: args.opt_parse("--prep-depth")?.unwrap_or(defaults.prep_depth),
        read_timeout_ms: args
            .opt_parse("--read-timeout-ms")?
            .unwrap_or(defaults.read_timeout_ms),
        write_timeout_ms: args
            .opt_parse("--write-timeout-ms")?
            .unwrap_or(defaults.write_timeout_ms),
        default_deadline_ms: args
            .opt_parse("--default-deadline-ms")?
            .unwrap_or(defaults.default_deadline_ms),
        cache_journal: args.opt_value("--cache-journal")?.map(Into::into),
    };
    let port_file: Option<PathBuf> = args.opt_value("--port-file")?.map(Into::into);
    let stats_out: Option<PathBuf> = args.opt_value("--stats-out")?.map(Into::into);
    let faults: Option<String> = args.opt_value("--faults")?;
    let log_json = args.opt_flag("--log-json");
    let log_level: Option<String> = args.opt_value("--log-level")?;
    args.finish()?;

    // Structured logs: `--log-json` turns on JSON event lines to
    // stderr at `info`; `--log-level LEVEL` picks the threshold
    // (error/warn/info/debug) and implies `--log-json`.
    if log_json || log_level.is_some() {
        let level = match log_level.as_deref() {
            Some(s) => crate::telemetry::Level::from_str(s)
                .with_context(|| format!("bad --log-level {s:?} (error|warn|info|debug)"))?,
            None => crate::telemetry::Level::Info,
        };
        crate::telemetry::log::enable_json(level);
    }

    // Chaos probes: `--faults name=prob,...` or the TAO_FAULTS env var
    // (flag wins). Disarmed probes cost one relaxed atomic load.
    if let Some(spec) = &faults {
        crate::util::fault::arm_from_spec(spec)?;
        eprintln!("serve: fault probes armed from --faults: {spec}");
    } else if crate::util::fault::arm_from_env()? {
        eprintln!("serve: fault probes armed from TAO_FAULTS");
    }

    if let Some(dir) = &surrogate_dir {
        let mut set = write_surrogate_set(dir)?;
        eprintln!("serve: wrote surrogate artifact set under {}", dir.display());
        models.append(&mut set);
    }
    anyhow::ensure!(
        !models.is_empty(),
        "serve needs --model <artifact.hlo.txt> (repeatable) or --surrogate-dir DIR"
    );
    let pool = ArtifactPool::load(&models)?;
    let server = Server::bind(pool, &cfg)?;
    let addr = server.local_addr()?;
    eprintln!(
        "serve: listening on {addr} ({} artifact(s), queue {}, cache {} chunks)",
        models.len(),
        cfg.queue_depth,
        cfg.cache_entries
    );
    if let Some(pf) = &port_file {
        std::fs::write(pf, addr.to_string()).with_context(|| format!("write {pf:?}"))?;
    }

    install_signal_handlers();
    let handle = server.handle();
    std::thread::spawn(move || loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            eprintln!("serve: signal received — draining");
            handle.request_shutdown();
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    });

    let stats = server.run()?;
    if let Some(path) = &stats_out {
        std::fs::write(path, stats.to_json()).with_context(|| format!("write {path:?}"))?;
        eprintln!("serve: wrote final stats to {}", path.display());
    }
    Ok(())
}

/// Resolve the daemon address from `--addr` or a `--port-file` written
/// by `tao serve`, waiting for the file (and the socket) to appear.
fn resolve_addr(
    addr: Option<String>,
    port_file: Option<PathBuf>,
    wait: Duration,
) -> Result<String> {
    if let Some(a) = addr {
        return Ok(a);
    }
    let pf = port_file.context("need --addr HOST:PORT or --port-file PATH")?;
    let deadline = Instant::now() + wait;
    loop {
        match std::fs::read_to_string(&pf) {
            Ok(s) if !s.trim().is_empty() => {
                let addr = s.trim().to_string();
                // The daemon writes the file after binding, but give
                // the health endpoint a chance too.
                if super::http::http_get(&addr, "/healthz").is_ok() {
                    return Ok(addr);
                }
                if Instant::now() >= deadline {
                    return Ok(addr);
                }
            }
            _ if Instant::now() >= deadline => {
                anyhow::bail!("port file {pf:?} did not appear within {wait:?}")
            }
            _ => {}
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// `tao loadgen` — replay mixed scenarios against a daemon.
pub fn cmd_loadgen(mut args: Args) -> Result<()> {
    let defaults = LoadgenOptions::default();
    let addr = args.opt_value("--addr")?;
    let port_file: Option<PathBuf> = args.opt_value("--port-file")?.map(Into::into);
    let wait_secs: u64 = args.opt_parse("--wait-secs")?.unwrap_or(30);
    let progress_every: Option<u64> = args.opt_parse("--progress-every")?;
    let opts = LoadgenOptions {
        addr: resolve_addr(addr, port_file, Duration::from_secs(wait_secs))?,
        jobs: args.opt_parse("--jobs")?.unwrap_or(defaults.jobs),
        threads: args.opt_parse("--threads")?.unwrap_or(defaults.threads),
        solo_jobs: args.opt_parse("--solo-jobs")?.unwrap_or(defaults.solo_jobs),
        insts: args.opt_parse("--insts")?.unwrap_or(defaults.insts),
        seed: args.opt_parse("--seed")?.unwrap_or(defaults.seed),
        chunk: args.opt_parse("--chunk")?.unwrap_or(defaults.chunk),
        json_out: args.opt_value("--json")?.map(Into::into),
        verify_models: args.opt_value("--verify-models")?.map(Into::into),
        assert_occupancy: args.opt_flag("--assert-occupancy"),
        shutdown_after: args.opt_flag("--shutdown"),
        chaos: args.opt_flag("--chaos"),
        progress_every: progress_every.map(Duration::from_secs),
    };
    args.finish()?;
    run_loadgen(&opts)?;
    Ok(())
}
