//! `tao serve` / `tao router` / `tao loadgen` / `tao router-bench`
//! command-line entry points.

use super::loadgen::{run_concurrent, run_loadgen, to_spec, LoadgenOptions};
use super::protocol::JobSpec;
use super::router::{peer_map, Router, RouterConfig};
use super::server::{Server, ServeConfig};
use crate::cli::args::Args;
use crate::runtime::{
    write_surrogate_artifact, write_surrogate_artifact_kind, ArtifactPool, ModelKind,
};
use crate::util::benchkit::{BenchReport, Measurement};
use crate::util::json::Json;
use crate::workloads::{mixed_tenant_scenarios, ScenarioArtifact};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Process-wide drain request flag, set by SIGINT/SIGTERM.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    // Only async-signal-safe work here: flip the atomic.
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Route SIGINT/SIGTERM into [`SIGNALLED`] (zero-dep: straight libc
/// `signal(2)`, which std already links).
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler: extern "C" fn(i32) = on_signal;
    let handler = handler as usize;
    unsafe {
        signal(2, handler); // SIGINT
        signal(15, handler); // SIGTERM
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Write the dev/CI surrogate artifact set under `dir`: two Tao models
/// and one SimNet baseline, all `B = 64`, `T = 16` — small jobs leave
/// tail-heavy batches, which is exactly the traffic shape cross-job
/// packing exists for. Returns the `.hlo.txt` paths.
pub fn write_surrogate_set(dir: &std::path::Path) -> Result<Vec<PathBuf>> {
    Ok(vec![
        write_surrogate_artifact(dir, "serve_tao_a", 64, 16)?,
        write_surrogate_artifact(dir, "serve_tao_b", 64, 16)?,
        write_surrogate_artifact_kind(dir, "serve_simnet_a", ModelKind::SimNet, 64, 16)?,
    ])
}

/// `tao serve` — run the simulation service daemon.
pub fn cmd_serve(mut args: Args) -> Result<()> {
    let mut models: Vec<PathBuf> = Vec::new();
    while let Some(m) = args.opt_value("--model")? {
        models.push(m.into());
    }
    let surrogate_dir: Option<PathBuf> = args.opt_value("--surrogate-dir")?.map(Into::into);
    let defaults = ServeConfig::default();
    let addr_flag = args.opt_value("--addr")?;
    let port: Option<u16> = args.opt_parse("--port")?;
    // Fleet wiring: `--peers a:1,b:2` names the ring siblings whose
    // caches this worker consults on a local miss; `--cache-quota
    // name=bytes` caps one artifact's cache share; `--warm-journal`
    // replays a (possibly dead) peer's cache journal read-only.
    let peers: Vec<String> = args
        .opt_value("--peers")?
        .map(|s| s.split(',').filter(|p| !p.is_empty()).map(str::to_string).collect())
        .unwrap_or_default();
    let mut cache_quotas: Vec<(String, u64)> = Vec::new();
    while let Some(q) = args.opt_value("--cache-quota")? {
        let (name, bytes) = q
            .split_once('=')
            .with_context(|| format!("--cache-quota wants NAME=BYTES, got {q:?}"))?;
        let bytes: u64 =
            bytes.parse().with_context(|| format!("bad --cache-quota bytes in {q:?}"))?;
        cache_quotas.push((name.to_string(), bytes));
    }
    let mut warm_journals: Vec<PathBuf> = Vec::new();
    while let Some(j) = args.opt_value("--warm-journal")? {
        warm_journals.push(j.into());
    }
    let cfg = ServeConfig {
        addr: addr_flag.unwrap_or_else(|| format!("127.0.0.1:{}", port.unwrap_or(0))),
        queue_depth: args.opt_parse("--queue-depth")?.unwrap_or(defaults.queue_depth),
        max_active: args.opt_parse("--max-active")?.unwrap_or(defaults.max_active),
        cache_entries: args.opt_parse("--cache-entries")?.unwrap_or(defaults.cache_entries),
        max_insts: args.opt_parse("--max-insts")?.unwrap_or(defaults.max_insts),
        pipeline: !args.opt_flag("--no-pipeline"),
        admission_wait_ms: args
            .opt_parse("--admission-wait-ms")?
            .unwrap_or(defaults.admission_wait_ms),
        prep_depth: args.opt_parse("--prep-depth")?.unwrap_or(defaults.prep_depth),
        read_timeout_ms: args
            .opt_parse("--read-timeout-ms")?
            .unwrap_or(defaults.read_timeout_ms),
        write_timeout_ms: args
            .opt_parse("--write-timeout-ms")?
            .unwrap_or(defaults.write_timeout_ms),
        default_deadline_ms: args
            .opt_parse("--default-deadline-ms")?
            .unwrap_or(defaults.default_deadline_ms),
        cache_journal: args.opt_value("--cache-journal")?.map(Into::into),
        peers,
        peer_timeout_ms: args
            .opt_parse("--peer-timeout-ms")?
            .unwrap_or(defaults.peer_timeout_ms),
        cache_quotas,
        warm_journals,
    };
    let port_file: Option<PathBuf> = args.opt_value("--port-file")?.map(Into::into);
    let stats_out: Option<PathBuf> = args.opt_value("--stats-out")?.map(Into::into);
    let faults: Option<String> = args.opt_value("--faults")?;
    let log_json = args.opt_flag("--log-json");
    let log_level: Option<String> = args.opt_value("--log-level")?;
    args.finish()?;

    // Structured logs: `--log-json` turns on JSON event lines to
    // stderr at `info`; `--log-level LEVEL` picks the threshold
    // (error/warn/info/debug) and implies `--log-json`.
    if log_json || log_level.is_some() {
        let level = match log_level.as_deref() {
            Some(s) => crate::telemetry::Level::from_str(s)
                .with_context(|| format!("bad --log-level {s:?} (error|warn|info|debug)"))?,
            None => crate::telemetry::Level::Info,
        };
        crate::telemetry::log::enable_json(level);
    }

    // Chaos probes: `--faults name=prob,...` or the TAO_FAULTS env var
    // (flag wins). Disarmed probes cost one relaxed atomic load.
    if let Some(spec) = &faults {
        crate::util::fault::arm_from_spec(spec)?;
        eprintln!("serve: fault probes armed from --faults: {spec}");
    } else if crate::util::fault::arm_from_env()? {
        eprintln!("serve: fault probes armed from TAO_FAULTS");
    }

    if let Some(dir) = &surrogate_dir {
        let mut set = write_surrogate_set(dir)?;
        eprintln!("serve: wrote surrogate artifact set under {}", dir.display());
        models.append(&mut set);
    }
    anyhow::ensure!(
        !models.is_empty(),
        "serve needs --model <artifact.hlo.txt> (repeatable) or --surrogate-dir DIR"
    );
    let pool = ArtifactPool::load(&models)?;
    let server = Server::bind(pool, &cfg)?;
    let addr = server.local_addr()?;
    eprintln!(
        "serve: listening on {addr} ({} artifact(s), queue {}, cache {} chunks)",
        models.len(),
        cfg.queue_depth,
        cfg.cache_entries
    );
    if let Some(pf) = &port_file {
        std::fs::write(pf, addr.to_string()).with_context(|| format!("write {pf:?}"))?;
    }

    install_signal_handlers();
    let handle = server.handle();
    std::thread::spawn(move || loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            eprintln!("serve: signal received — draining");
            handle.request_shutdown();
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    });

    let stats = server.run()?;
    if let Some(path) = &stats_out {
        std::fs::write(path, stats.to_json()).with_context(|| format!("write {path:?}"))?;
        eprintln!("serve: wrote final stats to {}", path.display());
    }
    Ok(())
}

/// Resolve the daemon address from `--addr` or a `--port-file` written
/// by `tao serve`, waiting for the file (and the socket) to appear.
fn resolve_addr(
    addr: Option<String>,
    port_file: Option<PathBuf>,
    wait: Duration,
) -> Result<String> {
    if let Some(a) = addr {
        return Ok(a);
    }
    let pf = port_file.context("need --addr HOST:PORT or --port-file PATH")?;
    let deadline = Instant::now() + wait;
    loop {
        match std::fs::read_to_string(&pf) {
            Ok(s) if !s.trim().is_empty() => {
                let addr = s.trim().to_string();
                // The daemon writes the file after binding, but give
                // the health endpoint a chance too.
                if super::http::http_get(&addr, "/healthz").is_ok() {
                    return Ok(addr);
                }
                if Instant::now() >= deadline {
                    return Ok(addr);
                }
            }
            _ if Instant::now() >= deadline => {
                anyhow::bail!("port file {pf:?} did not appear within {wait:?}")
            }
            _ => {}
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// `tao loadgen` — replay mixed scenarios against a daemon.
pub fn cmd_loadgen(mut args: Args) -> Result<()> {
    let defaults = LoadgenOptions::default();
    let addr = args.opt_value("--addr")?;
    let port_file: Option<PathBuf> = args.opt_value("--port-file")?.map(Into::into);
    let wait_secs: u64 = args.opt_parse("--wait-secs")?.unwrap_or(30);
    let progress_every: Option<u64> = args.opt_parse("--progress-every")?;
    let opts = LoadgenOptions {
        addr: resolve_addr(addr, port_file, Duration::from_secs(wait_secs))?,
        jobs: args.opt_parse("--jobs")?.unwrap_or(defaults.jobs),
        threads: args.opt_parse("--threads")?.unwrap_or(defaults.threads),
        solo_jobs: args.opt_parse("--solo-jobs")?.unwrap_or(defaults.solo_jobs),
        insts: args.opt_parse("--insts")?.unwrap_or(defaults.insts),
        seed: args.opt_parse("--seed")?.unwrap_or(defaults.seed),
        chunk: args.opt_parse("--chunk")?.unwrap_or(defaults.chunk),
        json_out: args.opt_value("--json")?.map(Into::into),
        verify_models: args.opt_value("--verify-models")?.map(Into::into),
        assert_occupancy: args.opt_flag("--assert-occupancy"),
        shutdown_after: args.opt_flag("--shutdown"),
        chaos: args.opt_flag("--chaos"),
        targets: args
            .opt_value("--targets")?
            .map(|s| s.split(',').filter(|t| !t.is_empty()).map(str::to_string).collect())
            .unwrap_or_default(),
        assert_balance: args.opt_flag("--assert-balance"),
        progress_every: progress_every.map(Duration::from_secs),
    };
    args.finish()?;
    ensure!(
        !opts.assert_balance || !opts.targets.is_empty(),
        "--assert-balance needs --targets host:port,... (the workers behind the router)"
    );
    run_loadgen(&opts)?;
    Ok(())
}

fn parse_worker(s: &str) -> Result<(String, u32)> {
    match s.split_once('=') {
        Some((addr, w)) => {
            let weight: u32 =
                w.parse().with_context(|| format!("bad worker weight in {s:?}"))?;
            Ok((addr.to_string(), weight))
        }
        None => Ok((s.to_string(), 1)),
    }
}

/// `tao router` — run the consistent-hash routing tier over a fleet of
/// `tao serve` workers.
pub fn cmd_router(mut args: Args) -> Result<()> {
    let defaults = RouterConfig::default();
    let mut workers: Vec<(String, u32)> = Vec::new();
    while let Some(w) = args.opt_value("--worker")? {
        workers.push(parse_worker(&w)?);
    }
    if let Some(list) = args.opt_value("--workers")? {
        for w in list.split(',').filter(|s| !s.is_empty()) {
            workers.push(parse_worker(w)?);
        }
    }
    let addr_flag = args.opt_value("--addr")?;
    let port: Option<u16> = args.opt_parse("--port")?;
    let cfg = RouterConfig {
        addr: addr_flag.unwrap_or_else(|| format!("127.0.0.1:{}", port.unwrap_or(0))),
        workers,
        health_interval_ms: args
            .opt_parse("--health-interval-ms")?
            .unwrap_or(defaults.health_interval_ms),
        health_timeout_ms: args
            .opt_parse("--health-timeout-ms")?
            .unwrap_or(defaults.health_timeout_ms),
        replica_walk: args.opt_parse("--replica-walk")?.unwrap_or(defaults.replica_walk),
        hop_cap_ms: args.opt_parse("--hop-cap-ms")?.unwrap_or(defaults.hop_cap_ms),
        max_attempts: args.opt_parse("--max-attempts")?.unwrap_or(defaults.max_attempts),
        default_deadline_ms: args
            .opt_parse("--default-deadline-ms")?
            .unwrap_or(defaults.default_deadline_ms),
        read_timeout_ms: args
            .opt_parse("--read-timeout-ms")?
            .unwrap_or(defaults.read_timeout_ms),
        write_timeout_ms: args
            .opt_parse("--write-timeout-ms")?
            .unwrap_or(defaults.write_timeout_ms),
    };
    let port_file: Option<PathBuf> = args.opt_value("--port-file")?.map(Into::into);
    let print_peers = args.opt_flag("--print-peers");
    let log_json = args.opt_flag("--log-json");
    let log_level: Option<String> = args.opt_value("--log-level")?;
    args.finish()?;

    if log_json || log_level.is_some() {
        let level = match log_level.as_deref() {
            Some(s) => crate::telemetry::Level::from_str(s)
                .with_context(|| format!("bad --log-level {s:?} (error|warn|info|debug)"))?,
            None => crate::telemetry::Level::Info,
        };
        crate::telemetry::log::enable_json(level);
    }
    if print_peers {
        // Emit the stable peer wiring (`worker peer1,peer2`) so fleet
        // scripts can hand each `tao serve` its `--peers` list.
        for (worker, peers) in peer_map(&cfg.workers, cfg.replica_walk) {
            println!("{worker} {}", peers.join(","));
        }
        return Ok(());
    }

    let router = Router::bind(&cfg)?;
    let addr = router.local_addr()?;
    eprintln!(
        "router: listening on {addr} ({} worker(s), replica walk {}, max {} attempts)",
        cfg.workers.len(),
        cfg.replica_walk,
        cfg.max_attempts
    );
    if let Some(pf) = &port_file {
        std::fs::write(pf, addr.to_string()).with_context(|| format!("write {pf:?}"))?;
    }

    install_signal_handlers();
    let handle = router.handle();
    std::thread::spawn(move || loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            eprintln!("router: signal received — draining");
            handle.request_shutdown();
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    });
    router.run()
}

/// Load an existing `BENCH_serve.json` so `router-bench` can append
/// its metrics without clobbering the loadgen sweep's. Keys the bench
/// is about to re-emit (`router_*`) are dropped; a missing file is an
/// empty report.
fn load_report(path: Option<&Path>) -> Result<BenchReport> {
    let mut report = BenchReport::new();
    let Some(path) = path else { return Ok(report) };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return Ok(report),
    };
    let parsed = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
    if let Some(cases) = parsed.get("cases").and_then(Json::as_arr) {
        for c in cases {
            let (Some(name), Some(items)) =
                (c.get("name").and_then(Json::as_str), c.get("items").and_then(Json::as_u64))
            else {
                continue;
            };
            let f = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            report.push(Measurement {
                name: name.to_string(),
                items,
                mean_ns: f("mean_ns"),
                min_ns: f("min_ns"),
                max_ns: f("max_ns"),
            });
        }
    }
    if let Some(Json::Obj(metrics)) = parsed.get("metrics") {
        for (k, v) in metrics {
            if k.starts_with("router_") {
                continue;
            }
            if let Some(x) = v.as_f64() {
                report.metric(k, x);
            }
        }
    }
    Ok(report)
}

/// Wait until the router's `/healthz` reports the whole fleet live, so
/// the measurement starts failover-free.
fn wait_fleet_live(router_addr: &str, want: u64, wait: Duration) -> Result<()> {
    let deadline = Instant::now() + wait;
    loop {
        if let Ok(resp) = super::http::http_get(router_addr, "/healthz") {
            if let Ok(body) = Json::parse(&resp.body) {
                if body.get("workers_live").and_then(Json::as_u64) == Some(want) {
                    return Ok(());
                }
            }
        }
        ensure!(
            Instant::now() < deadline,
            "router at {router_addr} never saw all {want} workers live within {wait:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Spawn `n` worker processes + an in-process router, run the spec set
/// through the router cold, and return jobs/sec. Workers are killed
/// before returning, success or not.
fn bench_fleet(
    n: usize,
    models: &[PathBuf],
    specs: &[JobSpec],
    threads: usize,
    cache_entries: usize,
    work_dir: &Path,
) -> Result<f64> {
    let exe = std::env::current_exe().context("locate tao binary")?;
    let mut children = Vec::new();
    let mut port_files = Vec::new();
    for i in 0..n {
        let pf = work_dir.join(format!("worker-{n}w-{i}.port"));
        let _ = std::fs::remove_file(&pf);
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("serve");
        for m in models {
            cmd.arg("--model").arg(m);
        }
        cmd.arg("--port")
            .arg("0")
            .arg("--port-file")
            .arg(&pf)
            .arg("--cache-entries")
            .arg(cache_entries.to_string())
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null());
        children.push(cmd.spawn().with_context(|| format!("spawn worker {i}"))?);
        port_files.push(pf);
    }
    let result = (|| {
        let mut addrs = Vec::new();
        for pf in &port_files {
            addrs.push(resolve_addr(None, Some(pf.clone()), Duration::from_secs(30))?);
        }
        let cfg = RouterConfig {
            addr: "127.0.0.1:0".into(),
            workers: addrs.iter().map(|a| (a.clone(), 1)).collect(),
            health_interval_ms: 100,
            ..RouterConfig::default()
        };
        let router = Router::bind(&cfg)?;
        let router_addr = router.local_addr()?.to_string();
        let handle = router.handle();
        let run = std::thread::spawn(move || router.run());
        wait_fleet_live(&router_addr, n as u64, Duration::from_secs(30))?;
        let t0 = Instant::now();
        run_concurrent(&router_addr, specs, threads)?;
        let elapsed = t0.elapsed();
        handle.request_shutdown();
        run.join()
            .map_err(|_| anyhow::anyhow!("router thread panicked"))?
            .context("router run")?;
        Ok(specs.len() as f64 / elapsed.as_secs_f64().max(1e-9))
    })();
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
    result
}

/// `tao router-bench` — measure router-tier throughput scale-up.
///
/// For each fleet size (default 1, 2, 4): spawn that many worker
/// processes on a shared surrogate artifact set, put an in-process
/// router in front, and run a cold tenant-skewed mix through it.
/// Emits `router_jobs_per_sec_{N}w` and the scale-up ratios
/// `router_scaleup_2w` / `router_scaleup_4w` (jobs/sec vs the
/// single-worker fleet), merged into an existing `--json` report.
pub fn cmd_router_bench(mut args: Args) -> Result<()> {
    let work_dir: PathBuf = args.opt_value("--work-dir")?.map(Into::into).unwrap_or_else(|| {
        std::env::temp_dir().join(format!("tao-router-bench-{}", std::process::id()))
    });
    let jobs: usize = args.opt_parse("--jobs")?.unwrap_or(24);
    let threads: usize = args.opt_parse("--threads")?.unwrap_or(8);
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(150);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let chunk: usize = args.opt_parse("--chunk")?.unwrap_or(64);
    let cache_entries: usize = args.opt_parse("--cache-entries")?.unwrap_or(4096);
    let json_out: Option<PathBuf> = args.opt_value("--json")?.map(Into::into);
    let fleets: Vec<usize> = match args.opt_value("--fleets")? {
        Some(s) => s
            .split(',')
            .filter(|x| !x.is_empty())
            .map(|x| x.parse().with_context(|| format!("bad --fleets entry {x:?}")))
            .collect::<Result<_>>()?,
        None => vec![1, 2, 4],
    };
    args.finish()?;
    ensure!(!fleets.is_empty(), "--fleets must name at least one fleet size");

    std::fs::create_dir_all(&work_dir).with_context(|| format!("mkdir {work_dir:?}"))?;
    let models = write_surrogate_set(&work_dir)?;
    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
        ScenarioArtifact { name: "serve_simnet_a".into(), simnet: true },
    ];
    // Tenant-skewed mix: the hot artifact saturates its shard while
    // the minority tenants exercise the other shards — the scaling we
    // claim has to survive realistic imbalance, not a uniform spray.
    let specs: Vec<JobSpec> = mixed_tenant_scenarios(&arts, jobs, insts, seed, 0)
        .iter()
        .map(|j| to_spec(j, chunk))
        .collect();

    let mut rates: BTreeMap<usize, f64> = BTreeMap::new();
    for &n in &fleets {
        let rate = bench_fleet(n, &models, &specs, threads, cache_entries, &work_dir)?;
        eprintln!("router-bench: {n} worker(s): {rate:.1} jobs/s cold");
        rates.insert(n, rate);
    }

    let mut report = load_report(json_out.as_deref())?;
    for (n, rate) in &rates {
        report.metric(&format!("router_jobs_per_sec_{n}w"), *rate);
    }
    if let Some(base) = rates.get(&1).copied().filter(|r| *r > 0.0) {
        for (n, rate) in &rates {
            if *n > 1 {
                report.metric(&format!("router_scaleup_{n}w"), rate / base);
            }
        }
    }
    if let Some(path) = &json_out {
        report.write_json(path).with_context(|| format!("write {path:?}"))?;
        eprintln!("router-bench: merged metrics into {}", path.display());
    }
    Ok(())
}
