//! Bounded admission queue for simulation jobs.
//!
//! Backpressure happens here, not in the socket layer: the queue holds
//! at most `capacity` pending jobs; a submit against a full queue fails
//! immediately and the HTTP handler turns that into a retryable 429,
//! so heavy traffic degrades into fast rejections instead of unbounded
//! memory growth. Closing the queue (graceful drain) fails *new*
//! submits with a retryable 503 while lanes keep popping until the
//! backlog — jobs the server already accepted — is empty.
//!
//! Lanes pop selectively by artifact name ([`JobQueue::pop_for`]): one
//! queue serves every lane, and the bound covers the whole daemon.

use super::protocol::{JobOutcome, JobSpec, ServeError};
use crate::telemetry::{registry, Gauge, Histogram};
use crate::util::fault::{self, Probe};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// A job admitted to the queue: the parsed spec plus the channel the
/// lane answers on (the HTTP handler blocks on the receiver).
pub struct QueuedJob {
    /// Parsed, validated request.
    pub spec: JobSpec,
    /// Completion channel back to the waiting connection handler.
    pub done: std::sync::mpsc::Sender<Result<JobOutcome, ServeError>>,
    /// Admission timestamp (for `elapsed_ms`).
    pub admitted_at: Instant,
    /// Absolute cancellation deadline (spec `deadline_ms` or the
    /// server default, resolved at admission). `None` = no deadline.
    pub deadline: Option<Instant>,
    /// The job's trace id (client-supplied or minted at admission):
    /// the correlation key for `--log-json` lines and span logs.
    pub trace_id: String,
}

impl QueuedJob {
    /// Has this job's deadline passed?
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why a submit was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity — retry later.
    Full,
    /// The daemon is draining — retry against another instance.
    Closed,
}

struct State {
    pending: VecDeque<QueuedJob>,
    closed: bool,
}

/// The shared bounded queue.
pub struct JobQueue {
    state: Mutex<State>,
    cond: Condvar,
    capacity: usize,
    depth_gauge: Gauge,
    wait_hist: Histogram,
}

impl JobQueue {
    /// Queue admitting at most `capacity` pending jobs.
    pub fn new(capacity: usize) -> JobQueue {
        assert!(capacity >= 1, "queue capacity must be positive");
        JobQueue {
            state: Mutex::new(State { pending: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
            capacity,
            depth_gauge: registry().gauge(
                "tao_queue_depth",
                "Jobs admitted and waiting for a lane.",
                &[],
            ),
            wait_hist: registry().histogram(
                "tao_queue_wait_seconds",
                "Time from admission to lane pickup.",
                &[],
            ),
        }
    }

    /// Admit a job, or refuse with backpressure. On refusal the job is
    /// handed back so the caller can answer its completion channel.
    pub fn submit(&self, job: QueuedJob) -> Result<(), (QueuedJob, SubmitError)> {
        let mut st = fault::relock(&self.state);
        if st.closed {
            return Err((job, SubmitError::Closed));
        }
        if st.pending.len() >= self.capacity {
            return Err((job, SubmitError::Full));
        }
        st.pending.push_back(job);
        self.depth_gauge.set(st.pending.len() as i64);
        drop(st);
        self.cond.notify_all();
        Ok(())
    }

    /// Pop the oldest pending job whose spec targets `artifact`,
    /// waiting up to `timeout` for one to arrive. Returns `None` on
    /// timeout or when the queue is closed with no matching job left.
    pub fn pop_for(&self, artifact: &str, timeout: Duration) -> Option<QueuedJob> {
        if fault::should_fire(Probe::QueueStall) {
            // Injected consumer stall: bounded, so it degrades latency
            // without violating any liveness contract.
            std::thread::sleep(Duration::from_millis(50));
        }
        let deadline = Instant::now() + timeout;
        let mut st = fault::relock(&self.state);
        loop {
            if let Some(i) = st.pending.iter().position(|j| j.spec.artifact == artifact) {
                let job = st.pending.remove(i);
                self.depth_gauge.set(st.pending.len() as i64);
                if let Some(j) = &job {
                    self.wait_hist.record(j.admitted_at.elapsed());
                }
                return job;
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (next, timed_out) = self
                .cond
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = next;
            if timed_out.timed_out() && st.pending.iter().all(|j| j.spec.artifact != artifact)
            {
                return None;
            }
        }
    }

    /// Begin draining: new submits fail with [`SubmitError::Closed`];
    /// already-admitted jobs stay poppable.
    pub fn close(&self) {
        fault::relock(&self.state).closed = true;
        self.cond.notify_all();
    }

    /// True once [`JobQueue::close`] has run.
    pub fn is_closed(&self) -> bool {
        fault::relock(&self.state).closed
    }

    /// True when closed and fully drained (lanes may exit).
    pub fn is_drained(&self) -> bool {
        let st = fault::relock(&self.state);
        st.closed && st.pending.is_empty()
    }

    /// Jobs waiting for a lane.
    pub fn depth(&self) -> usize {
        fault::relock(&self.state).pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn job(artifact: &str) -> (QueuedJob, mpsc::Receiver<Result<JobOutcome, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            QueuedJob {
                spec: JobSpec {
                    bench: "mcf".into(),
                    insts: 10,
                    seed: 1,
                    artifact: artifact.into(),
                    chunk: 8,
                    ctx_uarch: None,
                    deadline_ms: None,
                    trace: None,
                    plan: None,
                    trace_id: None,
                },
                done: tx,
                admitted_at: Instant::now(),
                deadline: None,
                trace_id: String::new(),
            },
            rx,
        )
    }

    #[test]
    fn deadline_expiry_is_visible() {
        let (mut j, _r) = job("a");
        let now = Instant::now();
        assert!(!j.expired(now), "no deadline never expires");
        j.deadline = Some(now + Duration::from_secs(60));
        assert!(!j.expired(now));
        j.deadline = Some(now);
        assert!(j.expired(now));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = JobQueue::new(2);
        let (j1, _r1) = job("a");
        let (j2, _r2) = job("a");
        let (j3, _r3) = job("a");
        assert!(q.submit(j1).is_ok());
        assert!(q.submit(j2).is_ok());
        match q.submit(j3) {
            Err((_, SubmitError::Full)) => {}
            other => panic!("expected Full, got {:?}", other.map(|_| ()).map_err(|(_, e)| e)),
        }
        assert_eq!(q.depth(), 2);
        // Popping frees a slot.
        assert!(q.pop_for("a", Duration::from_millis(10)).is_some());
        let (j4, _r4) = job("a");
        assert!(q.submit(j4).is_ok());
    }

    #[test]
    fn pop_filters_by_artifact() {
        let q = JobQueue::new(8);
        let (ja, _ra) = job("lane_a");
        let (jb, _rb) = job("lane_b");
        q.submit(ja).unwrap();
        q.submit(jb).unwrap();
        // lane_b's worker skips lane_a's job.
        let got = q.pop_for("lane_b", Duration::from_millis(10)).unwrap();
        assert_eq!(got.spec.artifact, "lane_b");
        assert_eq!(q.depth(), 1);
        assert!(q.pop_for("lane_b", Duration::from_millis(10)).is_none());
        assert!(q.pop_for("lane_a", Duration::from_millis(10)).is_some());
    }

    #[test]
    fn close_rejects_new_but_drains_backlog() {
        let q = JobQueue::new(4);
        let (j1, _r1) = job("a");
        q.submit(j1).unwrap();
        q.close();
        let (j2, _r2) = job("a");
        match q.submit(j2) {
            Err((_, SubmitError::Closed)) => {}
            _ => panic!("expected Closed"),
        }
        assert!(!q.is_drained(), "backlog still pending");
        assert!(q.pop_for("a", Duration::from_millis(10)).is_some());
        assert!(q.is_drained());
        // Closed + drained: pop returns immediately, no timeout wait.
        let t0 = Instant::now();
        assert!(q.pop_for("a", Duration::from_secs(5)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn pop_wakes_on_cross_thread_submit() {
        let q = std::sync::Arc::new(JobQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_for("a", Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        let (j, _r) = job("a");
        q.submit(j).unwrap();
        assert!(t.join().unwrap().is_some());
    }
}
