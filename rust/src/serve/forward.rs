//! Per-hop forwarding with failover, and the peer-cache client.
//!
//! Two consumers share this module's machinery:
//!
//! * The **router** forwards `/v1/simulate` bodies along a key's ring
//!   replica walk ([`forward`]). Each hop gets a timeout of
//!   `min(remaining deadline, hop cap)`; a transport failure or a
//!   *failover-class* typed error ([`failover_code`]) advances to the
//!   next replica after a capped, jittered backoff. Anything else —
//!   including `deadline_exceeded`, which a retry cannot outrun — is
//!   relayed to the client verbatim, preserving PR 6's failure
//!   taxonomy end to end.
//! * **Workers** consult their key's ring neighbours' caches via
//!   [`PeerCache`] before computing a missed chunk: a short-timeout
//!   `POST /v1/cache/lookup`, with unreachable peers marked down for a
//!   hold-off window so a dead neighbour costs one timeout, not one
//!   per miss.
//!
//! Everything rides the existing hand-rolled HTTP/1.1 client
//! ([`http_post_timeout`]) — the inter-node RPC *is* the public
//! protocol, so every hop stays curl-debuggable.

use super::http::{http_post_timeout, Response};
use super::protocol::{
    cache_lookup_json, cache_result_from_json, ErrorCode, ServeError,
};
use crate::coordinator::engine::PredAccum;
use crate::serve::cache::ChunkKey;
use crate::telemetry::registry;
use crate::util::rng::Rng;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Below this remaining budget a hop is pointless: connect + exchange
/// cannot complete, so the forwarder answers `deadline_exceeded`
/// instead of burning a doomed connection.
pub const MIN_HOP: Duration = Duration::from_millis(10);

/// Forwarding knobs (router-configurable).
#[derive(Debug, Clone, Copy)]
pub struct ForwardPolicy {
    /// Per-hop timeout ceiling (the remaining deadline may cut it
    /// shorter). Jobs block until served, so this bounds one worker's
    /// service time before the router gives up on it.
    pub hop_cap: Duration,
    /// Total attempts across the replica walk (wraps around it).
    pub max_attempts: u32,
}

impl Default for ForwardPolicy {
    fn default() -> ForwardPolicy {
        ForwardPolicy { hop_cap: Duration::from_secs(300), max_attempts: 6 }
    }
}

/// Should this typed error move the job to the next ring replica?
/// Queue-full, draining, and lane/exec failures are worker-local — a
/// sibling can serve the identical spec. `deadline_exceeded` is NOT in
/// the set: the job's budget is spent, and a second worker would only
/// exceed it again.
pub fn failover_code(code: ErrorCode) -> bool {
    matches!(
        code,
        ErrorCode::QueueFull
            | ErrorCode::Draining
            | ErrorCode::LaneFailed
            | ErrorCode::ExecFailed
    )
}

/// Router-side backoff between failover hops: `5ms × 2^attempt` capped
/// at 200ms, jittered to [½·base, 1½·base) — deterministic (seeded by
/// the caller), decorrelated, and strictly shorter than the client's
/// own retry ladder so the router exhausts its replicas before the
/// client re-submits.
pub fn failover_backoff(attempt: u32, rng: &mut Rng) -> Duration {
    let base = (5u64 << attempt.min(6)).min(200);
    Duration::from_millis(base / 2 + rng.gen_range(base.max(1)))
}

/// What one forwarded request resolved to.
#[derive(Debug, Clone)]
pub struct Forwarded {
    /// Final status to relay.
    pub status: u16,
    /// Final body to relay.
    pub body: String,
    /// Worker that produced the final answer (`None` when the walk was
    /// empty or nobody answered at all).
    pub worker: Option<String>,
    /// Connection attempts made.
    pub attempts: u32,
    /// Attempts that failed over (transport or failover-class error).
    pub failovers: u32,
}

fn synthesized(code: ErrorCode, message: String) -> (u16, String) {
    let err = ServeError::new(code, message);
    (code.http_status(), err.to_json())
}

/// Forward `body` to the first replica that answers non-retryably,
/// walking `replicas` in ring order (wrapping, up to
/// `policy.max_attempts` hops) with per-hop deadline budgets and
/// jittered backoff between failovers. Never panics and never returns
/// transport errors: every outcome is an HTTP status + typed body the
/// caller can relay as-is.
pub fn forward(
    replicas: &[String],
    path: &str,
    body: &str,
    deadline: Instant,
    policy: &ForwardPolicy,
    rng: &mut Rng,
) -> Forwarded {
    let reg = registry();
    if replicas.is_empty() {
        let (status, body) =
            synthesized(ErrorCode::Draining, "no live workers on the ring".to_string());
        return Forwarded { status, body, worker: None, attempts: 0, failovers: 0 };
    }
    let mut attempts = 0u32;
    let mut failovers = 0u32;
    let mut last: Option<(u16, String, String)> = None; // status, body, worker
    while attempts < policy.max_attempts {
        let worker = &replicas[(attempts as usize) % replicas.len()];
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining < MIN_HOP {
            let (status, body) = synthesized(
                ErrorCode::DeadlineExceeded,
                format!("deadline exhausted after {attempts} forward attempts"),
            );
            return Forwarded { status, body, worker: None, attempts, failovers };
        }
        attempts += 1;
        reg.counter(
            "tao_router_forwards_total",
            "Forward attempts per worker",
            &[("worker", worker.as_str())],
        )
        .inc();
        let hop = remaining.min(policy.hop_cap);
        match http_post_timeout(worker.as_str(), path, body, hop) {
            Ok(Response { status: 200, body }) => {
                return Forwarded {
                    status: 200,
                    body,
                    worker: Some(worker.clone()),
                    attempts,
                    failovers,
                };
            }
            Ok(Response { status, body }) => {
                let err = ServeError::from_body(status, &body);
                if !failover_code(err.code) {
                    // Terminal (4xx/500/504): the contract says relay,
                    // not mask — a second worker would answer the same.
                    return Forwarded {
                        status,
                        body,
                        worker: Some(worker.clone()),
                        attempts,
                        failovers,
                    };
                }
                reg.counter(
                    "tao_router_failovers_total",
                    "Failovers away from a worker, by reason",
                    &[("worker", worker.as_str()), ("reason", err.code.as_str())],
                )
                .inc();
                failovers += 1;
                last = Some((status, body, worker.clone()));
            }
            Err(_) => {
                // Connect refused / reset / hop timeout: the worker is
                // gone or wedged — exactly what the ring successor is
                // for.
                reg.counter(
                    "tao_router_failovers_total",
                    "Failovers away from a worker, by reason",
                    &[("worker", worker.as_str()), ("reason", "transport")],
                )
                .inc();
                failovers += 1;
                if last.is_none() {
                    let (status, body) = synthesized(
                        ErrorCode::LaneFailed,
                        format!("worker {worker} unreachable"),
                    );
                    last = Some((status, body, worker.clone()));
                }
            }
        }
        let nap = failover_backoff(failovers.saturating_sub(1), rng)
            .min(deadline.saturating_duration_since(Instant::now()));
        std::thread::sleep(nap);
    }
    // Every hop failed retryably: relay the last typed answer — it is
    // retryable, so the client's own backoff ladder takes over.
    let (status, body, worker) = last.expect("max_attempts >= 1 ensures an attempt ran");
    Forwarded { status, body, worker: Some(worker), attempts, failovers }
}

/// How long an erroring peer stays skipped before lookups resume.
pub const PEER_HOLDOFF: Duration = Duration::from_secs(5);

struct PeerSlot {
    addr: String,
    /// `Some(t)`: skip this peer until `t` (it errored recently).
    down_until: Mutex<Option<Instant>>,
}

/// Client side of the fleet-warm cache: consult the ring neighbours'
/// `/v1/cache/lookup` before computing a missed chunk. Lookups are
/// short-timeout and strictly best-effort — any failure is a miss, and
/// the failing peer is held off for [`PEER_HOLDOFF`] so a dead
/// neighbour costs one timeout, not one per miss.
pub struct PeerCache {
    peers: Vec<PeerSlot>,
    timeout: Duration,
}

impl PeerCache {
    /// Peer set (ring-neighbour `host:port`s, nearest first) and the
    /// per-lookup timeout.
    pub fn new(peers: Vec<String>, timeout: Duration) -> PeerCache {
        PeerCache {
            peers: peers
                .into_iter()
                .map(|addr| PeerSlot { addr, down_until: Mutex::new(None) })
                .collect(),
            timeout,
        }
    }

    /// True when no peers are configured (lookups are free no-ops).
    pub fn is_empty(&self) -> bool {
        self.peers.is_empty()
    }

    fn count(result: &str) {
        registry()
            .counter(
                "tao_cache_peer_lookups_total",
                "Peer cache lookups by result",
                &[("result", result)],
            )
            .inc();
    }

    /// Ask each live peer for `key`, nearest ring neighbour first.
    /// Returns the first hit's accumulator, decoded from its journal
    /// frame — the same codec the on-disk journal uses, so a peer hit
    /// is bit-identical to having computed the chunk locally.
    pub fn lookup(&self, key: &ChunkKey) -> Option<PredAccum> {
        let body = cache_lookup_json(key);
        for peer in &self.peers {
            {
                let mut down = crate::util::fault::relock(&peer.down_until);
                match *down {
                    Some(t) if Instant::now() < t => continue,
                    _ => *down = None,
                }
            }
            let resp = match http_post_timeout(peer.addr.as_str(), "/v1/cache/lookup", &body, self.timeout)
            {
                Ok(r) => r,
                Err(_) => {
                    Self::count("error");
                    *crate::util::fault::relock(&peer.down_until) =
                        Some(Instant::now() + PEER_HOLDOFF);
                    continue;
                }
            };
            if resp.status != 200 {
                // Draining/starting peers answer 503 — hold off too.
                Self::count("error");
                *crate::util::fault::relock(&peer.down_until) =
                    Some(Instant::now() + PEER_HOLDOFF);
                continue;
            }
            match cache_result_from_json(&resp.body) {
                Ok(Some(bytes)) => match PredAccum::decode_journal(&bytes) {
                    Ok(accum) => {
                        Self::count("hit");
                        return Some(accum);
                    }
                    Err(_) => {
                        Self::count("error");
                        continue;
                    }
                },
                Ok(None) => {
                    Self::count("miss");
                    continue;
                }
                Err(_) => {
                    Self::count("error");
                    continue;
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::http::{read_request, write_response};
    use crate::serve::protocol::{cache_found_json, cache_lookup_from_json, cache_miss_json};
    use std::io::BufReader;
    use std::net::TcpListener;

    /// One-shot loopback server answering `n` connections via `f`.
    fn serve_n<F>(n: usize, f: F) -> std::net::SocketAddr
    where
        F: Fn(usize, &str, &str) -> (u16, String) + Send + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for i in 0..n {
                let (stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let req = read_request(&mut reader).unwrap();
                let (status, body) = f(i, &req.path, &req.body);
                let mut stream = stream;
                let _ = write_response(&mut stream, status, &body);
            }
        });
        addr
    }

    fn refused_addr() -> String {
        // Bind then drop: the kernel won't reuse the port immediately,
        // so connects are refused.
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        addr.to_string()
    }

    #[test]
    fn forward_fails_over_to_the_ring_successor() {
        let dead = refused_addr();
        let alive = serve_n(1, |_, path, body| {
            assert_eq!(path, "/v1/simulate");
            assert_eq!(body, "{\"x\":1}");
            (200, "{\"ok\":true}".to_string())
        });
        let replicas = vec![dead, alive.to_string()];
        let mut rng = Rng::new(7);
        let fwd = forward(
            &replicas,
            "/v1/simulate",
            "{\"x\":1}",
            Instant::now() + Duration::from_secs(10),
            &ForwardPolicy { hop_cap: Duration::from_secs(2), max_attempts: 4 },
            &mut rng,
        );
        assert_eq!(fwd.status, 200);
        assert_eq!(fwd.worker.as_deref(), Some(alive.to_string().as_str()));
        assert_eq!(fwd.attempts, 2);
        assert_eq!(fwd.failovers, 1);
    }

    #[test]
    fn forward_retries_failover_codes_but_relays_terminal_ones() {
        // First worker: lane_failed (failover). Second: 400 (relay).
        let first = serve_n(1, |_, _, _| {
            (503, ServeError::new(ErrorCode::LaneFailed, "lane died").to_json())
        });
        let second = serve_n(1, |_, _, _| {
            (400, ServeError::new(ErrorCode::BadRequest, "nope").to_json())
        });
        let replicas = vec![first.to_string(), second.to_string()];
        let mut rng = Rng::new(8);
        let fwd = forward(
            &replicas,
            "/v1/simulate",
            "{}",
            Instant::now() + Duration::from_secs(10),
            &ForwardPolicy { hop_cap: Duration::from_secs(2), max_attempts: 4 },
            &mut rng,
        );
        assert_eq!(fwd.status, 400);
        assert_eq!(fwd.failovers, 1);
        let err = ServeError::from_body(fwd.status, &fwd.body);
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn forward_exhaustion_relays_a_retryable_answer() {
        let dead = refused_addr();
        let mut rng = Rng::new(9);
        let fwd = forward(
            &[dead],
            "/v1/simulate",
            "{}",
            Instant::now() + Duration::from_secs(5),
            &ForwardPolicy { hop_cap: Duration::from_millis(200), max_attempts: 2 },
            &mut rng,
        );
        assert_eq!(fwd.attempts, 2);
        assert_eq!(fwd.failovers, 2);
        let err = ServeError::from_body(fwd.status, &fwd.body);
        assert!(err.code.retryable(), "exhaustion must stay client-retryable: {err}");
        // Empty ring: typed draining, zero attempts.
        let fwd = forward(
            &[],
            "/v1/simulate",
            "{}",
            Instant::now() + Duration::from_secs(1),
            &ForwardPolicy::default(),
            &mut rng,
        );
        assert_eq!(fwd.attempts, 0);
        assert_eq!(ServeError::from_body(fwd.status, &fwd.body).code, ErrorCode::Draining);
    }

    #[test]
    fn forward_respects_the_deadline_budget() {
        let mut rng = Rng::new(10);
        let fwd = forward(
            &["127.0.0.1:9".to_string()],
            "/v1/simulate",
            "{}",
            Instant::now(), // already expired
            &ForwardPolicy::default(),
            &mut rng,
        );
        assert_eq!(fwd.status, 504);
        assert_eq!(
            ServeError::from_body(fwd.status, &fwd.body).code,
            ErrorCode::DeadlineExceeded
        );
        assert_eq!(fwd.attempts, 0);
    }

    #[test]
    fn failover_backoff_is_capped_and_jittered() {
        let mut rng = Rng::new(11);
        for attempt in 0..20 {
            let d = failover_backoff(attempt, &mut rng);
            assert!(d >= Duration::from_millis(2), "{d:?}");
            assert!(d < Duration::from_millis(300), "{d:?}");
        }
    }

    #[test]
    fn peer_cache_hits_decode_bit_exactly() {
        use crate::runtime::{ModelKind, ModelOutputs};
        let mut want = PredAccum::default();
        let out = ModelOutputs {
            fetch: vec![2.5; 3],
            exec: vec![1.0 / 3.0; 3],
            branch: vec![0.25; 3],
            access: vec![0.125; 12],
            icache: vec![0.1; 3],
            tlb: vec![0.9; 3],
        };
        want.absorb(&out, ModelKind::Tao);
        let mut frame = Vec::new();
        want.encode_journal(&mut frame);
        let key = ChunkKey { artifact: 0xdead_beef_dead_beef, prefix: 7, content: 9 };
        // Peer 1 misses; peer 2 hits with the encoded frame.
        let missing = serve_n(1, |_, path, _| {
            assert_eq!(path, "/v1/cache/lookup");
            (200, cache_miss_json())
        });
        let holding = serve_n(1, move |_, _, body| {
            let got = cache_lookup_from_json(body).unwrap();
            assert_eq!(got, ChunkKey { artifact: 0xdead_beef_dead_beef, prefix: 7, content: 9 });
            (200, cache_found_json(&frame))
        });
        let pc = PeerCache::new(
            vec![missing.to_string(), holding.to_string()],
            Duration::from_secs(2),
        );
        let got = pc.lookup(&key).expect("second peer holds the key");
        assert_eq!(got.instructions, want.instructions);
        assert_eq!(got.fetch_cycles.to_bits(), want.fetch_cycles.to_bits());
        assert_eq!(got.tlb_misses.to_bits(), want.tlb_misses.to_bits());
    }

    #[test]
    fn peer_cache_holds_off_dead_peers() {
        let dead = refused_addr();
        let pc = PeerCache::new(vec![dead], Duration::from_millis(200));
        let key = ChunkKey { artifact: 1, prefix: 2, content: 3 };
        let t0 = Instant::now();
        assert!(pc.lookup(&key).is_none()); // pays the connect failure once
        assert!(pc.lookup(&key).is_none()); // held off: near-instant
        assert!(pc.lookup(&key).is_none());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "held-off peer must not be re-probed per miss"
        );
        assert!(PeerCache::new(vec![], Duration::from_millis(50)).is_empty());
    }
}
