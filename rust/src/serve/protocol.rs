//! The `tao serve` wire protocol: JSON request/response bodies.
//!
//! Everything rides the repo's hand-rolled [`util::json`](crate::util::json)
//! — no serde. Requests parse strictly (unknown benchmarks, artifacts
//! and malformed fields are rejected with 400s before admission);
//! responses render deterministically (sorted keys) and `f64` metric
//! sums round-trip bit-exactly, which is what lets clients assert
//! served results *identical* to offline runs.
//!
//! Endpoints (see docs/SERVE.md for the full reference):
//!
//! * `POST /v1/simulate` — body [`JobSpec`]; blocks until the job
//!   completes; 200 with [`JobOutcome`], otherwise a typed
//!   [`ServeError`] body whose [`ErrorCode`] fixes the HTTP status and
//!   whether the client should retry (docs/SERVE.md "Failure
//!   semantics" has the full taxonomy table).
//! * `GET  /v1/stats` — serving counters (queue, packing occupancy,
//!   cache hit rates, lane restarts).
//! * `POST /v1/shutdown` — begin graceful drain.
//! * `GET  /healthz` — readiness (`serving`/`degraded`/`draining`).

use crate::stats::Metrics;
use crate::uarch::UarchConfig;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};

/// Default per-job streaming chunk (instructions per cache unit).
pub const DEFAULT_CHUNK: usize = 4_096;

/// A simulation request.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Benchmark short name (`workloads::by_name`).
    pub bench: String,
    /// Instructions to simulate.
    pub insts: u64,
    /// Trace seed.
    pub seed: u64,
    /// Artifact registry name (the `.hlo.txt` stem the daemon loaded).
    pub artifact: String,
    /// Streaming chunk size — also the prediction-cache granularity.
    pub chunk: usize,
    /// Detailed design providing SimNet's µarch-specific context input:
    /// a preset name (`a`, `uarch_b`, ...) or `design:<index>` into the
    /// Table 3 space. Required for SimNet artifacts, ignored for Tao.
    pub ctx_uarch: Option<String>,
    /// Per-job deadline in milliseconds, measured from admission. An
    /// expired job is cancelled (its lane slot reclaimed) and answered
    /// with a retryable [`ErrorCode::DeadlineExceeded`]. `None` takes
    /// the server's `--default-deadline-ms`.
    pub deadline_ms: Option<u64>,
    /// Server-local path to a recorded functional trace (either on-disk
    /// format; sniffed by magic). When set, the job replays the trace
    /// instead of generating the stream: `bench` and `insts` come from
    /// the trace header and must be omitted, and the artifact must be a
    /// Tao model (SimNet needs detailed context a trace does not carry).
    pub trace: Option<String>,
    /// Server-local path to a `TAOPLAN1` phase-sampling plan sidecar
    /// (`tao sample compute` writes them). Requires `trace`; the job
    /// replays only the plan's representative slices and reconstructs
    /// whole-trace metrics by weighted accumulator merge. The served
    /// `metrics.instructions` still counts every trace row.
    pub plan: Option<String>,
    /// Client-supplied trace id for cross-system correlation (echoed in
    /// the outcome and threaded through the daemon's `--log-json`
    /// lines). `None` lets the server mint one at admission. Restricted
    /// to 1–64 `[A-Za-z0-9_-]` chars so ids stay greppable and cannot
    /// inject into log lines.
    pub trace_id: Option<String>,
}

/// Largest integer the JSON number channel carries exactly (`f64`
/// mantissa). User-controlled u64 fields are rejected above this
/// rather than silently rounded.
pub const MAX_SAFE_JSON_INT: u64 = 1 << 53;

/// Render a full-range `u64` as fixed-width lowercase hex. Cache keys
/// and artifact fingerprints occupy all 64 bits, which a JSON number
/// cannot carry exactly (see [`MAX_SAFE_JSON_INT`]) — they travel as
/// hex strings on the wire.
pub fn u64_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`u64_hex`] (any 1–16 hex digits accepted).
pub fn u64_from_hex(s: &str) -> Result<u64> {
    ensure!(!s.is_empty() && s.len() <= 16, "hex u64 {s:?} out of range");
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 {s:?}"))
}

/// Lowercase hex of an arbitrary byte payload (accumulator frames).
pub fn bytes_hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Inverse of [`bytes_hex`].
pub fn bytes_from_hex(s: &str) -> Result<Vec<u8>> {
    ensure!(s.len() % 2 == 0, "hex payload has odd length {}", s.len());
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .with_context(|| format!("bad hex byte at offset {i}"))
        })
        .collect()
}

impl JobSpec {
    /// Parse a `/v1/simulate` body.
    pub fn from_json(text: &str) -> Result<JobSpec> {
        let j = Json::parse(text).context("malformed JSON body")?;
        let trace = j.get("trace").and_then(Json::as_str).map(str::to_string);
        if trace.is_some() {
            // The trace header is the source of truth for both.
            ensure!(
                j.get("bench").is_none() && j.get("insts").is_none(),
                "trace jobs take bench and insts from the trace header; omit both"
            );
        }
        let plan = j.get("plan").and_then(Json::as_str).map(str::to_string);
        ensure!(
            plan.is_none() || trace.is_some(),
            "plan selects representative slices of a recorded trace; it requires trace"
        );
        let spec = JobSpec {
            bench: match trace {
                Some(_) => String::new(),
                None => j.req_str("bench")?.to_string(),
            },
            insts: match trace {
                Some(_) => 0,
                None => j.req_u64("insts")?,
            },
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(42),
            artifact: j.req_str("artifact")?.to_string(),
            chunk: j.get("chunk").and_then(Json::as_u64).unwrap_or(DEFAULT_CHUNK as u64)
                as usize,
            ctx_uarch: j.get("ctx_uarch").and_then(Json::as_str).map(str::to_string),
            deadline_ms: j.get("deadline_ms").and_then(Json::as_u64),
            trace,
            plan,
            trace_id: j.get("trace_id").and_then(Json::as_str).map(str::to_string),
        };
        if let Some(id) = &spec.trace_id {
            ensure!(
                !id.is_empty()
                    && id.len() <= 64
                    && id.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-'),
                "trace_id must be 1-64 chars of [A-Za-z0-9_-]"
            );
        }
        ensure!(spec.trace.is_some() || spec.insts >= 1, "insts must be positive");
        ensure!(spec.chunk >= 1, "chunk must be positive");
        ensure!(spec.deadline_ms != Some(0), "deadline_ms must be positive");
        for (name, v) in [
            ("insts", spec.insts),
            ("seed", spec.seed),
            ("chunk", spec.chunk as u64),
            ("deadline_ms", spec.deadline_ms.unwrap_or(0)),
        ] {
            ensure!(
                v <= MAX_SAFE_JSON_INT,
                "{name} {v} exceeds the exact JSON integer range (2^53)"
            );
        }
        Ok(spec)
    }

    /// Render as a `/v1/simulate` body.
    pub fn to_json(&self) -> String {
        let mut pairs = if self.trace.is_some() {
            vec![
                ("seed", Json::of_u64(self.seed)),
                ("artifact", Json::of_str(&self.artifact)),
                ("chunk", Json::of_u64(self.chunk as u64)),
            ]
        } else {
            vec![
                ("bench", Json::of_str(&self.bench)),
                ("insts", Json::of_u64(self.insts)),
                ("seed", Json::of_u64(self.seed)),
                ("artifact", Json::of_str(&self.artifact)),
                ("chunk", Json::of_u64(self.chunk as u64)),
            ]
        };
        if let Some(t) = &self.trace {
            pairs.push(("trace", Json::of_str(t)));
        }
        if let Some(p) = &self.plan {
            pairs.push(("plan", Json::of_str(p)));
        }
        if let Some(u) = &self.ctx_uarch {
            pairs.push(("ctx_uarch", Json::of_str(u)));
        }
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::of_u64(d)));
        }
        if let Some(id) = &self.trace_id {
            pairs.push(("trace_id", Json::of_str(id)));
        }
        Json::obj(pairs).render()
    }
}

/// Resolve a [`JobSpec::ctx_uarch`] selector: a µarch preset name or
/// `design:<index>` into the paper's Table 3 design space.
pub fn resolve_ctx_uarch(spec: &str) -> Result<UarchConfig> {
    if let Some(idx) = spec.strip_prefix("design:") {
        let idx: u64 = idx.parse().with_context(|| format!("bad design index {idx:?}"))?;
        let space = crate::dse::DesignSpace::table3();
        ensure!(
            idx < space.count(),
            "design index {idx} out of range (Table 3 has {})",
            space.count()
        );
        return Ok(space.design(idx));
    }
    UarchConfig::preset(spec).with_context(|| format!("unknown uarch {spec:?}"))
}

/// A completed job's response body.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    /// Server-assigned job id.
    pub job_id: u64,
    /// Predicted run-level metrics.
    pub metrics: Metrics,
    /// Windows this job contributed to packed batches (cache hits
    /// contribute none).
    pub windows: u64,
    /// Prediction-cache chunk hits for this job.
    pub cache_hits: u64,
    /// Prediction-cache chunk misses for this job.
    pub cache_misses: u64,
    /// Wall-clock from admission to completion, milliseconds.
    pub elapsed_ms: f64,
    /// The job's trace id (client-supplied or server-minted): the grep
    /// key tying this response to the daemon's `--log-json` lines.
    pub trace_id: String,
}

fn metrics_json(m: &Metrics) -> Json {
    Json::obj([
        ("instructions", Json::of_u64(m.instructions)),
        ("cycles", Json::Num(m.cycles)),
        ("mispredicts", Json::Num(m.mispredicts)),
        ("l1d_misses", Json::Num(m.l1d_misses)),
        ("l1i_misses", Json::Num(m.l1i_misses)),
        ("tlb_misses", Json::Num(m.tlb_misses)),
        ("cpi", Json::Num(m.cpi())),
    ])
}

fn metrics_from_json(j: &Json) -> Result<Metrics> {
    Ok(Metrics {
        instructions: j.req_u64("instructions")?,
        cycles: j.req_f64("cycles")?,
        mispredicts: j.req_f64("mispredicts")?,
        l1d_misses: j.req_f64("l1d_misses")?,
        l1i_misses: j.req_f64("l1i_misses")?,
        tlb_misses: j.req_f64("tlb_misses")?,
    })
}

impl JobOutcome {
    /// Render the 200 response body.
    pub fn to_json(&self) -> String {
        Json::obj([
            ("job_id", Json::of_u64(self.job_id)),
            ("metrics", metrics_json(&self.metrics)),
            ("windows", Json::of_u64(self.windows)),
            (
                "cache",
                Json::obj([
                    ("hits", Json::of_u64(self.cache_hits)),
                    ("misses", Json::of_u64(self.cache_misses)),
                ]),
            ),
            ("elapsed_ms", Json::Num(self.elapsed_ms)),
            ("trace_id", Json::of_str(&self.trace_id)),
        ])
        .render()
    }

    /// Parse a 200 response body.
    pub fn from_json(text: &str) -> Result<JobOutcome> {
        let j = Json::parse(text).context("malformed job outcome")?;
        let cache = j.get("cache").context("missing cache")?;
        Ok(JobOutcome {
            job_id: j.req_u64("job_id")?,
            metrics: metrics_from_json(j.get("metrics").context("missing metrics")?)?,
            windows: j.req_u64("windows")?,
            cache_hits: cache.req_u64("hits")?,
            cache_misses: cache.req_u64("misses")?,
            elapsed_ms: j.req_f64("elapsed_ms")?,
            // Absent from pre-telemetry daemons' bodies; empty then.
            trace_id: j
                .get("trace_id")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
        })
    }
}

/// An error response body (any non-200 status).
pub fn error_body(message: &str, retryable: bool) -> String {
    Json::obj([
        ("error", Json::of_str(message)),
        ("retryable", Json::Bool(retryable)),
    ])
    .render()
}

/// Parse an error body's `retryable` flag (false when absent/garbled).
pub fn error_retryable(text: &str) -> bool {
    Json::parse(text)
        .ok()
        .and_then(|j| match j.get("retryable") {
            Some(Json::Bool(b)) => Some(*b),
            _ => None,
        })
        .unwrap_or(false)
}

/// The serving error taxonomy. Every non-200 response carries one of
/// these codes; the code alone fixes the HTTP status and whether a
/// retry can succeed, so clients never have to pattern-match message
/// strings (docs/SERVE.md "Failure semantics" tabulates all of them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed or unresolvable request (bad JSON, unknown
    /// bench/artifact, over admission limits).
    BadRequest,
    /// The client stalled past the per-connection read timeout.
    RequestTimeout,
    /// Request header or body exceeds the server's size limits.
    TooLarge,
    /// Admission queue full — back off and retry.
    QueueFull,
    /// The daemon is draining and admits nothing new.
    Draining,
    /// The job's lane thread failed or is restarting; the job did not
    /// run (or did not complete) and is safe to resubmit.
    LaneFailed,
    /// A packed model batch failed to execute; the affected jobs are
    /// safe to resubmit.
    ExecFailed,
    /// The job's deadline expired before it completed.
    DeadlineExceeded,
    /// The job itself failed deterministically (e.g. its trace chunk
    /// would not decode) — resubmitting the same spec fails again.
    JobFailed,
    /// Unexpected server-side failure.
    Internal,
}

impl ErrorCode {
    /// The HTTP status this code travels under.
    pub fn http_status(self) -> u16 {
        match self {
            ErrorCode::BadRequest => 400,
            ErrorCode::RequestTimeout => 408,
            ErrorCode::TooLarge => 413,
            ErrorCode::QueueFull => 429,
            ErrorCode::Draining | ErrorCode::LaneFailed | ErrorCode::ExecFailed => 503,
            ErrorCode::DeadlineExceeded => 504,
            ErrorCode::JobFailed | ErrorCode::Internal => 500,
        }
    }

    /// Can an identical resubmission succeed?
    pub fn retryable(self) -> bool {
        matches!(
            self,
            ErrorCode::QueueFull
                | ErrorCode::Draining
                | ErrorCode::LaneFailed
                | ErrorCode::ExecFailed
                | ErrorCode::DeadlineExceeded
        )
    }

    /// Wire name (the body's `code` field).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::RequestTimeout => "request_timeout",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Draining => "draining",
            ErrorCode::LaneFailed => "lane_failed",
            ErrorCode::ExecFailed => "exec_failed",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::JobFailed => "job_failed",
            ErrorCode::Internal => "internal",
        }
    }

    /// Inverse of [`ErrorCode::as_str`].
    pub fn from_str(name: &str) -> Option<ErrorCode> {
        ALL_CODES.iter().copied().find(|c| c.as_str() == name)
    }
}

const ALL_CODES: [ErrorCode; 10] = [
    ErrorCode::BadRequest,
    ErrorCode::RequestTimeout,
    ErrorCode::TooLarge,
    ErrorCode::QueueFull,
    ErrorCode::Draining,
    ErrorCode::LaneFailed,
    ErrorCode::ExecFailed,
    ErrorCode::DeadlineExceeded,
    ErrorCode::JobFailed,
    ErrorCode::Internal,
];

/// A typed serving error: taxonomy code + human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeError {
    /// Taxonomy code (fixes status + retryability).
    pub code: ErrorCode,
    /// Human-readable detail for logs; carries no contract.
    pub message: String,
}

impl ServeError {
    /// Construct.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServeError {
        ServeError { code, message: message.into() }
    }

    /// Render the response body. Keeps the legacy `retryable` flag so
    /// older clients (`error_retryable`) classify correctly.
    pub fn to_json(&self) -> String {
        Json::obj([
            ("code", Json::of_str(self.code.as_str())),
            ("error", Json::of_str(&self.message)),
            ("retryable", Json::Bool(self.code.retryable())),
        ])
        .render()
    }

    /// Classify a non-200 response. Falls back to the HTTP status when
    /// the body carries no recognizable code (proxy/garbled bodies).
    pub fn from_body(status: u16, text: &str) -> ServeError {
        let j = Json::parse(text).ok();
        let code = j
            .as_ref()
            .and_then(|j| j.get("code"))
            .and_then(Json::as_str)
            .and_then(ErrorCode::from_str)
            .unwrap_or(match status {
                400 => ErrorCode::BadRequest,
                408 => ErrorCode::RequestTimeout,
                413 => ErrorCode::TooLarge,
                429 => ErrorCode::QueueFull,
                503 => ErrorCode::Draining,
                504 => ErrorCode::DeadlineExceeded,
                _ => ErrorCode::Internal,
            });
        let message = j
            .as_ref()
            .and_then(|j| j.get("error"))
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| text.to_string());
        ServeError { code, message }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

/// Snapshot of the daemon's serving counters (`GET /v1/stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Jobs accepted into the queue.
    pub jobs_submitted: u64,
    /// Jobs completed (response sent).
    pub jobs_done: u64,
    /// Jobs rejected by admission control (queue full / draining).
    pub jobs_rejected: u64,
    /// Jobs queued, not yet admitted to a lane.
    pub queue_depth: u64,
    /// Jobs currently active inside lanes.
    pub active_jobs: u64,
    /// Model batches executed.
    pub batches: u64,
    /// Windows packed into those batches.
    pub packed_windows: u64,
    /// Slots available in those batches (Σ per-lane `B`).
    pub batch_slots: u64,
    /// Prediction-cache hits.
    pub cache_hits: u64,
    /// Prediction-cache misses.
    pub cache_misses: u64,
    /// Prediction-cache evictions.
    pub cache_evictions: u64,
    /// Prediction-cache resident entries.
    pub cache_entries: u64,
    /// Prediction-cache entries warm-loaded from the journal at start.
    pub cache_recovered: u64,
    /// Lane threads respawned after a panic or fatal lane error.
    pub lane_restarts: u64,
}

impl StatsSnapshot {
    /// Mean packed-batch occupancy in `[0, 1]` (1.0 when no batch ran).
    pub fn occupancy(&self) -> f64 {
        if self.batch_slots == 0 {
            1.0
        } else {
            self.packed_windows as f64 / self.batch_slots as f64
        }
    }

    /// Counter-wise difference (`self - earlier`) for phase deltas.
    pub fn delta_from(&self, earlier: &StatsSnapshot) -> StatsSnapshot {
        StatsSnapshot {
            jobs_submitted: self.jobs_submitted - earlier.jobs_submitted,
            jobs_done: self.jobs_done - earlier.jobs_done,
            jobs_rejected: self.jobs_rejected - earlier.jobs_rejected,
            queue_depth: self.queue_depth,
            active_jobs: self.active_jobs,
            batches: self.batches - earlier.batches,
            packed_windows: self.packed_windows - earlier.packed_windows,
            batch_slots: self.batch_slots - earlier.batch_slots,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            cache_evictions: self.cache_evictions - earlier.cache_evictions,
            cache_entries: self.cache_entries,
            cache_recovered: self.cache_recovered,
            lane_restarts: self.lane_restarts - earlier.lane_restarts,
        }
    }

    /// Render the `/v1/stats` body.
    pub fn to_json(&self) -> String {
        self.json_obj().render()
    }

    /// Render the `/v1/stats` body with the daemon's per-lane detail
    /// appended under `"lanes"`. [`StatsSnapshot::from_json`] reads
    /// only the scalar fields, so clients parse both shapes unchanged.
    pub fn to_json_with_lanes(&self, lanes: Json) -> String {
        self.to_json_with(vec![("lanes", lanes)])
    }

    /// Render the `/v1/stats` body with arbitrary extra top-level
    /// sections appended (per-lane detail, per-artifact cache tenancy,
    /// the router's per-worker rollup). [`StatsSnapshot::from_json`]
    /// reads only the scalar fields, so every client parses every
    /// shape unchanged.
    pub fn to_json_with(&self, extras: Vec<(&str, Json)>) -> String {
        match self.json_obj() {
            Json::Obj(mut m) => {
                for (k, v) in extras {
                    m.insert(k.to_string(), v);
                }
                Json::Obj(m).render()
            }
            _ => unreachable!("json_obj always builds an object"),
        }
    }

    fn json_obj(&self) -> Json {
        Json::obj([
            ("jobs_submitted", Json::of_u64(self.jobs_submitted)),
            ("jobs_done", Json::of_u64(self.jobs_done)),
            ("jobs_rejected", Json::of_u64(self.jobs_rejected)),
            ("queue_depth", Json::of_u64(self.queue_depth)),
            ("active_jobs", Json::of_u64(self.active_jobs)),
            ("batches", Json::of_u64(self.batches)),
            ("packed_windows", Json::of_u64(self.packed_windows)),
            ("batch_slots", Json::of_u64(self.batch_slots)),
            ("occupancy", Json::Num(self.occupancy())),
            ("cache_hits", Json::of_u64(self.cache_hits)),
            ("cache_misses", Json::of_u64(self.cache_misses)),
            ("cache_evictions", Json::of_u64(self.cache_evictions)),
            ("cache_entries", Json::of_u64(self.cache_entries)),
            ("cache_recovered", Json::of_u64(self.cache_recovered)),
            ("lane_restarts", Json::of_u64(self.lane_restarts)),
        ])
    }

    /// Parse a `/v1/stats` body.
    pub fn from_json(text: &str) -> Result<StatsSnapshot> {
        let j = Json::parse(text).context("malformed stats")?;
        Ok(StatsSnapshot {
            jobs_submitted: j.req_u64("jobs_submitted")?,
            jobs_done: j.req_u64("jobs_done")?,
            jobs_rejected: j.req_u64("jobs_rejected")?,
            queue_depth: j.req_u64("queue_depth")?,
            active_jobs: j.req_u64("active_jobs")?,
            batches: j.req_u64("batches")?,
            packed_windows: j.req_u64("packed_windows")?,
            batch_slots: j.req_u64("batch_slots")?,
            cache_hits: j.req_u64("cache_hits")?,
            cache_misses: j.req_u64("cache_misses")?,
            cache_evictions: j.req_u64("cache_evictions")?,
            cache_entries: j.req_u64("cache_entries")?,
            cache_recovered: j.req_u64("cache_recovered")?,
            lane_restarts: j.req_u64("lane_restarts")?,
        })
    }
}

/// One artifact's registry entry (`GET /v1/artifacts`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    /// Registry name requests use.
    pub name: String,
    /// `"tao"` or `"simnet"`.
    pub kind: String,
    /// Fixed model batch `B`.
    pub batch: u64,
    /// Context window `T`.
    pub context: u64,
    /// Content fingerprint of the artifact bytes — identical across
    /// every daemon that loaded the same model, which is what lets the
    /// router key its hash ring on it. `None` when listing a
    /// pre-router daemon that does not advertise one.
    pub fingerprint: Option<u64>,
}

impl ArtifactInfo {
    /// True for SimNet artifacts (which need `ctx_uarch`).
    pub fn is_simnet(&self) -> bool {
        self.kind == "simnet"
    }
}

/// Render the `/v1/artifacts` body from the daemon's pool.
pub fn artifacts_json(pool: &crate::runtime::ArtifactPool) -> String {
    let items: Vec<Json> = pool
        .iter()
        .map(|a| {
            Json::obj([
                ("name", Json::of_str(&a.name)),
                (
                    "kind",
                    Json::of_str(match a.meta.kind {
                        crate::runtime::ModelKind::Tao => "tao",
                        crate::runtime::ModelKind::SimNet => "simnet",
                    }),
                ),
                ("batch", Json::of_u64(a.meta.batch as u64)),
                ("context", Json::of_u64(a.meta.context as u64)),
                ("fingerprint", Json::of_str(&u64_hex(a.fingerprint))),
            ])
        })
        .collect();
    Json::obj([("artifacts", Json::Arr(items))]).render()
}

/// Parse a `/v1/artifacts` body.
pub fn artifacts_from_json(text: &str) -> Result<Vec<ArtifactInfo>> {
    let j = Json::parse(text).context("malformed artifacts body")?;
    let items = j
        .get("artifacts")
        .and_then(Json::as_arr)
        .context("missing artifacts array")?;
    items
        .iter()
        .map(|a| {
            Ok(ArtifactInfo {
                name: a.req_str("name")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                batch: a.req_u64("batch")?,
                context: a.req_u64("context")?,
                fingerprint: match a.get("fingerprint").and_then(Json::as_str) {
                    Some(hex) => Some(u64_from_hex(hex)?),
                    None => None,
                },
            })
        })
        .collect()
}

/// Render a `POST /v1/cache/lookup` request body for one chunk key.
pub fn cache_lookup_json(key: &crate::serve::cache::ChunkKey) -> String {
    Json::obj([
        ("artifact", Json::of_str(&u64_hex(key.artifact))),
        ("prefix", Json::of_str(&u64_hex(key.prefix))),
        ("content", Json::of_str(&u64_hex(key.content))),
    ])
    .render()
}

/// Parse a `POST /v1/cache/lookup` request body.
pub fn cache_lookup_from_json(text: &str) -> Result<crate::serve::cache::ChunkKey> {
    let j = Json::parse(text).context("malformed cache lookup")?;
    Ok(crate::serve::cache::ChunkKey {
        artifact: u64_from_hex(j.req_str("artifact")?)?,
        prefix: u64_from_hex(j.req_str("prefix")?)?,
        content: u64_from_hex(j.req_str("content")?)?,
    })
}

/// Render a `/v1/cache/lookup` hit response: the resident accumulator's
/// journal frame ([`PredAccum::encode_journal`] bytes), hex-encoded.
///
/// [`PredAccum::encode_journal`]: crate::coordinator::engine::PredAccum::encode_journal
pub fn cache_found_json(payload: &[u8]) -> String {
    Json::obj([
        ("found", Json::Bool(true)),
        ("accum", Json::of_str(&bytes_hex(payload))),
    ])
    .render()
}

/// Render a `/v1/cache/lookup` miss response.
pub fn cache_miss_json() -> String {
    Json::obj([("found", Json::Bool(false))]).render()
}

/// Parse a `/v1/cache/lookup` response: `Some(journal-frame bytes)` on
/// a hit, `None` on a miss.
pub fn cache_result_from_json(text: &str) -> Result<Option<Vec<u8>>> {
    let j = Json::parse(text).context("malformed cache lookup response")?;
    match j.get("found") {
        Some(Json::Bool(true)) => Ok(Some(bytes_from_hex(j.req_str("accum")?)?)),
        Some(Json::Bool(false)) => Ok(None),
        _ => anyhow::bail!("cache lookup response missing found flag"),
    }
}

/// Admission ceiling for SimNet jobs, regardless of `--max-insts`.
/// Unlike Tao jobs (generator-backed, O(chunk) resident), a SimNet job
/// materializes its functional trace *and* its detailed-sim context
/// array up front (~51 B/instruction resident for the job's lifetime),
/// so the streaming-sized default limit would let a handful of
/// requests blow the daemon's memory envelope.
pub const SIMNET_MAX_INSTS: u64 = 1_000_000;

/// Validate a parsed spec against the server's registries (bench and
/// artifact existence, kind/ctx pairing, admission size limits).
/// Returns the artifact's model kind on success.
pub fn validate_spec(
    spec: &JobSpec,
    pool: &crate::runtime::ArtifactPool,
    max_insts: u64,
) -> Result<crate::runtime::ModelKind> {
    if let Some(trace) = &spec.trace {
        // Trace-replay admission: the artifact must be a Tao model and
        // the file must be a readable tao trace whose declared count
        // fits the admission limit. Foreign or truncated files are
        // refused here with the typed trace-error taxonomy, before the
        // job ever reaches a lane.
        let art = pool
            .get(&spec.artifact)
            .with_context(|| format!("unknown artifact {:?}", spec.artifact))?;
        ensure!(
            art.meta.kind == crate::runtime::ModelKind::Tao,
            "trace jobs require a Tao artifact (SimNet needs detailed-sim \
             context a recorded trace does not carry)"
        );
        let (_, name, records) = crate::trace::trace_header(std::path::Path::new(trace))?;
        ensure!(records >= 1, "trace {trace:?} declares zero records");
        ensure!(
            records <= max_insts,
            "trace {trace:?} declares {records} insts, exceeding the \
             admission limit {max_insts}"
        );
        if let Some(plan) = &spec.plan {
            // Sampled-replay admission: the sidecar must parse (magic +
            // CRC + invariants) and describe exactly this trace, so a
            // stale or foreign plan is a 400, not a lane failure.
            let plan = crate::sampling::SamplingPlan::load(std::path::Path::new(plan))?;
            plan.check_matches(&name, records)?;
        }
        return Ok(art.meta.kind);
    }
    ensure!(
        crate::workloads::by_name(&spec.bench).is_some(),
        "unknown benchmark {:?}",
        spec.bench
    );
    ensure!(
        spec.insts <= max_insts,
        "insts {} exceeds the admission limit {max_insts}",
        spec.insts
    );
    let art = pool
        .get(&spec.artifact)
        .with_context(|| format!("unknown artifact {:?}", spec.artifact))?;
    match art.meta.kind {
        crate::runtime::ModelKind::SimNet => {
            let cap = max_insts.min(SIMNET_MAX_INSTS);
            ensure!(
                spec.insts <= cap,
                "insts {} exceeds the SimNet admission limit {cap} \
                 (SimNet jobs hold their trace + detailed context resident)",
                spec.insts
            );
            let sel = spec
                .ctx_uarch
                .as_deref()
                .context("SimNet artifacts require ctx_uarch (a preset or design:<index>)")?;
            resolve_ctx_uarch(sel)?;
        }
        crate::runtime::ModelKind::Tao => {}
    }
    Ok(art.meta.kind)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_round_trips() {
        let spec = JobSpec {
            bench: "mcf".into(),
            insts: 5_000,
            seed: 7,
            artifact: "tao_a".into(),
            chunk: 257,
            ctx_uarch: Some("design:123".into()),
            deadline_ms: Some(5_000),
            trace: None,
            plan: None,
            trace_id: Some("client-abc_123".into()),
        };
        assert_eq!(JobSpec::from_json(&spec.to_json()).unwrap(), spec);
        // Hostile trace ids are refused before admission.
        for bad in ["", "has space", "quo\"te", &"x".repeat(65)] {
            let body = format!(
                r#"{{"bench":"mcf","insts":10,"artifact":"x","trace_id":{}}}"#,
                Json::of_str(bad).render()
            );
            assert!(JobSpec::from_json(&body).is_err(), "trace_id {bad:?} must be rejected");
        }
        // Trace jobs: bench/insts come from the file, so the wire body
        // must omit them — and the round trip preserves the path.
        let tspec = JobSpec {
            bench: String::new(),
            insts: 0,
            seed: 7,
            artifact: "tao_a".into(),
            chunk: 257,
            ctx_uarch: None,
            deadline_ms: None,
            trace: Some("/tmp/mcf.trace".into()),
            plan: None,
            trace_id: None,
        };
        assert_eq!(JobSpec::from_json(&tspec.to_json()).unwrap(), tspec);
        assert!(
            JobSpec::from_json(r#"{"bench":"mcf","artifact":"x","trace":"t"}"#).is_err(),
            "bench alongside trace must be rejected"
        );
        assert!(
            JobSpec::from_json(r#"{"insts":5,"artifact":"x","trace":"t"}"#).is_err(),
            "insts alongside trace must be rejected"
        );
        // Sampled replay: the plan sidecar rides the trace path.
        let pspec = JobSpec {
            plan: Some("/tmp/mcf.plan".into()),
            ..tspec.clone()
        };
        assert_eq!(JobSpec::from_json(&pspec.to_json()).unwrap(), pspec);
        assert!(
            JobSpec::from_json(r#"{"bench":"mcf","insts":5,"artifact":"x","plan":"p"}"#)
                .is_err(),
            "plan without trace must be rejected"
        );
        // Defaults fill in.
        let min = JobSpec::from_json(r#"{"bench":"mcf","insts":10,"artifact":"x"}"#).unwrap();
        assert_eq!(min.seed, 42);
        assert_eq!(min.chunk, DEFAULT_CHUNK);
        assert_eq!(min.ctx_uarch, None);
        assert_eq!(min.deadline_ms, None);
        // Degenerate values rejected.
        assert!(JobSpec::from_json(r#"{"bench":"mcf","insts":0,"artifact":"x"}"#).is_err());
        assert!(
            JobSpec::from_json(r#"{"bench":"mcf","insts":1,"artifact":"x","chunk":0}"#).is_err()
        );
        assert!(JobSpec::from_json(
            r#"{"bench":"mcf","insts":1,"artifact":"x","deadline_ms":0}"#
        )
        .is_err());
        assert!(JobSpec::from_json("{nope").is_err());
        // Integers past the exact f64 range are rejected, not rounded.
        let big = format!(
            r#"{{"bench":"mcf","insts":10,"artifact":"x","seed":{}}}"#,
            (1u64 << 53) + 2
        );
        assert!(JobSpec::from_json(&big).is_err(), "oversized seed must be rejected");
    }

    #[test]
    fn job_outcome_round_trips_exact_metrics() {
        let out = JobOutcome {
            job_id: 9,
            metrics: Metrics {
                instructions: 12_345,
                cycles: 98765.432109876,
                mispredicts: 1.0 / 3.0,
                l1d_misses: 0.1 + 0.2,
                l1i_misses: 0.0,
                tlb_misses: 17.25,
            },
            windows: 12_000,
            cache_hits: 2,
            cache_misses: 3,
            elapsed_ms: 12.5,
            trace_id: "9f3c0000aa11bb22".into(),
        };
        let back = JobOutcome::from_json(&out.to_json()).unwrap();
        assert_eq!(back.metrics.cycles.to_bits(), out.metrics.cycles.to_bits());
        assert_eq!(back.metrics.mispredicts.to_bits(), out.metrics.mispredicts.to_bits());
        assert_eq!(back, out);
    }

    #[test]
    fn stats_round_trip_and_occupancy() {
        let s = StatsSnapshot {
            batches: 10,
            packed_windows: 600,
            batch_slots: 640,
            ..Default::default()
        };
        assert!((s.occupancy() - 0.9375).abs() < 1e-12);
        let back = StatsSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let earlier = StatsSnapshot {
            batches: 4,
            packed_windows: 100,
            batch_slots: 256,
            ..Default::default()
        };
        let d = back.delta_from(&earlier);
        assert_eq!(d.batches, 6);
        assert_eq!(d.packed_windows, 500);
    }

    #[test]
    fn ctx_uarch_selectors_resolve() {
        assert_eq!(resolve_ctx_uarch("a").unwrap().name, "uarch_a");
        let d = resolve_ctx_uarch("design:12345").unwrap();
        assert_eq!(d.name, "design_12345");
        assert!(resolve_ctx_uarch("design:999999999").is_err());
        assert!(resolve_ctx_uarch("design:abc").is_err());
        assert!(resolve_ctx_uarch("zz").is_err());
    }

    #[test]
    fn error_bodies_carry_retryability() {
        assert!(error_retryable(&error_body("queue full", true)));
        assert!(!error_retryable(&error_body("bad request", false)));
        assert!(!error_retryable("garbage"));
    }

    #[test]
    fn serve_errors_round_trip_and_classify() {
        for code in ALL_CODES {
            assert_eq!(ErrorCode::from_str(code.as_str()), Some(code));
            let err = ServeError::new(code, format!("probe {}", code.as_str()));
            let back = ServeError::from_body(code.http_status(), &err.to_json());
            assert_eq!(back, err);
            // The legacy flag matches the taxonomy.
            assert_eq!(error_retryable(&err.to_json()), code.retryable());
        }
        assert_eq!(ErrorCode::from_str("nope"), None);
        // Garbled bodies fall back to the HTTP status.
        assert_eq!(ServeError::from_body(429, "garbage").code, ErrorCode::QueueFull);
        assert_eq!(ServeError::from_body(504, "").code, ErrorCode::DeadlineExceeded);
        assert_eq!(ServeError::from_body(500, "{}").code, ErrorCode::Internal);
        // Retryability is exactly the transient set.
        assert!(ErrorCode::QueueFull.retryable());
        assert!(ErrorCode::LaneFailed.retryable());
        assert!(ErrorCode::DeadlineExceeded.retryable());
        assert!(!ErrorCode::JobFailed.retryable());
        assert!(!ErrorCode::BadRequest.retryable());
        assert_eq!(
            ServeError::new(ErrorCode::ExecFailed, "batch died").to_string(),
            "exec_failed: batch died"
        );
    }

    #[test]
    fn artifact_listing_round_trips() {
        let dir = std::env::temp_dir().join(format!("tao-proto-{}", std::process::id()));
        let a = crate::runtime::write_surrogate_artifact(&dir, "al_tao", 16, 8).unwrap();
        let b = crate::runtime::write_surrogate_artifact_kind(
            &dir,
            "al_sn",
            crate::runtime::ModelKind::SimNet,
            32,
            4,
        )
        .unwrap();
        let pool = crate::runtime::ArtifactPool::load(&[a, b]).unwrap();
        let infos = artifacts_from_json(&artifacts_json(&pool)).unwrap();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "al_tao");
        assert!(!infos[0].is_simnet());
        assert_eq!(infos[0].batch, 16);
        assert!(infos[1].is_simnet());
        assert_eq!(infos[1].context, 4);
    }

    #[test]
    fn cache_lookup_wire_round_trips() {
        // Keys travel as hex strings so full-range u64s survive the
        // f64-backed JSON number representation.
        let key = crate::serve::cache::ChunkKey {
            artifact: u64::MAX,
            prefix: 0,
            content: 0x9f3c_0000_aa11_bb22,
        };
        assert_eq!(cache_lookup_from_json(&cache_lookup_json(&key)).unwrap(), key);

        assert_eq!(u64_from_hex(&u64_hex(u64::MAX)).unwrap(), u64::MAX);
        assert_eq!(u64_from_hex(&u64_hex(0)).unwrap(), 0);
        assert!(u64_from_hex("").is_err());
        assert!(u64_from_hex("12345678901234567").is_err(), "17 digits overflow");
        assert!(u64_from_hex("xy").is_err());

        let payload: Vec<u8> = (0..=255).collect();
        assert_eq!(bytes_from_hex(&bytes_hex(&payload)).unwrap(), payload);
        assert!(bytes_from_hex("abc").is_err(), "odd length");
        assert!(bytes_from_hex("zz").is_err());

        assert_eq!(
            cache_result_from_json(&cache_found_json(&payload)).unwrap(),
            Some(payload)
        );
        assert_eq!(cache_result_from_json(&cache_miss_json()).unwrap(), None);
        assert!(cache_result_from_json("{}").is_err());
    }

    #[test]
    fn validate_spec_checks_registries() {
        let dir = std::env::temp_dir()
            .join(format!("tao-proto-{}", std::process::id()));
        let tao =
            crate::runtime::write_surrogate_artifact(&dir, "vp_tao", 4, 8).unwrap();
        let sn = crate::runtime::write_surrogate_artifact_kind(
            &dir,
            "vp_sn",
            crate::runtime::ModelKind::SimNet,
            4,
            8,
        )
        .unwrap();
        let pool = crate::runtime::ArtifactPool::load(&[tao, sn]).unwrap();
        let mut spec = JobSpec {
            bench: "mcf".into(),
            insts: 100,
            seed: 1,
            artifact: "vp_tao".into(),
            chunk: 64,
            ctx_uarch: None,
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        };
        assert_eq!(
            validate_spec(&spec, &pool, 1_000).unwrap(),
            crate::runtime::ModelKind::Tao
        );
        spec.insts = 2_000;
        assert!(validate_spec(&spec, &pool, 1_000).is_err(), "admission size limit");
        spec.insts = 100;
        spec.bench = "nope".into();
        assert!(validate_spec(&spec, &pool, 1_000).is_err());
        spec.bench = "mcf".into();
        spec.artifact = "missing".into();
        assert!(validate_spec(&spec, &pool, 1_000).is_err());
        spec.artifact = "vp_sn".into();
        assert!(validate_spec(&spec, &pool, 1_000).is_err(), "SimNet needs ctx_uarch");
        spec.ctx_uarch = Some("b".into());
        assert_eq!(
            validate_spec(&spec, &pool, 1_000).unwrap(),
            crate::runtime::ModelKind::SimNet
        );
        // SimNet jobs get the tighter resident-trace ceiling even when
        // the general limit is huge.
        spec.insts = SIMNET_MAX_INSTS + 1;
        assert!(validate_spec(&spec, &pool, u64::MAX).is_err(), "SimNet resident cap");
        spec.artifact = "vp_tao".into();
        spec.ctx_uarch = None;
        assert!(validate_spec(&spec, &pool, u64::MAX).is_ok(), "Tao streams past the cap");

        // Trace-replay admission: Tao-only, header-driven size check,
        // typed foreign-file refusal.
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("vp.trace");
        let cols = crate::functional::FunctionalSim::new(
            &crate::workloads::by_name("dee").unwrap().build(3),
        )
        .run(200)
        .to_columns();
        crate::trace::TraceWriteOptions::new(crate::trace::TraceFormat::V2)
            .write(&trace, "dee", &cols)
            .unwrap();
        let tspec = JobSpec {
            bench: String::new(),
            insts: 0,
            seed: 1,
            artifact: "vp_tao".into(),
            chunk: 64,
            ctx_uarch: None,
            deadline_ms: None,
            trace: Some(trace.to_string_lossy().into_owned()),
            plan: None,
            trace_id: None,
        };
        assert_eq!(
            validate_spec(&tspec, &pool, 1_000).unwrap(),
            crate::runtime::ModelKind::Tao
        );
        assert!(
            validate_spec(&tspec, &pool, 100).is_err(),
            "declared trace count must respect the admission limit"
        );
        let mut sn_t = tspec.clone();
        sn_t.artifact = "vp_sn".into();
        sn_t.ctx_uarch = Some("b".into());
        assert!(validate_spec(&sn_t, &pool, 1_000).is_err(), "trace jobs are Tao-only");
        let foreign = dir.join("vp_foreign.trace");
        std::fs::write(&foreign, b"GARBAGE!!").unwrap();
        let mut f_t = tspec.clone();
        f_t.trace = Some(foreign.to_string_lossy().into_owned());
        let err = validate_spec(&f_t, &pool, 1_000).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<crate::trace::TraceError>(),
            Some(crate::trace::TraceError::Foreign { .. })
        ));

        // Sampled-replay admission: a plan for this trace passes; a
        // plan for a different trace (or a garbled sidecar) is refused
        // before the job reaches a lane.
        let good_plan = dir.join("vp.plan");
        crate::sampling::SamplingPlan::exhaustive("dee", 200, 50)
            .save(&good_plan)
            .unwrap();
        let mut p_t = tspec.clone();
        p_t.plan = Some(good_plan.to_string_lossy().into_owned());
        assert_eq!(
            validate_spec(&p_t, &pool, 1_000).unwrap(),
            crate::runtime::ModelKind::Tao
        );
        let stale_plan = dir.join("vp_stale.plan");
        crate::sampling::SamplingPlan::exhaustive("dee", 999, 50)
            .save(&stale_plan)
            .unwrap();
        p_t.plan = Some(stale_plan.to_string_lossy().into_owned());
        assert!(
            validate_spec(&p_t, &pool, 1_000).is_err(),
            "a plan for a different row count must be refused"
        );
        let junk_plan = dir.join("vp_junk.plan");
        std::fs::write(&junk_plan, b"NOTAPLAN").unwrap();
        p_t.plan = Some(junk_plan.to_string_lossy().into_owned());
        assert!(validate_spec(&p_t, &pool, 1_000).is_err(), "garbled sidecar refused");
    }
}
