//! Weighted consistent-hash ring for the router tier.
//!
//! Jobs are keyed by **artifact fingerprint** (the FNV-1a hash of the
//! artifact's model bytes, identical across every worker that loaded
//! the same model), so one artifact's traffic — and therefore its
//! prediction-cache working set — lands on one worker and stays there
//! as the fleet changes. Each member contributes `weight ×`
//! [`POINTS_PER_WEIGHT`] virtual points hashed onto a `u64` circle;
//! a key routes to the first point clockwise from its own hash.
//!
//! The consistent-hashing contract this module's tests pin down:
//! adding, removing, or re-weighting one member moves only the key
//! fraction proportional to the weight that changed — everything else
//! keeps its placement, which is what keeps the fleet's caches warm
//! through membership churn. [`HashRing::replicas`] returns the
//! successor walk (distinct members in ring order); the forwarder
//! fails over along it, and cache peering asks the next replica first.

use crate::util::hash::{fnv1a64, fnv1a64_u64, FNV_OFFSET};

/// Virtual points per unit of member weight. High enough that a
/// three-member ring splits keys within a few percent of the weight
/// ratio; low enough that rebuilding a fleet's ring stays trivial.
pub const POINTS_PER_WEIGHT: u32 = 64;

/// One ring member (a worker daemon).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Member {
    /// Member identity: the worker's `host:port`.
    pub name: String,
    /// Relative capacity; 0 keeps the member known but takes it out of
    /// the point set (drained/unhealthy).
    pub weight: u32,
}

/// A weighted consistent-hash ring.
#[derive(Debug, Clone, Default)]
pub struct HashRing {
    members: Vec<Member>,
    /// Sorted (point, member index) pairs — the circle.
    points: Vec<(u64, usize)>,
}

fn point_hash(name: &str, replica: u32) -> u64 {
    fnv1a64_u64(replica as u64, fnv1a64(name.as_bytes(), FNV_OFFSET))
}

impl HashRing {
    /// Empty ring.
    pub fn new() -> HashRing {
        HashRing::default()
    }

    /// Build a ring from members (last duplicate name wins).
    pub fn from_members(members: impl IntoIterator<Item = Member>) -> HashRing {
        let mut ring = HashRing::new();
        for m in members {
            ring.set(&m.name, m.weight);
        }
        ring
    }

    /// Insert or re-weight a member and rebuild the point set.
    /// Weight 0 keeps the member listed but contributes no points.
    pub fn set(&mut self, name: &str, weight: u32) {
        match self.members.iter_mut().find(|m| m.name == name) {
            Some(m) => m.weight = weight,
            None => self.members.push(Member { name: name.to_string(), weight }),
        }
        self.rebuild();
    }

    /// Remove a member entirely.
    pub fn remove(&mut self, name: &str) {
        self.members.retain(|m| m.name != name);
        self.rebuild();
    }

    /// The member list (stable insertion order).
    pub fn members(&self) -> &[Member] {
        &self.members
    }

    /// Members with at least one point on the circle.
    pub fn live_members(&self) -> usize {
        self.members.iter().filter(|m| m.weight > 0).count()
    }

    /// True when no member contributes points.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    fn rebuild(&mut self) {
        self.points.clear();
        for (idx, m) in self.members.iter().enumerate() {
            for r in 0..m.weight.saturating_mul(POINTS_PER_WEIGHT) {
                self.points.push((point_hash(&m.name, r), idx));
            }
        }
        // Point-hash ties across members are broken by member index so
        // the ordering (and therefore routing) is deterministic.
        self.points.sort_unstable();
    }

    /// The primary member for `key`: owner of the first point clockwise
    /// from the key's position on the circle.
    pub fn primary(&self, key: u64) -> Option<&str> {
        self.walk_from(key).next()
    }

    /// The failover walk for `key`: up to `n` *distinct* members in
    /// ring-successor order, primary first. Fewer are returned when the
    /// ring has fewer live members.
    pub fn replicas(&self, key: u64, n: usize) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::with_capacity(n);
        for name in self.walk_from(key) {
            if out.len() == n {
                break;
            }
            if !out.contains(&name) {
                out.push(name);
            }
        }
        out
    }

    /// Iterate member names point-by-point clockwise from `key`
    /// (repeats members — callers dedup).
    fn walk_from(&self, key: u64) -> impl Iterator<Item = &str> {
        let hashed = fnv1a64_u64(key, FNV_OFFSET);
        let start = self.points.partition_point(|&(p, _)| p < hashed);
        let n = self.points.len();
        (0..n).map(move |i| {
            let (_, idx) = self.points[(start + i) % n];
            self.members[idx].name.as_str()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn counts(ring: &HashRing, keys: u64) -> HashMap<String, u64> {
        let mut c = HashMap::new();
        for k in 0..keys {
            let name = ring.primary(k).expect("non-empty ring").to_string();
            *c.entry(name).or_insert(0) += 1;
        }
        c
    }

    fn three_workers() -> HashRing {
        HashRing::from_members(
            ["w1:1", "w2:1", "w3:1"]
                .map(|n| Member { name: n.into(), weight: 1 })
        )
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let a = three_workers();
        let b = three_workers();
        for k in 0..500u64 {
            assert_eq!(a.primary(k), b.primary(k));
            assert_eq!(a.replicas(k, 3), b.replicas(k, 3));
        }
        assert!(HashRing::new().primary(7).is_none());
        assert!(HashRing::new().replicas(7, 2).is_empty());
    }

    #[test]
    fn equal_weights_split_keys_roughly_evenly() {
        let ring = three_workers();
        let c = counts(&ring, 3000);
        for m in ring.members() {
            let share = *c.get(&m.name).unwrap_or(&0) as f64 / 3000.0;
            assert!(
                (share - 1.0 / 3.0).abs() < 0.12,
                "{} got {share:.3} of keys (want ~0.333)",
                m.name
            );
        }
    }

    #[test]
    fn double_weight_doubles_share() {
        let mut ring = three_workers();
        ring.set("w1:1", 2);
        let c = counts(&ring, 4000);
        let w1 = *c.get("w1:1").unwrap() as f64 / 4000.0;
        assert!((w1 - 0.5).abs() < 0.12, "weight-2 member got {w1:.3} (want ~0.5)");
    }

    #[test]
    fn removing_a_member_moves_only_its_keys() {
        let ring = three_workers();
        let before: Vec<String> =
            (0..2000u64).map(|k| ring.primary(k).unwrap().to_string()).collect();
        let mut smaller = ring.clone();
        smaller.remove("w2:1");
        let mut moved = 0u64;
        for (k, old) in before.iter().enumerate() {
            let new = smaller.primary(k as u64).unwrap();
            if old == "w2:1" {
                assert_ne!(new, "w2:1");
            } else {
                assert_eq!(new, old.as_str(), "key {k} moved although its owner stayed");
                continue;
            }
            moved += 1;
        }
        // Exactly the removed member's keys moved — about a third.
        let frac = moved as f64 / 2000.0;
        assert!((frac - 1.0 / 3.0).abs() < 0.12, "moved fraction {frac:.3}");
    }

    #[test]
    fn weight_change_moves_only_the_expected_fraction() {
        let ring = three_workers();
        let before: Vec<String> =
            (0..3000u64).map(|k| ring.primary(k).unwrap().to_string()).collect();
        // Bump one member 1 → 2: it should *gain* keys (about a share's
        // worth) and nothing should shuffle between the other two.
        let mut heavier = ring.clone();
        heavier.set("w3:1", 2);
        let mut moved = 0u64;
        for (k, old) in before.iter().enumerate() {
            let new = heavier.primary(k as u64).unwrap();
            if new != old.as_str() {
                assert_eq!(new, "w3:1", "keys may only move *to* the re-weighted member");
                moved += 1;
            }
        }
        let frac = moved as f64 / 3000.0;
        // 1/3 split becomes 2/4 = 1/2: expect ~1/6 of all keys to move.
        assert!(frac > 0.05 && frac < 0.30, "moved fraction {frac:.3} (want ~0.167)");
    }

    #[test]
    fn weight_zero_drains_without_forgetting() {
        let mut ring = three_workers();
        ring.set("w2:1", 0);
        assert_eq!(ring.members().len(), 3);
        assert_eq!(ring.live_members(), 2);
        for k in 0..500u64 {
            assert_ne!(ring.primary(k).unwrap(), "w2:1");
        }
        // Re-weighting restores the original placement exactly.
        ring.set("w2:1", 1);
        let fresh = three_workers();
        for k in 0..500u64 {
            assert_eq!(ring.primary(k), fresh.primary(k));
        }
    }

    #[test]
    fn replicas_are_distinct_and_start_at_primary() {
        let ring = three_workers();
        for k in 0..200u64 {
            let reps = ring.replicas(k, 3);
            assert_eq!(reps.len(), 3);
            assert_eq!(reps[0], ring.primary(k).unwrap());
            let mut uniq = reps.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "replicas must be distinct members");
        }
        // Asking for more replicas than members returns what exists.
        assert_eq!(ring.replicas(1, 8).len(), 3);
    }
}
