//! `tao loadgen` — replay mixed scenarios against a running daemon and
//! measure the serving economics: requests/sec, packed-batch occupancy
//! (concurrent vs solo), and chunk-cache hit rates, emitted as
//! `BENCH_serve.json` for the bench-trajectory gate.
//!
//! Three phases, each bracketed by `/v1/stats` snapshots:
//!
//! 1. **solo** — scenarios one at a time (disjoint seed space): every
//!    request pads its own tail windows, the per-request occupancy
//!    baseline.
//! 2. **concurrent cold** — the full mix from `threads` client
//!    threads: lanes pack windows across jobs, so occupancy rises and
//!    the tail padding amortizes across traffic.
//! 3. **concurrent warm** — the cold mix replayed verbatim: every
//!    chunk hits the prediction cache; model execution drops to zero.
//!
//! `--verify` recomputes every job offline through
//! [`simulate_chunked`](crate::coordinator::engine::simulate_chunked)
//! and demands *identical* metrics — cold and warm — which is the
//! serving subsystem's correctness contract.
//!
//! `--chaos` swaps the measurement sweep for a robustness soak
//! ([`run_chaos`] via [`run_loadgen`]): the mixed scenario set replays
//! twice from all client threads while ~2% of submissions stall
//! mid-body ([`Probe::SlowClient`]) — typically against a daemon with
//! its own probes armed via `TAO_FAULTS`. Retryable answers resubmit
//! with capped exponential backoff + deterministic jitter; the pass
//! criteria are the failure contract: every job ends *typed* (outcome
//! or [`ServeError`]), nothing hangs, and every success is still
//! bit-identical to the offline engine.

use super::http::{http_get, http_post, http_post_stalled};
use super::protocol::{
    artifacts_from_json, resolve_ctx_uarch, JobOutcome, JobSpec, ServeError, StatsSnapshot,
};
use super::ring::{HashRing, Member};
use crate::stats::Metrics;
use crate::telemetry::prometheus::{histogram_quantile, parse as parse_prom, sample_value};
use crate::util::benchkit::{BenchReport, Measurement};
use crate::util::fault::{self, Probe};
use crate::util::hash::{fnv1a64, FNV_OFFSET};
use crate::util::rng::Rng;
use crate::workloads::{mixed_scenarios, ScenarioArtifact, ScenarioJob};
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Loadgen options (see `tao loadgen --help`).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Daemon address (`host:port`).
    pub addr: String,
    /// Concurrent-phase job count.
    pub jobs: usize,
    /// Client threads in the concurrent phases.
    pub threads: usize,
    /// Solo-phase job count.
    pub solo_jobs: usize,
    /// Base trace length for the mix.
    pub insts: u64,
    /// Scenario seed base.
    pub seed: u64,
    /// Per-job chunk size (cache granularity).
    pub chunk: usize,
    /// Write `BENCH_serve.json` here.
    pub json_out: Option<PathBuf>,
    /// Verify every served result against the offline engine, loading
    /// artifacts from this directory.
    pub verify_models: Option<PathBuf>,
    /// Fail unless concurrent occupancy exceeds solo occupancy.
    pub assert_occupancy: bool,
    /// POST `/v1/shutdown` when done.
    pub shutdown_after: bool,
    /// Run the chaos soak instead of the measurement sweep.
    pub chaos: bool,
    /// Worker addresses behind the router at `addr` (`--targets`).
    /// When set, the sweep snapshots each worker's `/v1/stats` and
    /// reports the measured per-worker job distribution against the
    /// consistent-hash prediction. Ignored by `--chaos`.
    pub targets: Vec<String>,
    /// Fail unless each worker's measured job count equals the
    /// equal-weight consistent-hash placement (assumes a healthy fleet
    /// with no mid-sweep failover).
    pub assert_balance: bool,
    /// Print a periodic progress summary sourced from the daemon's
    /// `/metrics` exposition every this many seconds (`None` = quiet).
    pub progress_every: Option<Duration>,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            addr: "127.0.0.1:8080".into(),
            jobs: 24,
            threads: 8,
            solo_jobs: 6,
            insts: 150,
            seed: 42,
            chunk: 64,
            json_out: None,
            verify_models: None,
            assert_occupancy: false,
            shutdown_after: false,
            chaos: false,
            targets: Vec::new(),
            assert_balance: false,
            progress_every: None,
        }
    }
}

/// The routing key the router derives for an artifact: its
/// wire-reported fingerprint, falling back to the FNV-1a hash of the
/// registry name exactly as the router does against a fleet that
/// predates fingerprint reporting.
pub fn artifact_key(name: &str, fingerprint: Option<u64>) -> u64 {
    fingerprint.unwrap_or_else(|| fnv1a64(name.as_bytes(), FNV_OFFSET))
}

/// Predict each worker's job count for `specs` under equal-weight
/// consistent hashing — the router's placement when the whole fleet is
/// healthy and no failover fires. `keys` maps artifact name to routing
/// key ([`artifact_key`]); unlisted artifacts fall back to the name
/// hash, mirroring the router.
pub fn predict_balance<'a>(
    targets: &[String],
    keys: &HashMap<String, u64>,
    specs: impl IntoIterator<Item = &'a JobSpec>,
) -> BTreeMap<String, u64> {
    let ring = HashRing::from_members(
        targets.iter().map(|t| Member { name: t.clone(), weight: 1 }),
    );
    let mut counts: BTreeMap<String, u64> = targets.iter().map(|t| (t.clone(), 0)).collect();
    for spec in specs {
        let key = keys
            .get(&spec.artifact)
            .copied()
            .unwrap_or_else(|| fnv1a64(spec.artifact.as_bytes(), FNV_OFFSET));
        if let Some(primary) = ring.primary(key) {
            *counts.get_mut(primary).expect("primary is a target") += 1;
        }
    }
    counts
}

/// Report (and with `--assert-balance`, enforce) the per-worker job
/// distribution after a sweep: measured `jobs_done` deltas per target
/// versus the consistent-hash prediction for the submitted spec set.
fn check_balance(
    opts: &LoadgenOptions,
    before: &[StatsSnapshot],
    keys: &HashMap<String, u64>,
    all_specs: &[&JobSpec],
) -> Result<()> {
    let mut measured: BTreeMap<String, u64> = BTreeMap::new();
    for (t, b) in opts.targets.iter().zip(before) {
        let d = stats(t).with_context(|| format!("worker {t} stats"))?.delta_from(b);
        measured.insert(t.clone(), d.jobs_done);
    }
    let expected = predict_balance(&opts.targets, keys, all_specs.iter().copied());
    eprintln!("loadgen: per-worker job distribution (measured / hash-predicted):");
    for t in &opts.targets {
        eprintln!("  {t}: {} / {}", measured[t], expected[t]);
    }
    if opts.assert_balance {
        for t in &opts.targets {
            ensure!(
                measured[t] == expected[t],
                "worker {t} served {} jobs but consistent hashing predicts {} — \
                 a failover, unhealthy worker, or direct traffic shifted placement",
                measured[t],
                expected[t]
            );
        }
        eprintln!("loadgen: balance matches consistent-hash placement exactly");
    }
    Ok(())
}

pub(crate) fn to_spec(j: &ScenarioJob, chunk: usize) -> JobSpec {
    JobSpec {
        bench: j.bench.clone(),
        insts: j.insts,
        seed: j.seed,
        artifact: j.artifact.clone(),
        chunk,
        ctx_uarch: j.ctx_uarch.clone(),
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    }
}

/// Background progress reporter (`--progress-every N`): polls the
/// daemon's Prometheus `/metrics` exposition on a cadence and prints a
/// one-line summary — it consumes the same bytes a real scraper would,
/// so it doubles as a continuous exposition-format check. Scrape
/// failures are reported once per cadence and never fail the run.
struct ProgressReporter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ProgressReporter {
    fn start(addr: &str, every: Duration) -> ProgressReporter {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let addr = addr.to_string();
        let handle = std::thread::spawn(move || {
            let mut next = Instant::now() + every;
            while !flag.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(50));
                if Instant::now() < next {
                    continue;
                }
                next += every;
                match scrape_summary(&addr) {
                    Ok(line) => eprintln!("loadgen: progress — {line}"),
                    Err(e) => eprintln!("loadgen: progress scrape failed: {e:#}"),
                }
            }
        });
        ProgressReporter { stop, handle: Some(handle) }
    }

    fn finish(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scrape_summary(addr: &str) -> Result<String> {
    let resp = http_get(addr, "/metrics")?;
    ensure!(resp.status == 200, "/metrics returned {}", resp.status);
    let samples = parse_prom(&resp.body)?;
    let v = |name: &str| sample_value(&samples, name, &[]).unwrap_or(0.0);
    let done = v("tao_jobs_done_total");
    let submitted = v("tao_jobs_submitted_total");
    let depth = v("tao_queue_depth");
    let active = v("tao_jobs_active");
    let hits = v("tao_cache_hits_total");
    let misses = v("tao_cache_misses_total");
    let hit_rate = 100.0 * hits / (hits + misses).max(1.0);
    let p95_ms = histogram_quantile(&samples, "tao_request_seconds", 0.95)
        .map(|s| s * 1e3)
        .unwrap_or(0.0);
    Ok(format!(
        "{done:.0}/{submitted:.0} jobs done, {active:.0} active, queue {depth:.0}, \
         cache hit {hit_rate:.1}%, req p95 {p95_ms:.1}ms"
    ))
}

/// Exponential backoff with deterministic jitter: `10ms × 2^attempt`
/// capped at 500ms, then drawn uniformly from [½·base, 1½·base) so a
/// thundering herd of rejected clients decorrelates — deterministically
/// (the rng is seeded from the spec, never the clock).
fn backoff_delay(attempt: u32, rng: &mut Rng) -> Duration {
    let base = (10u64 << attempt.min(6)).min(500);
    Duration::from_millis(base / 2 + rng.gen_range(base.max(1)))
}

/// Submit one job, resubmitting on every *retryable* typed answer
/// (429 queue-full, 503 draining/lane-restart, 504 deadline) with
/// capped exponential backoff + jitter. Terminal answers and transport
/// failures bail.
fn submit(addr: &str, spec: &JobSpec) -> Result<JobOutcome> {
    let body = spec.to_json();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut rng = Rng::new(spec.seed ^ spec.insts.rotate_left(17));
    let mut attempt = 0u32;
    loop {
        let resp = http_post(addr, "/v1/simulate", &body)?;
        if resp.status == 200 {
            return JobOutcome::from_json(&resp.body);
        }
        let err = ServeError::from_body(resp.status, &resp.body);
        if err.code.retryable() && Instant::now() < deadline {
            std::thread::sleep(backoff_delay(attempt, &mut rng));
            attempt += 1;
            continue;
        }
        bail!("job {spec:?} failed with {}: {err}", resp.status);
    }
}

fn stats(addr: &str) -> Result<StatsSnapshot> {
    let resp = http_get(addr, "/v1/stats")?;
    ensure!(resp.status == 200, "stats returned {}", resp.status);
    StatsSnapshot::from_json(&resp.body)
}

/// Run the concurrent phase: `threads` workers pull specs off a shared
/// cursor and submit; results return in spec order.
pub(crate) fn run_concurrent(
    addr: &str,
    specs: &[JobSpec],
    threads: usize,
) -> Result<Vec<JobOutcome>> {
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<JobOutcome>>> = Mutex::new(vec![None; specs.len()]);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= specs.len() {
                    break;
                }
                match submit(addr, &specs[i]) {
                    Ok(out) => results.lock().expect("results")[i] = Some(out),
                    Err(e) => errors.lock().expect("errors").push(format!("{e:#}")),
                }
            });
        }
    });
    let errors = errors.into_inner().expect("errors");
    ensure!(errors.is_empty(), "concurrent jobs failed: {}", errors.join("; "));
    results
        .into_inner()
        .expect("results")
        .into_iter()
        .map(|o| o.context("missing job result"))
        .collect()
}

/// Offline oracle for one job spec: the same (trace, artifact,
/// chunking) through the single-stream engine. Shared by `--verify`
/// and the loopback integration tests.
pub fn offline_reference(spec: &JobSpec, models_dir: &Path) -> Result<Metrics> {
    use crate::coordinator::engine::simulate_chunked;
    use crate::functional::FunctionalSim;
    use crate::runtime::{ModelKind, Session};
    use crate::trace::OwnedChunkSource;

    let hlo = models_dir.join(format!("{}.hlo.txt", spec.artifact));
    let mut session = Session::load(&hlo).with_context(|| format!("load {hlo:?}"))?;
    let program = crate::workloads::by_name(&spec.bench)
        .with_context(|| format!("unknown benchmark {:?}", spec.bench))?
        .build(spec.seed);
    let result = match session.meta().kind {
        ModelKind::Tao => {
            let mut src = FunctionalSim::new(&program).into_chunks(spec.insts);
            simulate_chunked(&mut session, &mut src, spec.chunk, None)?
        }
        ModelKind::SimNet => {
            let sel = spec.ctx_uarch.as_deref().context("SimNet spec without ctx_uarch")?;
            let cfg = resolve_ctx_uarch(sel)?;
            let cols = FunctionalSim::new(&program).run(spec.insts).to_columns();
            let ctx = crate::dataset::simnet_ctx_metrics(&program, &cfg, spec.insts);
            let mut src = OwnedChunkSource::new(cols, Some(ctx))?;
            simulate_chunked(&mut session, &mut src, spec.chunk, None)?
        }
    };
    Ok(result.metrics)
}

/// Exact-equality check between a served outcome and the offline
/// oracle (all six metric fields, bit for bit).
pub fn assert_identical(served: &Metrics, offline: &Metrics, tag: &str) -> Result<()> {
    ensure!(
        served.instructions == offline.instructions
            && served.cycles == offline.cycles
            && served.mispredicts == offline.mispredicts
            && served.l1d_misses == offline.l1d_misses
            && served.l1i_misses == offline.l1i_misses
            && served.tlb_misses == offline.tlb_misses,
        "{tag}: served metrics diverge from offline: served={served:?} offline={offline:?}"
    );
    Ok(())
}

fn verify_all(specs: &[JobSpec], outs: &[JobOutcome], dir: &Path, phase: &str) -> Result<()> {
    for (spec, out) in specs.iter().zip(outs) {
        let offline = offline_reference(spec, dir)?;
        assert_identical(
            &out.metrics,
            &offline,
            &format!("{phase} {}/{}@{}", spec.bench, spec.artifact, spec.seed),
        )?;
    }
    Ok(())
}

fn phase_case(name: &str, insts: u64, elapsed: Duration) -> Measurement {
    let ns = elapsed.as_nanos() as f64;
    Measurement { name: name.into(), items: insts, mean_ns: ns, min_ns: ns, max_ns: ns }
}

/// Run the full loadgen sweep (or the chaos soak with `--chaos`).
/// Returns the final report (also written to `--json` when
/// configured).
pub fn run_loadgen(opts: &LoadgenOptions) -> Result<BenchReport> {
    if opts.chaos {
        return run_chaos(opts);
    }
    ensure!(opts.jobs >= 1, "--jobs must be at least 1");
    ensure!(
        opts.solo_jobs >= 1,
        "--solo-jobs must be at least 1 (the solo phase is the occupancy baseline)"
    );
    ensure!(opts.insts >= 2, "--insts must be at least 2");
    let addr = opts.addr.as_str();
    let health = http_get(addr, "/healthz").context("daemon unreachable")?;
    ensure!(health.status == 200, "daemon unhealthy: {}", health.status);
    let arts_resp = http_get(addr, "/v1/artifacts")?;
    ensure!(arts_resp.status == 200, "artifact listing failed");
    let infos = artifacts_from_json(&arts_resp.body)?;
    let art_keys: HashMap<String, u64> = infos
        .iter()
        .map(|a| (a.name.clone(), artifact_key(&a.name, a.fingerprint)))
        .collect();
    let arts: Vec<ScenarioArtifact> = infos
        .into_iter()
        .map(|a| ScenarioArtifact { simnet: a.is_simnet(), name: a.name })
        .collect();
    ensure!(!arts.is_empty(), "daemon serves no artifacts");
    eprintln!(
        "loadgen: {} artifact(s) at {addr}: {}",
        arts.len(),
        arts.iter().map(|a| a.name.as_str()).collect::<Vec<_>>().join(", ")
    );
    // Per-worker baselines for the balance report (`--targets`): taken
    // before any submission so the deltas cover the whole sweep.
    let targets_before: Vec<StatsSnapshot> = opts
        .targets
        .iter()
        .map(|t| stats(t).with_context(|| format!("worker {t} unreachable")))
        .collect::<Result<_>>()?;
    let progress = opts.progress_every.map(|every| ProgressReporter::start(addr, every));

    let mut report = BenchReport::new();

    // Phase 1: solo (disjoint seed space so it cannot warm phase 2/3).
    let solo_specs: Vec<JobSpec> =
        mixed_scenarios(&arts, opts.solo_jobs, opts.insts, opts.seed + 500_000)
            .iter()
            .map(|j| to_spec(j, opts.chunk))
            .collect();
    let before = stats(addr)?;
    let t0 = Instant::now();
    let mut solo_outs = Vec::new();
    for spec in &solo_specs {
        solo_outs.push(submit(addr, spec)?);
    }
    let solo_elapsed = t0.elapsed();
    let solo_delta = stats(addr)?.delta_from(&before);
    let solo_insts: u64 = solo_specs.iter().map(|s| s.insts).sum();
    report.push(phase_case("serve/solo", solo_insts, solo_elapsed));
    report.metric("occupancy_solo", solo_delta.occupancy());

    // Phase 2: concurrent, cold cache (fresh seed space).
    let specs: Vec<JobSpec> = mixed_scenarios(&arts, opts.jobs, opts.insts, opts.seed)
        .iter()
        .map(|j| to_spec(j, opts.chunk))
        .collect();
    let total_insts: u64 = specs.iter().map(|s| s.insts).sum();
    let before = stats(addr)?;
    let t0 = Instant::now();
    let cold_outs = run_concurrent(addr, &specs, opts.threads)?;
    let cold_elapsed = t0.elapsed();
    let cold_delta = stats(addr)?.delta_from(&before);
    report.push(phase_case("serve/concurrent_cold", total_insts, cold_elapsed));
    report.metric("occupancy_concurrent", cold_delta.occupancy());
    report.metric(
        "requests_per_sec_cold",
        specs.len() as f64 / cold_elapsed.as_secs_f64().max(1e-9),
    );

    // Phase 3: concurrent, warm cache (identical specs).
    let before = stats(addr)?;
    let t0 = Instant::now();
    let warm_outs = run_concurrent(addr, &specs, opts.threads)?;
    let warm_elapsed = t0.elapsed();
    let warm_delta = stats(addr)?.delta_from(&before);
    report.push(phase_case("serve/concurrent_warm", total_insts, warm_elapsed));
    let warm_lookups = warm_delta.cache_hits + warm_delta.cache_misses;
    report.metric(
        "cache_hit_rate_warm",
        warm_delta.cache_hits as f64 / (warm_lookups.max(1)) as f64,
    );
    report.metric(
        "requests_per_sec_warm",
        specs.len() as f64 / warm_elapsed.as_secs_f64().max(1e-9),
    );
    report.metric(
        "warm_speedup",
        cold_elapsed.as_secs_f64() / warm_elapsed.as_secs_f64().max(1e-9),
    );

    eprintln!(
        "loadgen: solo occupancy {:.1}% over {} batches; concurrent {:.1}% over {}; \
         warm hit-rate {:.1}% ({} hits)",
        solo_delta.occupancy() * 100.0,
        solo_delta.batches,
        cold_delta.occupancy() * 100.0,
        cold_delta.batches,
        100.0 * warm_delta.cache_hits as f64 / warm_lookups.max(1) as f64,
        warm_delta.cache_hits,
    );

    if !opts.targets.is_empty() {
        // Every job submitted this sweep: solo once, the mix twice
        // (cold + warm replay the same specs).
        let all: Vec<&JobSpec> =
            solo_specs.iter().chain(&specs).chain(&specs).collect();
        check_balance(opts, &targets_before, &art_keys, &all)?;
    }

    if let Some(dir) = &opts.verify_models {
        verify_all(&solo_specs, &solo_outs, dir, "solo")?;
        verify_all(&specs, &cold_outs, dir, "cold")?;
        verify_all(&specs, &warm_outs, dir, "warm")?;
        // Warm-vs-cold served results must agree with each other too
        // (same spec, cache on vs off the path).
        for ((spec, cold), warm) in specs.iter().zip(&cold_outs).zip(&warm_outs) {
            assert_identical(
                &warm.metrics,
                &cold.metrics,
                &format!("warm-vs-cold {}/{}", spec.bench, spec.artifact),
            )?;
        }
        // Only demand warm hits when the daemon actually caches
        // (`--cache-entries 0` is a supported configuration and the
        // equality checks above still hold there).
        if warm_delta.cache_entries > 0 {
            ensure!(
                warm_delta.cache_hits > 0,
                "warm phase produced no cache hits — cache is not engaging"
            );
        }
        eprintln!(
            "loadgen: verified {} served results identical to offline engine runs",
            solo_specs.len() + 2 * specs.len()
        );
    }

    if opts.assert_occupancy {
        ensure!(
            cold_delta.occupancy() > solo_delta.occupancy(),
            "packed occupancy {:.3} did not exceed solo occupancy {:.3}",
            cold_delta.occupancy(),
            solo_delta.occupancy()
        );
    }
    if let Some(p) = progress {
        p.finish();
    }

    if let Some(path) = &opts.json_out {
        report.write_json(path).with_context(|| format!("write {path:?}"))?;
        eprintln!("loadgen: wrote {}", path.display());
    }
    if opts.shutdown_after {
        let resp = http_post(addr, "/v1/shutdown", "")?;
        ensure!(resp.status == 200, "shutdown returned {}", resp.status);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(artifact: &str) -> JobSpec {
        JobSpec {
            bench: "dee".into(),
            insts: 100,
            seed: 1,
            artifact: artifact.into(),
            chunk: 64,
            ctx_uarch: None,
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        }
    }

    #[test]
    fn balance_prediction_is_total_deterministic_and_keyed_per_artifact() {
        let targets = vec!["w1:1".to_string(), "w2:1".to_string(), "w3:1".to_string()];
        let keys: HashMap<String, u64> =
            [("a".to_string(), 11u64), ("b".to_string(), 22), ("c".to_string(), 33)].into();
        let specs: Vec<JobSpec> =
            (0..30).map(|i| spec(["a", "b", "c"][i % 3])).collect();
        let counts = predict_balance(&targets, &keys, specs.iter());
        assert_eq!(counts.values().sum::<u64>(), 30, "every job placed");
        assert_eq!(counts, predict_balance(&targets, &keys, specs.iter()));
        // Same artifact → same worker: each artifact's 10 jobs land as
        // one block, so every count is a multiple of 10.
        assert!(counts.values().all(|&c| c % 10 == 0), "{counts:?}");
        // A solo fleet takes everything.
        let solo = vec!["only:1".to_string()];
        let all = predict_balance(&solo, &keys, specs.iter());
        assert_eq!(all["only:1"], 30);
    }

    #[test]
    fn artifact_key_prefers_wire_fingerprint() {
        assert_eq!(artifact_key("m", Some(7)), 7);
        assert_eq!(artifact_key("m", None), fnv1a64(b"m", FNV_OFFSET));
    }
}

/// One chaos submission: maybe stall mid-body (the client-side
/// [`Probe::SlowClient`] abuse), resubmit retryable answers with
/// capped backoff, and return *terminal* typed answers as values —
/// the soak tolerates and counts them. An outer `Err` means an
/// untyped transport failure, which the soak treats as a robustness
/// bug in the daemon.
#[allow(clippy::type_complexity)]
fn submit_chaos(
    addr: &str,
    spec: &JobSpec,
    round: u64,
    retries: &AtomicU64,
    stalls: &AtomicU64,
) -> Result<Result<JobOutcome, ServeError>> {
    let body = spec.to_json();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut rng = Rng::new(spec.seed ^ spec.insts.rotate_left(17) ^ (round << 56));
    let mut attempt = 0u32;
    loop {
        let resp = if fault::should_fire(Probe::SlowClient) {
            stalls.fetch_add(1, Ordering::Relaxed);
            http_post_stalled(addr, "/v1/simulate", &body, Duration::from_millis(250))?
        } else {
            http_post(addr, "/v1/simulate", &body)?
        };
        if resp.status == 200 {
            return Ok(Ok(JobOutcome::from_json(&resp.body)?));
        }
        let err = ServeError::from_body(resp.status, &resp.body);
        if err.code.retryable() && Instant::now() < deadline {
            retries.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(backoff_delay(attempt, &mut rng));
            attempt += 1;
            continue;
        }
        return Ok(Err(err));
    }
}

/// Chaos soak (`--chaos`): replay the mixed scenario set twice —
/// cold, then against a warmed cache, so the retry and cache paths
/// interact — from all client threads, stalling ~2% of submissions
/// mid-body. The daemon under test typically has its own probes armed
/// via `TAO_FAULTS`. Pass criteria are the failure contract, not
/// throughput: every job ends typed, nothing hangs, at least one job
/// succeeds, and (with `--verify-models`) every success is
/// bit-identical to the offline engine.
pub fn run_chaos(opts: &LoadgenOptions) -> Result<BenchReport> {
    ensure!(opts.jobs >= 1, "--jobs must be at least 1");
    ensure!(opts.insts >= 2, "--insts must be at least 2");
    let addr = opts.addr.as_str();
    let health = http_get(addr, "/healthz").context("daemon unreachable")?;
    ensure!(health.status == 200, "daemon unhealthy: {}", health.status);
    let arts_resp = http_get(addr, "/v1/artifacts")?;
    ensure!(arts_resp.status == 200, "artifact listing failed");
    let arts: Vec<ScenarioArtifact> = artifacts_from_json(&arts_resp.body)?
        .into_iter()
        .map(|a| ScenarioArtifact { simnet: a.is_simnet(), name: a.name })
        .collect();
    ensure!(!arts.is_empty(), "daemon serves no artifacts");

    let specs: Vec<JobSpec> = mixed_scenarios(&arts, opts.jobs, opts.insts, opts.seed)
        .iter()
        .map(|j| to_spec(j, opts.chunk))
        .collect();
    let total_insts: u64 = specs.iter().map(|s| s.insts).sum();
    eprintln!(
        "chaos: {} jobs x 2 rounds against {addr} ({} artifact(s)), ~2% stalled submissions",
        specs.len(),
        arts.len()
    );
    let progress = opts.progress_every.map(|every| ProgressReporter::start(addr, every));

    // Client-side abuse: ~2% of submissions stall mid-body for 250ms
    // (short of the server's default read timeout, so they must still
    // be served, not 408'd).
    fault::arm(Probe::SlowClient, 20_000);
    let retries = AtomicU64::new(0);
    let stalls = AtomicU64::new(0);
    let mut all: Vec<(usize, Result<JobOutcome, ServeError>)> = Vec::new();
    let t0 = Instant::now();
    for round in 0..2u64 {
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<Result<JobOutcome, ServeError>>>> =
            Mutex::new(vec![None; specs.len()]);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..opts.threads.max(1) {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    match submit_chaos(addr, &specs[i], round, &retries, &stalls) {
                        Ok(res) => results.lock().expect("results")[i] = Some(res),
                        Err(e) => errors.lock().expect("errors").push(format!("{e:#}")),
                    }
                });
            }
        });
        let errors = errors.into_inner().expect("errors");
        ensure!(
            errors.is_empty(),
            "chaos round {round}: untyped transport failures: {}",
            errors.join("; ")
        );
        for (i, res) in results.into_inner().expect("results").into_iter().enumerate() {
            all.push((i, res.context("missing chaos result")?));
        }
    }
    // Neutralize the client-side probe without clobbering any probes a
    // same-process harness armed for the daemon.
    fault::arm(Probe::SlowClient, 0);
    let elapsed = t0.elapsed();

    let mut succeeded = 0u64;
    let mut failed_typed = 0u64;
    let mut verified = 0u64;
    for (i, res) in &all {
        match res {
            Ok(out) => {
                succeeded += 1;
                if let Some(dir) = &opts.verify_models {
                    let spec = &specs[*i];
                    let offline = offline_reference(spec, dir)?;
                    assert_identical(
                        &out.metrics,
                        &offline,
                        &format!("chaos {}/{}@{}", spec.bench, spec.artifact, spec.seed),
                    )?;
                    verified += 1;
                }
            }
            Err(se) => {
                failed_typed += 1;
                eprintln!("chaos: job {i} ended typed: {se}");
            }
        }
    }
    ensure!(succeeded > 0, "chaos soak: every job failed — daemon never served");
    if let Some(p) = progress {
        p.finish();
    }

    let mut report = BenchReport::new();
    report.push(phase_case("serve/chaos", 2 * total_insts, elapsed));
    report.metric("chaos_jobs_ok", succeeded as f64);
    report.metric("chaos_jobs_failed_typed", failed_typed as f64);
    report.metric("chaos_retries", retries.load(Ordering::Relaxed) as f64);
    report.metric("chaos_stalled_submits", stalls.load(Ordering::Relaxed) as f64);
    eprintln!(
        "chaos: {} submissions — {} ok ({} verified), {} typed failures, \
         {} retries, {} stalled posts, {:.1}s",
        all.len(),
        succeeded,
        verified,
        failed_typed,
        retries.load(Ordering::Relaxed),
        stalls.load(Ordering::Relaxed),
        elapsed.as_secs_f64(),
    );

    if let Some(path) = &opts.json_out {
        report.write_json(path).with_context(|| format!("write {path:?}"))?;
        eprintln!("chaos: wrote {}", path.display());
    }
    if opts.shutdown_after {
        let resp = http_post(addr, "/v1/shutdown", "")?;
        ensure!(resp.status == 200, "shutdown returned {}", resp.status);
    }
    Ok(report)
}
