//! Append-only crash-safe journal for the prediction cache.
//!
//! The PR-4 prediction cache evaporated on restart; this journal makes
//! it durable without changing a single served bit. Every *fresh*
//! cache insert appends one CRC-framed record; on startup the daemon
//! replays the journal into the cache ([`PredictionCache::warm_load`]
//! (super::PredictionCache::warm_load)), so a restarted daemon serves
//! previously-computed chunks as hits with metrics identical to the
//! first run — the accumulator codec
//! ([`PredAccum::encode_journal`]) stores `f64`s as raw bits.
//!
//! On-disk format (all integers little-endian):
//!
//! ```text
//! [magic "TAOJRNL1": 8 bytes]
//! repeated records:
//!   [len:   u32]   // payload length; fixed per version (88)
//!   [crc32: u32]   // IEEE CRC-32 of the payload
//!   [payload: len] // ChunkKey (artifact, prefix, content: 3×u64)
//!                  // + PredAccum journal encoding (64 bytes)
//! ```
//!
//! Durability model: each append is one unbuffered `write_all`, so a
//! `kill -9` (or the injected [`Probe::CacheTornWrite`] fault) loses
//! at most a torn tail record. Recovery walks the file from the magic,
//! stops at the first short/garbled/CRC-bad frame, and truncates there
//! — a crash can cost the tail entry, never produce a wrong answer.
//! `fsync` happens once per graceful drain, not per append. Chunk keys
//! embed the artifact fingerprint, so a journal replayed under changed
//! model bytes simply never hits.

use super::cache::ChunkKey;
use crate::coordinator::engine::PredAccum;
use crate::util::fault::{self, Probe};
use crate::util::hash::crc32;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

/// File magic: format name + version.
const MAGIC: &[u8; 8] = b"TAOJRNL1";
/// Record payload: [`ChunkKey`] (3×u64) + accumulator encoding.
const PAYLOAD_BYTES: usize = 24 + PredAccum::JOURNAL_BYTES;
/// Full frame: length + CRC header, then the payload.
const FRAME_BYTES: usize = 8 + PAYLOAD_BYTES;

/// An open cache journal, positioned for appends.
pub struct CacheJournal {
    file: File,
    path: PathBuf,
    /// A torn-write fault fired: the file ends mid-frame, exactly as a
    /// crash would leave it. Further appends are dropped so the torn
    /// tail survives for the recovery path to exercise.
    torn: bool,
}

/// What [`CacheJournal::open`] recovered from an existing file.
pub struct Recovered {
    /// Replayable entries, in append order (replay preserves it, so a
    /// duplicated key resolves last-wins).
    pub entries: Vec<(ChunkKey, PredAccum)>,
    /// Bytes of torn/garbled tail truncated away (0 = clean file).
    pub truncated_bytes: u64,
}

/// The shared recovery walk: validate the magic, replay every intact
/// frame, and return the entries plus the byte offset where the valid
/// prefix ends (everything past it is torn or garbled).
fn recover(bytes: &[u8], path: &Path) -> Result<(Vec<(ChunkKey, PredAccum)>, usize)> {
    if bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] != MAGIC {
        bail!("{path:?} is not a cache journal (bad magic); refusing to overwrite");
    }
    let mut entries = Vec::new();
    let mut valid = bytes.len().min(MAGIC.len());
    if valid == MAGIC.len() {
        let mut off = MAGIC.len();
        while bytes.len() - off >= 8 {
            let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
            if len != PAYLOAD_BYTES || bytes.len() - off - 8 < len {
                break; // garbled length or torn payload
            }
            let payload = &bytes[off + 8..off + 8 + len];
            if crc32(payload) != crc {
                break; // torn or bit-rotted record
            }
            let k =
                |i: usize| u64::from_le_bytes(payload[i * 8..(i + 1) * 8].try_into().unwrap());
            let key = ChunkKey { artifact: k(0), prefix: k(1), content: k(2) };
            let accum = PredAccum::decode_journal(&payload[24..])?;
            entries.push((key, accum));
            off += 8 + len;
            valid = off;
        }
    }
    Ok((entries, valid))
}

impl CacheJournal {
    /// Open `path` (creating it if absent), validate + recover its
    /// contents, truncate any torn tail, and return the journal ready
    /// for appends. Fails on a file that is not a cache journal at all
    /// (wrong magic) rather than clobbering it.
    pub fn open(path: &Path) -> Result<(CacheJournal, Recovered)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e).with_context(|| format!("read cache journal {path:?}")),
        };
        let (entries, valid) = recover(&bytes, path)?;
        let truncated_bytes = (bytes.len() - valid) as u64;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open cache journal {path:?}"))?;
        // Drop the torn tail (or a torn 8-byte header from a crash
        // during creation) so appends resume on a frame boundary.
        file.set_len(valid as u64)
            .with_context(|| format!("truncate cache journal {path:?}"))?;
        let mut journal = CacheJournal { file, path: path.to_path_buf(), torn: false };
        if valid < MAGIC.len() {
            journal
                .file
                .write_all(MAGIC)
                .with_context(|| format!("initialize cache journal {path:?}"))?;
        }
        Ok((journal, Recovered { entries, truncated_bytes }))
    }

    /// Recover a journal's entries **read-only** — no truncation, no
    /// append handle, the file is left byte-for-byte untouched. This is
    /// how a ring successor warm-loads a dead worker's `--warm-journal`
    /// file: the successor inherits the predecessor's computed chunks
    /// while the original journal stays intact for the worker's own
    /// respawn. A torn tail is simply skipped, exactly as
    /// [`CacheJournal::open`] would truncate it.
    pub fn replay(path: &Path) -> Result<Recovered> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read cache journal {path:?}"))?;
        let (entries, valid) = recover(&bytes, path)?;
        Ok(Recovered { entries, truncated_bytes: (bytes.len() - valid) as u64 })
    }

    /// Append one cache entry. A single unbuffered `write_all` per
    /// frame: a crash mid-append costs at most this one record. Under
    /// an armed [`Probe::CacheTornWrite`] the frame is cut short and
    /// the journal goes inert, simulating exactly that crash without
    /// killing the process.
    pub fn append(&mut self, key: &ChunkKey, value: &PredAccum) -> Result<()> {
        if self.torn {
            return Ok(());
        }
        let mut payload = Vec::with_capacity(PAYLOAD_BYTES);
        payload.extend_from_slice(&key.artifact.to_le_bytes());
        payload.extend_from_slice(&key.prefix.to_le_bytes());
        payload.extend_from_slice(&key.content.to_le_bytes());
        value.encode_journal(&mut payload);
        debug_assert_eq!(payload.len(), PAYLOAD_BYTES);
        let mut frame = Vec::with_capacity(FRAME_BYTES);
        frame.extend_from_slice(&(PAYLOAD_BYTES as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        if fault::should_fire(Probe::CacheTornWrite) {
            self.torn = true;
            return self
                .file
                .write_all(&frame[..FRAME_BYTES / 2])
                .with_context(|| format!("torn append to cache journal {:?}", self.path));
        }
        self.file
            .write_all(&frame)
            .with_context(|| format!("append to cache journal {:?}", self.path))
    }

    /// Flush to stable storage (called once per graceful drain).
    pub fn sync(&mut self) -> Result<()> {
        self.file
            .sync_data()
            .with_context(|| format!("fsync cache journal {:?}", self.path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelKind, ModelOutputs};

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-journal-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join("cache.journal")
    }

    fn key(n: u64) -> ChunkKey {
        ChunkKey { artifact: 7, prefix: n.wrapping_mul(31), content: n }
    }

    fn accum(insts: u64) -> PredAccum {
        let n = insts as usize;
        let mut a = PredAccum::default();
        let out = ModelOutputs {
            fetch: vec![2.5; n],
            exec: vec![1.25; n],
            branch: vec![1.0 / 3.0; n],
            access: vec![0.25; n * 4],
            icache: vec![0.1; n],
            tlb: vec![0.9; n],
        };
        a.absorb(&out, ModelKind::Tao);
        a
    }

    fn reopen(path: &Path) -> Recovered {
        let (_j, rec) = CacheJournal::open(path).unwrap();
        rec
    }

    #[test]
    fn round_trips_entries_bit_exactly() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let (mut j, rec) = CacheJournal::open(&path).unwrap();
        assert!(rec.entries.is_empty());
        assert_eq!(rec.truncated_bytes, 0);
        for n in 1..=5u64 {
            j.append(&key(n), &accum(n)).unwrap();
        }
        j.sync().unwrap();
        drop(j);
        let rec = reopen(&path);
        assert_eq!(rec.entries.len(), 5);
        assert_eq!(rec.truncated_bytes, 0);
        for (n, (k, a)) in (1..=5u64).zip(&rec.entries) {
            assert_eq!(*k, key(n));
            let want = accum(n);
            assert_eq!(a.instructions, want.instructions);
            assert_eq!(a.fetch_cycles.to_bits(), want.fetch_cycles.to_bits());
            assert_eq!(a.last_exec.to_bits(), want.last_exec.to_bits());
            assert_eq!(a.tlb_misses.to_bits(), want.tlb_misses.to_bits());
        }
    }

    #[test]
    fn torn_tail_is_truncated_and_appends_resume() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("torn-tail");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = CacheJournal::open(&path).unwrap();
        j.append(&key(1), &accum(1)).unwrap();
        j.append(&key(2), &accum(2)).unwrap();
        drop(j);
        // Simulate a crash mid-append: chop the last record in half.
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - (FRAME_BYTES as u64) / 2).unwrap();
        drop(f);
        let (mut j, rec) = CacheJournal::open(&path).unwrap();
        assert_eq!(rec.entries.len(), 1, "torn record must not replay");
        assert_eq!(rec.truncated_bytes, (FRAME_BYTES as u64) / 2);
        // Appends after recovery land on a clean frame boundary.
        j.append(&key(3), &accum(3)).unwrap();
        drop(j);
        let rec = reopen(&path);
        assert_eq!(rec.entries.len(), 2);
        assert_eq!(rec.entries[1].0, key(3));
    }

    #[test]
    fn crc_corruption_stops_replay() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("crc");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = CacheJournal::open(&path).unwrap();
        for n in 1..=3u64 {
            j.append(&key(n), &accum(n)).unwrap();
        }
        drop(j);
        // Flip one payload byte in the middle record.
        let mut bytes = std::fs::read(&path).unwrap();
        let off = MAGIC.len() + FRAME_BYTES + 8 + 5;
        bytes[off] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let rec = reopen(&path);
        // Replay stops at the first bad record — suffix entries after
        // corruption are not trusted (the stream prefix is broken).
        assert_eq!(rec.entries.len(), 1);
        assert_eq!(rec.truncated_bytes, 2 * FRAME_BYTES as u64);
    }

    #[test]
    fn duplicate_keys_replay_in_append_order() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("dups");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = CacheJournal::open(&path).unwrap();
        j.append(&key(1), &accum(1)).unwrap();
        j.append(&key(1), &accum(9)).unwrap();
        drop(j);
        let rec = reopen(&path);
        assert_eq!(rec.entries.len(), 2);
        // Last-wins falls out of replay order.
        assert_eq!(rec.entries[1].1.instructions, 9);
    }

    #[test]
    fn replay_is_read_only_and_skips_torn_tails() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("replay");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = CacheJournal::open(&path).unwrap();
        for n in 1..=3u64 {
            j.append(&key(n), &accum(n)).unwrap();
        }
        drop(j);
        // Tear the last frame as a successor would find it after the
        // owner died mid-append.
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - (FRAME_BYTES as u64) / 2).unwrap();
        drop(f);
        let rec = CacheJournal::replay(&path).unwrap();
        assert_eq!(rec.entries.len(), 2, "intact prefix replays");
        assert_eq!(rec.truncated_bytes, (FRAME_BYTES as u64) / 2);
        // The file itself is untouched — the owner's own recovery path
        // still sees the torn tail.
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            full - (FRAME_BYTES as u64) / 2
        );
        assert!(CacheJournal::replay(&tmp("replay-missing")).is_err());
        let foreign = tmp("replay-foreign");
        std::fs::write(&foreign, b"not a journal at all....").unwrap();
        assert!(CacheJournal::replay(&foreign).is_err());
    }

    #[test]
    fn foreign_files_are_refused() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("foreign");
        std::fs::write(&path, b"definitely not a journal").unwrap();
        assert!(CacheJournal::open(&path).is_err());
        // A torn sub-magic header (crash during creation) recovers.
        std::fs::write(&path, &MAGIC[..3]).unwrap();
        let (_j, rec) = CacheJournal::open(&path).unwrap();
        assert!(rec.entries.is_empty());
    }

    #[test]
    fn torn_write_probe_leaves_recoverable_tail() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let path = tmp("torn-probe");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = CacheJournal::open(&path).unwrap();
        j.append(&key(1), &accum(1)).unwrap();
        fault::arm_nth(Probe::CacheTornWrite, 1);
        j.append(&key(2), &accum(2)).unwrap(); // cut short mid-frame
        fault::disarm_all();
        j.append(&key(3), &accum(3)).unwrap(); // inert: journal is torn
        drop(j);
        let rec = reopen(&path);
        assert_eq!(rec.entries.len(), 1, "only the pre-tear record survives");
        assert_eq!(rec.entries[0].0, key(1));
        assert_eq!(rec.truncated_bytes, (FRAME_BYTES as u64) / 2);
    }
}
