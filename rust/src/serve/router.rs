//! `tao router` — the horizontal sharding tier in front of N worker
//! daemons.
//!
//! One `tao serve` daemon caps throughput at one box's lanes. The
//! router scales the service *out* without touching the protocol: it
//! speaks the same hand-rolled HTTP/1.1 on both sides, so a client
//! cannot tell a router from a worker, and a worker cannot tell the
//! router from a client — `tao loadgen` works against either,
//! unchanged.
//!
//! **Placement.** Jobs consistent-hash onto a weighted ring
//! ([`super::ring`]) keyed by **artifact fingerprint** — the content
//! hash every worker that loaded the same model advertises via
//! `GET /v1/artifacts`. Keying on content (not worker count, not
//! round-robin) is what makes the prediction cache *shard*: all
//! requests for one artifact land on the same worker (and its
//! failover successors), so that worker's chunk cache stays hot for
//! exactly the keyspace the ring assigned it. Adding a worker moves
//! only `1/(n+1)` of the keyspace; the rest of the fleet's caches
//! survive the resize.
//!
//! **Membership.** A health loop polls every worker's `/healthz` on an
//! interval: `serving` and `degraded` keep full ring weight (a
//! degraded worker still serves its healthy lanes); `starting`,
//! `draining`, and unreachable workers drop to weight 0 — known but
//! out of the point set, so their keys move to ring successors while
//! in-flight jobs finish on the old connection. Weight-0 members keep
//! their identity: a worker bouncing back gets its exact keyspace
//! back, so its (journal-recovered) cache is warm for it.
//!
//! **Forwarding.** `/v1/simulate` bodies forward along the key's
//! replica walk with per-hop deadline budgets and failover on
//! retryable codes ([`super::forward`]). Terminal answers relay
//! verbatim — the router adds availability, never masks the failure
//! taxonomy.
//!
//! **Fleet-warm cache.** The router computes each worker's ring
//! neighbours and the `tao router` CLI can print them (`--print-peers`)
//! for wiring workers' `--peers` flags; a worker that misses a chunk
//! asks its neighbours' `/v1/cache/lookup` before computing, and a
//! replacement worker warm-loads a dead predecessor's journal via
//! `--warm-journal`. Failover traffic therefore lands on a successor
//! whose cache already holds (or can fetch) the moved keys.

use super::forward::{forward, ForwardPolicy};
use super::http::{
    http_get_timeout, read_error_status, read_request, write_response, write_response_typed,
};
use super::protocol::{artifacts_from_json, error_body, ErrorCode, ServeError, StatsSnapshot};
use super::ring::HashRing;
use crate::telemetry::{self, prometheus, registry, Gauge, Histogram};
use crate::util::fault::relock;
use crate::util::hash::{fnv1a64, FNV_OFFSET};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Bind address (`127.0.0.1:0` for an ephemeral port).
    pub addr: String,
    /// Worker daemons: (`host:port`, ring weight). Weight scales a
    /// worker's keyspace share (a 2× box takes 2× the artifacts).
    pub workers: Vec<(String, u32)>,
    /// Health-poll interval, milliseconds.
    pub health_interval_ms: u64,
    /// Per-probe `/healthz` timeout, milliseconds.
    pub health_timeout_ms: u64,
    /// Distinct ring replicas a job may fail over across.
    pub replica_walk: usize,
    /// Per-hop forward timeout ceiling, milliseconds.
    pub hop_cap_ms: u64,
    /// Total forward attempts across the replica walk.
    pub max_attempts: u32,
    /// Deadline for requests that don't carry `deadline_ms`.
    pub default_deadline_ms: u64,
    /// Per-connection socket read timeout, milliseconds.
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout, milliseconds.
    pub write_timeout_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            addr: "127.0.0.1:0".into(),
            workers: Vec::new(),
            health_interval_ms: 250,
            health_timeout_ms: 1_000,
            replica_walk: 3,
            hop_cap_ms: 300_000,
            max_attempts: 6,
            default_deadline_ms: 300_000,
            read_timeout_ms: 10_000,
            write_timeout_ms: 30_000,
        }
    }
}

/// The ring neighbours (successors) of each worker — who a worker
/// should consult for warm cache entries, and who inherits its keys if
/// it dies. Computed from the *configured* full-weight ring so the
/// wiring is stable across transient health flaps.
pub fn peer_map(workers: &[(String, u32)], walk: usize) -> BTreeMap<String, Vec<String>> {
    let ring = HashRing::from_members(workers.iter().map(|(name, weight)| {
        super::ring::Member { name: name.clone(), weight: (*weight).max(1) }
    }));
    let mut out = BTreeMap::new();
    for (name, _) in workers {
        // A worker's neighbours: walk the ring from the worker's own
        // identity hash; drop self; keep `walk` distinct successors.
        let key = fnv1a64(name.as_bytes(), FNV_OFFSET);
        let peers: Vec<String> = ring
            .replicas(key, walk + 1)
            .into_iter()
            .filter(|p| p != name)
            .take(walk)
            .map(str::to_string)
            .collect();
        out.insert(name.clone(), peers);
    }
    out
}

/// Router-level metric handles, resolved once at bind.
struct RouterTele {
    workers_live: Gauge,
    workers_known: Gauge,
    request_seconds: Histogram,
}

impl RouterTele {
    fn new() -> RouterTele {
        let reg = registry();
        RouterTele {
            workers_live: reg.gauge(
                "tao_router_workers_live",
                "Workers currently in the hash ring (weight > 0).",
                &[],
            ),
            workers_known: reg.gauge(
                "tao_router_workers_known",
                "Workers configured, live or not.",
                &[],
            ),
            request_seconds: reg.histogram(
                "tao_router_request_seconds",
                "Router request wall time, accept to relayed response.",
                &[],
            ),
        }
    }
}

struct RouterShared {
    workers: Vec<(String, u32)>,
    ring: Mutex<HashRing>,
    /// Artifact name → fingerprint, discovered from `/v1/artifacts`.
    arts: Mutex<HashMap<String, u64>>,
    tele: RouterTele,
    shutdown: AtomicBool,
    started: AtomicBool,
    /// Decorrelates per-request forwarding jitter.
    seed: AtomicU64,
    policy: ForwardPolicy,
    replica_walk: usize,
    default_deadline: Duration,
    health_interval: Duration,
    health_timeout: Duration,
    read_timeout: Duration,
    write_timeout: Duration,
}

impl RouterShared {
    /// The ring key for an artifact: the fleet-advertised fingerprint
    /// when discovery has seen it, else a stable hash of the name (a
    /// pre-fingerprint worker still shards deterministically).
    fn key_for(&self, artifact: &str) -> u64 {
        relock(&self.arts)
            .get(artifact)
            .copied()
            .unwrap_or_else(|| fnv1a64(artifact.as_bytes(), FNV_OFFSET))
    }

    /// One health pass: poll every worker, drive ring weights, and
    /// (until it succeeds) discover the artifact → fingerprint map
    /// from any live worker.
    fn health_pass(&self) {
        let mut live = 0i64;
        for (addr, weight) in &self.workers {
            let up = match http_get_timeout(addr.as_str(), "/healthz", self.health_timeout) {
                // `serving` and `degraded` answer 200 — a degraded
                // worker still serves its healthy lanes, so it keeps
                // its keyspace. `starting`/`draining` answer 503.
                Ok(resp) => resp.status == 200,
                Err(_) => false,
            };
            let mut ring = relock(&self.ring);
            let was =
                ring.members().iter().find(|m| m.name == *addr).map(|m| m.weight).unwrap_or(0);
            let now = if up { (*weight).max(1) } else { 0 };
            if was != now {
                eprintln!(
                    "router: worker {addr} {} (weight {was} → {now})",
                    if up { "joined the ring" } else { "left the ring" },
                );
            }
            ring.set(addr, now);
            live += i64::from(up);
        }
        self.tele.workers_live.set(live);
        self.tele.workers_known.set(self.workers.len() as i64);
        if live > 0 && relock(&self.arts).is_empty() {
            self.discover_artifacts();
        }
    }

    /// Fill the fingerprint map from the first live worker that
    /// answers `/v1/artifacts`. The fleet serves one artifact set, so
    /// one answer is authoritative; workers that predate fingerprints
    /// fall back to the name hash (consistent fleet-wide too).
    fn discover_artifacts(&self) {
        let live: Vec<String> = {
            let ring = relock(&self.ring);
            ring.members()
                .iter()
                .filter(|m| m.weight > 0)
                .map(|m| m.name.clone())
                .collect()
        };
        for addr in live {
            let Ok(resp) = http_get_timeout(addr.as_str(), "/v1/artifacts", self.health_timeout)
            else {
                continue;
            };
            if resp.status != 200 {
                continue;
            }
            let Ok(infos) = artifacts_from_json(&resp.body) else { continue };
            let mut arts = relock(&self.arts);
            for info in infos {
                let fp = info
                    .fingerprint
                    .unwrap_or_else(|| fnv1a64(info.name.as_bytes(), FNV_OFFSET));
                arts.insert(info.name, fp);
            }
            if !arts.is_empty() {
                return;
            }
        }
    }
}

/// A cloneable control handle (the CLI's SIGINT watcher uses this).
#[derive(Clone)]
pub struct RouterHandle {
    shared: Arc<RouterShared>,
}

impl RouterHandle {
    /// Begin graceful drain (idempotent): new jobs get a retryable
    /// 503, in-flight forwards finish.
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }
}

/// A bound router. [`Router::run`] serves until drain.
pub struct Router {
    listener: TcpListener,
    shared: Arc<RouterShared>,
}

impl Router {
    /// Bind the socket and seed the ring (every worker starts at
    /// weight 0 until its first health probe answers).
    pub fn bind(cfg: &RouterConfig) -> Result<Router> {
        telemetry::arm();
        ensure!(!cfg.workers.is_empty(), "router needs at least one --worker");
        ensure!(cfg.replica_walk >= 1, "replica walk must be positive");
        ensure!(cfg.max_attempts >= 1, "max attempts must be positive");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let mut ring = HashRing::new();
        for (addr, _) in &cfg.workers {
            ring.set(addr, 0);
        }
        let shared = Arc::new(RouterShared {
            workers: cfg.workers.clone(),
            ring: Mutex::new(ring),
            arts: Mutex::new(HashMap::new()),
            tele: RouterTele::new(),
            shutdown: AtomicBool::new(false),
            started: AtomicBool::new(false),
            seed: AtomicU64::new(0x9e37_79b9_7f4a_7c15),
            policy: ForwardPolicy {
                hop_cap: Duration::from_millis(cfg.hop_cap_ms.max(1)),
                max_attempts: cfg.max_attempts,
            },
            replica_walk: cfg.replica_walk,
            default_deadline: Duration::from_millis(cfg.default_deadline_ms.max(1)),
            health_interval: Duration::from_millis(cfg.health_interval_ms.max(10)),
            health_timeout: Duration::from_millis(cfg.health_timeout_ms.max(1)),
            read_timeout: Duration::from_millis(cfg.read_timeout_ms.max(1)),
            write_timeout: Duration::from_millis(cfg.write_timeout_ms.max(1)),
        });
        Ok(Router { listener, shared })
    }

    /// The bound address (read the ephemeral port here).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("local_addr")
    }

    /// Control handle for shutdown from other threads.
    pub fn handle(&self) -> RouterHandle {
        RouterHandle { shared: self.shared.clone() }
    }

    /// Serve until a graceful shutdown completes. The first health
    /// pass runs *before* the accept loop opens, so a client that
    /// beats the pollers never sees an all-zero ring on a healthy
    /// fleet.
    pub fn run(self) -> Result<()> {
        let Router { listener, shared } = self;
        shared.health_pass();
        let health = {
            let shared = shared.clone();
            std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(shared.health_interval);
                    shared.health_pass();
                }
            })
        };
        shared.started.store(true, Ordering::SeqCst);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let shared = shared.clone();
                    conns.push(std::thread::spawn(move || {
                        let t0 = Instant::now();
                        if let Err(e) = serve_connection(stream, &shared) {
                            eprintln!("router: connection error: {e:#}");
                        }
                        shared.tele.request_seconds.record(t0.elapsed());
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("router: accept error (continuing): {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
            if conns.len() >= 64 {
                conns.retain(|h| !h.is_finished());
            }
        }
        // Drain: let in-flight forwards relay their answers.
        for conn in conns {
            let _ = conn.join();
        }
        let _ = health.join();
        eprintln!("router: drained");
        Ok(())
    }
}

fn serve_connection(stream: TcpStream, shared: &RouterShared) -> Result<()> {
    stream.set_read_timeout(Some(shared.read_timeout))?;
    stream.set_write_timeout(Some(shared.write_timeout))?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut out = stream;
    let req = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let status = read_error_status(&e);
            let code = match status {
                408 => ErrorCode::RequestTimeout,
                413 => ErrorCode::TooLarge,
                _ => ErrorCode::BadRequest,
            };
            let se = ServeError::new(code, format!("{e:#}"));
            let _ = write_response(&mut out, status, &se.to_json());
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let (status, body) = router_health(shared);
            write_response(&mut out, status, &body)
        }
        ("GET", "/v1/stats") => {
            let body = aggregate_stats(shared);
            write_response(&mut out, 200, &body)
        }
        ("GET", "/metrics") => {
            let body = prometheus::render(&registry().snapshot());
            write_response_typed(&mut out, 200, prometheus::CONTENT_TYPE, &body)
        }
        ("GET", "/v1/artifacts") => relay_artifacts(&mut out, shared),
        ("POST", "/v1/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            write_response(&mut out, 200, "{\"draining\":true}")
        }
        ("POST", "/v1/simulate") => handle_simulate(&mut out, &req.body, shared),
        ("GET" | "POST", _) => {
            write_response(&mut out, 404, &error_body("no such endpoint", false))
        }
        _ => write_response(&mut out, 405, &error_body("method not allowed", false)),
    }
}

/// Router `/healthz`: `starting` before the accept loop, `draining`
/// after shutdown, `degraded` when some (not all) workers are out of
/// the ring, `serving` with a full ring — plus `down` (503) when *no*
/// worker is live, which is the one state a worker can't have.
fn router_health(shared: &RouterShared) -> (u16, String) {
    let live = { relock(&shared.ring).live_members() };
    let known = shared.workers.len();
    let (status, state) = if shared.shutdown.load(Ordering::SeqCst) {
        (503, "draining")
    } else if !shared.started.load(Ordering::SeqCst) {
        (503, "starting")
    } else if live == 0 {
        (503, "down")
    } else if live < known {
        (200, "degraded")
    } else {
        (200, "serving")
    };
    (
        status,
        format!(
            "{{\"ok\":{},\"status\":\"{state}\",\"workers_live\":{live},\"workers_known\":{known}}}",
            status == 200
        ),
    )
}

/// Aggregate `/v1/stats` across the fleet: monotonic counters sum,
/// residency gauges sum, and the per-worker snapshots ride along under
/// `"workers"`. The rollup parses as a plain [`StatsSnapshot`], so
/// `tao loadgen` pointed at a router measures the fleet unchanged.
fn aggregate_stats(shared: &RouterShared) -> String {
    let mut total = StatsSnapshot::default();
    let mut workers = BTreeMap::new();
    let mut polled = 0u64;
    for (addr, _) in &shared.workers {
        let resp = match http_get_timeout(addr.as_str(), "/v1/stats", shared.health_timeout) {
            Ok(r) if r.status == 200 => r,
            _ => {
                workers.insert(addr.clone(), Json::Null);
                continue;
            }
        };
        let Ok(s) = StatsSnapshot::from_json(&resp.body) else {
            workers.insert(addr.clone(), Json::Null);
            continue;
        };
        polled += 1;
        total.jobs_submitted += s.jobs_submitted;
        total.jobs_done += s.jobs_done;
        total.jobs_rejected += s.jobs_rejected;
        total.queue_depth += s.queue_depth;
        total.active_jobs += s.active_jobs;
        total.batches += s.batches;
        total.packed_windows += s.packed_windows;
        total.batch_slots += s.batch_slots;
        total.cache_hits += s.cache_hits;
        total.cache_misses += s.cache_misses;
        total.cache_evictions += s.cache_evictions;
        total.cache_entries += s.cache_entries;
        total.cache_recovered += s.cache_recovered;
        total.lane_restarts += s.lane_restarts;
        let peer_hits = Json::parse(&resp.body)
            .ok()
            .and_then(|j| j.get("cache_peer_hits").and_then(Json::as_u64))
            .unwrap_or(0);
        workers.insert(
            addr.clone(),
            Json::obj([
                ("jobs_done", Json::of_u64(s.jobs_done)),
                ("jobs_rejected", Json::of_u64(s.jobs_rejected)),
                ("cache_hits", Json::of_u64(s.cache_hits)),
                ("cache_misses", Json::of_u64(s.cache_misses)),
                ("cache_peer_hits", Json::of_u64(peer_hits)),
                ("batches", Json::of_u64(s.batches)),
                ("lane_restarts", Json::of_u64(s.lane_restarts)),
            ]),
        );
    }
    total.to_json_with(vec![
        ("workers", Json::Obj(workers)),
        ("workers_polled", Json::of_u64(polled)),
    ])
}

/// Relay `/v1/artifacts` from the first live worker (the fleet serves
/// one artifact set).
fn relay_artifacts(out: &mut TcpStream, shared: &RouterShared) -> Result<()> {
    let live: Vec<String> = {
        let ring = relock(&shared.ring);
        ring.members().iter().filter(|m| m.weight > 0).map(|m| m.name.clone()).collect()
    };
    for addr in live {
        if let Ok(resp) = http_get_timeout(addr.as_str(), "/v1/artifacts", shared.health_timeout)
        {
            if resp.status == 200 {
                return write_response(out, 200, &resp.body);
            }
        }
    }
    let se = ServeError::new(ErrorCode::Draining, "no live workers on the ring");
    write_response(out, se.code.http_status(), &se.to_json())
}

fn handle_simulate(out: &mut TcpStream, body: &str, shared: &RouterShared) -> Result<()> {
    if shared.shutdown.load(Ordering::SeqCst) {
        let se = ServeError::new(ErrorCode::Draining, "router draining");
        return write_response(out, se.code.http_status(), &se.to_json());
    }
    // Routing needs only the artifact name and deadline; full spec
    // validation stays on the worker, so router and worker never skew
    // on what a valid job is.
    let parsed = Json::parse(body).ok();
    let artifact = parsed
        .as_ref()
        .and_then(|j| j.get("artifact").and_then(Json::as_str))
        .unwrap_or("");
    let deadline_ms = parsed
        .as_ref()
        .and_then(|j| j.get("deadline_ms").and_then(Json::as_u64))
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(shared.default_deadline);
    let key = shared.key_for(artifact);
    let replicas: Vec<String> = {
        let ring = relock(&shared.ring);
        ring.replicas(key, shared.replica_walk).into_iter().map(str::to_string).collect()
    };
    // Per-request decorrelated jitter, deterministic per (key, seq).
    let seq = shared.seed.fetch_add(1, Ordering::Relaxed);
    let mut rng = Rng::new(key ^ seq.rotate_left(32));
    let fwd = forward(
        &replicas,
        "/v1/simulate",
        body,
        Instant::now() + deadline_ms,
        &shared.policy,
        &mut rng,
    );
    write_response(out, fwd.status, &fwd.body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peer_map_is_self_free_distinct_and_stable() {
        let workers: Vec<(String, u32)> = ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"]
            .iter()
            .map(|a| (a.to_string(), 1))
            .collect();
        let peers = peer_map(&workers, 2);
        assert_eq!(peers.len(), 3);
        for (me, ps) in &peers {
            assert_eq!(ps.len(), 2, "{me} gets both siblings");
            assert!(!ps.contains(me), "{me} must not peer with itself");
            let mut uniq = ps.clone();
            uniq.dedup();
            assert_eq!(&uniq, ps);
        }
        // Stable: recomputing yields the identical wiring.
        assert_eq!(peers, peer_map(&workers, 2));
        // A single worker has nobody to peer with.
        let solo = peer_map(&[("127.0.0.1:7001".to_string(), 1)], 2);
        assert!(solo["127.0.0.1:7001"].is_empty());
    }
}
