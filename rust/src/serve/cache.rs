//! Chunk-level prediction cache (LRU).
//!
//! Tao's economic argument is that one functional trace is generated
//! once and reused across microarchitectures (PAPER.md §4.1). The
//! serving cache operationalizes that at chunk granularity: the
//! per-chunk *prediction accumulator* — the folded model outputs for
//! every window whose instruction lands in the chunk — is memoized
//! under a key that pins down everything the predictions depend on:
//!
//! * **artifact fingerprint** — which model bytes ran;
//! * **warm-up prefix hash** — a rolling hash over every chunk the
//!   stream pulled before this one. Extractor and window-history state
//!   at a chunk boundary is a pure function of the whole prefix, so
//!   equal prefix hash + equal content ⇒ byte-identical staged windows
//!   ⇒ identical predictions. This is the exact-state analogue of the
//!   engine's warm-up overlap re-run — nothing is approximated;
//! * **chunk content hash** — the chunk's column bytes plus, for
//!   SimNet, its µarch-specific context rows (so jobs against
//!   different detailed designs key separately, while Tao jobs reuse
//!   the µarch-agnostic functional chunks across design sweeps).
//!
//! A hit replays the accumulator via the order-independent
//! [`PredAccum::merge`](crate::coordinator::engine::PredAccum::merge)
//! and skips model execution entirely; the consumer fast-forwards its
//! extractor state with
//! [`WindowStager::advance_only`](crate::coordinator::engine::WindowStager)
//! (exact, state-only), so a later miss resumes bit-for-bit.
//!
//! With a [`CacheJournal`](super::journal::CacheJournal) attached,
//! every fresh insert is also appended to an on-disk journal and a
//! restarted daemon warm-loads the recovered entries — the cache
//! survives crashes without changing a single served bit (keys embed
//! the artifact fingerprint, so stale model bytes simply never hit).

use super::journal::CacheJournal;
use crate::coordinator::engine::PredAccum;
use crate::trace::ChunkBuf;
use crate::util::hash::{fnv1a64, fnv1a64_u64, FNV_OFFSET};
use std::collections::HashMap;

/// Cache key: (artifact fingerprint, warm-up prefix hash, chunk
/// content hash). See the module docs for what each part pins down.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ChunkKey {
    /// Artifact (model bytes) fingerprint.
    pub artifact: u64,
    /// Rolling hash of every prior chunk's content hash.
    pub prefix: u64,
    /// This chunk's content hash.
    pub content: u64,
}

/// Hash a pulled chunk's content: every record column, plus the ctx
/// side channel when the source carries one.
pub fn hash_chunk(buf: &ChunkBuf) -> u64 {
    let mut h = fnv1a64_u64(buf.cols.len() as u64, FNV_OFFSET);
    for i in 0..buf.cols.len() {
        h = fnv1a64_u64(buf.cols.pc[i], h);
        h = fnv1a64(&[buf.cols.opcode[i], buf.cols.mem_bytes[i], buf.cols.taken[i]], h);
        h = fnv1a64_u64(buf.cols.reg_bitmap[i], h);
        h = fnv1a64_u64(buf.cols.mem_addr[i], h);
    }
    for v in &buf.ctx {
        h = fnv1a64(&v.to_le_bytes(), h);
    }
    h
}

/// Advance a warm-up prefix hash past a chunk with the given content
/// hash (the rolling chain that makes [`ChunkKey::prefix`]).
pub fn chain_prefix(prefix: u64, content: u64) -> u64 {
    fnv1a64_u64(content, prefix)
}

/// The prefix hash of an empty stream.
pub const PREFIX_SEED: u64 = FNV_OFFSET;

/// Cumulative cache counters (monotonic; snapshot for deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found an entry (including adopted peer hits).
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Entries inserted.
    pub insertions: u64,
    /// Entries evicted by capacity pressure.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
    /// Entries replayed from the crash-safe journal at startup.
    pub recovered: u64,
    /// Local misses converted to hits by a ring peer's cache
    /// ([`PredictionCache::adopt`]) — the fleet-warm subset of `hits`.
    pub peer_hits: u64,
}

/// Approximate resident bytes per cache entry: 24-byte key + the
/// accumulator's journal-frame scalars + map and recency-list
/// overhead. `--cache-quota artifact=BYTES` divides by this to turn a
/// byte budget into an entry quota.
pub const ENTRY_BYTES: u64 = 160;

/// Per-artifact cache counters (one registered tenant's view).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactCacheStats {
    /// Artifact registry name.
    pub name: String,
    /// Artifact fingerprint (the [`ChunkKey::artifact`] it keys on).
    pub fingerprint: u64,
    /// Entry quota (0 = unlimited).
    pub quota: u64,
    /// Entries currently resident for this artifact.
    pub entries: u64,
    /// Lookups for this artifact that hit.
    pub hits: u64,
    /// Lookups for this artifact that missed.
    pub misses: u64,
    /// Entries inserted for this artifact.
    pub insertions: u64,
    /// Entries evicted (quota or global capacity pressure).
    pub evictions: u64,
}

struct ArtState {
    name: String,
    /// Max resident entries; 0 = unlimited.
    quota: usize,
    entries: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    /// Per-artifact recency list head (most recently used).
    head: usize,
    /// Per-artifact recency list tail (least recently used).
    tail: usize,
}

struct Slot {
    key: ChunkKey,
    value: PredAccum,
    prev: usize,
    next: usize,
    /// Per-artifact recency links (NIL/NIL when the slot's artifact is
    /// not registered).
    aprev: usize,
    anext: usize,
}

const NIL: usize = usize::MAX;

/// A fixed-capacity LRU map from [`ChunkKey`] to the chunk's folded
/// prediction accumulator. Intrusive doubly-linked recency list over a
/// slot arena: get/insert are O(1); eviction drops the least recently
/// used entry. `capacity == 0` disables the cache (every lookup
/// misses, nothing is stored).
pub struct PredictionCache {
    capacity: usize,
    map: HashMap<ChunkKey, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
    stats: CacheStats,
    journal: Option<CacheJournal>,
    /// Registered tenants by artifact fingerprint: quota enforcement +
    /// per-artifact accounting. Unregistered artifacts are cached
    /// unconstrained (global LRU only).
    arts: HashMap<u64, ArtState>,
}

impl PredictionCache {
    /// Cache holding at most `capacity` chunk entries.
    pub fn new(capacity: usize) -> PredictionCache {
        PredictionCache {
            capacity,
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            stats: CacheStats::default(),
            journal: None,
            arts: HashMap::new(),
        }
    }

    /// Register an artifact tenant: entries keyed on `fingerprint` get
    /// per-artifact hit/miss/evict accounting and, when
    /// `quota_entries > 0`, their own LRU capped at that many entries —
    /// one hot tenant can no longer evict the others. Call at bind
    /// time, before warm-loading or serving, so every resident entry is
    /// accounted.
    pub fn register_artifact(&mut self, fingerprint: u64, name: &str, quota_entries: usize) {
        debug_assert!(
            !self.map.keys().any(|k| k.artifact == fingerprint),
            "register_artifact after entries for it exist"
        );
        self.arts.insert(
            fingerprint,
            ArtState {
                name: name.to_string(),
                quota: quota_entries,
                entries: 0,
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
                head: NIL,
                tail: NIL,
            },
        );
    }

    /// Per-artifact counters for every registered tenant, sorted by
    /// name (deterministic `/v1/stats` and `/metrics` rendering).
    pub fn artifact_stats(&self) -> Vec<ArtifactCacheStats> {
        let mut out: Vec<ArtifactCacheStats> = self
            .arts
            .iter()
            .map(|(&fp, a)| ArtifactCacheStats {
                name: a.name.clone(),
                fingerprint: fp,
                quota: a.quota as u64,
                entries: a.entries as u64,
                hits: a.hits,
                misses: a.misses,
                insertions: a.insertions,
                evictions: a.evictions,
            })
            .collect();
        out.sort_by(|x, y| x.name.cmp(&y.name));
        out
    }

    /// Replay journal-recovered entries (append order, so a duplicated
    /// key resolves last-wins) without re-journaling them. Returns the
    /// number replayed. Call *before* [`PredictionCache::attach_journal`].
    pub fn warm_load(&mut self, entries: Vec<(ChunkKey, PredAccum)>) -> usize {
        debug_assert!(self.journal.is_none(), "warm_load would re-journal recovered entries");
        let n = entries.len();
        for (key, value) in entries {
            self.insert(key, value);
        }
        self.stats.recovered += n as u64;
        n
    }

    /// Attach an open journal: every subsequent fresh insert is
    /// appended to it. An append failure disables persistence for the
    /// rest of the process (logged once) — serving never stops for a
    /// full disk.
    pub fn attach_journal(&mut self, journal: CacheJournal) {
        self.journal = Some(journal);
    }

    /// Flush the journal to stable storage, if one is attached.
    pub fn sync_journal(&mut self) -> anyhow::Result<()> {
        match &mut self.journal {
            Some(j) => j.sync(),
            None => Ok(()),
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            entries: self.map.len() as u64,
            ..self.stats
        }
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Detach slot `i` from its artifact's recency list (no-op for
    /// unregistered artifacts, whose links are always NIL).
    fn aunlink(&mut self, i: usize) {
        let fp = self.slots[i].key.artifact;
        if !self.arts.contains_key(&fp) {
            return;
        }
        let (prev, next) = (self.slots[i].aprev, self.slots[i].anext);
        if prev == NIL {
            self.arts.get_mut(&fp).unwrap().head = next;
        } else {
            self.slots[prev].anext = next;
        }
        if next == NIL {
            self.arts.get_mut(&fp).unwrap().tail = prev;
        } else {
            self.slots[next].aprev = prev;
        }
    }

    /// Push slot `i` to the front of its artifact's recency list
    /// (no-op for unregistered artifacts).
    fn apush_front(&mut self, i: usize) {
        let fp = self.slots[i].key.artifact;
        let head = match self.arts.get(&fp) {
            Some(a) => a.head,
            None => return,
        };
        self.slots[i].aprev = NIL;
        self.slots[i].anext = head;
        if head != NIL {
            self.slots[head].aprev = i;
        }
        let art = self.arts.get_mut(&fp).unwrap();
        art.head = i;
        if art.tail == NIL {
            art.tail = i;
        }
    }

    /// Remove slot `i` entirely, counting an eviction (global and, when
    /// registered, per-artifact).
    fn evict_slot(&mut self, i: usize) {
        self.unlink(i);
        self.aunlink(i);
        self.map.remove(&self.slots[i].key);
        if let Some(art) = self.arts.get_mut(&self.slots[i].key.artifact) {
            art.entries -= 1;
            art.evictions += 1;
        }
        self.free.push(i);
        self.stats.evictions += 1;
    }

    /// Look up a chunk, refreshing its recency. Returns a clone of the
    /// stored accumulator (cheap: a handful of scalars; phase series
    /// are never cached).
    pub fn get(&mut self, key: &ChunkKey) -> Option<PredAccum> {
        match self.map.get(key).copied() {
            Some(i) => {
                self.stats.hits += 1;
                if let Some(art) = self.arts.get_mut(&key.artifact) {
                    art.hits += 1;
                }
                self.unlink(i);
                self.push_front(i);
                self.aunlink(i);
                self.apush_front(i);
                Some(self.slots[i].value.clone())
            }
            None => {
                self.stats.misses += 1;
                if let Some(art) = self.arts.get_mut(&key.artifact) {
                    art.misses += 1;
                }
                None
            }
        }
    }

    /// Look up a chunk **without** counting or refreshing recency — the
    /// `/v1/cache/lookup` peer endpoint. A peer probe must not perturb
    /// this daemon's hit/miss accounting (the structural identity
    /// `hits + misses == chunks` is asserted in CI) or its LRU order.
    pub fn peek(&self, key: &ChunkKey) -> Option<&PredAccum> {
        self.map.get(key).map(|&i| &self.slots[i].value)
    }

    /// Adopt a peer-supplied accumulator for a key this cache just
    /// missed on: the immediately-preceding [`PredictionCache::get`]
    /// miss is reclassified as a (peer) hit, and the value is inserted
    /// locally (journaled, quota-enforced) so the next lookup hits
    /// without leaving the process.
    pub fn adopt(&mut self, key: ChunkKey, value: PredAccum) {
        self.stats.misses = self.stats.misses.saturating_sub(1);
        self.stats.hits += 1;
        self.stats.peer_hits += 1;
        if let Some(art) = self.arts.get_mut(&key.artifact) {
            art.misses = art.misses.saturating_sub(1);
            art.hits += 1;
        }
        self.insert(key, value);
    }

    /// Insert a fully-folded chunk accumulator, evicting the LRU entry
    /// at capacity (the artifact's own LRU tail first when its quota is
    /// exhausted). Re-inserting an existing key refreshes it.
    pub fn insert(&mut self, key: ChunkKey, value: PredAccum) {
        if self.capacity == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            self.unlink(i);
            self.push_front(i);
            self.aunlink(i);
            self.apush_front(i);
            return;
        }
        if let Some(j) = &mut self.journal {
            // Journal only fresh inserts: a refresh stores the same
            // deterministic value, and evicted entries stay replayable.
            if let Err(e) = j.append(&key, &value) {
                eprintln!("tao serve: cache journal append failed, persistence disabled: {e:#}");
                self.journal = None;
            }
        }
        if let Some(art) = self.arts.get(&key.artifact) {
            if art.quota > 0 && art.entries >= art.quota {
                let victim = art.tail;
                debug_assert_ne!(victim, NIL);
                self.evict_slot(victim);
            }
        }
        if self.map.len() >= self.capacity {
            let lru = self.tail;
            debug_assert_ne!(lru, NIL);
            self.evict_slot(lru);
        }
        let slot = Slot { key, value, prev: NIL, next: NIL, aprev: NIL, anext: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = slot;
                i
            }
            None => {
                self.slots.push(slot);
                self.slots.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
        self.apush_front(i);
        if let Some(art) = self.arts.get_mut(&key.artifact) {
            art.entries += 1;
            art.insertions += 1;
        }
        self.stats.insertions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::runtime::{ModelKind, ModelOutputs};

    fn key(n: u64) -> ChunkKey {
        ChunkKey { artifact: 1, prefix: 2, content: n }
    }

    fn accum(insts: u64) -> PredAccum {
        let n = insts as usize;
        let mut a = PredAccum::default();
        let out = ModelOutputs {
            fetch: vec![2.0; n],
            exec: vec![1.0; n],
            branch: vec![0.0; n],
            access: vec![0.0; n * 4],
            icache: vec![0.0; n],
            tlb: vec![0.0; n],
        };
        a.absorb(&out, ModelKind::Tao);
        a
    }

    #[test]
    fn hit_and_miss_counting() {
        let mut c = PredictionCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), accum(10));
        let got = c.get(&key(1)).unwrap();
        assert_eq!(got.instructions, 10);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions, s.entries), (1, 1, 1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = PredictionCache::new(2);
        c.insert(key(1), accum(1));
        c.insert(key(2), accum(2));
        // Touch 1 so 2 becomes LRU.
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), accum(3));
        assert!(c.get(&key(2)).is_none(), "LRU entry must be evicted");
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = PredictionCache::new(2);
        c.insert(key(1), accum(1));
        c.insert(key(2), accum(2));
        c.insert(key(1), accum(11));
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.get(&key(1)).unwrap().instructions, 11);
    }

    #[test]
    fn zero_capacity_disables_storage() {
        let mut c = PredictionCache::new(0);
        c.insert(key(1), accum(1));
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn chunk_hash_sensitive_to_columns_and_ctx() {
        use crate::trace::ChunkBuf;
        let mut a = ChunkBuf::new();
        a.cols.push_fields(0x400000, 3, 0b11, 0, 0, false);
        let mut b = ChunkBuf::new();
        b.cols.push_fields(0x400000, 3, 0b11, 0, 0, true);
        assert_ne!(hash_chunk(&a), hash_chunk(&b));
        let base = hash_chunk(&a);
        a.ctx.extend_from_slice(&[1.0; 6]);
        assert_ne!(hash_chunk(&a), base, "ctx rows must key the chunk");
        // Prefix chaining is order-sensitive.
        assert_ne!(
            chain_prefix(chain_prefix(PREFIX_SEED, 1), 2),
            chain_prefix(chain_prefix(PREFIX_SEED, 2), 1)
        );
    }

    #[test]
    fn journal_round_trip_restores_hits() {
        let _gate = crate::util::fault::exclusive();
        crate::util::fault::disarm_all();
        let dir =
            std::env::temp_dir().join(format!("tao-cache-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.journal");
        let _ = std::fs::remove_file(&path);

        // First life: populate a journaled cache.
        let (journal, rec) = CacheJournal::open(&path).unwrap();
        assert!(rec.entries.is_empty());
        let mut c = PredictionCache::new(8);
        c.attach_journal(journal);
        for n in 1..=3 {
            c.insert(key(n), accum(n));
        }
        c.get(&key(1)); // refreshes are not journaled
        c.insert(key(2), accum(2));
        c.sync_journal().unwrap();
        drop(c);

        // Second life: recover, warm-load, and hit without recompute.
        let (journal, rec) = CacheJournal::open(&path).unwrap();
        assert_eq!(rec.entries.len(), 3, "one record per fresh insert");
        assert_eq!(rec.truncated_bytes, 0);
        let mut c = PredictionCache::new(8);
        assert_eq!(c.warm_load(rec.entries), 3);
        c.attach_journal(journal);
        let s = c.stats();
        assert_eq!((s.recovered, s.entries), (3, 3));
        for n in 1..=3 {
            let got = c.get(&key(n)).expect("recovered entry must hit");
            assert_eq!(got.instructions, accum(n).instructions);
            assert_eq!(got.fetch_cycles.to_bits(), accum(n).fetch_cycles.to_bits());
        }
    }

    fn akey(art: u64, n: u64) -> ChunkKey {
        ChunkKey { artifact: art, prefix: 2, content: n }
    }

    #[test]
    fn artifact_quota_walls_off_tenants() {
        let mut c = PredictionCache::new(16);
        c.register_artifact(7, "hot", 2);
        c.register_artifact(8, "cold", 4);
        // The hot tenant pours in entries; only its own LRU churns.
        for n in 0..4 {
            c.insert(akey(8, n), accum(n + 1));
        }
        for n in 0..10 {
            c.insert(akey(7, n), accum(n + 1));
        }
        // Cold tenant untouched despite the hot tenant's pressure.
        for n in 0..4 {
            assert!(c.get(&akey(8, n)).is_some(), "cold tenant entry {n} evicted");
        }
        // Hot tenant holds exactly its quota: the 2 most recent.
        assert!(c.get(&akey(7, 9)).is_some());
        assert!(c.get(&akey(7, 8)).is_some());
        assert!(c.get(&akey(7, 0)).is_none());
        let stats: Vec<_> = c.artifact_stats();
        assert_eq!(stats.len(), 2);
        // Sorted by name: cold first.
        assert_eq!((stats[0].name.as_str(), stats[0].entries), ("cold", 4));
        assert_eq!(stats[0].evictions, 0);
        assert_eq!((stats[1].name.as_str(), stats[1].entries), ("hot", 2));
        assert_eq!(stats[1].evictions, 8);
        assert_eq!(stats[1].insertions, 10);
        // Global evictions count the quota evictions too.
        assert_eq!(c.stats().evictions, 8);
    }

    #[test]
    fn unregistered_artifacts_stay_unconstrained() {
        let mut c = PredictionCache::new(4);
        c.register_artifact(7, "quoted", 1);
        for n in 0..3 {
            c.insert(akey(99, n), accum(1));
        }
        assert_eq!(c.stats().entries, 3);
        assert_eq!(c.stats().evictions, 0);
        // Global capacity still evicts across tenants, LRU-first.
        c.insert(akey(7, 0), accum(1));
        c.insert(akey(7, 1), accum(1)); // quota evicts akey(7, 0)
        c.insert(akey(99, 3), accum(1)); // capacity evicts akey(99, 0)
        assert!(c.get(&akey(99, 0)).is_none());
        assert!(c.get(&akey(7, 1)).is_some());
        assert_eq!(c.artifact_stats()[0].entries, 1);
    }

    #[test]
    fn peek_counts_nothing_and_keeps_recency() {
        let mut c = PredictionCache::new(2);
        c.insert(key(1), accum(1));
        c.insert(key(2), accum(2));
        // Peek at 1 — unlike get, this must NOT make key 1 recent.
        assert_eq!(c.peek(&key(1)).unwrap().instructions, 1);
        assert!(c.peek(&key(9)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "peek is invisible to accounting");
        c.insert(key(3), accum(3));
        assert!(c.peek(&key(1)).is_none(), "peek must not refresh recency");
        assert!(c.peek(&key(2)).is_some());
    }

    #[test]
    fn adopt_reclassifies_a_miss_as_peer_hit() {
        let mut c = PredictionCache::new(4);
        c.register_artifact(1, "a", 0);
        assert!(c.get(&key(1)).is_none()); // the local miss...
        c.adopt(key(1), accum(5)); // ...answered by a ring peer
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.peer_hits), (1, 0, 1));
        assert_eq!(s.insertions, 1);
        // hits + misses still equals the one lookup performed.
        assert_eq!(s.hits + s.misses, 1);
        let a = &c.artifact_stats()[0];
        assert_eq!((a.hits, a.misses), (1, 0));
        // The adopted entry is now resident locally.
        assert_eq!(c.get(&key(1)).unwrap().instructions, 5);
    }

    #[test]
    fn many_inserts_stay_bounded() {
        let mut c = PredictionCache::new(8);
        for i in 0..100 {
            c.insert(key(i), accum(i));
            if i >= 3 {
                // Keep a couple of keys hot; they must survive.
                c.get(&key(i - 1));
                c.get(&key(i - 2));
            }
        }
        let s = c.stats();
        assert_eq!(s.entries, 8);
        assert_eq!(s.insertions, 100);
        assert_eq!(s.evictions, 92);
        assert!(c.get(&key(99)).is_some());
    }
}
