//! `tao serve` — the concurrent simulation service (Layer 3's
//! always-on face).
//!
//! The paper's economics say a functional trace is generated once and
//! reused across microarchitectures; NeuroScalar frames DL performance
//! prediction as an in-the-wild *service*, not an offline tool. This
//! subsystem turns the PR 1–3 streaming pipeline into that service: a
//! multi-client daemon speaking hand-rolled HTTP/1.1 + `util::json`
//! over `std::net` (zero new dependencies), built from five pieces:
//!
//! * [`protocol`] — wire types; bit-exact `f64` metric round-trips.
//! * [`http`] — the minimal HTTP/1.1 server/client layer.
//! * [`queue`] — bounded admission with backpressure (429/503s
//!   instead of unbounded memory).
//! * [`scheduler`] — per-artifact lanes that pack context windows
//!   **across concurrent jobs** into the fixed-`B` model batch and
//!   demux outputs to per-job accumulators; execution runs through the
//!   shared engine-level double-buffered
//!   [`ExecPipeline`](crate::coordinator::pipeline::ExecPipeline)
//!   (staging overlaps model execution), and job preparation runs on a
//!   bounded prep stage off the lane thread.
//! * [`cache`] — the LRU chunk-level prediction cache keyed by
//!   (artifact, warm-up prefix, chunk content): repeated trace regions
//!   across requests and design sweeps skip model execution entirely,
//!   with results *identical* to the offline engine.
//! * [`journal`] — the crash-safe on-disk journal behind the cache:
//!   CRC-framed appends, torn-tail truncation on recovery, warm-load
//!   at startup.
//!
//! The daemon is built to *degrade*, not die: jobs carry deadlines,
//! failures are typed retryable/terminal ([`protocol::ServeError`]),
//! panicked lanes are isolated and respawned by a supervisor, and
//! every failure mode is rehearsable via [`crate::util::fault`]
//! probes. Under faults and retries, every successfully served result
//! is still bit-identical to the offline engine.
//!
//! One daemon caps throughput at one box; the router tier shards the
//! service horizontally:
//!
//! * [`ring`] — weighted consistent-hash ring keyed by artifact
//!   fingerprint (membership churn moves only the affected keys).
//! * [`forward`] — per-hop deadline-budgeted forwarding with failover
//!   on retryable codes, plus the peer-cache lookup client.
//! * [`router`] — the `tao router` daemon: health-checks workers into
//!   and out of the ring, forwards `/v1/simulate`, aggregates
//!   `/v1/stats`, serves its own `/metrics`.
//!
//! Workers peer their prediction caches over `/v1/cache/lookup` (a
//! local miss consults the key's ring neighbours before computing), so
//! the fleet's cache is warm wherever the ring places a key.
//!
//! [`server`] wires them together; [`loadgen`] is the measurement +
//! chaos client (`BENCH_serve.json`); [`cli`] holds the `tao serve` /
//! `tao router` / `tao loadgen` entry points.

pub mod cache;
pub mod cli;
pub mod forward;
pub mod http;
pub mod journal;
pub mod loadgen;
pub mod protocol;
pub mod queue;
pub mod ring;
pub mod router;
pub mod scheduler;
pub mod server;

pub use cache::PredictionCache;
pub use forward::PeerCache;
pub use journal::CacheJournal;
pub use protocol::{ErrorCode, JobOutcome, JobSpec, ServeError, StatsSnapshot};
pub use queue::JobQueue;
pub use ring::HashRing;
pub use router::{Router, RouterConfig};
pub use scheduler::{LaneConfig, ServeCounters};
pub use server::{Server, ServeConfig};
