//! The serving daemon: socket accept loop, request routing, graceful
//! drain.
//!
//! Threading model: one accept loop (non-blocking poll so shutdown is
//! observed promptly), one short-lived thread per connection (a
//! connection is one request: parse → validate → submit → block on the
//! job's completion channel → respond), one lane thread per artifact
//! plus its pipelined executor ([`super::scheduler`]).
//!
//! Graceful shutdown (`POST /v1/shutdown`, or SIGINT via the CLI):
//! stop accepting, close the admission queue — new submits get a
//! retryable 503 — let lanes finish the backlog and every in-flight
//! job, join everything, and flush the final stats (cache hit rates,
//! packing occupancy) to stderr and to the caller.

use super::cache::PredictionCache;
use super::http::{read_request, write_response};
use super::protocol::{error_body, validate_spec, JobSpec, StatsSnapshot};
use super::queue::{JobQueue, QueuedJob, SubmitError};
use super::scheduler::{run_lane, LaneConfig, ServeCounters};
use crate::runtime::ArtifactPool;
use anyhow::{ensure, Context, Result};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` for an ephemeral port).
    pub addr: String,
    /// Admission-queue capacity (backpressure bound).
    pub queue_depth: usize,
    /// Concurrent jobs packed per lane.
    pub max_active: usize,
    /// Prediction-cache capacity in chunk entries (0 disables).
    pub cache_entries: usize,
    /// Largest `insts` a request may ask for.
    pub max_insts: u64,
    /// Double-buffered executor threads.
    pub pipeline: bool,
    /// Lane batch-formation window, milliseconds.
    pub admission_wait_ms: u64,
    /// Jobs prepared off the lane thread ahead of admission (bounds
    /// resident prepared-but-unadmitted jobs; 0 prepares inline).
    pub prep_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            queue_depth: 64,
            max_active: 16,
            cache_entries: 1024,
            max_insts: 10_000_000,
            pipeline: true,
            admission_wait_ms: 2,
            prep_depth: 2,
        }
    }
}

struct Shared {
    pool: ArtifactPool,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    shutdown: AtomicBool,
    max_insts: u64,
}

/// A cloneable control handle: request shutdown / read stats from
/// outside the accept loop (the CLI's SIGINT watcher uses this).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Begin graceful drain (idempotent).
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Current serving counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared
            .counters
            .snapshot(&self.shared.queue, &self.shared.cache)
    }
}

/// A bound, lanes-running daemon. [`Server::run`] serves until drain.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    lanes: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Bind the socket and start one lane per pooled artifact.
    pub fn bind(pool: ArtifactPool, cfg: &ServeConfig) -> Result<Server> {
        ensure!(!pool.is_empty(), "serve needs at least one --model artifact");
        ensure!(cfg.queue_depth >= 1, "queue depth must be positive");
        ensure!(cfg.max_active >= 1, "max active jobs must be positive");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let queue = Arc::new(JobQueue::new(cfg.queue_depth));
        let cache = Arc::new(Mutex::new(PredictionCache::new(cfg.cache_entries)));
        let counters = Arc::new(ServeCounters::default());
        let lane_cfg = LaneConfig {
            max_active: cfg.max_active,
            pipeline: cfg.pipeline,
            admission_wait: Duration::from_millis(cfg.admission_wait_ms),
            prep_depth: cfg.prep_depth,
        };
        let mut lanes = Vec::new();
        for art in pool.iter() {
            let art = art.clone();
            let queue = queue.clone();
            let cache = cache.clone();
            let counters = counters.clone();
            lanes.push(std::thread::spawn(move || {
                run_lane(art, queue, cache, counters, lane_cfg)
            }));
        }
        let shared = Arc::new(Shared {
            pool,
            queue,
            cache,
            counters,
            shutdown: AtomicBool::new(false),
            max_insts: cfg.max_insts,
        });
        Ok(Server { listener, shared, lanes })
    }

    /// The bound address (read the ephemeral port here).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("local_addr")
    }

    /// Control handle for shutdown/stats from other threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    /// Serve until a graceful shutdown completes; returns the final
    /// counter snapshot after the drain.
    pub fn run(self) -> Result<StatsSnapshot> {
        let Server { listener, shared, lanes } = self;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut draining = false;
        loop {
            // Keep accepting through the drain: connections racing the
            // shutdown get the documented retryable 503 (and stats and
            // health stay readable) instead of a reset from the
            // listener's backlog. The loop ends once every lane has
            // finished its backlog and in-flight jobs.
            if !draining && shared.shutdown.load(Ordering::SeqCst) {
                draining = true;
                shared.queue.close();
            }
            if draining && lanes.iter().all(|h| h.is_finished()) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    // The listener is non-blocking (shutdown polling);
                    // accepted sockets must not inherit that (they do
                    // on some platforms).
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let shared = shared.clone();
                    conns.push(std::thread::spawn(move || {
                        handle_connection(stream, &shared);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    // EMFILE/ECONNABORTED and friends are transient
                    // overload, not reasons to drop every in-flight
                    // job — log, back off, keep serving. A wedged
                    // socket still exits via /v1/shutdown or SIGINT.
                    eprintln!("serve: accept error (continuing): {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
            if conns.len() >= 64 {
                conns.retain(|h| !h.is_finished());
            }
        }

        // Lanes have drained (backlog + in-flight all answered); stop
        // accepting, join everything, flush stats.
        for lane in lanes {
            match lane.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("serve: lane exited with error: {e:#}"),
                Err(_) => eprintln!("serve: lane panicked"),
            }
        }
        for conn in conns {
            let _ = conn.join();
        }
        let stats = shared.counters.snapshot(&shared.queue, &shared.cache);
        eprintln!(
            "serve: drained — {} jobs done, {} rejected; {} batches at {:.1}% occupancy; \
             cache {} hits / {} misses / {} evictions ({} resident)",
            stats.jobs_done,
            stats.jobs_rejected,
            stats.batches,
            stats.occupancy() * 100.0,
            stats.cache_hits,
            stats.cache_misses,
            stats.cache_evictions,
            stats.cache_entries,
        );
        Ok(stats)
    }
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    if let Err(e) = serve_connection(stream, shared) {
        eprintln!("serve: connection error: {e:#}");
    }
}

fn serve_connection(stream: TcpStream, shared: &Shared) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut out = stream;
    let req = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_response(&mut out, 400, &error_body(&format!("{e:#}"), false));
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => write_response(&mut out, 200, "{\"ok\":true}"),
        ("GET", "/v1/stats") => {
            let stats = shared.counters.snapshot(&shared.queue, &shared.cache);
            write_response(&mut out, 200, &stats.to_json())
        }
        ("GET", "/v1/artifacts") => {
            write_response(&mut out, 200, &super::protocol::artifacts_json(&shared.pool))
        }
        ("POST", "/v1/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            write_response(&mut out, 200, "{\"draining\":true}")
        }
        ("POST", "/v1/simulate") => handle_simulate(&mut out, &req.body, shared),
        ("GET" | "POST", _) => {
            write_response(&mut out, 404, &error_body("no such endpoint", false))
        }
        _ => write_response(&mut out, 405, &error_body("method not allowed", false)),
    }
}

fn handle_simulate(out: &mut TcpStream, body: &str, shared: &Shared) -> Result<()> {
    if shared.shutdown.load(Ordering::SeqCst) || shared.queue.is_closed() {
        shared.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
        return write_response(out, 503, &error_body("draining", true));
    }
    let spec = match JobSpec::from_json(body) {
        Ok(s) => s,
        Err(e) => return write_response(out, 400, &error_body(&format!("{e:#}"), false)),
    };
    if let Err(e) = validate_spec(&spec, &shared.pool, shared.max_insts) {
        return write_response(out, 400, &error_body(&format!("{e:#}"), false));
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let job = QueuedJob { spec, done: tx, admitted_at: std::time::Instant::now() };
    match shared.queue.submit(job) {
        Ok(()) => {}
        Err((_, SubmitError::Full)) => {
            shared.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return write_response(out, 429, &error_body("queue full", true));
        }
        Err((_, SubmitError::Closed)) => {
            shared.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return write_response(out, 503, &error_body("draining", true));
        }
    }
    shared.counters.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    // Block until the lane answers. Lanes always answer — completion,
    // job error, drain, or lane failure — so this cannot leak.
    match rx.recv() {
        Ok(Ok(outcome)) => write_response(out, 200, &outcome.to_json()),
        Ok(Err(msg)) => write_response(out, 500, &error_body(&msg, false)),
        Err(_) => write_response(out, 500, &error_body("job dropped", false)),
    }
}
