//! The serving daemon: socket accept loop, request routing, graceful
//! drain.
//!
//! Threading model: one accept loop (non-blocking poll so shutdown is
//! observed promptly), one short-lived thread per connection (a
//! connection is one request: parse → validate → submit → block on the
//! job's completion channel → respond), one lane thread per artifact
//! plus its pipelined executor ([`super::scheduler`]).
//!
//! Graceful shutdown (`POST /v1/shutdown`, or SIGINT via the CLI):
//! stop accepting, close the admission queue — new submits get a
//! retryable 503 — let lanes finish the backlog and every in-flight
//! job, join everything, fsync the cache journal, and flush the final
//! stats (cache hit rates, packing occupancy) to stderr and to the
//! caller.
//!
//! Fault tolerance: each lane runs under a **supervisor** thread that
//! catches lane-fatal errors *and panics*, answers the artifact's
//! queued jobs retryably through an exponential backoff, and respawns
//! the lane — one poisoned artifact or injected panic degrades that
//! lane, never the daemon. `/healthz` reports the resulting readiness
//! state (`starting` / `serving` / `degraded` / `draining`), and with
//! [`ServeConfig::cache_journal`] set, the prediction cache persists
//! across crashes via the crash-safe journal ([`super::journal`]).

use super::cache::{PredictionCache, ENTRY_BYTES};
use super::forward::PeerCache;
use super::http::{read_error_status, read_request, write_response, write_response_typed};
use super::journal::CacheJournal;
use super::protocol::{
    error_body, validate_spec, ErrorCode, JobSpec, ServeError, StatsSnapshot,
};
use super::queue::{JobQueue, QueuedJob, SubmitError};
use super::scheduler::{run_lane_ext, LaneConfig, LaneLinks, ServeCounters};
use crate::runtime::{ArtifactPool, PooledArtifact};
use crate::telemetry::{
    self, log_enabled, prometheus, registry, Counter, Field, Gauge, Histogram, Level,
};
use crate::util::fault::{self, panic_message, relock};
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` for an ephemeral port).
    pub addr: String,
    /// Admission-queue capacity (backpressure bound).
    pub queue_depth: usize,
    /// Concurrent jobs packed per lane.
    pub max_active: usize,
    /// Prediction-cache capacity in chunk entries (0 disables).
    pub cache_entries: usize,
    /// Largest `insts` a request may ask for.
    pub max_insts: u64,
    /// Double-buffered executor threads.
    pub pipeline: bool,
    /// Lane batch-formation window, milliseconds.
    pub admission_wait_ms: u64,
    /// Jobs prepared off the lane thread ahead of admission (bounds
    /// resident prepared-but-unadmitted jobs; 0 prepares inline).
    pub prep_depth: usize,
    /// Per-connection socket read timeout, milliseconds: a client that
    /// stalls mid-request this long gets a 408 and the thread back.
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout, milliseconds (a client not
    /// draining its response).
    pub write_timeout_ms: u64,
    /// Default job deadline, milliseconds, for specs that don't carry
    /// their own `deadline_ms` (0 = no default; expired jobs die with
    /// a retryable `deadline_exceeded`).
    pub default_deadline_ms: u64,
    /// Crash-safe cache journal path: recovered entries warm-load at
    /// bind, fresh inserts append, drain fsyncs. `None` keeps the
    /// cache memory-only.
    pub cache_journal: Option<std::path::PathBuf>,
    /// Ring-peer worker addresses (`host:port`). When non-empty, a
    /// local prediction-cache miss consults these peers over
    /// `POST /v1/cache/lookup` before paying for model execution — the
    /// router hands each worker its ring neighbours here.
    pub peers: Vec<String>,
    /// Peer cache-lookup timeout, milliseconds. Deliberately tiny: a
    /// slow peer must cost less than recomputing the chunk.
    pub peer_timeout_ms: u64,
    /// Per-artifact cache byte quotas (`name` → bytes; entries =
    /// `bytes / cache::ENTRY_BYTES`). Artifacts without an explicit
    /// quota share the capacity proportionally (an equal split of
    /// `cache_entries`), so one hot tenant cannot evict the fleet.
    pub cache_quotas: Vec<(String, u64)>,
    /// Foreign cache journals to warm-load read-only at bind (a dead
    /// ring predecessor's `--cache-journal` file): entries replay into
    /// the cache but the files are never appended to or truncated.
    pub warm_journals: Vec<std::path::PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            queue_depth: 64,
            max_active: 16,
            cache_entries: 1024,
            max_insts: 10_000_000,
            pipeline: true,
            admission_wait_ms: 2,
            prep_depth: 2,
            read_timeout_ms: 10_000,
            write_timeout_ms: 30_000,
            default_deadline_ms: 300_000,
            cache_journal: None,
            peers: Vec::new(),
            peer_timeout_ms: 100,
            cache_quotas: Vec::new(),
            warm_journals: Vec::new(),
        }
    }
}

/// Daemon-level metric handles, resolved once at bind. The counters
/// whose source of truth is [`ServeCounters`] or the cache are
/// *mirrored* into the registry at `/metrics` scrape time; the rest
/// are incremented live on the request path.
struct ServeTele {
    jobs_submitted: Counter,
    jobs_done: Counter,
    jobs_active: Gauge,
    lanes_down: Gauge,
    request_seconds: Histogram,
}

impl ServeTele {
    fn new() -> ServeTele {
        let reg = registry();
        ServeTele {
            jobs_submitted: reg.counter(
                "tao_jobs_submitted_total",
                "Jobs accepted into the admission queue.",
                &[],
            ),
            jobs_done: reg.counter(
                "tao_jobs_done_total",
                "Jobs answered (success or typed error).",
                &[],
            ),
            jobs_active: reg.gauge("tao_jobs_active", "Jobs currently active inside lanes.", &[]),
            lanes_down: reg.gauge(
                "tao_lanes_down",
                "Lanes currently in respawn backoff (degraded when > 0).",
                &[],
            ),
            request_seconds: reg.histogram(
                "tao_request_seconds",
                "HTTP request wall time, connection accept to response.",
                &[],
            ),
        }
    }

    /// Pre-register the per-code error families for the codes the
    /// admission path can emit, so scrapers see them (at zero) before
    /// the first error instead of a family popping into existence.
    fn preregister_error_codes() {
        let reg = registry();
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::QueueFull,
            ErrorCode::Draining,
            ErrorCode::DeadlineExceeded,
            ErrorCode::LaneFailed,
        ] {
            reg.counter(
                "tao_jobs_rejected_total",
                "Jobs rejected by admission control, by error code.",
                &[("code", code.as_str())],
            );
            reg.counter(
                "tao_errors_total",
                "Error responses sent to clients, by error code.",
                &[("code", code.as_str())],
            );
        }
    }
}

/// Count a rejected admission by error code
/// (`tao_jobs_rejected_total{code=...}`).
fn count_rejected(code: ErrorCode) {
    if telemetry::armed() {
        registry()
            .counter(
                "tao_jobs_rejected_total",
                "Jobs rejected by admission control, by error code.",
                &[("code", code.as_str())],
            )
            .inc();
    }
}

/// Count an error response by code (`tao_errors_total{code=...}`).
fn count_error(code: ErrorCode) {
    if telemetry::armed() {
        registry()
            .counter(
                "tao_errors_total",
                "Error responses sent to clients, by error code.",
                &[("code", code.as_str())],
            )
            .inc();
    }
}

struct Shared {
    pool: ArtifactPool,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    tele: ServeTele,
    shutdown: AtomicBool,
    /// Flipped when the accept loop starts; `/healthz` says `starting`
    /// until then.
    started: AtomicBool,
    max_insts: u64,
    read_timeout: Duration,
    write_timeout: Duration,
    /// Applied to specs without their own `deadline_ms`.
    default_deadline: Option<Duration>,
}

/// A cloneable control handle: request shutdown / read stats from
/// outside the accept loop (the CLI's SIGINT watcher uses this).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Begin graceful drain (idempotent).
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Current serving counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared
            .counters
            .snapshot(&self.shared.queue, &self.shared.cache)
    }
}

/// A bound, lanes-running daemon. [`Server::run`] serves until drain.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    lanes: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Bind the socket and start one lane per pooled artifact.
    pub fn bind(pool: ArtifactPool, cfg: &ServeConfig) -> Result<Server> {
        // The daemon always meters itself: one relaxed atomic add per
        // site is noise next to a socket round-trip, and `/metrics`
        // must be truthful from the first request.
        telemetry::arm();
        ensure!(!pool.is_empty(), "serve needs at least one --model artifact");
        ensure!(cfg.queue_depth >= 1, "queue depth must be positive");
        ensure!(cfg.max_active >= 1, "max active jobs must be positive");
        ensure!(cfg.read_timeout_ms >= 1, "read timeout must be positive");
        ensure!(cfg.write_timeout_ms >= 1, "write timeout must be positive");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let queue = Arc::new(JobQueue::new(cfg.queue_depth));
        let cache = Arc::new(Mutex::new(PredictionCache::new(cfg.cache_entries)));
        if cfg.cache_entries > 0 {
            // Register every artifact *before* any warm-load so the
            // per-tenant accounting sees each recovered entry. Explicit
            // `--cache-quota name=bytes` wins; everyone else gets an
            // equal split of the capacity (0 bytes = unlimited).
            let mut c = relock(&cache);
            let default_quota = cfg.cache_entries / pool.len().max(1);
            for art in pool.iter() {
                let quota = match cfg.cache_quotas.iter().find(|(n, _)| *n == art.name) {
                    Some((_, 0)) => 0,
                    Some((_, bytes)) => ((bytes / ENTRY_BYTES).max(1)) as usize,
                    None => default_quota,
                };
                c.register_artifact(art.fingerprint, &art.name, quota);
            }
        }
        // Foreign warm journals (a dead ring predecessor's cache) are
        // replayed read-only: entries fold in, files stay untouched.
        for path in cfg.warm_journals.iter().filter(|_| cfg.cache_entries > 0) {
            match CacheJournal::replay(path) {
                Ok(rec) => {
                    let n = relock(&cache).warm_load(rec.entries);
                    eprintln!(
                        "serve: warm journal {path:?}: adopted {n} chunk entries read-only"
                    );
                }
                Err(e) => {
                    eprintln!("serve: warm journal {path:?} unreadable, skipped: {e:#}")
                }
            }
        }
        if let Some(path) = cfg.cache_journal.as_deref().filter(|_| cfg.cache_entries > 0) {
            // Persistence is best-effort: an unreadable journal logs
            // and degrades to a memory-only cache; it never stops the
            // daemon from binding.
            match CacheJournal::open(path) {
                Ok((journal, rec)) => {
                    if rec.truncated_bytes > 0 {
                        eprintln!(
                            "serve: cache journal {path:?}: truncated {} torn tail byte(s)",
                            rec.truncated_bytes
                        );
                    }
                    let mut c = relock(&cache);
                    let n = c.warm_load(rec.entries);
                    c.attach_journal(journal);
                    eprintln!("serve: cache journal {path:?}: recovered {n} chunk entries");
                }
                Err(e) => eprintln!(
                    "serve: cache journal {path:?} unavailable, persistence disabled: {e:#}"
                ),
            }
        }
        let counters = Arc::new(ServeCounters::default());
        let lane_cfg = LaneConfig {
            max_active: cfg.max_active,
            pipeline: cfg.pipeline,
            admission_wait: Duration::from_millis(cfg.admission_wait_ms),
            prep_depth: cfg.prep_depth,
        };
        let peers: Option<Arc<PeerCache>> = (!cfg.peers.is_empty()).then(|| {
            Arc::new(PeerCache::new(
                cfg.peers.clone(),
                Duration::from_millis(cfg.peer_timeout_ms.max(1)),
            ))
        });
        let mut lanes = Vec::new();
        for art in pool.iter() {
            let art = art.clone();
            let queue = queue.clone();
            let cache = cache.clone();
            let counters = counters.clone();
            let peers = peers.clone();
            lanes.push(std::thread::spawn(move || {
                lane_supervisor(art, queue, cache, counters, lane_cfg, peers)
            }));
        }
        let shared = Arc::new(Shared {
            pool,
            queue,
            cache,
            counters,
            tele: {
                ServeTele::preregister_error_codes();
                ServeTele::new()
            },
            shutdown: AtomicBool::new(false),
            started: AtomicBool::new(false),
            max_insts: cfg.max_insts,
            read_timeout: Duration::from_millis(cfg.read_timeout_ms),
            write_timeout: Duration::from_millis(cfg.write_timeout_ms),
            default_deadline: (cfg.default_deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.default_deadline_ms)),
        });
        Ok(Server { listener, shared, lanes })
    }

    /// The bound address (read the ephemeral port here).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("local_addr")
    }

    /// Control handle for shutdown/stats from other threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    /// Serve until a graceful shutdown completes; returns the final
    /// counter snapshot after the drain.
    pub fn run(self) -> Result<StatsSnapshot> {
        let Server { listener, shared, lanes } = self;
        shared.started.store(true, Ordering::SeqCst);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut draining = false;
        loop {
            // Keep accepting through the drain: connections racing the
            // shutdown get the documented retryable 503 (and stats and
            // health stay readable) instead of a reset from the
            // listener's backlog. The loop ends once every lane has
            // finished its backlog and in-flight jobs.
            if !draining && shared.shutdown.load(Ordering::SeqCst) {
                draining = true;
                shared.queue.close();
            }
            if draining && lanes.iter().all(|h| h.is_finished()) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    // The listener is non-blocking (shutdown polling);
                    // accepted sockets must not inherit that (they do
                    // on some platforms).
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let shared = shared.clone();
                    conns.push(std::thread::spawn(move || {
                        handle_connection(stream, &shared);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    // EMFILE/ECONNABORTED and friends are transient
                    // overload, not reasons to drop every in-flight
                    // job — log, back off, keep serving. A wedged
                    // socket still exits via /v1/shutdown or SIGINT.
                    eprintln!("serve: accept error (continuing): {e}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
            if conns.len() >= 64 {
                conns.retain(|h| !h.is_finished());
            }
        }

        // Lanes have drained (backlog + in-flight all answered); stop
        // accepting, join everything, flush stats.
        for lane in lanes {
            match lane.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("serve: lane exited with error: {e:#}"),
                Err(_) => eprintln!("serve: lane supervisor panicked"),
            }
        }
        for conn in conns {
            let _ = conn.join();
        }
        // Make the cache journal durable before reporting the drain
        // complete (appends are unbuffered writes; this is the fsync).
        if let Err(e) = relock(&shared.cache).sync_journal() {
            eprintln!("serve: cache journal fsync failed: {e:#}");
        }
        let stats = shared.counters.snapshot(&shared.queue, &shared.cache);
        eprintln!(
            "serve: drained — {} jobs done, {} rejected; {} batches at {:.1}% occupancy; \
             cache {} hits / {} misses / {} evictions ({} resident, {} recovered); \
             {} lane restart(s)",
            stats.jobs_done,
            stats.jobs_rejected,
            stats.batches,
            stats.occupancy() * 100.0,
            stats.cache_hits,
            stats.cache_misses,
            stats.cache_evictions,
            stats.cache_entries,
            stats.cache_recovered,
            stats.lane_restarts,
        );
        Ok(stats)
    }
}

/// Keep one artifact's lane alive until the queue drains: run it under
/// `catch_unwind`, and on a lane-fatal error **or panic** answer the
/// artifact's queued jobs with a retryable `lane_failed` through an
/// exponential backoff, then respawn the lane. In-flight jobs of a
/// *panicked* lane are answered by their completion senders dropping
/// (the HTTP layer maps that to a retryable 503); a lane that failed
/// cleanly already answered them itself.
fn lane_supervisor(
    art: PooledArtifact,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    cfg: LaneConfig,
    peers: Option<Arc<PeerCache>>,
) -> Result<()> {
    let mut failures = 0u32;
    // The degraded flag stays raised from the moment the lane dies
    // until a respawned lane's executor is actually up again — the lane
    // itself clears it (see [`LaneLinks`]), so `/healthz` reports
    // `degraded` through the whole backoff + restart window instead of
    // flickering back to `serving` when the retry is merely scheduled.
    let down = Arc::new(AtomicBool::new(false));
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            run_lane_ext(
                art.clone(),
                queue.clone(),
                cache.clone(),
                counters.clone(),
                cfg,
                LaneLinks { peers: peers.clone(), down: Some(down.clone()) },
            )
        }));
        let err = match run {
            // Clean exit: the queue closed and drained.
            Ok(Ok(())) => return Ok(()),
            Ok(Err(e)) => format!("{e:#}"),
            Err(p) => format!("lane panicked: {}", panic_message(p.as_ref())),
        };
        failures += 1;
        counters.lane_restarts.fetch_add(1, Ordering::Relaxed);
        if !down.swap(true, Ordering::Relaxed) {
            counters.lanes_down.fetch_add(1, Ordering::Relaxed);
        }
        // The registry cell is keyed by artifact label and outlives the
        // lane thread, so `/v1/stats` per-lane respawn counts survive
        // the respawn they are counting.
        if telemetry::armed() {
            registry()
                .counter(
                    "tao_lane_respawns_total",
                    "Lane threads respawned after a panic or fatal lane error.",
                    &[("artifact", &art.name)],
                )
                .inc();
        }
        let backoff = Duration::from_millis((50u64 << failures.min(5)).min(2_000));
        eprintln!(
            "serve: lane {:?} down ({err}); respawn in {}ms (restart #{failures})",
            art.name,
            backoff.as_millis()
        );
        if log_enabled(Level::Warn) {
            telemetry::emit(
                Level::Warn,
                "lane_respawn",
                &[
                    ("artifact", Field::Str(&art.name)),
                    ("error", Field::Str(&err)),
                    ("backoff_ms", Field::U64(backoff.as_millis() as u64)),
                    ("restart", Field::U64(u64::from(failures))),
                ],
            );
        }
        // Answer this artifact's queued jobs retryably while backing
        // off — a waiting connection must never hang on a down lane.
        let until = Instant::now() + backoff;
        loop {
            let now = Instant::now();
            if now >= until {
                break;
            }
            match queue.pop_for(&art.name, until - now) {
                Some(qj) => {
                    let se = ServeError::new(
                        ErrorCode::LaneFailed,
                        format!("lane {:?} restarting: {err}", art.name),
                    );
                    let _ = qj.done.send(Err(se));
                    counters.jobs_done.fetch_add(1, Ordering::Relaxed);
                }
                None if queue.is_drained() => break,
                None => {}
            }
        }
        // NOTE: `lanes_down` is *not* decremented here — the respawned
        // lane decrements it itself once `Executor::start` succeeds, so
        // a lane that keeps failing to start stays `degraded`.
        if queue.is_drained() {
            anyhow::bail!("lane {:?} failed during drain: {err}", art.name);
        }
    }
}

/// `/healthz` readiness: `starting` until the accept loop runs (503),
/// `draining` once shutdown began (503 — stop sending work here),
/// `degraded` while any lane sits in respawn backoff (200 — still
/// serving, other lanes unaffected), else `serving` (200).
///
/// Pure so the state machine is unit-testable; the router maps these
/// states to ring membership (`serving`/`degraded` → in the ring,
/// `starting`/`draining`/unreachable → out).
pub(crate) fn health_status(
    draining: bool,
    started: bool,
    lanes_down: u64,
) -> (u16, &'static str) {
    if draining {
        (503, "draining")
    } else if !started {
        (503, "starting")
    } else if lanes_down > 0 {
        (200, "degraded")
    } else {
        (200, "serving")
    }
}

fn health(shared: &Shared) -> (u16, String) {
    let (status, state) = health_status(
        shared.shutdown.load(Ordering::SeqCst) || shared.queue.is_closed(),
        shared.started.load(Ordering::SeqCst),
        shared.counters.lanes_down.load(Ordering::Relaxed),
    );
    (status, format!("{{\"ok\":{},\"status\":\"{state}\"}}", status == 200))
}

/// Per-lane detail for `/v1/stats`, read back out of the registry.
/// Cells are keyed by artifact label and owned by the process-global
/// registry, not the lane thread, so the counts are cumulative across
/// lane respawns (`respawn_count` says how many happened).
fn lanes_json(pool: &ArtifactPool) -> Json {
    let reg = registry();
    let mut lanes = std::collections::BTreeMap::new();
    for art in pool.iter() {
        let labels: [(&str, &str); 1] = [("artifact", &art.name)];
        let jobs = reg.counter_value("tao_lane_jobs_total", Some(&labels)).unwrap_or(0);
        let batches = reg.counter_value("tao_lane_batches_total", Some(&labels)).unwrap_or(0);
        let respawns =
            reg.counter_value("tao_lane_respawns_total", Some(&labels)).unwrap_or(0);
        lanes.insert(
            art.name.clone(),
            Json::obj([
                ("jobs_done", Json::of_u64(jobs)),
                ("batches", Json::of_u64(batches)),
                ("respawn_count", Json::of_u64(respawns)),
            ]),
        );
    }
    Json::Obj(lanes)
}

/// Per-artifact cache tenancy for `/v1/stats` (`"cache_artifacts"`).
fn cache_artifacts_json(arts: &[super::cache::ArtifactCacheStats]) -> Json {
    let mut m = std::collections::BTreeMap::new();
    for a in arts {
        m.insert(
            a.name.clone(),
            Json::obj([
                ("quota_entries", Json::of_u64(a.quota)),
                ("entries", Json::of_u64(a.entries)),
                ("hits", Json::of_u64(a.hits)),
                ("misses", Json::of_u64(a.misses)),
                ("insertions", Json::of_u64(a.insertions)),
                ("evictions", Json::of_u64(a.evictions)),
            ]),
        );
    }
    Json::Obj(m)
}

/// `POST /v1/cache/lookup` — the ring-peer warm-cache protocol. A
/// *read-only* probe: [`PredictionCache::peek`] touches no counters
/// and no recency state, so a remote fleet's curiosity cannot perturb
/// this daemon's `hits + misses == chunks` identity or its LRU order.
/// The payload is the accumulator's journal encoding — the same
/// bit-exact frame the crash journal uses.
fn handle_cache_lookup(out: &mut TcpStream, body: &str, shared: &Shared) -> Result<()> {
    let key = match super::protocol::cache_lookup_from_json(body) {
        Ok(k) => k,
        Err(e) => {
            let se = ServeError::new(ErrorCode::BadRequest, format!("{e:#}"));
            count_error(se.code);
            return write_response(out, se.code.http_status(), &se.to_json());
        }
    };
    let payload = relock(&shared.cache).peek(&key).map(|accum| {
        let mut bytes = Vec::with_capacity(crate::coordinator::engine::PredAccum::JOURNAL_BYTES);
        accum.encode_journal(&mut bytes);
        bytes
    });
    let body = match payload {
        Some(bytes) => super::protocol::cache_found_json(&bytes),
        None => super::protocol::cache_miss_json(),
    };
    write_response(out, 200, &body)
}

/// Render the Prometheus exposition. Counters owned by other
/// subsystems ([`ServeCounters`], the cache, `util::fault`) are
/// mirrored into their registry cells here, at scrape time, so one
/// scrape sees one coherent view.
fn metrics_body(shared: &Shared) -> String {
    let reg = registry();
    let c = &shared.counters;
    shared.tele.jobs_done.mirror(c.jobs_done.load(Ordering::Relaxed));
    shared.tele.jobs_active.set(c.active_jobs.load(Ordering::Relaxed) as i64);
    shared.tele.lanes_down.set(c.lanes_down.load(Ordering::Relaxed) as i64);
    let (cs, arts) = {
        let c = relock(&shared.cache);
        (c.stats(), c.artifact_stats())
    };
    reg.counter("tao_cache_insertions_total", "Prediction-cache entries inserted.", &[])
        .mirror(cs.insertions);
    reg.counter(
        "tao_cache_evictions_total",
        "Prediction-cache entries evicted by capacity pressure.",
        &[],
    )
    .mirror(cs.evictions);
    reg.gauge("tao_cache_entries", "Prediction-cache resident entries.", &[])
        .set(cs.entries as i64);
    reg.counter(
        "tao_cache_peer_hits_total",
        "Chunk results adopted from ring-peer caches instead of recomputed.",
        &[],
    )
    .mirror(cs.peer_hits);
    for a in &arts {
        let labels: [(&str, &str); 1] = [("artifact", a.name.as_str())];
        reg.counter(
            "tao_cache_artifact_hits_total",
            "Prediction-cache hits, by artifact tenant.",
            &labels,
        )
        .mirror(a.hits);
        reg.counter(
            "tao_cache_artifact_misses_total",
            "Prediction-cache misses, by artifact tenant.",
            &labels,
        )
        .mirror(a.misses);
        reg.counter(
            "tao_cache_artifact_evictions_total",
            "Prediction-cache evictions charged to an artifact's quota.",
            &labels,
        )
        .mirror(a.evictions);
        reg.gauge(
            "tao_cache_artifact_entries",
            "Prediction-cache resident entries, by artifact tenant.",
            &labels,
        )
        .set(a.entries as i64);
        reg.gauge(
            "tao_cache_artifact_quota_entries",
            "Per-artifact cache entry quota (0 = unlimited).",
            &labels,
        )
        .set(a.quota as i64);
    }
    for p in fault::PROBES {
        let st = fault::stats(p);
        reg.counter(
            "tao_fault_checks_total",
            "Fault-probe site traversals, by probe.",
            &[("probe", p.name())],
        )
        .mirror(st.checks);
        reg.counter(
            "tao_fault_fires_total",
            "Fault-probe injected failures, by probe.",
            &[("probe", p.name())],
        )
        .mirror(st.fires);
    }
    prometheus::render(&reg.snapshot())
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    if let Err(e) = serve_connection(stream, shared) {
        eprintln!("serve: connection error: {e:#}");
    }
}

fn serve_connection(stream: TcpStream, shared: &Shared) -> Result<()> {
    let t0 = Instant::now();
    let res = serve_connection_timed(stream, shared);
    shared.tele.request_seconds.record(t0.elapsed());
    res
}

fn serve_connection_timed(stream: TcpStream, shared: &Shared) -> Result<()> {
    stream.set_read_timeout(Some(shared.read_timeout))?;
    stream.set_write_timeout(Some(shared.write_timeout))?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut out = stream;
    let req = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            // 408 for a stalled client, 413 for limit abuse, 400 for
            // garbage — all terminal, all answered promptly so the
            // connection thread is reclaimed.
            let status = read_error_status(&e);
            let code = match status {
                408 => ErrorCode::RequestTimeout,
                413 => ErrorCode::TooLarge,
                _ => ErrorCode::BadRequest,
            };
            let se = ServeError::new(code, format!("{e:#}"));
            count_error(se.code);
            let _ = write_response(&mut out, status, &se.to_json());
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let (status, body) = health(shared);
            write_response(&mut out, status, &body)
        }
        ("GET", "/v1/stats") => {
            let stats = shared.counters.snapshot(&shared.queue, &shared.cache);
            let (peer_hits, arts) = {
                let c = relock(&shared.cache);
                (c.stats().peer_hits, c.artifact_stats())
            };
            let body = stats.to_json_with(vec![
                ("lanes", lanes_json(&shared.pool)),
                ("cache_peer_hits", Json::of_u64(peer_hits)),
                ("cache_artifacts", cache_artifacts_json(&arts)),
            ]);
            write_response(&mut out, 200, &body)
        }
        ("GET", "/metrics") => {
            let body = metrics_body(shared);
            write_response_typed(&mut out, 200, prometheus::CONTENT_TYPE, &body)
        }
        ("GET", "/v1/artifacts") => {
            write_response(&mut out, 200, &super::protocol::artifacts_json(&shared.pool))
        }
        ("POST", "/v1/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            write_response(&mut out, 200, "{\"draining\":true}")
        }
        ("POST", "/v1/simulate") => handle_simulate(&mut out, &req.body, shared),
        ("POST", "/v1/cache/lookup") => handle_cache_lookup(&mut out, &req.body, shared),
        ("GET" | "POST", _) => {
            write_response(&mut out, 404, &error_body("no such endpoint", false))
        }
        _ => write_response(&mut out, 405, &error_body("method not allowed", false)),
    }
}

fn handle_simulate(out: &mut TcpStream, body: &str, shared: &Shared) -> Result<()> {
    let reject = |out: &mut TcpStream, shared: &Shared, se: ServeError| {
        shared.counters.jobs_rejected.fetch_add(1, Ordering::Relaxed);
        count_rejected(se.code);
        count_error(se.code);
        write_response(out, se.code.http_status(), &se.to_json())
    };
    if shared.shutdown.load(Ordering::SeqCst) || shared.queue.is_closed() {
        return reject(out, shared, ServeError::new(ErrorCode::Draining, "draining"));
    }
    let spec = match JobSpec::from_json(body) {
        Ok(s) => s,
        Err(e) => {
            let se = ServeError::new(ErrorCode::BadRequest, format!("{e:#}"));
            count_error(se.code);
            return write_response(out, se.code.http_status(), &se.to_json());
        }
    };
    if let Err(e) = validate_spec(&spec, &shared.pool, shared.max_insts) {
        let se = ServeError::new(ErrorCode::BadRequest, format!("{e:#}"));
        count_error(se.code);
        return write_response(out, se.code.http_status(), &se.to_json());
    }
    // Resolve the cancellation deadline at admission: the spec's own
    // deadline_ms wins, else the server default (0 = none).
    let admitted_at = std::time::Instant::now();
    let deadline = spec
        .deadline_ms
        .map(Duration::from_millis)
        .or(shared.default_deadline)
        .map(|d| admitted_at + d);
    // The trace id follows the job through queue → lane → spans → logs
    // → outcome: the client's own id when it sent one, else minted
    // here, at admission.
    let trace_id = match spec.trace_id.clone() {
        Some(t) => t,
        None => telemetry::fresh_trace_id(),
    };
    if log_enabled(Level::Info) {
        telemetry::emit(
            Level::Info,
            "job_admitted",
            &[
                ("trace_id", Field::Str(&trace_id)),
                ("artifact", Field::Str(&spec.artifact)),
                ("bench", Field::Str(&spec.bench)),
                ("insts", Field::U64(spec.insts)),
            ],
        );
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let job = QueuedJob { spec, done: tx, admitted_at, deadline, trace_id };
    match shared.queue.submit(job) {
        Ok(()) => {}
        Err((_, SubmitError::Full)) => {
            return reject(out, shared, ServeError::new(ErrorCode::QueueFull, "queue full"));
        }
        Err((_, SubmitError::Closed)) => {
            return reject(out, shared, ServeError::new(ErrorCode::Draining, "draining"));
        }
    }
    shared.counters.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    shared.tele.jobs_submitted.inc();
    // Block until the lane answers. Lanes always answer — completion,
    // typed job error, deadline, drain, or lane failure. The one other
    // way out is the completion sender dropping because the lane
    // thread panicked mid-job: a retryable lane restart, not a client
    // error, and never a hang.
    match rx.recv() {
        Ok(Ok(outcome)) => write_response(out, 200, &outcome.to_json()),
        Ok(Err(se)) => {
            count_error(se.code);
            write_response(out, se.code.http_status(), &se.to_json())
        }
        Err(_) => {
            let se =
                ServeError::new(ErrorCode::LaneFailed, "job dropped during lane restart");
            count_error(se.code);
            write_response(out, se.code.http_status(), &se.to_json())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::write_surrogate_artifact;

    /// The `/healthz` state machine, exhaustively: `draining` outranks
    /// everything (the router must pull a draining worker from the
    /// ring no matter what its lanes look like), `starting` outranks
    /// lane health, and only lane backoff separates `degraded` from
    /// `serving`.
    #[test]
    fn health_status_orders_states() {
        assert_eq!(health_status(false, false, 0), (503, "starting"));
        assert_eq!(health_status(false, false, 2), (503, "starting"));
        assert_eq!(health_status(false, true, 0), (200, "serving"));
        assert_eq!(health_status(false, true, 1), (200, "degraded"));
        assert_eq!(health_status(false, true, 7), (200, "degraded"));
        assert_eq!(health_status(true, true, 0), (503, "draining"));
        assert_eq!(health_status(true, true, 3), (503, "draining"));
        assert_eq!(health_status(true, false, 0), (503, "draining"));
    }

    /// The degraded-flag protocol between supervisor and lane: the
    /// supervisor raises `down` (and bumps `lanes_down`) when a lane
    /// dies, and the *respawned lane itself* clears both — only once
    /// its executor and prep stage are actually up. So a successful
    /// lane startup drives `lanes_down` 1 → 0, and `/healthz` reports
    /// `degraded` for the entire backoff window in between.
    #[test]
    fn lane_startup_clears_the_degraded_flag() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let dir = std::env::temp_dir().join(format!("tao-server-{}", std::process::id()));
        let hlo = write_surrogate_artifact(&dir, "srv_flag", 8, 4).unwrap();
        let art = ArtifactPool::load(&[hlo]).unwrap().get("srv_flag").unwrap().clone();
        let queue = Arc::new(JobQueue::new(4));
        queue.close();
        let counters = Arc::new(ServeCounters::default());
        let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
        // Simulate the supervisor's crash bookkeeping.
        let down = Arc::new(AtomicBool::new(true));
        counters.lanes_down.fetch_add(1, Ordering::Relaxed);
        assert_eq!(health_status(false, true, 1).1, "degraded");
        run_lane_ext(
            art,
            queue,
            cache,
            counters.clone(),
            LaneConfig {
                max_active: 4,
                pipeline: false,
                admission_wait: Duration::ZERO,
                prep_depth: 0,
            },
            LaneLinks { peers: None, down: Some(down.clone()) },
        )
        .unwrap();
        assert!(!down.load(Ordering::Relaxed), "lane startup clears its down flag");
        assert_eq!(counters.lanes_down.load(Ordering::Relaxed), 0);
        assert_eq!(health_status(false, true, 0).1, "serving");
    }
}
