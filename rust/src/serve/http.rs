//! Minimal HTTP/1.1 over `std::net` — just enough for the serving
//! protocol, hand-rolled in the repo's zero-dependency idiom.
//!
//! One request per connection (`Connection: close` semantics): the
//! daemon reads a request, writes a response, closes. Limits guard the
//! parser — 8 KiB of headers, 1 MiB of body — and every malformed
//! input surfaces as an error, never a panic. [`read_error_status`]
//! classifies read failures for the server: limit violations answer
//! 413, a stalled client tripping the per-connection read timeout
//! answers 408, everything else malformed 400. The client side
//! ([`http_get`], [`http_post`], and the deliberately abusive
//! [`http_post_stalled`]) is the same code path loadgen and the
//! loopback tests use.

use anyhow::{bail, ensure, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Maximum accepted header section (request line + headers).
pub const MAX_HEADER_BYTES: usize = 8 << 10;

/// Maximum accepted body.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A parsed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// `GET` / `POST` / ...
    pub method: String,
    /// Request target (path only; no query parsing).
    pub path: String,
    /// Raw body bytes decoded per `Content-Length`.
    pub body: String,
}

/// Read one HTTP/1.1 request from a buffered stream.
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Request> {
    // Hard-cap the header section *at the reader*: `read_line` grows
    // its buffer until a newline arrives, so without the `take` a
    // newline-free stream would buffer unboundedly before the
    // per-line size check ever ran. One byte of slack lets the check
    // below distinguish "exactly at the limit" from "over it".
    let mut capped = reader.take(MAX_HEADER_BYTES as u64 + 1);
    let mut line = String::new();
    let mut header_bytes = 0usize;
    capped.read_line(&mut line).context("read request line")?;
    ensure!(!line.is_empty(), "empty request");
    header_bytes += line.len();
    ensure!(header_bytes <= MAX_HEADER_BYTES, "header section too large");
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing HTTP version")?;
    ensure!(version.starts_with("HTTP/1."), "unsupported version {version:?}");

    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        let n = capped.read_line(&mut header).context("read header")?;
        ensure!(n > 0, "truncated request header");
        header_bytes += n;
        ensure!(header_bytes <= MAX_HEADER_BYTES, "header section too large");
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            bail!("malformed header {header:?}");
        };
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse()
                .with_context(|| format!("bad Content-Length {value:?}"))?;
            ensure!(content_length <= MAX_BODY_BYTES, "body too large");
        }
    }
    let reader = capped.into_inner();
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("read body")?;
    let body = String::from_utf8(body).context("non-utf8 body")?;
    Ok(Request { method, path, body })
}

/// Write an HTTP/1.1 response with a JSON body.
pub fn write_response<W: Write>(w: &mut W, status: u16, body: &str) -> Result<()> {
    write_response_typed(w, status, "application/json", body)
}

/// Write an HTTP/1.1 response with an explicit `Content-Type` (the
/// `/metrics` endpoint answers Prometheus text exposition, not JSON).
pub fn write_response_typed<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &str,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Response",
    };
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    w.flush()?;
    Ok(())
}

/// Map a request-read failure to its response status: 408 for a
/// stalled/timed-out read (the socket's read timeout fired mid
/// request), 413 for an over-limit header section or body, 400 for
/// everything merely malformed.
pub fn read_error_status(e: &anyhow::Error) -> u16 {
    for cause in e.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            if matches!(
                io.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            ) {
                return 408;
            }
        }
    }
    if format!("{e:#}").contains("too large") {
        return 413;
    }
    400
}

/// A client-side response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Body text.
    pub body: String,
}

fn read_response<R: BufRead>(reader: &mut R) -> Result<Response> {
    let mut line = String::new();
    reader.read_line(&mut line).context("read status line")?;
    let mut parts = line.split_whitespace();
    let version = parts.next().context("missing version")?;
    ensure!(version.starts_with("HTTP/1."), "bad status line {line:?}");
    let status: u16 = parts
        .next()
        .context("missing status code")?
        .parse()
        .context("bad status code")?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).context("read header")?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = Some(value.trim().parse().context("bad Content-Length")?);
            }
        }
    }
    let body = match content_length {
        Some(n) => {
            ensure!(n <= MAX_BODY_BYTES, "response body too large");
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf).context("read body")?;
            String::from_utf8(buf).context("non-utf8 body")?
        }
        None => {
            let mut buf = String::new();
            reader.read_to_string(&mut buf).context("read body to EOF")?;
            buf
        }
    };
    Ok(Response { status, body })
}

fn request<A: ToSocketAddrs>(
    addr: A,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> Result<Response> {
    let addr = addr
        .to_socket_addrs()
        .context("resolve address")?
        .next()
        .context("no address")?;
    let stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut writer = stream.try_clone()?;
    write!(
        writer,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    writer.flush()?;
    read_response(&mut BufReader::new(stream))
}

/// Default client timeout. Jobs block server-side until completion, so
/// this bounds an entire simulation request.
pub const CLIENT_TIMEOUT: Duration = Duration::from_secs(300);

/// `GET path` against `addr`.
pub fn http_get<A: ToSocketAddrs>(addr: A, path: &str) -> Result<Response> {
    request(addr, "GET", path, "", CLIENT_TIMEOUT)
}

/// `POST path` with a JSON body against `addr`.
pub fn http_post<A: ToSocketAddrs>(addr: A, path: &str, body: &str) -> Result<Response> {
    request(addr, "POST", path, body, CLIENT_TIMEOUT)
}

/// `GET path` with an explicit timeout covering connect, write, and
/// read. The router tier uses this for health probes (short timeout)
/// and per-hop forwarding (remaining deadline budget); a connect
/// refusal or timeout surfaces as `Err`, which the forwarder treats as
/// a failover signal.
pub fn http_get_timeout<A: ToSocketAddrs>(
    addr: A,
    path: &str,
    timeout: Duration,
) -> Result<Response> {
    request(addr, "GET", path, "", timeout)
}

/// `POST path` with an explicit timeout covering connect, write, and
/// read (per-hop deadline budgets — see [`http_get_timeout`]).
pub fn http_post_timeout<A: ToSocketAddrs>(
    addr: A,
    path: &str,
    body: &str,
    timeout: Duration,
) -> Result<Response> {
    request(addr, "POST", path, body, timeout)
}

/// A slow-loris-shaped `POST`: send the headers and half the body,
/// stall, then (best-effort) send the rest and read the response. The
/// chaos client uses short stalls to rough up the daemon; the
/// timeout tests use stalls past the server's read timeout to assert
/// the 408 path. Writes after the stall are best-effort because a
/// server that already answered 408 may have closed its read side.
pub fn http_post_stalled<A: ToSocketAddrs>(
    addr: A,
    path: &str,
    body: &str,
    stall: Duration,
) -> Result<Response> {
    let addr = addr
        .to_socket_addrs()
        .context("resolve address")?
        .next()
        .context("no address")?;
    let stream = TcpStream::connect_timeout(&addr, CLIENT_TIMEOUT)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(CLIENT_TIMEOUT))?;
    stream.set_write_timeout(Some(CLIENT_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let half = body.len() / 2;
    write!(
        writer,
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{}",
        body.len(),
        &body[..half]
    )?;
    writer.flush()?;
    std::thread::sleep(stall);
    let _ = writer.write_all(body[half..].as_bytes());
    let _ = writer.flush();
    read_response(&mut BufReader::new(stream))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /v1/simulate HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/simulate");
        assert_eq!(req.body, "hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(raw)).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.body, "");
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            "",
            "GARBAGE\r\n\r\n",
            "GET /x SPDY/3\r\n\r\n",
            "GET /x HTTP/1.1\r\nno-colon-header\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort",
        ] {
            assert!(read_request(&mut Cursor::new(raw)).is_err(), "{raw:?}");
        }
        // Over-limit body is refused before allocation.
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(read_request(&mut Cursor::new(huge)).is_err());
    }

    #[test]
    fn newline_free_flood_is_capped_at_the_reader() {
        // A request line that never ends must fail at MAX_HEADER_BYTES,
        // not buffer the whole stream.
        let flood = "G".repeat(4 * MAX_HEADER_BYTES);
        assert!(read_request(&mut Cursor::new(flood)).is_err());
        // One endless header line is equally bounded.
        let flood = format!("GET /x HTTP/1.1\r\nX: {}", "y".repeat(4 * MAX_HEADER_BYTES));
        assert!(read_request(&mut Cursor::new(flood)).is_err());
        // A request missing its terminating blank line is truncated,
        // not silently treated as header-complete.
        let cut = "GET /x HTTP/1.1\r\nHost: a\r\n";
        assert!(read_request(&mut Cursor::new(cut)).is_err());
    }

    #[test]
    fn read_errors_classify_to_statuses() {
        // A stalled read surfaces as an io timeout somewhere in the
        // chain → 408.
        let io = std::io::Error::new(std::io::ErrorKind::TimedOut, "stalled");
        let e = anyhow::Error::new(io).context("read request line");
        assert_eq!(read_error_status(&e), 408);
        let io = std::io::Error::new(std::io::ErrorKind::WouldBlock, "stalled");
        assert_eq!(read_error_status(&anyhow::Error::new(io)), 408);
        // Limit violations → 413; anything else malformed → 400.
        let flood = "G".repeat(4 * MAX_HEADER_BYTES);
        let e = read_request(&mut Cursor::new(flood)).unwrap_err();
        assert_eq!(read_error_status(&e), 413);
        let huge =
            format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let e = read_request(&mut Cursor::new(huge)).unwrap_err();
        assert_eq!(read_error_status(&e), 413);
        let e = read_request(&mut Cursor::new("GARBAGE\r\n\r\n")).unwrap_err();
        assert_eq!(read_error_status(&e), 400);
    }

    #[test]
    fn timeout_reasons_render() {
        for (status, reason) in
            [(408, "Request Timeout"), (413, "Payload Too Large"), (504, "Gateway Timeout")]
        {
            let mut wire = Vec::new();
            write_response(&mut wire, status, "{}").unwrap();
            let text = String::from_utf8(wire).unwrap();
            assert!(text.starts_with(&format!("HTTP/1.1 {status} {reason}")), "{text}");
        }
    }

    #[test]
    fn response_round_trips_through_client_parser() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, r#"{"error":"queue full","retryable":true}"#).unwrap();
        let resp = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(resp.status, 429);
        assert!(resp.body.contains("queue full"));
    }

    #[test]
    fn loopback_get_and_post() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            for _ in 0..2 {
                let (stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let req = read_request(&mut reader).unwrap();
                let body = format!("{{\"echo\":\"{} {}\",\"len\":{}}}", req.method, req.path, req.body.len());
                let mut stream = stream;
                write_response(&mut stream, 200, &body).unwrap();
            }
        });
        let r = http_get(addr, "/healthz").unwrap();
        assert_eq!(r.status, 200);
        assert!(r.body.contains("GET /healthz"));
        let r = http_post(addr, "/v1/simulate", "{\"x\":1}").unwrap();
        assert!(r.body.contains("\"len\":7"));
        server.join().unwrap();
    }
}
