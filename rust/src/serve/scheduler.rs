//! Lane scheduler: cross-job batch packing with a pipelined executor.
//!
//! Every artifact the daemon loaded gets a **lane** — a worker thread
//! owning a compiled session for that artifact. A lane admits up to
//! `max_active` concurrent jobs and packs context windows **from all
//! of them** into the artifact's fixed-`B` model batch: the batch is a
//! shared bus, not a per-request allocation. SimNet showed fixed-batch
//! DL inference collapses when batches run underfilled; per-request
//! execution pays that tail padding on *every* request, while packing
//! amortizes it across traffic — the only underfilled batch is the
//! final drain flush when a lane runs out of work entirely.
//!
//! Demux rides the engine's order-independent accumulators: each
//! output row routes back to its job's
//! [`PredAccum`](crate::coordinator::engine::PredAccum) via
//! `absorb_one`, in stream order per job (batches execute FIFO, slots
//! absorb in order), so a job's folded metrics are bit-identical to an
//! offline [`simulate_chunked`](crate::coordinator::engine::simulate_chunked)
//! run of the same (trace, artifact, chunking) — the loopback tests
//! assert exactly that.
//!
//! The executor is **double-buffered** through the engine-level
//! [`ExecPipeline`](crate::coordinator::pipeline::ExecPipeline) — the
//! machinery born here in PR 4 and since extracted into
//! `coordinator::pipeline` so the offline `simulate_parallel*` workers
//! share the same implementation: two staging buffer sets rotate
//! through a `sync_channel(1)` to a dedicated executor thread, so
//! feature extraction and window packing of batch `k+1` overlap model
//! execution of batch `k`.
//!
//! Job **preparation** (building the trace source — for SimNet,
//! materializing the functional trace and running the detailed sim for
//! its ctx metrics) runs on a bounded prep stage off the lane thread
//! ([`LaneConfig::prep_depth`]), so admissions no longer stall active
//! jobs; resident prepared-but-unadmitted bytes stay bounded by the
//! prep-queue depth.
//!
//! Chunk-level caching happens at the pack boundary: each job pulls
//! its trace in `chunk`-row units, keys them by (artifact fingerprint,
//! warm-up prefix hash, content hash), and on a hit skips straight
//! past the chunk — merging the memoized accumulator and fast-
//! forwarding extractor state exactly (see [`super::cache`]).
//!
//! **Failure semantics.** Every way a job can die maps to a typed
//! [`ServeError`]: preparation failures are terminal (`bad_request` /
//! `job_failed`), a failed batch kills exactly the jobs whose windows
//! rode in it with a retryable `exec_failed`, an expired deadline is a
//! retryable `deadline_exceeded` (swept both in the queue and across
//! active jobs, reclaiming lane buffers), and a lane-fatal error
//! answers every in-flight and in-prep job retryably
//! (`lane_failed`) before [`run_lane`] returns `Err` — the server's
//! supervisor then respawns the lane with backoff. Fault probes
//! ([`crate::util::fault`]) let tests and the chaos harness trigger
//! each path deterministically.

use super::cache::{chain_prefix, hash_chunk, ChunkKey, PredictionCache, PREFIX_SEED};
use super::forward::PeerCache;
use super::protocol::{
    resolve_ctx_uarch, ErrorCode, JobOutcome, JobSpec, ServeError, StatsSnapshot,
};
use super::queue::{JobQueue, QueuedJob};
use crate::coordinator::engine::{PredAccum, WindowStager};
use crate::coordinator::pipeline::{
    spawn_exec_pipeline, ExecBatch, ExecBuffers, ExecPipeline, PipeMsg,
};
use crate::functional::FunctionalSim;
use crate::runtime::{ModelKind, ModelOutputs, PooledArtifact};
use crate::telemetry::{self, log_enabled, registry, Counter, Field, Level, Stage};
use crate::trace::{ChunkBuf, ChunkSource, OwnedChunkSource, CTX_WIDTH};
use crate::util::fault::{self, Probe};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Scheduler telemetry
// ---------------------------------------------------------------------

/// Pre-resolved scheduler-wide metric handles. `tao_jobs_chunks_total`
/// and the cache hit/miss counters are incremented at the *same*
/// segment-decision site in [`ActiveJob::next_window`], so
/// `hits + misses == chunks` holds structurally — the CI metrics-smoke
/// job asserts that identity over `/metrics`.
struct SchedTele {
    chunks: Counter,
    cache_hits: Counter,
    cache_misses: Counter,
    deadline_sweeps: Counter,
    deadline_expired: Counter,
    packed_windows: Counter,
    batch_slots: Counter,
}

fn tele() -> &'static SchedTele {
    static T: OnceLock<SchedTele> = OnceLock::new();
    T.get_or_init(|| {
        let reg = registry();
        SchedTele {
            chunks: reg.counter(
                "tao_jobs_chunks_total",
                "Trace chunks pulled by serving jobs (each is a cache hit or miss).",
                &[],
            ),
            cache_hits: reg.counter(
                "tao_cache_hits_total",
                "Prediction-cache chunk hits at the pack boundary.",
                &[],
            ),
            cache_misses: reg.counter(
                "tao_cache_misses_total",
                "Prediction-cache chunk misses at the pack boundary.",
                &[],
            ),
            deadline_sweeps: reg.counter(
                "tao_deadline_sweeps_total",
                "Lane deadline sweep passes over active jobs.",
                &[],
            ),
            deadline_expired: reg.counter(
                "tao_deadline_expired_total",
                "Jobs cancelled because their deadline expired.",
                &[],
            ),
            packed_windows: reg.counter(
                "tao_packed_windows_total",
                "Context windows packed into executed batches.",
                &[],
            ),
            batch_slots: reg.counter(
                "tao_batch_slots_total",
                "Slots available in executed batches (sum of lane B).",
                &[],
            ),
        }
    })
}

/// Interned serving-side decode stage (`tao_stage_seconds{stage="serve_decode"}`),
/// span-traced with each job's trace id.
fn serve_decode_stage() -> &'static Stage {
    static S: OnceLock<Stage> = OnceLock::new();
    S.get_or_init(|| Stage::new("serve_decode"))
}

/// Help text for the per-lane counter families (satellite of the
/// respawn-loss fix: the registry cells outlive lane threads, so these
/// stay cumulative across supervisor respawns).
const LANE_JOBS_HELP: &str = "Jobs answered by this artifact's lane (cumulative across respawns).";
const LANE_BATCHES_HELP: &str =
    "Batches executed by this artifact's lane (cumulative across respawns).";

/// Lane tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct LaneConfig {
    /// Concurrent jobs a lane packs from.
    pub max_active: usize,
    /// Double-buffered executor thread (false = execute inline, mainly
    /// for deterministic unit tests).
    pub pipeline: bool,
    /// Batch-formation window: when an idle lane admits its first job,
    /// wait this long for more jobs so the first batches already pack
    /// cross-job (the classic dynamic-batching admission delay).
    pub admission_wait: Duration,
    /// Jobs prepared off the lane thread ahead of admission (trace
    /// source construction; for SimNet, the detailed-sim ctx
    /// materialization). Bounds resident prepared-but-unadmitted jobs;
    /// 0 prepares inline on the lane thread (the pre-prep-stage
    /// behavior, mainly for deterministic unit tests).
    pub prep_depth: usize,
}

impl Default for LaneConfig {
    fn default() -> LaneConfig {
        LaneConfig {
            max_active: 16,
            pipeline: true,
            admission_wait: Duration::from_millis(2),
            prep_depth: 2,
        }
    }
}

/// Daemon-wide serving counters (lanes update, `/v1/stats` snapshots).
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Jobs accepted into the queue.
    pub jobs_submitted: AtomicU64,
    /// Jobs answered (success or error).
    pub jobs_done: AtomicU64,
    /// Jobs refused by admission control.
    pub jobs_rejected: AtomicU64,
    /// Jobs currently active inside lanes.
    pub active_jobs: AtomicU64,
    /// Model batches executed.
    pub batches: AtomicU64,
    /// Windows packed into executed batches.
    pub packed_windows: AtomicU64,
    /// Slots available in executed batches (Σ lane `B`).
    pub batch_slots: AtomicU64,
    /// Lanes respawned by the supervisor after a failure or panic.
    pub lane_restarts: AtomicU64,
    /// Lanes currently down (failed, inside their respawn backoff).
    pub lanes_down: AtomicU64,
}

impl ServeCounters {
    /// Assemble the `/v1/stats` snapshot.
    pub fn snapshot(
        &self,
        queue: &JobQueue,
        cache: &Mutex<PredictionCache>,
    ) -> StatsSnapshot {
        let cs = fault::relock(cache).stats();
        StatsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            queue_depth: queue.depth() as u64,
            active_jobs: self.active_jobs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            packed_windows: self.packed_windows.load(Ordering::Relaxed),
            batch_slots: self.batch_slots.load(Ordering::Relaxed),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_evictions: cs.evictions,
            cache_entries: cs.entries,
            cache_recovered: cs.recovered,
            lane_restarts: self.lane_restarts.load(Ordering::Relaxed),
        }
    }
}

/// Shorthand for a job's completion channel.
type DoneTx = std::sync::mpsc::Sender<Result<JobOutcome, ServeError>>;

static NEXT_JOB_ID: AtomicU64 = AtomicU64::new(1);

// ---------------------------------------------------------------------
// Per-job stream state
// ---------------------------------------------------------------------

/// A stream-ordered accounting segment: one pulled chunk, either
/// skipped via the cache or awaiting its windows' model outputs.
///
/// `weight` is the phase-sampling expansion factor for this chunk
/// (1.0 for full replay). Cached deltas always store the *raw* slice
/// accumulator; the weight applies only when the segment settles into
/// the job accumulator, so cache entries stay reusable across plans.
enum Segment {
    /// Cached chunk: merge `accum` once absorption reaches `start`.
    Hit { start: u64, accum: PredAccum, weight: f64 },
    /// Computed chunk: rows fold into `accum` alongside the job
    /// accumulator; when absorption reaches `end` the delta is
    /// published to the cache under `key`.
    Miss { key: ChunkKey, end: u64, accum: PredAccum, weight: f64 },
}

struct ActiveJob {
    id: u64,
    spec: JobSpec,
    kind: ModelKind,
    source: Box<dyn ChunkSource + Send>,
    stager: WindowStager,
    accum: PredAccum,
    buf: ChunkBuf,
    pos: usize,
    buf_len: usize,
    prefix: u64,
    emitted: u64,
    absorbed: u64,
    segments: VecDeque<Segment>,
    /// Sampled replay (`spec.plan`): per-phase weights in stream order,
    /// consumed one per pulled chunk (the pull grain is the plan's
    /// slice size, so chunk == phase == cache unit).
    weights: Option<VecDeque<f64>>,
    stream_done: bool,
    hits: u64,
    misses: u64,
    windows: u64,
    dead: Option<ServeError>,
    done: DoneTx,
    admitted_at: Instant,
    deadline: Option<Instant>,
    trace_id: String,
}

impl ActiveJob {
    fn prepare(
        mut spec: JobSpec,
        done: DoneTx,
        admitted_at: Instant,
        deadline: Option<Instant>,
        trace_id: String,
        art: &PooledArtifact,
    ) -> Result<ActiveJob> {
        let kind = art.meta.kind;
        let mut weights = None;
        let source: Box<dyn ChunkSource + Send> = if let Some(trace) = &spec.trace {
            // Replay a recorded trace of either on-disk format.
            // Decompression happens inside `next_chunk`, i.e. on this
            // lane's pull — no extra decode stage, no resident trace.
            anyhow::ensure!(
                kind == ModelKind::Tao,
                "trace jobs require a Tao artifact"
            );
            let src = crate::trace::open_trace_source(std::path::Path::new(trace))?;
            if let Some(plan) = &spec.plan {
                // Sampled replay: stream only the plan's representative
                // slices. The pull grain becomes the plan's slice size
                // so every chunk is exactly one phase — chunk, phase
                // and cache unit coincide, and the cached delta for a
                // representative slice is reusable by any job sampling
                // the same trace prefix.
                let plan =
                    crate::sampling::SamplingPlan::load(std::path::Path::new(plan))?;
                spec.chunk = plan.slice_rows as usize;
                let sampled = crate::sampling::SampledTraceSource::new(src, plan)?;
                weights = Some(sampled.weights().into_iter().collect());
                Box::new(sampled)
            } else {
                Box::new(src)
            }
        } else {
            let workload = crate::workloads::by_name(&spec.bench)
                .with_context(|| format!("unknown benchmark {:?}", spec.bench))?;
            let program = workload.build(spec.seed);
            match kind {
                // Tao consumes the µarch-agnostic functional stream;
                // jobs pull it straight off the generator, never
                // resident.
                ModelKind::Tao => Box::new(FunctionalSim::new(&program).into_chunks(spec.insts)),
                // SimNet needs the detailed trace of its target design
                // as a per-instruction context input — materialized up
                // front (that cost is the paper's argument against
                // SimNet).
                ModelKind::SimNet => {
                    let sel = spec
                        .ctx_uarch
                        .as_deref()
                        .context("SimNet artifacts require ctx_uarch")?;
                    let cfg = resolve_ctx_uarch(sel)?;
                    let cols = FunctionalSim::new(&program).run(spec.insts).to_columns();
                    let ctx = crate::dataset::simnet_ctx_metrics(&program, &cfg, spec.insts);
                    Box::new(OwnedChunkSource::new(cols, Some(ctx))?)
                }
            }
        };
        Ok(ActiveJob {
            id: NEXT_JOB_ID.fetch_add(1, Ordering::Relaxed),
            kind,
            source,
            stager: WindowStager::new(&art.meta),
            accum: PredAccum::default(),
            buf: ChunkBuf::new(),
            pos: 0,
            buf_len: 0,
            prefix: PREFIX_SEED,
            emitted: 0,
            absorbed: 0,
            segments: VecDeque::new(),
            weights,
            stream_done: false,
            hits: 0,
            misses: 0,
            windows: 0,
            dead: None,
            done,
            admitted_at,
            deadline,
            trace_id,
            spec,
        })
    }

    /// Emit the next window into the caller's batch slot, pulling (and
    /// cache-probing) chunks as needed. `Ok(false)` means the stream is
    /// exhausted. A local cache miss consults the key's ring peers
    /// (`peers`) before falling through to model execution; an adopted
    /// peer result is reclassified as a hit at the single decision site
    /// so `hits + misses == chunks` stays structural.
    fn next_window(
        &mut self,
        cache: &Mutex<PredictionCache>,
        artifact_fp: u64,
        peers: Option<&PeerCache>,
        ops_slot: &mut [i32],
        feat_slot: &mut [f32],
        ctx_slot: Option<&mut [f32]>,
    ) -> Result<bool> {
        loop {
            if self.pos < self.buf_len {
                let i = self.pos;
                let rec = self.buf.cols.record(i);
                let ctx_row = (self.kind == ModelKind::SimNet)
                    .then(|| &self.buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
                self.stager.stage_window(&rec, ctx_row, ops_slot, feat_slot, ctx_slot);
                self.pos += 1;
                self.emitted += 1;
                self.windows += 1;
                return Ok(true);
            }
            if self.stream_done {
                return Ok(false);
            }
            if fault::should_fire(Probe::ChunkDecode) {
                anyhow::bail!("injected fault: chunk decode failed");
            }
            let n = {
                let _sp = serve_decode_stage().span_traced(&self.trace_id);
                self.source.next_chunk(&mut self.buf, self.spec.chunk)?
            };
            if n == 0 {
                self.stream_done = true;
                return Ok(false);
            }
            if self.kind == ModelKind::SimNet {
                anyhow::ensure!(
                    self.buf.ctx.len() == n * CTX_WIDTH,
                    "SimNet source must carry [n×6] ctx metrics"
                );
            }
            self.buf_len = n;
            self.pos = 0;
            let weight = match &mut self.weights {
                // One pull per phase: the sampled source never crosses a
                // phase boundary and the pull grain is the slice size.
                Some(w) => w
                    .pop_front()
                    .context("sampled trace delivered more chunks than the plan has phases")?,
                None => 1.0,
            };
            let content = hash_chunk(&self.buf);
            let key = ChunkKey { artifact: artifact_fp, prefix: self.prefix, content };
            self.prefix = chain_prefix(self.prefix, content);
            let mut hit = fault::relock(cache).get(&key);
            // One chunk == one hit or one miss, decided right here:
            // the CI identity hits + misses == chunks is structural.
            tele().chunks.inc();
            if hit.is_none() {
                // Local miss: ask the key's ring peers before paying for
                // model execution. The lookup runs *outside* the cache
                // lock (it is a network RPC); an adopted accumulator is
                // re-inserted under the lock and the miss `get` just
                // counted is reclassified as a peer hit.
                if let Some(peers) = peers {
                    if let Some(found) = peers.lookup(&key) {
                        if found.instructions == n as u64 {
                            fault::relock(cache).adopt(key, found.clone());
                            hit = Some(found);
                        }
                    }
                }
            }
            match hit {
                Some(delta) if delta.instructions == n as u64 => {
                    // Cache hit: skip the whole chunk. Fast-forward the
                    // extractor exactly (state-only advance; the last
                    // T-1 rows roll through the window history so a
                    // later miss stages bit-identical windows) and
                    // queue the memoized accumulator for in-order
                    // merging.
                    let hist = self.stager.history_rows();
                    for i in 0..n {
                        let rec = self.buf.cols.record(i);
                        if i + hist < n {
                            self.stager.advance_only(&rec);
                        } else {
                            let ctx_row = (self.kind == ModelKind::SimNet)
                                .then(|| &self.buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
                            self.stager.roll_only(&rec, ctx_row);
                        }
                    }
                    self.segments.push_back(Segment::Hit {
                        start: self.emitted,
                        accum: delta,
                        weight,
                    });
                    self.hits += 1;
                    tele().cache_hits.inc();
                    self.emitted += n as u64;
                    self.pos = n;
                    self.pump(cache);
                }
                _ => {
                    self.misses += 1;
                    tele().cache_misses.inc();
                    self.segments.push_back(Segment::Miss {
                        key,
                        end: self.emitted + n as u64,
                        accum: PredAccum::at_base(self.emitted),
                        weight,
                    });
                }
            }
        }
    }

    /// Fold one routed output row (stream order per job is guaranteed
    /// by FIFO batches + in-order slots).
    ///
    /// Sampled jobs fold rows only into the open segment; the weighted
    /// expansion into the job accumulator happens when the segment
    /// settles in [`ActiveJob::pump`], so every phase merges exactly
    /// once at its plan weight.
    fn absorb_row(
        &mut self,
        out: &ModelOutputs,
        row: usize,
        cache: &Mutex<PredictionCache>,
    ) {
        if self.weights.is_none() {
            self.accum.absorb_one(out, self.kind, row);
        }
        match self.segments.front_mut() {
            Some(Segment::Miss { accum, .. }) => accum.absorb_one(out, self.kind, row),
            _ => debug_assert!(false, "output row with no open miss segment"),
        }
        self.absorbed += 1;
        self.pump(cache);
    }

    /// Settle stream-ordered segments: merge hit accumulators the
    /// moment absorption reaches them; publish completed miss deltas
    /// to the cache (raw, unweighted — a sampled job's weighted merge
    /// happens here too, after the raw delta is captured).
    fn pump(&mut self, cache: &Mutex<PredictionCache>) {
        let sampled = self.weights.is_some();
        loop {
            match self.segments.front() {
                Some(Segment::Hit { start, .. }) if *start == self.absorbed => {
                    let Some(Segment::Hit { accum, weight, .. }) = self.segments.pop_front()
                    else {
                        unreachable!()
                    };
                    self.absorbed += accum.instructions;
                    if sampled {
                        self.accum.merge_weighted(&accum, weight);
                    } else {
                        self.accum.merge(&accum);
                    }
                }
                Some(Segment::Miss { end, .. }) if *end == self.absorbed => {
                    let Some(Segment::Miss { key, accum, weight, .. }) =
                        self.segments.pop_front()
                    else {
                        unreachable!()
                    };
                    if sampled {
                        self.accum.merge_weighted(&accum, weight);
                    }
                    fault::relock(cache).insert(key, accum);
                }
                _ => break,
            }
        }
    }

    fn is_complete(&self) -> bool {
        self.stream_done && self.segments.is_empty() && self.absorbed == self.emitted
    }

    fn outcome(&self) -> JobOutcome {
        JobOutcome {
            job_id: self.id,
            metrics: self.accum.metrics(),
            windows: self.windows,
            cache_hits: self.hits,
            cache_misses: self.misses,
            elapsed_ms: self.admitted_at.elapsed().as_secs_f64() * 1e3,
            trace_id: self.trace_id.clone(),
        }
    }
}

// ---------------------------------------------------------------------
// Executor (the shared engine pipeline) + prep stage
// ---------------------------------------------------------------------

/// A finished batch back from the executor: the jobs whose windows
/// rode in it, plus the outputs — or a failure scoped to exactly those
/// jobs (an executor hiccup on job A's batch must not 500 job B).
struct ExecOutcome {
    routes: Vec<u64>,
    result: Result<ModelOutputs, String>,
}

/// The lane's execution backend. The pipelined variant is the shared
/// engine [`ExecPipeline`] (this module's PR 4 double-buffering,
/// extracted); inline executes synchronously on the lane thread for
/// deterministic unit tests.
enum Executor {
    Inline {
        session: crate::runtime::Session,
        bufs: Option<ExecBuffers>,
    },
    Pipelined(ExecPipeline<Vec<u64>>),
}

impl Executor {
    fn start(art: &PooledArtifact, cfg: &LaneConfig) -> Result<Executor> {
        if fault::should_fire(Probe::ArtifactLoad) {
            anyhow::bail!("injected fault: artifact load failed");
        }
        let (b, t, f) = (art.meta.batch, art.meta.context, art.meta.feature_dim);
        let kind = art.meta.kind;
        Ok(if cfg.pipeline {
            let session_art = art.clone();
            Executor::Pipelined(spawn_exec_pipeline(
                move || session_art.open_session(),
                kind,
                b,
                t,
                f,
                2,
            ))
        } else {
            Executor::Inline {
                session: art.open_session()?,
                bufs: Some(ExecBuffers::new(b, t, f, kind)),
            }
        })
    }

    fn in_flight(&self) -> usize {
        match self {
            Executor::Inline { .. } => 0,
            Executor::Pipelined(p) => p.in_flight(),
        }
    }

    /// A free staging buffer set, if one is available right now.
    fn stage_buffer(&mut self) -> Option<ExecBuffers> {
        match self {
            Executor::Inline { bufs, .. } => bufs.take(),
            Executor::Pipelined(p) => p.take_buf(),
        }
    }

    fn release(&mut self, b: ExecBuffers) {
        match self {
            Executor::Inline { bufs, .. } => *bufs = Some(b),
            Executor::Pipelined(p) => p.release(b),
        }
    }

    /// Run (inline) or enqueue (pipelined) one packed batch. Inline
    /// returns the outcome immediately; pipelined outcomes come back
    /// through [`Executor::try_done`] / [`Executor::recv_done`].
    /// `Err` is lane-fatal.
    fn dispatch(
        &mut self,
        bufs: ExecBuffers,
        valid: usize,
        routes: Vec<u64>,
        kind: ModelKind,
    ) -> Result<Option<ExecOutcome>, String> {
        if fault::should_fire(Probe::ExecPanic) {
            // Unwinds the lane thread: the supervisor's catch_unwind
            // converts this into a lane restart, and waiting
            // connections see their completion senders drop (answered
            // as a retryable 503 by the HTTP layer).
            panic!("injected fault: executor panicked");
        }
        match self {
            Executor::Inline { session, bufs: slot } => {
                let ctx = match kind {
                    ModelKind::SimNet => Some(&bufs.ctx[..]),
                    ModelKind::Tao => None,
                };
                let result = session
                    .run_on(&bufs.ops, &bufs.feats, ctx, valid)
                    .map_err(|e| format!("model execution: {e:#}"));
                *slot = Some(bufs);
                Ok(Some(ExecOutcome { routes, result }))
            }
            Executor::Pipelined(p) => {
                p.submit(bufs, ExecBatch { valid, tag: routes })
                    .map_err(|e| format!("{e:#}"))?;
                Ok(None)
            }
        }
    }

    /// Non-blocking poll for a finished batch.
    fn try_done(&mut self) -> Result<Option<ExecOutcome>, String> {
        match self {
            Executor::Inline { .. } => Ok(None),
            Executor::Pipelined(p) => match p.try_recv() {
                Ok(None) => Ok(None),
                Ok(Some(msg)) => Self::map_msg(p, msg).map(Some),
                Err(e) => Err(format!("{e:#}")),
            },
        }
    }

    /// Block for the oldest in-flight batch.
    fn recv_done(&mut self) -> Result<ExecOutcome, String> {
        match self {
            Executor::Inline { .. } => Err("inline executor has no in-flight batches".into()),
            Executor::Pipelined(p) => {
                let msg = p.recv().map_err(|e| format!("{e:#}"))?;
                Self::map_msg(p, msg)
            }
        }
    }

    fn map_msg(
        p: &mut ExecPipeline<Vec<u64>>,
        msg: PipeMsg<ExecBuffers, ExecBatch<Vec<u64>>, ModelOutputs>,
    ) -> Result<ExecOutcome, String> {
        match msg {
            PipeMsg::Done { buf, payload, result } => {
                p.release(buf);
                Ok(ExecOutcome {
                    routes: payload.tag,
                    result: result.map_err(|e| format!("model execution: {e}")),
                })
            }
            PipeMsg::InitFailed { msg } => Err(format!("open session: {msg}")),
        }
    }
}

/// A prepared job (or its preparation failure, with the completion
/// channel so the waiting connection gets an answer).
type PrepResult = Result<Box<ActiveJob>, (DoneTx, ServeError)>;

struct PrepLane {
    tx: SyncSender<QueuedJob>,
    rx: Receiver<PrepResult>,
    handle: std::thread::JoinHandle<()>,
    /// Raised by [`PrepStage::abort`]: skip the (expensive) preparation
    /// of still-queued jobs so failing lanes answer promptly.
    aborting: Arc<std::sync::atomic::AtomicBool>,
}

/// Bounded off-lane job preparation: popped queue jobs go to a prep
/// thread that builds their trace sources (the SimNet detailed-sim ctx
/// materialization is the expensive case), so the lane keeps packing
/// for active jobs while admissions materialize. At most `depth` jobs
/// sit prepared-but-unadmitted (both channels are `depth`-bounded), so
/// resident bytes stay bounded by the prep-queue depth — the reason
/// preparation does not simply run on the connection threads.
struct PrepStage {
    lane: Option<PrepLane>,
    in_flight: usize,
}

impl PrepStage {
    fn start(art: &PooledArtifact, depth: usize) -> PrepStage {
        if depth == 0 {
            return PrepStage { lane: None, in_flight: 0 };
        }
        let (tx, rx_jobs) = sync_channel::<QueuedJob>(depth);
        let (tx_done, rx) = sync_channel::<PrepResult>(depth);
        let art = art.clone();
        let aborting = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let abort_flag = aborting.clone();
        let handle = std::thread::spawn(move || {
            for qj in rx_jobs {
                let expired = qj.expired(Instant::now());
                let QueuedJob { spec, done, admitted_at, deadline, trace_id } = qj;
                let res = if abort_flag.load(Ordering::Relaxed) {
                    // The lane is failing: don't burn a detailed-sim
                    // run per queued job; abort() answers them.
                    Err((
                        done,
                        ServeError::new(
                            ErrorCode::LaneFailed,
                            "lane aborted during preparation",
                        ),
                    ))
                } else if expired {
                    // The deadline lapsed while waiting for prep:
                    // don't spend a detailed-sim run on a dead job.
                    Err((
                        done,
                        ServeError::new(
                            ErrorCode::DeadlineExceeded,
                            "deadline expired before preparation",
                        ),
                    ))
                } else {
                    match ActiveJob::prepare(
                        spec,
                        done.clone(),
                        admitted_at,
                        deadline,
                        trace_id,
                        &art,
                    ) {
                        Ok(job) => Ok(Box::new(job)),
                        Err(e) => Err((done, prep_error(&e))),
                    }
                };
                if tx_done.send(res).is_err() {
                    return;
                }
            }
        });
        PrepStage { lane: Some(PrepLane { tx, rx, handle, aborting }), in_flight: 0 }
    }

    /// Jobs handed to the prep thread and not yet admitted/answered.
    fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Hand one popped job to the prep stage. With no prep thread
    /// (depth 0) the job prepares inline and is admitted right here.
    /// The caller must keep `in_flight() < depth` so the send never
    /// blocks the lane.
    fn begin(
        &mut self,
        qj: QueuedJob,
        art: &PooledArtifact,
        active: &mut Vec<ActiveJob>,
        counters: &ServeCounters,
    ) {
        match &self.lane {
            Some(l) => match l.tx.try_send(qj) {
                Ok(()) => self.in_flight += 1,
                // Prep thread gone (it only exits with us) or the
                // bound was violated: fall back to inline prep rather
                // than lose the job.
                Err(TrySendError::Full(qj)) | Err(TrySendError::Disconnected(qj)) => {
                    admit_prepared(prepare_inline(qj, art), active, counters)
                }
            },
            None => admit_prepared(prepare_inline(qj, art), active, counters),
        }
    }

    /// Non-blocking poll for a prepared job.
    fn try_ready(&mut self) -> Option<PrepResult> {
        let lane = self.lane.as_ref()?;
        match lane.rx.try_recv() {
            Ok(res) => {
                self.in_flight -= 1;
                Some(res)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.reap();
                None
            }
        }
    }

    /// Block up to `timeout` for a prepared job (idle lane, admissions
    /// still materializing).
    fn ready_timeout(&mut self, timeout: Duration) -> Option<PrepResult> {
        let lane = self.lane.as_ref()?;
        match lane.rx.recv_timeout(timeout) {
            Ok(res) => {
                self.in_flight -= 1;
                Some(res)
            }
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                self.reap();
                None
            }
        }
    }

    /// The prep thread died (it only exits on its own if
    /// `ActiveJob::prepare` panicked). Its in-flight jobs are gone —
    /// their completion senders dropped with it, so waiting connections
    /// get "job dropped" — and the lane must not keep waiting on them:
    /// zero the counter and fall back to inline prep for future jobs.
    fn reap(&mut self) {
        if let Some(l) = self.lane.take() {
            eprintln!(
                "serve: prep thread died with {} job(s) in flight; preparing inline from now on",
                self.in_flight
            );
            let _ = l.handle.join();
        }
        self.in_flight = 0;
    }

    /// Clean shutdown: close the intake and join (no jobs in flight).
    fn shutdown(self) {
        if let Some(l) = self.lane {
            drop(l.tx);
            let _ = l.handle.join();
        }
    }

    /// Lane-failure shutdown: answer every in-prep job with the lane
    /// error so no connection hangs. Raising `aborting` first makes the
    /// prep thread skip still-queued preparations, so the answers (and
    /// the zombie drain behind them) are prompt.
    fn abort(self, err: &str, counters: &ServeCounters) {
        let Some(l) = self.lane else { return };
        l.aborting.store(true, Ordering::Relaxed);
        drop(l.tx);
        for res in l.rx.iter() {
            let done = match res {
                Ok(job) => job.done.clone(),
                Err((done, _)) => done,
            };
            let se = ServeError::new(ErrorCode::LaneFailed, format!("lane failed: {err}"));
            let _ = done.send(Err(se));
            counters.jobs_done.fetch_add(1, Ordering::Relaxed);
        }
        let _ = l.handle.join();
    }
}

/// Classify a preparation failure: always terminal (bad benchmark,
/// missing ctx_uarch, malformed spec — a retry would fail identically).
fn prep_error(e: &anyhow::Error) -> ServeError {
    ServeError::new(ErrorCode::BadRequest, format!("job preparation failed: {e:#}"))
}

/// Prepare a job on the current thread (prep stage disabled or
/// unavailable).
fn prepare_inline(qj: QueuedJob, art: &PooledArtifact) -> PrepResult {
    let QueuedJob { spec, done, admitted_at, deadline, trace_id } = qj;
    match ActiveJob::prepare(spec, done.clone(), admitted_at, deadline, trace_id, art) {
        Ok(job) => Ok(Box::new(job)),
        Err(e) => Err((done, prep_error(&e))),
    }
}

/// Admit a prepared job into the lane's active set (or answer its
/// preparation failure).
fn admit_prepared(res: PrepResult, active: &mut Vec<ActiveJob>, counters: &ServeCounters) {
    match res {
        Ok(job) => {
            counters.active_jobs.fetch_add(1, Ordering::Relaxed);
            active.push(*job);
        }
        Err((done, err)) => {
            let _ = done.send(Err(err));
            counters.jobs_done.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Answer a popped job whose deadline already lapsed (retryable
/// `deadline_exceeded`), or hand it back for admission.
fn expire_popped(qj: QueuedJob, counters: &ServeCounters) -> Option<QueuedJob> {
    if !qj.expired(Instant::now()) {
        return Some(qj);
    }
    let se = ServeError::new(
        ErrorCode::DeadlineExceeded,
        "deadline expired before the job reached a lane",
    );
    tele().deadline_expired.inc();
    let _ = qj.done.send(Err(se));
    counters.jobs_done.fetch_add(1, Ordering::Relaxed);
    None
}

// ---------------------------------------------------------------------
// The lane
// ---------------------------------------------------------------------

/// Run one artifact lane until the queue is closed and drained. Pops
/// jobs targeting `art` from the shared queue into the bounded prep
/// stage, packs windows across every active job into the artifact's
/// `[B, T, F]` batch, executes (pipelined through the shared engine
/// [`ExecPipeline`] by default), demuxes outputs to per-job
/// accumulators, and answers each job's completion channel.
///
/// On a lane-fatal error (executor init/channel death) every in-flight
/// and in-prep job is answered with a retryable `lane_failed` and the
/// function returns `Err` — the server's supervisor logs it, backs
/// off, and respawns the lane. A panic on this thread reaches the same
/// supervisor via `catch_unwind`.
pub fn run_lane(
    art: PooledArtifact,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    cfg: LaneConfig,
) -> Result<()> {
    run_lane_ext(art, queue, cache, counters, cfg, LaneLinks::default())
}

/// Fleet wiring for a lane, all optional — a standalone daemon runs
/// every lane with [`LaneLinks::default`].
///
/// * `peers` — the ring-neighbour cache client: a local prediction-
///   cache miss consults the key's replicas over `/v1/cache/lookup`
///   before paying for model execution.
/// * `down` — the supervisor's per-lane degraded flag. The supervisor
///   raises it (and bumps `lanes_down`) when the lane dies; the lane
///   clears it only once its executor and prep stage are actually up
///   again, so `/healthz` reports `degraded` for the whole backoff
///   window, not just the instant of the crash.
#[derive(Default)]
pub struct LaneLinks {
    pub peers: Option<Arc<PeerCache>>,
    pub down: Option<Arc<AtomicBool>>,
}

/// [`run_lane`] with fleet wiring (peer cache + supervisor down flag).
pub fn run_lane_ext(
    art: PooledArtifact,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    cfg: LaneConfig,
    links: LaneLinks,
) -> Result<()> {
    let (b, t, f) = (art.meta.batch, art.meta.context, art.meta.feature_dim);
    let kind = art.meta.kind;
    let fp = art.fingerprint;
    let mut exec = Executor::start(&art, &cfg)?;
    let mut prep = PrepStage::start(&art, cfg.prep_depth);
    // Executor + prep stage are live: if the supervisor marked this
    // lane degraded, clear it now — not when the respawn was merely
    // *scheduled* (an `Executor::start` failure above leaves the flag
    // raised and `?`s back to the supervisor's backoff loop).
    if let Some(down) = &links.down {
        if down.swap(false, Ordering::Relaxed) {
            counters.lanes_down.fetch_sub(1, Ordering::Relaxed);
        }
    }
    let peers: Option<&PeerCache> =
        links.peers.as_deref().filter(|p: &&PeerCache| !p.is_empty());
    let mut active: Vec<ActiveJob> = Vec::new();
    let mut rr = 0usize;
    // Per-artifact lane counters. The registry cells are process-global
    // and keyed by label, so these survive a lane respawn: a fresh lane
    // thread re-resolves the *same* cells and keeps counting.
    let lane_jobs =
        registry().counter("tao_lane_jobs_total", LANE_JOBS_HELP, &[("artifact", &art.name)]);
    let lane_batches = registry().counter(
        "tao_lane_batches_total",
        LANE_BATCHES_HELP,
        &[("artifact", &art.name)],
    );

    macro_rules! fatal {
        ($e:expr) => {{
            let e: String = $e;
            fail_lane(&e, &mut active, &counters);
            prep.abort(&e, &counters);
            anyhow::bail!("lane {:?} failed: {e}", art.name);
        }};
    }

    loop {
        // Absorb every result that is already done (non-blocking).
        loop {
            match exec.try_done() {
                Ok(Some(outcome)) => apply_outcome(outcome, &mut active, &cache),
                Ok(None) => break,
                Err(e) => fatal!(e),
            }
        }
        // Deadline sweep: an expired job dies retryably and the
        // finalize below drops it, reclaiming its chunk buffers and
        // source (any still-in-flight output rows demux to nobody).
        let now = Instant::now();
        tele().deadline_sweeps.inc();
        for job in active.iter_mut() {
            if job.dead.is_none() && job.deadline.is_some_and(|d| now >= d) {
                job.dead = Some(ServeError::new(
                    ErrorCode::DeadlineExceeded,
                    "job deadline exceeded while streaming",
                ));
                tele().deadline_expired.inc();
            }
        }
        finalize(&mut active, &counters, &lane_jobs);

        // Admission: admit whatever the prep stage finished, refill it
        // from the queue up to spare capacity; when waking from idle,
        // hold the batch-formation window so the first batches pack.
        let was_idle = active.is_empty() && exec.in_flight() == 0 && prep.in_flight() == 0;
        while active.len() + prep.in_flight() < cfg.max_active {
            match prep.try_ready() {
                Some(res) => admit_prepared(res, &mut active, &counters),
                None => break,
            }
        }
        while active.len() + prep.in_flight() < cfg.max_active
            && prep.in_flight() < cfg.prep_depth.max(1)
        {
            let timeout =
                if active.is_empty() && exec.in_flight() == 0 && prep.in_flight() == 0 {
                    Duration::from_millis(50)
                } else {
                    Duration::ZERO
                };
            match queue.pop_for(&art.name, timeout) {
                Some(qj) => {
                    if let Some(qj) = expire_popped(qj, &counters) {
                        prep.begin(qj, &art, &mut active, &counters);
                    }
                }
                None => break,
            }
        }
        if was_idle
            && (!active.is_empty() || prep.in_flight() > 0)
            && !cfg.admission_wait.is_zero()
        {
            let deadline = Instant::now() + cfg.admission_wait;
            while active.len() + prep.in_flight() < cfg.max_active
                && prep.in_flight() < cfg.prep_depth.max(1)
            {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match queue.pop_for(&art.name, deadline - now) {
                    Some(qj) => {
                        if let Some(qj) = expire_popped(qj, &counters) {
                            prep.begin(qj, &art, &mut active, &counters);
                        }
                    }
                    None => break,
                }
            }
        }
        while let Some(res) = prep.try_ready() {
            admit_prepared(res, &mut active, &counters);
        }
        finalize(&mut active, &counters, &lane_jobs);

        if active.is_empty() && exec.in_flight() == 0 {
            if prep.in_flight() > 0 {
                // Admissions are still materializing off-thread.
                if let Some(res) = prep.ready_timeout(Duration::from_millis(50)) {
                    admit_prepared(res, &mut active, &counters);
                }
                continue;
            }
            if queue.is_drained() {
                break;
            }
            continue;
        }

        // Stage and dispatch one packed batch (or wait for capacity).
        if let Some(mut bufs) = exec.stage_buffer() {
            let (valid, routes) =
                pack(&mut active, &mut rr, &mut bufs, &cache, fp, peers, b, t, f);
            if valid > 0 {
                counters.batches.fetch_add(1, Ordering::Relaxed);
                counters.packed_windows.fetch_add(valid as u64, Ordering::Relaxed);
                counters.batch_slots.fetch_add(b as u64, Ordering::Relaxed);
                lane_batches.inc();
                tele().packed_windows.inc_by(valid as u64);
                tele().batch_slots.inc_by(b as u64);
                match exec.dispatch(bufs, valid, routes, kind) {
                    Ok(Some(outcome)) => apply_outcome(outcome, &mut active, &cache),
                    Ok(None) => {}
                    Err(e) => fatal!(e),
                }
            } else {
                // No job can emit: everything active is stream-done and
                // waiting on in-flight outputs (or already complete).
                exec.release(bufs);
                if exec.in_flight() > 0 {
                    match exec.recv_done() {
                        Ok(outcome) => apply_outcome(outcome, &mut active, &cache),
                        Err(e) => fatal!(e),
                    }
                }
            }
        } else {
            // Both buffer sets in flight: block for one to come home.
            match exec.recv_done() {
                Ok(outcome) => apply_outcome(outcome, &mut active, &cache),
                Err(e) => fatal!(e),
            }
        }
        finalize(&mut active, &counters, &lane_jobs);
    }

    prep.shutdown();
    if let Executor::Pipelined(mut p) = exec {
        p.shutdown();
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn pack(
    active: &mut [ActiveJob],
    rr: &mut usize,
    bufs: &mut ExecBuffers,
    cache: &Mutex<PredictionCache>,
    fp: u64,
    peers: Option<&PeerCache>,
    b: usize,
    t: usize,
    f: usize,
) -> (usize, Vec<u64>) {
    let mut routes = Vec::with_capacity(b);
    let mut slot = 0usize;
    let n = active.len();
    while slot < b && n > 0 {
        let mut progressed = false;
        for k in 0..n {
            if slot == b {
                break;
            }
            let j = (*rr + k) % n;
            let job = &mut active[j];
            if job.dead.is_some() {
                continue;
            }
            let ops_slot = &mut bufs.ops[slot * t..(slot + 1) * t];
            let feat_slot = &mut bufs.feats[slot * t * f..(slot + 1) * t * f];
            let ctx_slot = match job.kind {
                ModelKind::SimNet => {
                    Some(&mut bufs.ctx[slot * t * CTX_WIDTH..(slot + 1) * t * CTX_WIDTH])
                }
                ModelKind::Tao => None,
            };
            match job.next_window(cache, fp, peers, ops_slot, feat_slot, ctx_slot) {
                Ok(true) => {
                    routes.push(job.id);
                    slot += 1;
                    progressed = true;
                }
                Ok(false) => {}
                // Stream errors (chunk decode, ctx mismatch) are
                // deterministic: a retry would fail identically.
                Err(e) => {
                    job.dead = Some(ServeError::new(ErrorCode::JobFailed, format!("{e:#}")))
                }
            }
        }
        *rr = (*rr + 1) % n;
        if !progressed {
            break;
        }
    }
    (slot, routes)
}

fn demux(
    out: &ModelOutputs,
    routes: &[u64],
    active: &mut [ActiveJob],
    cache: &Mutex<PredictionCache>,
) {
    for (row, id) in routes.iter().enumerate() {
        if let Some(job) = active.iter_mut().find(|j| j.id == *id && j.dead.is_none()) {
            job.absorb_row(out, row, cache);
        }
    }
}

/// Fold one finished batch back into the lane: demux outputs to the
/// routed jobs, or — on a scoped batch failure — kill exactly the jobs
/// whose windows rode in it (the rest keep streaming).
fn apply_outcome(outcome: ExecOutcome, active: &mut [ActiveJob], cache: &Mutex<PredictionCache>) {
    match outcome.result {
        Ok(out) => demux(&out, &outcome.routes, active, cache),
        Err(msg) => {
            // An execution hiccup is transient from the client's view:
            // the same spec resubmitted will pack into fresh batches.
            for job in active.iter_mut() {
                if outcome.routes.contains(&job.id) {
                    job.dead = Some(ServeError::new(
                        ErrorCode::ExecFailed,
                        format!("batch failed: {msg}"),
                    ));
                }
            }
        }
    }
}

fn finalize(active: &mut Vec<ActiveJob>, counters: &ServeCounters, lane_jobs: &Counter) {
    active.retain(|job| {
        if let Some(err) = &job.dead {
            if log_enabled(Level::Warn) {
                telemetry::emit(
                    Level::Warn,
                    "job_failed",
                    &[
                        ("trace_id", Field::Str(&job.trace_id)),
                        ("artifact", Field::Str(&job.spec.artifact)),
                        ("code", Field::Str(err.code.as_str())),
                    ],
                );
            }
            let _ = job.done.send(Err(err.clone()));
        } else if job.is_complete() {
            if log_enabled(Level::Info) {
                telemetry::emit(
                    Level::Info,
                    "job_done",
                    &[
                        ("trace_id", Field::Str(&job.trace_id)),
                        ("artifact", Field::Str(&job.spec.artifact)),
                        ("hits", Field::U64(job.hits)),
                        ("misses", Field::U64(job.misses)),
                        ("elapsed_ms", Field::F64(job.admitted_at.elapsed().as_secs_f64() * 1e3)),
                    ],
                );
            }
            let _ = job.done.send(Ok(job.outcome()));
        } else {
            return true;
        }
        lane_jobs.inc();
        counters.active_jobs.fetch_sub(1, Ordering::Relaxed);
        counters.jobs_done.fetch_add(1, Ordering::Relaxed);
        false
    });
}

fn fail_lane(err: &str, active: &mut Vec<ActiveJob>, counters: &ServeCounters) {
    for job in active.drain(..) {
        let se = ServeError::new(ErrorCode::LaneFailed, format!("lane failed: {err}"));
        let _ = job.done.send(Err(se));
        counters.active_jobs.fetch_sub(1, Ordering::Relaxed);
        counters.jobs_done.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine;
    use crate::runtime::{write_surrogate_artifact, ArtifactPool, Session};
    use crate::stats::Metrics;
    use std::sync::mpsc;

    fn pooled(name: &str, b: usize, t: usize) -> PooledArtifact {
        let dir = std::env::temp_dir().join(format!("tao-sched-{}", std::process::id()));
        let hlo = write_surrogate_artifact(&dir, name, b, t).unwrap();
        ArtifactPool::load(&[hlo]).unwrap().get(name).unwrap().clone()
    }

    fn spec(artifact: &str, bench: &str, insts: u64, seed: u64, chunk: usize) -> JobSpec {
        JobSpec {
            bench: bench.into(),
            insts,
            seed,
            artifact: artifact.into(),
            chunk,
            ctx_uarch: None,
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        }
    }

    /// The offline oracle: `simulate_chunked` over the same generator
    /// stream, artifact and chunk grid.
    fn offline(art: &PooledArtifact, s: &JobSpec) -> Metrics {
        let program = crate::workloads::by_name(&s.bench).unwrap().build(s.seed);
        let mut session = Session::load(&art.hlo_path).unwrap();
        let mut src = FunctionalSim::new(&program).into_chunks(s.insts);
        engine::simulate_chunked(&mut session, &mut src, s.chunk, None)
            .unwrap()
            .metrics
    }

    fn submit(
        queue: &JobQueue,
        s: &JobSpec,
    ) -> mpsc::Receiver<Result<JobOutcome, ServeError>> {
        let (tx, rx) = mpsc::channel();
        queue
            .submit(QueuedJob {
                spec: s.clone(),
                done: tx,
                admitted_at: Instant::now(),
                deadline: None,
                trace_id: String::new(),
            })
            .map_err(|_| "submit failed")
            .unwrap();
        rx
    }

    fn assert_metrics_identical(got: &Metrics, want: &Metrics, tag: &str) {
        assert_eq!(got.instructions, want.instructions, "{tag}: instructions");
        assert_eq!(got.cycles, want.cycles, "{tag}: cycles");
        assert_eq!(got.mispredicts, want.mispredicts, "{tag}: mispredicts");
        assert_eq!(got.l1d_misses, want.l1d_misses, "{tag}: l1d");
        assert_eq!(got.l1i_misses, want.l1i_misses, "{tag}: l1i");
        assert_eq!(got.tlb_misses, want.tlb_misses, "{tag}: tlb");
    }

    #[test]
    fn packed_lane_demuxes_to_offline_metrics_and_caches() {
        // Lane code traverses probe check sites; serialize with any
        // test that arms (probe state is process-global).
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_eq", 8, 6);
        let specs = vec![
            spec("sched_eq", "mcf", 701, 5, 97),
            spec("sched_eq", "dee", 400, 9, 64),
            spec("sched_eq", "xal", 333, 2, 50),
        ];
        let cache = Arc::new(Mutex::new(PredictionCache::new(256)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 8,
            pipeline: false,
            admission_wait: Duration::ZERO,
            prep_depth: 0,
        };
        let mut batches_after_cold = 0;
        for pass in 0..2 {
            let queue = Arc::new(JobQueue::new(16));
            let rxs: Vec<_> = specs.iter().map(|s| submit(&queue, s)).collect();
            queue.close();
            run_lane(art.clone(), queue, cache.clone(), counters.clone(), cfg).unwrap();
            for (s, rx) in specs.iter().zip(&rxs) {
                let got = rx.recv().unwrap().unwrap();
                let want = offline(&art, s);
                assert_metrics_identical(&got.metrics, &want, &format!("pass {pass} {}", s.bench));
                if pass == 0 {
                    assert_eq!(got.cache_hits, 0, "cold pass must miss");
                    assert!(got.cache_misses > 0);
                    assert_eq!(got.windows, s.insts, "every window packed once");
                } else {
                    assert_eq!(
                        got.cache_hits,
                        s.insts.div_ceil(s.chunk as u64),
                        "warm pass must hit every chunk"
                    );
                    assert_eq!(got.windows, 0, "warm pass skips model execution");
                }
            }
            if pass == 0 {
                batches_after_cold = counters.batches.load(Ordering::Relaxed);
                assert!(batches_after_cold > 0);
            } else {
                assert_eq!(
                    counters.batches.load(Ordering::Relaxed),
                    batches_after_cold,
                    "warm pass must execute zero batches"
                );
            }
        }
        // Three interleaved jobs share batches: far fewer slots wasted
        // than three solo runs (each would pad its own tail).
        let packed = counters.packed_windows.load(Ordering::Relaxed);
        let slots = counters.batch_slots.load(Ordering::Relaxed);
        assert_eq!(packed, 701 + 400 + 333);
        assert!(slots >= packed);
    }

    #[test]
    fn pipelined_lane_matches_offline_too() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_pipe", 16, 8);
        let specs = vec![
            spec("sched_pipe", "mcf", 900, 11, 128),
            spec("sched_pipe", "nab", 555, 3, 111),
        ];
        let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: true,
            admission_wait: Duration::ZERO,
            prep_depth: 2,
        };
        let queue = Arc::new(JobQueue::new(16));
        let rxs: Vec<_> = specs.iter().map(|s| submit(&queue, s)).collect();
        queue.close();
        run_lane(art.clone(), queue, cache, counters, cfg).unwrap();
        for (s, rx) in specs.iter().zip(&rxs) {
            let got = rx.recv().unwrap().unwrap();
            assert_metrics_identical(&got.metrics, &offline(&art, s), &s.bench);
            // Cache disabled: every chunk misses, nothing is stored.
            assert_eq!(got.cache_hits, 0);
        }
    }

    #[test]
    fn sampled_trace_jobs_weight_phases_and_reuse_the_cache() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_smp", 8, 6);
        let dir = std::env::temp_dir().join(format!("tao-sched-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("smp.trace");
        let cols = FunctionalSim::new(&crate::workloads::by_name("dee").unwrap().build(7))
            .run(4_000)
            .to_columns();
        crate::trace::TraceWriteOptions::new(crate::trace::TraceFormat::V2)
            .chunk_rows(500)
            .write(&trace, "dee", &cols)
            .unwrap();
        let exhaustive = dir.join("smp_exh.plan");
        crate::sampling::SamplingPlan::exhaustive("dee", 4_000, 500)
            .save(&exhaustive)
            .unwrap();
        let weighted_plan = crate::sampling::plan_trace(
            &trace,
            &crate::sampling::SamplingOptions { slice_rows: 500, max_phases: 3, seed: 5 },
        )
        .unwrap();
        let weighted = dir.join("smp_w.plan");
        weighted_plan.save(&weighted).unwrap();

        let mut tspec = spec("sched_smp", "", 0, 42, 500);
        tspec.trace = Some(trace.to_string_lossy().into_owned());
        let mut exh_spec = tspec.clone();
        exh_spec.plan = Some(exhaustive.to_string_lossy().into_owned());
        let mut w_spec = tspec.clone();
        w_spec.plan = Some(weighted.to_string_lossy().into_owned());

        let cache = Arc::new(Mutex::new(PredictionCache::new(256)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: false,
            admission_wait: Duration::ZERO,
            prep_depth: 0,
        };
        let run = |s: &JobSpec| {
            let queue = Arc::new(JobQueue::new(4));
            let rx = submit(&queue, s);
            queue.close();
            run_lane(art.clone(), queue, cache.clone(), counters.clone(), cfg).unwrap();
            rx.recv().unwrap().unwrap()
        };

        // Cold sampled pass with the exhaustive (weight-1, contiguous)
        // plan: every slice is simulated once.
        let exh = run(&exh_spec);
        assert_eq!(exh.metrics.instructions, 4_000);
        assert_eq!(exh.cache_misses, 8);
        assert_eq!(exh.windows, 4_000);

        // A plain full replay on the same chunk grid pulls the same
        // chunk sequence, so it rides the sampled job's cache entries
        // entirely — and the weight-1 plan was exact: identical metrics.
        let full = run(&tspec);
        assert_metrics_identical(&full.metrics, &exh.metrics, "exhaustive == full");
        assert_eq!(full.cache_hits, 8, "full replay reuses sampled slice deltas");
        assert_eq!(full.windows, 0);

        // Weighted plan: fewer slices simulated, every trace row still
        // accounted (the plan's ratio weights expand exactly), and the
        // replay is deterministic — a rerun hits every representative
        // slice in cache and reproduces the metrics bit-for-bit.
        let w1 = run(&w_spec);
        assert_eq!(w1.metrics.instructions, 4_000);
        assert!(weighted_plan.phases.len() <= 3);
        assert!(w1.windows <= weighted_plan.simulated_rows());
        let w2 = run(&w_spec);
        assert_metrics_identical(&w2.metrics, &w1.metrics, "sampled rerun");
        assert_eq!(w2.cache_hits, weighted_plan.phases.len() as u64);
        assert_eq!(w2.windows, 0);
    }

    #[test]
    fn simnet_lane_needs_and_uses_ctx() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let dir = std::env::temp_dir().join(format!("tao-sched-{}", std::process::id()));
        let hlo = crate::runtime::write_surrogate_artifact_kind(
            &dir,
            "sched_sn",
            ModelKind::SimNet,
            8,
            4,
        )
        .unwrap();
        let art = ArtifactPool::load(&[hlo]).unwrap().get("sched_sn").unwrap().clone();
        let mut s = spec("sched_sn", "dee", 300, 7, 77);
        s.ctx_uarch = Some("b".into());
        let cache = Arc::new(Mutex::new(PredictionCache::new(64)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: false,
            admission_wait: Duration::ZERO,
            prep_depth: 2,
        };
        let queue = Arc::new(JobQueue::new(4));
        let rx = submit(&queue, &s);
        queue.close();
        run_lane(art.clone(), queue, cache.clone(), counters.clone(), cfg).unwrap();
        let got = rx.recv().unwrap().unwrap();
        // Offline SimNet oracle: same trace + ctx through simulate_chunked.
        let program = crate::workloads::by_name("dee").unwrap().build(7);
        let cols = FunctionalSim::new(&program).run(300).to_columns();
        let cfg_u = resolve_ctx_uarch("b").unwrap();
        let ctx = crate::dataset::simnet_ctx_metrics(&program, &cfg_u, 300);
        let mut session = Session::load(&art.hlo_path).unwrap();
        let mut src = OwnedChunkSource::new(cols, Some(ctx)).unwrap();
        let want = engine::simulate_chunked(&mut session, &mut src, 77, None)
            .unwrap()
            .metrics;
        assert_metrics_identical(&got.metrics, &want, "simnet");

        // A job missing ctx_uarch fails at preparation (on the prep
        // thread) with an error response, not a hang.
        let queue = Arc::new(JobQueue::new(4));
        let bad = spec("sched_sn", "dee", 100, 1, 50);
        let rx = submit(&queue, &bad);
        queue.close();
        run_lane(art, queue, cache, counters, cfg).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest, "prep failure is terminal");
    }

    /// A job whose deadline lapsed in the queue is answered with a
    /// retryable `deadline_exceeded` without executing a single batch.
    #[test]
    fn expired_deadline_answers_without_execution() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_dl", 8, 4);
        let queue = Arc::new(JobQueue::new(4));
        let s = spec("sched_dl", "mcf", 200, 3, 64);
        let (tx, rx) = mpsc::channel();
        queue
            .submit(QueuedJob {
                spec: s,
                done: tx,
                admitted_at: Instant::now(),
                deadline: Some(Instant::now()),
                trace_id: String::new(),
            })
            .map_err(|_| "submit failed")
            .unwrap();
        queue.close();
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: false,
            admission_wait: Duration::ZERO,
            prep_depth: 0,
        };
        let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
        run_lane(art, queue, cache, counters.clone(), cfg).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded);
        assert!(err.code.retryable());
        assert_eq!(counters.batches.load(Ordering::Relaxed), 0, "no batch for a dead job");
        assert_eq!(counters.jobs_done.load(Ordering::Relaxed), 1);
    }

    /// An injected chunk-decode fault kills exactly the faulted job
    /// with a terminal `job_failed`; a healthy concurrent job still
    /// matches the offline oracle bit-for-bit.
    #[test]
    fn chunk_decode_fault_is_job_scoped() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_fault", 8, 4);
        let good = spec("sched_fault", "mcf", 300, 5, 64);
        let bad = spec("sched_fault", "dee", 300, 7, 64);
        let queue = Arc::new(JobQueue::new(8));
        let rx_good = submit(&queue, &good);
        let rx_bad = submit(&queue, &bad);
        queue.close();
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: false,
            admission_wait: Duration::ZERO,
            prep_depth: 0,
        };
        let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
        // Fire on the second chunk pull: job order in the active set is
        // submission order, so the *first* pull of the second job — but
        // round-robin interleaving makes "which job" timing-dependent;
        // all this test pins down is blast radius: exactly one job dies
        // typed, every other completes exactly.
        fault::arm_nth(Probe::ChunkDecode, 2);
        let res = run_lane(art.clone(), queue, cache, counters, cfg);
        fault::disarm_all();
        res.unwrap();
        let answers = [rx_good.recv().unwrap(), rx_bad.recv().unwrap()];
        let died: Vec<_> = answers.iter().filter(|a| a.is_err()).collect();
        assert_eq!(died.len(), 1, "exactly one job absorbs the fault");
        let err = died[0].as_ref().unwrap_err();
        assert_eq!(err.code, ErrorCode::JobFailed);
        assert!(err.message.contains("chunk decode"), "typed cause: {}", err.message);
        for (s, a) in [&good, &bad].into_iter().zip(&answers) {
            if let Ok(out) = a {
                assert_metrics_identical(&out.metrics, &offline(&art, s), &s.bench);
            }
        }
    }

    /// The bounded prep stage must change *when* jobs materialize, not
    /// what they compute: off-thread-prepped lanes answer with metrics
    /// identical to inline-prepped ones, and every job is answered.
    #[test]
    fn prep_stage_admissions_match_inline_prep() {
        let _gate = fault::exclusive();
        fault::disarm_all();
        let art = pooled("sched_prep", 8, 4);
        let specs = vec![
            spec("sched_prep", "mcf", 450, 13, 64),
            spec("sched_prep", "dee", 300, 4, 50),
            spec("sched_prep", "xal", 275, 8, 44),
            spec("sched_prep", "nab", 333, 2, 77),
        ];
        let mut answers: Vec<Vec<Metrics>> = Vec::new();
        for prep_depth in [0usize, 1, 2] {
            let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
            let counters = Arc::new(ServeCounters::default());
            let cfg = LaneConfig {
                max_active: 3, // < job count: admissions interleave packing
                pipeline: prep_depth != 0,
                admission_wait: Duration::ZERO,
                prep_depth,
            };
            let queue = Arc::new(JobQueue::new(8));
            let rxs: Vec<_> = specs.iter().map(|s| submit(&queue, s)).collect();
            queue.close();
            run_lane(art.clone(), queue, cache, counters.clone(), cfg).unwrap();
            let got: Vec<Metrics> =
                rxs.iter().map(|rx| rx.recv().unwrap().unwrap().metrics).collect();
            assert_eq!(
                counters.jobs_done.load(Ordering::Relaxed),
                specs.len() as u64,
                "prep_depth={prep_depth}: every job answered"
            );
            assert_eq!(counters.active_jobs.load(Ordering::Relaxed), 0);
            answers.push(got);
        }
        for (s, rx0) in specs.iter().zip(&answers[0]) {
            assert_metrics_identical(rx0, &offline(&art, s), &format!("inline {}", s.bench));
        }
        for depth_answers in &answers[1..] {
            for ((s, a), b) in specs.iter().zip(&answers[0]).zip(depth_answers) {
                assert_metrics_identical(b, a, &format!("prep vs inline {}", s.bench));
            }
        }
    }
}
