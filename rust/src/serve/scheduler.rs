//! Lane scheduler: cross-job batch packing with a pipelined executor.
//!
//! Every artifact the daemon loaded gets a **lane** — a worker thread
//! owning a compiled session for that artifact. A lane admits up to
//! `max_active` concurrent jobs and packs context windows **from all
//! of them** into the artifact's fixed-`B` model batch: the batch is a
//! shared bus, not a per-request allocation. SimNet showed fixed-batch
//! DL inference collapses when batches run underfilled; per-request
//! execution pays that tail padding on *every* request, while packing
//! amortizes it across traffic — the only underfilled batch is the
//! final drain flush when a lane runs out of work entirely.
//!
//! Demux rides the engine's order-independent accumulators: each
//! output row routes back to its job's
//! [`PredAccum`](crate::coordinator::engine::PredAccum) via
//! `absorb_one`, in stream order per job (batches execute FIFO, slots
//! absorb in order), so a job's folded metrics are bit-identical to an
//! offline [`simulate_chunked`](crate::coordinator::engine::simulate_chunked)
//! run of the same (trace, artifact, chunking) — the loopback tests
//! assert exactly that.
//!
//! The executor is **double-buffered** (the open ROADMAP pipelining
//! item): two staging buffer sets rotate through a `sync_channel(1)`
//! to a dedicated executor thread, so feature extraction and window
//! packing of batch `k+1` overlap model execution of batch `k`.
//!
//! Chunk-level caching happens at the pack boundary: each job pulls
//! its trace in `chunk`-row units, keys them by (artifact fingerprint,
//! warm-up prefix hash, content hash), and on a hit skips straight
//! past the chunk — merging the memoized accumulator and fast-
//! forwarding extractor state exactly (see [`super::cache`]).

use super::cache::{chain_prefix, hash_chunk, ChunkKey, PredictionCache, PREFIX_SEED};
use super::protocol::{resolve_ctx_uarch, JobOutcome, JobSpec, StatsSnapshot};
use super::queue::{JobQueue, QueuedJob};
use crate::coordinator::engine::{PredAccum, WindowStager};
use crate::functional::FunctionalSim;
use crate::runtime::{ModelKind, ModelOutputs, PooledArtifact};
use crate::trace::{ChunkBuf, ChunkSource, OwnedChunkSource, CTX_WIDTH};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Lane tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct LaneConfig {
    /// Concurrent jobs a lane packs from.
    pub max_active: usize,
    /// Double-buffered executor thread (false = execute inline, mainly
    /// for deterministic unit tests).
    pub pipeline: bool,
    /// Batch-formation window: when an idle lane admits its first job,
    /// wait this long for more jobs so the first batches already pack
    /// cross-job (the classic dynamic-batching admission delay).
    pub admission_wait: Duration,
}

impl Default for LaneConfig {
    fn default() -> LaneConfig {
        LaneConfig {
            max_active: 16,
            pipeline: true,
            admission_wait: Duration::from_millis(2),
        }
    }
}

/// Daemon-wide serving counters (lanes update, `/v1/stats` snapshots).
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Jobs accepted into the queue.
    pub jobs_submitted: AtomicU64,
    /// Jobs answered (success or error).
    pub jobs_done: AtomicU64,
    /// Jobs refused by admission control.
    pub jobs_rejected: AtomicU64,
    /// Jobs currently active inside lanes.
    pub active_jobs: AtomicU64,
    /// Model batches executed.
    pub batches: AtomicU64,
    /// Windows packed into executed batches.
    pub packed_windows: AtomicU64,
    /// Slots available in executed batches (Σ lane `B`).
    pub batch_slots: AtomicU64,
}

impl ServeCounters {
    /// Assemble the `/v1/stats` snapshot.
    pub fn snapshot(
        &self,
        queue: &JobQueue,
        cache: &Mutex<PredictionCache>,
    ) -> StatsSnapshot {
        let cs = cache.lock().expect("cache poisoned").stats();
        StatsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            queue_depth: queue.depth() as u64,
            active_jobs: self.active_jobs.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            packed_windows: self.packed_windows.load(Ordering::Relaxed),
            batch_slots: self.batch_slots.load(Ordering::Relaxed),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_evictions: cs.evictions,
            cache_entries: cs.entries,
        }
    }
}

static NEXT_JOB_ID: AtomicU64 = AtomicU64::new(1);

// ---------------------------------------------------------------------
// Per-job stream state
// ---------------------------------------------------------------------

/// A stream-ordered accounting segment: one pulled chunk, either
/// skipped via the cache or awaiting its windows' model outputs.
enum Segment {
    /// Cached chunk: merge `accum` once absorption reaches `start`.
    Hit { start: u64, accum: PredAccum },
    /// Computed chunk: rows fold into `accum` alongside the job
    /// accumulator; when absorption reaches `end` the delta is
    /// published to the cache under `key`.
    Miss { key: ChunkKey, end: u64, accum: PredAccum },
}

struct ActiveJob {
    id: u64,
    spec: JobSpec,
    kind: ModelKind,
    source: Box<dyn ChunkSource + Send>,
    stager: WindowStager,
    accum: PredAccum,
    buf: ChunkBuf,
    pos: usize,
    buf_len: usize,
    prefix: u64,
    emitted: u64,
    absorbed: u64,
    segments: VecDeque<Segment>,
    stream_done: bool,
    hits: u64,
    misses: u64,
    windows: u64,
    dead: Option<String>,
    done: std::sync::mpsc::Sender<Result<JobOutcome, String>>,
    admitted_at: Instant,
}

impl ActiveJob {
    fn prepare(
        spec: JobSpec,
        done: std::sync::mpsc::Sender<Result<JobOutcome, String>>,
        admitted_at: Instant,
        art: &PooledArtifact,
    ) -> Result<ActiveJob> {
        let workload = crate::workloads::by_name(&spec.bench)
            .with_context(|| format!("unknown benchmark {:?}", spec.bench))?;
        let program = workload.build(spec.seed);
        let kind = art.meta.kind;
        let source: Box<dyn ChunkSource + Send> = match kind {
            // Tao consumes the µarch-agnostic functional stream; jobs
            // pull it straight off the generator, never resident.
            ModelKind::Tao => Box::new(FunctionalSim::new(&program).into_chunks(spec.insts)),
            // SimNet needs the detailed trace of its target design as
            // a per-instruction context input — materialized up front
            // (that cost is the paper's argument against SimNet).
            ModelKind::SimNet => {
                let sel = spec
                    .ctx_uarch
                    .as_deref()
                    .context("SimNet artifacts require ctx_uarch")?;
                let cfg = resolve_ctx_uarch(sel)?;
                let cols = FunctionalSim::new(&program).run(spec.insts).to_columns();
                let ctx = crate::dataset::simnet_ctx_metrics(&program, &cfg, spec.insts);
                Box::new(OwnedChunkSource::new(cols, Some(ctx))?)
            }
        };
        Ok(ActiveJob {
            id: NEXT_JOB_ID.fetch_add(1, Ordering::Relaxed),
            kind,
            source,
            stager: WindowStager::new(&art.meta),
            accum: PredAccum::default(),
            buf: ChunkBuf::new(),
            pos: 0,
            buf_len: 0,
            prefix: PREFIX_SEED,
            emitted: 0,
            absorbed: 0,
            segments: VecDeque::new(),
            stream_done: false,
            hits: 0,
            misses: 0,
            windows: 0,
            dead: None,
            done,
            admitted_at,
            spec,
        })
    }

    /// Emit the next window into the caller's batch slot, pulling (and
    /// cache-probing) chunks as needed. `Ok(false)` means the stream is
    /// exhausted.
    fn next_window(
        &mut self,
        cache: &Mutex<PredictionCache>,
        artifact_fp: u64,
        ops_slot: &mut [i32],
        feat_slot: &mut [f32],
        ctx_slot: Option<&mut [f32]>,
    ) -> Result<bool> {
        loop {
            if self.pos < self.buf_len {
                let i = self.pos;
                let rec = self.buf.cols.record(i);
                let ctx_row = (self.kind == ModelKind::SimNet)
                    .then(|| &self.buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
                self.stager.stage_window(&rec, ctx_row, ops_slot, feat_slot, ctx_slot);
                self.pos += 1;
                self.emitted += 1;
                self.windows += 1;
                return Ok(true);
            }
            if self.stream_done {
                return Ok(false);
            }
            let n = self.source.next_chunk(&mut self.buf, self.spec.chunk)?;
            if n == 0 {
                self.stream_done = true;
                return Ok(false);
            }
            if self.kind == ModelKind::SimNet {
                anyhow::ensure!(
                    self.buf.ctx.len() == n * CTX_WIDTH,
                    "SimNet source must carry [n×6] ctx metrics"
                );
            }
            self.buf_len = n;
            self.pos = 0;
            let content = hash_chunk(&self.buf);
            let key = ChunkKey { artifact: artifact_fp, prefix: self.prefix, content };
            self.prefix = chain_prefix(self.prefix, content);
            let hit = cache.lock().expect("cache poisoned").get(&key);
            match hit {
                Some(delta) if delta.instructions == n as u64 => {
                    // Cache hit: skip the whole chunk. Fast-forward the
                    // extractor exactly (state-only advance; the last
                    // T-1 rows roll through the window history so a
                    // later miss stages bit-identical windows) and
                    // queue the memoized accumulator for in-order
                    // merging.
                    let hist = self.stager.history_rows();
                    for i in 0..n {
                        let rec = self.buf.cols.record(i);
                        if i + hist < n {
                            self.stager.advance_only(&rec);
                        } else {
                            let ctx_row = (self.kind == ModelKind::SimNet)
                                .then(|| &self.buf.ctx[i * CTX_WIDTH..(i + 1) * CTX_WIDTH]);
                            self.stager.roll_only(&rec, ctx_row);
                        }
                    }
                    self.segments
                        .push_back(Segment::Hit { start: self.emitted, accum: delta });
                    self.hits += 1;
                    self.emitted += n as u64;
                    self.pos = n;
                    self.pump(cache);
                }
                _ => {
                    self.misses += 1;
                    self.segments.push_back(Segment::Miss {
                        key,
                        end: self.emitted + n as u64,
                        accum: PredAccum::at_base(self.emitted),
                    });
                }
            }
        }
    }

    /// Fold one routed output row (stream order per job is guaranteed
    /// by FIFO batches + in-order slots).
    fn absorb_row(
        &mut self,
        out: &ModelOutputs,
        row: usize,
        cache: &Mutex<PredictionCache>,
    ) {
        self.accum.absorb_one(out, self.kind, row);
        match self.segments.front_mut() {
            Some(Segment::Miss { accum, .. }) => accum.absorb_one(out, self.kind, row),
            _ => debug_assert!(false, "output row with no open miss segment"),
        }
        self.absorbed += 1;
        self.pump(cache);
    }

    /// Settle stream-ordered segments: merge hit accumulators the
    /// moment absorption reaches them; publish completed miss deltas
    /// to the cache.
    fn pump(&mut self, cache: &Mutex<PredictionCache>) {
        loop {
            match self.segments.front() {
                Some(Segment::Hit { start, .. }) if *start == self.absorbed => {
                    let Some(Segment::Hit { accum, .. }) = self.segments.pop_front() else {
                        unreachable!()
                    };
                    self.absorbed += accum.instructions;
                    self.accum.merge(&accum);
                }
                Some(Segment::Miss { end, .. }) if *end == self.absorbed => {
                    let Some(Segment::Miss { key, accum, .. }) = self.segments.pop_front()
                    else {
                        unreachable!()
                    };
                    cache.lock().expect("cache poisoned").insert(key, accum);
                }
                _ => break,
            }
        }
    }

    fn is_complete(&self) -> bool {
        self.stream_done && self.segments.is_empty() && self.absorbed == self.emitted
    }

    fn outcome(&self) -> JobOutcome {
        JobOutcome {
            job_id: self.id,
            metrics: self.accum.metrics(),
            windows: self.windows,
            cache_hits: self.hits,
            cache_misses: self.misses,
            elapsed_ms: self.admitted_at.elapsed().as_secs_f64() * 1e3,
        }
    }
}

// ---------------------------------------------------------------------
// Batch buffers + executor
// ---------------------------------------------------------------------

struct BatchBuffers {
    ops: Vec<i32>,
    feats: Vec<f32>,
    ctx: Vec<f32>,
}

impl BatchBuffers {
    fn new(b: usize, t: usize, f: usize, kind: ModelKind) -> BatchBuffers {
        BatchBuffers {
            ops: vec![0; b * t],
            feats: vec![0.0; b * t * f],
            ctx: match kind {
                ModelKind::SimNet => vec![0.0; b * t * CTX_WIDTH],
                ModelKind::Tao => Vec::new(),
            },
        }
    }
}

struct StagedBatch {
    bufs: BatchBuffers,
    valid: usize,
    routes: Vec<u64>,
}

struct ExecDone {
    out: ModelOutputs,
    routes: Vec<u64>,
    bufs: BatchBuffers,
}

/// A failed batch: what went wrong plus the jobs whose windows rode in
/// it (so only those jobs die — an executor hiccup on job A's batch
/// must not 500 job B).
struct BatchError {
    msg: String,
    routes: Vec<u64>,
}

/// What comes back from the executor: a finished batch or its failure.
type ExecMsg = Result<ExecDone, BatchError>;

enum Executor {
    Inline(crate::runtime::Session),
    Pipelined {
        to_exec: SyncSender<StagedBatch>,
        from_exec: Receiver<ExecMsg>,
        handle: std::thread::JoinHandle<()>,
    },
}

fn spawn_executor(art: &PooledArtifact, kind: ModelKind) -> Executor {
    // sync_channel(1): the stager may queue one staged batch while the
    // executor runs another — double buffering, bounded by the two
    // rotating buffer sets.
    let (to_exec, rx_batch) = sync_channel::<StagedBatch>(1);
    let (tx_done, from_exec) = sync_channel::<ExecMsg>(2);
    let art = art.clone();
    let handle = std::thread::spawn(move || {
        let session = match art.open_session() {
            Ok(s) => s,
            Err(e) => {
                let _ = tx_done.send(Err(BatchError {
                    msg: format!("open session: {e:#}"),
                    routes: Vec::new(),
                }));
                return;
            }
        };
        for batch in rx_batch {
            let ctx = match kind {
                ModelKind::SimNet => Some(&batch.bufs.ctx[..]),
                ModelKind::Tao => None,
            };
            let msg = match session.run_on(&batch.bufs.ops, &batch.bufs.feats, ctx, batch.valid)
            {
                Ok(out) => Ok(ExecDone { out, routes: batch.routes, bufs: batch.bufs }),
                Err(e) => Err(BatchError {
                    msg: format!("model execution: {e:#}"),
                    routes: batch.routes,
                }),
            };
            if tx_done.send(msg).is_err() {
                return;
            }
        }
    });
    Executor::Pipelined { to_exec, from_exec, handle }
}

// ---------------------------------------------------------------------
// The lane
// ---------------------------------------------------------------------

/// Run one artifact lane until the queue is closed and drained. Pops
/// jobs targeting `art` from the shared queue, packs windows across
/// every active job into the artifact's `[B, T, F]` batch, executes
/// (pipelined by default), demuxes outputs to per-job accumulators,
/// and answers each job's completion channel.
pub fn run_lane(
    art: PooledArtifact,
    queue: Arc<JobQueue>,
    cache: Arc<Mutex<PredictionCache>>,
    counters: Arc<ServeCounters>,
    cfg: LaneConfig,
) -> Result<()> {
    let (b, t, f) = (art.meta.batch, art.meta.context, art.meta.feature_dim);
    let kind = art.meta.kind;
    let fp = art.fingerprint;
    let mut exec = if cfg.pipeline {
        spawn_executor(&art, kind)
    } else {
        Executor::Inline(art.open_session()?)
    };
    let n_bufs = if cfg.pipeline { 2 } else { 1 };
    let mut free: Vec<BatchBuffers> =
        (0..n_bufs).map(|_| BatchBuffers::new(b, t, f, kind)).collect();
    let mut active: Vec<ActiveJob> = Vec::new();
    let mut in_flight = 0usize;
    let mut rr = 0usize;

    loop {
        // Absorb every result that is already done (non-blocking).
        loop {
            match try_recv_done(&mut exec) {
                Ok(Some(msg)) => {
                    // Saturating: an executor-startup error arrives
                    // without a corresponding in-flight batch.
                    in_flight = in_flight.saturating_sub(1);
                    handle_exec_msg(msg, &mut active, &mut free, &cache, b, t, f, kind);
                }
                Ok(None) => break,
                Err(e) => {
                    fail_lane(&e, &mut active, &counters);
                    return lane_zombie(&art, &queue, &counters, e);
                }
            }
        }
        finalize(&mut active, &counters);

        // Admission: fill spare capacity; when waking from idle, hold
        // the batch-formation window so the first batches pack.
        let was_idle = active.is_empty() && in_flight == 0;
        while active.len() < cfg.max_active {
            let timeout = if active.is_empty() && in_flight == 0 {
                Duration::from_millis(50)
            } else {
                Duration::ZERO
            };
            match queue.pop_for(&art.name, timeout) {
                Some(qj) => admit(qj, &art, &mut active, &counters),
                None => break,
            }
        }
        if was_idle && !active.is_empty() && !cfg.admission_wait.is_zero() {
            let deadline = Instant::now() + cfg.admission_wait;
            while active.len() < cfg.max_active {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match queue.pop_for(&art.name, deadline - now) {
                    Some(qj) => admit(qj, &art, &mut active, &counters),
                    None => break,
                }
            }
        }
        finalize(&mut active, &counters);

        if active.is_empty() && in_flight == 0 {
            if queue.is_drained() {
                break;
            }
            continue;
        }

        // Stage and dispatch one packed batch (or wait for capacity).
        if let Some(mut bufs) = free.pop() {
            let (valid, routes) = pack(&mut active, &mut rr, &mut bufs, &cache, fp, b, t, f);
            if valid > 0 {
                counters.batches.fetch_add(1, Ordering::Relaxed);
                counters.packed_windows.fetch_add(valid as u64, Ordering::Relaxed);
                counters.batch_slots.fetch_add(b as u64, Ordering::Relaxed);
                match &mut exec {
                    Executor::Inline(session) => {
                        let ctx = match kind {
                            ModelKind::SimNet => Some(&bufs.ctx[..]),
                            ModelKind::Tao => None,
                        };
                        match session.run_on(&bufs.ops, &bufs.feats, ctx, valid) {
                            Ok(out) => {
                                demux(&out, &routes, &mut active, &cache);
                                free.push(bufs);
                            }
                            Err(e) => {
                                // Scope the failure to the jobs in
                                // this batch, as the pipelined path
                                // does.
                                let msg = format!("model execution: {e:#}");
                                for job in active.iter_mut() {
                                    if routes.contains(&job.id) {
                                        job.dead = Some(format!("batch failed: {msg}"));
                                    }
                                }
                                free.push(bufs);
                            }
                        }
                    }
                    Executor::Pipelined { to_exec, .. } => {
                        if to_exec.send(StagedBatch { bufs, valid, routes }).is_err() {
                            let e = "executor thread exited".to_string();
                            fail_lane(&e, &mut active, &counters);
                            return lane_zombie(&art, &queue, &counters, e);
                        }
                        in_flight += 1;
                    }
                }
            } else {
                // No job can emit: everything active is stream-done and
                // waiting on in-flight outputs (or already complete).
                free.push(bufs);
                if in_flight > 0 {
                    match recv_done_blocking(&mut exec) {
                        Ok(msg) => {
                            in_flight = in_flight.saturating_sub(1);
                            handle_exec_msg(msg, &mut active, &mut free, &cache, b, t, f, kind);
                        }
                        Err(e) => {
                            fail_lane(&e, &mut active, &counters);
                            return lane_zombie(&art, &queue, &counters, e);
                        }
                    }
                }
            }
        } else {
            // Both buffers in flight: block for one to come home.
            match recv_done_blocking(&mut exec) {
                Ok(msg) => {
                    in_flight = in_flight.saturating_sub(1);
                    handle_exec_msg(msg, &mut active, &mut free, &cache, b, t, f, kind);
                }
                Err(e) => {
                    fail_lane(&e, &mut active, &counters);
                    return lane_zombie(&art, &queue, &counters, e);
                }
            }
        }
        finalize(&mut active, &counters);
    }

    if let Executor::Pipelined { to_exec, from_exec, handle } = exec {
        drop(to_exec);
        drop(from_exec);
        let _ = handle.join();
    }
    Ok(())
}

fn admit(
    qj: QueuedJob,
    art: &PooledArtifact,
    active: &mut Vec<ActiveJob>,
    counters: &ServeCounters,
) {
    let QueuedJob { spec, done, admitted_at } = qj;
    match ActiveJob::prepare(spec, done.clone(), admitted_at, art) {
        Ok(job) => {
            counters.active_jobs.fetch_add(1, Ordering::Relaxed);
            active.push(job);
        }
        Err(e) => {
            let _ = done.send(Err(format!("job preparation failed: {e:#}")));
            counters.jobs_done.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn pack(
    active: &mut [ActiveJob],
    rr: &mut usize,
    bufs: &mut BatchBuffers,
    cache: &Mutex<PredictionCache>,
    fp: u64,
    b: usize,
    t: usize,
    f: usize,
) -> (usize, Vec<u64>) {
    let mut routes = Vec::with_capacity(b);
    let mut slot = 0usize;
    let n = active.len();
    while slot < b && n > 0 {
        let mut progressed = false;
        for k in 0..n {
            if slot == b {
                break;
            }
            let j = (*rr + k) % n;
            let job = &mut active[j];
            if job.dead.is_some() {
                continue;
            }
            let ops_slot = &mut bufs.ops[slot * t..(slot + 1) * t];
            let feat_slot = &mut bufs.feats[slot * t * f..(slot + 1) * t * f];
            let ctx_slot = match job.kind {
                ModelKind::SimNet => {
                    Some(&mut bufs.ctx[slot * t * CTX_WIDTH..(slot + 1) * t * CTX_WIDTH])
                }
                ModelKind::Tao => None,
            };
            match job.next_window(cache, fp, ops_slot, feat_slot, ctx_slot) {
                Ok(true) => {
                    routes.push(job.id);
                    slot += 1;
                    progressed = true;
                }
                Ok(false) => {}
                Err(e) => job.dead = Some(format!("{e:#}")),
            }
        }
        *rr = (*rr + 1) % n;
        if !progressed {
            break;
        }
    }
    (slot, routes)
}

fn demux(
    out: &ModelOutputs,
    routes: &[u64],
    active: &mut [ActiveJob],
    cache: &Mutex<PredictionCache>,
) {
    for (row, id) in routes.iter().enumerate() {
        if let Some(job) = active.iter_mut().find(|j| j.id == *id && j.dead.is_none()) {
            job.absorb_row(out, row, cache);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_exec_msg(
    msg: ExecMsg,
    active: &mut Vec<ActiveJob>,
    free: &mut Vec<BatchBuffers>,
    cache: &Mutex<PredictionCache>,
    b: usize,
    t: usize,
    f: usize,
    kind: ModelKind,
) {
    match msg {
        Ok(done) => {
            demux(&done.out, &done.routes, active, cache);
            free.push(done.bufs);
        }
        Err(e) => {
            // Only the jobs whose windows rode in the failed batch
            // die; the rest keep streaming. The staged buffers died
            // with the batch, so mint a fresh set to keep the
            // free/in-flight invariant.
            for job in active.iter_mut() {
                if e.routes.contains(&job.id) {
                    job.dead = Some(format!("batch failed: {}", e.msg));
                }
            }
            free.push(BatchBuffers::new(b, t, f, kind));
        }
    }
}

fn finalize(active: &mut Vec<ActiveJob>, counters: &ServeCounters) {
    active.retain(|job| {
        if let Some(err) = &job.dead {
            let _ = job.done.send(Err(err.clone()));
        } else if job.is_complete() {
            let _ = job.done.send(Ok(job.outcome()));
        } else {
            return true;
        }
        counters.active_jobs.fetch_sub(1, Ordering::Relaxed);
        counters.jobs_done.fetch_add(1, Ordering::Relaxed);
        false
    });
}

fn fail_lane(err: &str, active: &mut Vec<ActiveJob>, counters: &ServeCounters) {
    for job in active.drain(..) {
        let _ = job.done.send(Err(format!("lane failed: {err}")));
        counters.active_jobs.fetch_sub(1, Ordering::Relaxed);
        counters.jobs_done.fetch_add(1, Ordering::Relaxed);
    }
}

/// Terminal state for a lane whose executor died: keep answering this
/// artifact's jobs with retryable-looking errors until drain, so
/// waiting connections never hang.
fn lane_zombie(
    art: &PooledArtifact,
    queue: &JobQueue,
    counters: &ServeCounters,
    err: String,
) -> Result<()> {
    eprintln!("serve: lane {:?} failed: {err}", art.name);
    loop {
        match queue.pop_for(&art.name, Duration::from_millis(200)) {
            Some(qj) => {
                let _ = qj.done.send(Err(format!("lane {:?} failed: {err}", art.name)));
                counters.jobs_done.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                if queue.is_drained() {
                    anyhow::bail!("lane {:?} failed: {err}", art.name);
                }
            }
        }
    }
}

fn try_recv_done(exec: &mut Executor) -> Result<Option<ExecMsg>, String> {
    match exec {
        Executor::Inline(_) => Ok(None),
        Executor::Pipelined { from_exec, .. } => match from_exec.try_recv() {
            Ok(msg) => Ok(Some(msg)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err("executor thread exited".into()),
        },
    }
}

fn recv_done_blocking(exec: &mut Executor) -> Result<ExecMsg, String> {
    match exec {
        Executor::Inline(_) => Err("inline executor has no in-flight batches".into()),
        Executor::Pipelined { from_exec, .. } => {
            from_exec.recv().map_err(|_| "executor thread exited".to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine;
    use crate::runtime::{write_surrogate_artifact, ArtifactPool, Session};
    use crate::stats::Metrics;
    use std::sync::mpsc;

    fn pooled(name: &str, b: usize, t: usize) -> PooledArtifact {
        let dir = std::env::temp_dir().join(format!("tao-sched-{}", std::process::id()));
        let hlo = write_surrogate_artifact(&dir, name, b, t).unwrap();
        ArtifactPool::load(&[hlo]).unwrap().get(name).unwrap().clone()
    }

    fn spec(artifact: &str, bench: &str, insts: u64, seed: u64, chunk: usize) -> JobSpec {
        JobSpec {
            bench: bench.into(),
            insts,
            seed,
            artifact: artifact.into(),
            chunk,
            ctx_uarch: None,
        }
    }

    /// The offline oracle: `simulate_chunked` over the same generator
    /// stream, artifact and chunk grid.
    fn offline(art: &PooledArtifact, s: &JobSpec) -> Metrics {
        let program = crate::workloads::by_name(&s.bench).unwrap().build(s.seed);
        let mut session = Session::load(&art.hlo_path).unwrap();
        let mut src = FunctionalSim::new(&program).into_chunks(s.insts);
        engine::simulate_chunked(&mut session, &mut src, s.chunk, None)
            .unwrap()
            .metrics
    }

    fn submit(
        queue: &JobQueue,
        s: &JobSpec,
    ) -> mpsc::Receiver<Result<JobOutcome, String>> {
        let (tx, rx) = mpsc::channel();
        queue
            .submit(QueuedJob { spec: s.clone(), done: tx, admitted_at: Instant::now() })
            .map_err(|_| "submit failed")
            .unwrap();
        rx
    }

    fn assert_metrics_identical(got: &Metrics, want: &Metrics, tag: &str) {
        assert_eq!(got.instructions, want.instructions, "{tag}: instructions");
        assert_eq!(got.cycles, want.cycles, "{tag}: cycles");
        assert_eq!(got.mispredicts, want.mispredicts, "{tag}: mispredicts");
        assert_eq!(got.l1d_misses, want.l1d_misses, "{tag}: l1d");
        assert_eq!(got.l1i_misses, want.l1i_misses, "{tag}: l1i");
        assert_eq!(got.tlb_misses, want.tlb_misses, "{tag}: tlb");
    }

    #[test]
    fn packed_lane_demuxes_to_offline_metrics_and_caches() {
        let art = pooled("sched_eq", 8, 6);
        let specs = vec![
            spec("sched_eq", "mcf", 701, 5, 97),
            spec("sched_eq", "dee", 400, 9, 64),
            spec("sched_eq", "xal", 333, 2, 50),
        ];
        let cache = Arc::new(Mutex::new(PredictionCache::new(256)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 8,
            pipeline: false,
            admission_wait: Duration::ZERO,
        };
        let mut batches_after_cold = 0;
        for pass in 0..2 {
            let queue = Arc::new(JobQueue::new(16));
            let rxs: Vec<_> = specs.iter().map(|s| submit(&queue, s)).collect();
            queue.close();
            run_lane(art.clone(), queue, cache.clone(), counters.clone(), cfg).unwrap();
            for (s, rx) in specs.iter().zip(&rxs) {
                let got = rx.recv().unwrap().unwrap();
                let want = offline(&art, s);
                assert_metrics_identical(&got.metrics, &want, &format!("pass {pass} {}", s.bench));
                if pass == 0 {
                    assert_eq!(got.cache_hits, 0, "cold pass must miss");
                    assert!(got.cache_misses > 0);
                    assert_eq!(got.windows, s.insts, "every window packed once");
                } else {
                    assert_eq!(
                        got.cache_hits,
                        s.insts.div_ceil(s.chunk as u64),
                        "warm pass must hit every chunk"
                    );
                    assert_eq!(got.windows, 0, "warm pass skips model execution");
                }
            }
            if pass == 0 {
                batches_after_cold = counters.batches.load(Ordering::Relaxed);
                assert!(batches_after_cold > 0);
            } else {
                assert_eq!(
                    counters.batches.load(Ordering::Relaxed),
                    batches_after_cold,
                    "warm pass must execute zero batches"
                );
            }
        }
        // Three interleaved jobs share batches: far fewer slots wasted
        // than three solo runs (each would pad its own tail).
        let packed = counters.packed_windows.load(Ordering::Relaxed);
        let slots = counters.batch_slots.load(Ordering::Relaxed);
        assert_eq!(packed, 701 + 400 + 333);
        assert!(slots >= packed);
    }

    #[test]
    fn pipelined_lane_matches_offline_too() {
        let art = pooled("sched_pipe", 16, 8);
        let specs = vec![
            spec("sched_pipe", "mcf", 900, 11, 128),
            spec("sched_pipe", "nab", 555, 3, 111),
        ];
        let cache = Arc::new(Mutex::new(PredictionCache::new(0)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: true,
            admission_wait: Duration::ZERO,
        };
        let queue = Arc::new(JobQueue::new(16));
        let rxs: Vec<_> = specs.iter().map(|s| submit(&queue, s)).collect();
        queue.close();
        run_lane(art.clone(), queue, cache, counters, cfg).unwrap();
        for (s, rx) in specs.iter().zip(&rxs) {
            let got = rx.recv().unwrap().unwrap();
            assert_metrics_identical(&got.metrics, &offline(&art, s), &s.bench);
            // Cache disabled: every chunk misses, nothing is stored.
            assert_eq!(got.cache_hits, 0);
        }
    }

    #[test]
    fn simnet_lane_needs_and_uses_ctx() {
        let dir = std::env::temp_dir().join(format!("tao-sched-{}", std::process::id()));
        let hlo = crate::runtime::write_surrogate_artifact_kind(
            &dir,
            "sched_sn",
            ModelKind::SimNet,
            8,
            4,
        )
        .unwrap();
        let art = ArtifactPool::load(&[hlo]).unwrap().get("sched_sn").unwrap().clone();
        let mut s = spec("sched_sn", "dee", 300, 7, 77);
        s.ctx_uarch = Some("b".into());
        let cache = Arc::new(Mutex::new(PredictionCache::new(64)));
        let counters = Arc::new(ServeCounters::default());
        let cfg = LaneConfig {
            max_active: 4,
            pipeline: false,
            admission_wait: Duration::ZERO,
        };
        let queue = Arc::new(JobQueue::new(4));
        let rx = submit(&queue, &s);
        queue.close();
        run_lane(art.clone(), queue, cache.clone(), counters.clone(), cfg).unwrap();
        let got = rx.recv().unwrap().unwrap();
        // Offline SimNet oracle: same trace + ctx through simulate_chunked.
        let program = crate::workloads::by_name("dee").unwrap().build(7);
        let cols = FunctionalSim::new(&program).run(300).to_columns();
        let cfg_u = resolve_ctx_uarch("b").unwrap();
        let ctx = crate::dataset::simnet_ctx_metrics(&program, &cfg_u, 300);
        let mut session = Session::load(&art.hlo_path).unwrap();
        let mut src = OwnedChunkSource::new(cols, Some(ctx)).unwrap();
        let want = engine::simulate_chunked(&mut session, &mut src, 77, None)
            .unwrap()
            .metrics;
        assert_metrics_identical(&got.metrics, &want, "simnet");

        // A job missing ctx_uarch fails at preparation with an error
        // response, not a hang.
        let queue = Arc::new(JobQueue::new(4));
        let bad = spec("sched_sn", "dee", 100, 1, 50);
        let rx = submit(&queue, &bad);
        queue.close();
        run_lane(art, queue, cache, counters, cfg).unwrap();
        assert!(rx.recv().unwrap().is_err());
    }
}
