//! Artifact metadata + PJRT session.

use crate::features::FeatureConfig;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Which model family an artifact holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Tao multi-metric model: inputs (opcodes, features); 6 outputs.
    Tao,
    /// SimNet baseline: inputs (opcodes, features, ctx_metrics); 2 outputs.
    SimNet,
}

/// Parsed `<artifact>.meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Model family.
    pub kind: ModelKind,
    /// Fixed batch size `B` the HLO was lowered with.
    pub batch: usize,
    /// Context window length `T`.
    pub context: usize,
    /// Per-instruction feature width `F`.
    pub feature_dim: usize,
    /// Opcode vocabulary size.
    pub num_opcodes: usize,
    /// Feature-engineering hyperparameters baked into the model.
    pub features: FeatureConfig,
    /// Names of the output tensors, in tuple order.
    pub outputs: Vec<String>,
    /// Hash of the opcode vocabulary at training time.
    pub vocab_hash: String,
    /// Which kernel implementation was lowered ("pallas" / "jnp").
    pub kernel: String,
}

impl ArtifactMeta {
    /// Load and validate `<path>.meta.json` given the HLO path.
    pub fn load(hlo_path: &Path) -> Result<ArtifactMeta> {
        let meta_path = meta_path_for(hlo_path);
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {meta_path:?}"))?;
        let kind = match j.req_str("kind")? {
            "tao" => ModelKind::Tao,
            "simnet" => ModelKind::SimNet,
            other => bail!("unknown artifact kind {other:?}"),
        };
        let fc = j
            .get("feature_config")
            .context("missing feature_config")?;
        let meta = ArtifactMeta {
            kind,
            batch: j.req_u64("batch")? as usize,
            context: j.req_u64("context")? as usize,
            feature_dim: j.req_u64("feature_dim")? as usize,
            num_opcodes: j.req_u64("num_opcodes")? as usize,
            features: FeatureConfig {
                nb: fc.req_u64("nb")? as usize,
                nq: fc.req_u64("nq")? as usize,
                nm: fc.req_u64("nm")? as usize,
            },
            outputs: j
                .get("outputs")
                .and_then(Json::as_arr)
                .context("missing outputs")?
                .iter()
                .map(|o| o.as_str().unwrap_or("?").to_string())
                .collect(),
            vocab_hash: j.req_str("vocab_hash")?.to_string(),
            kernel: j.req_str("kernel")?.to_string(),
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Cross-check against the Rust-side constants.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.num_opcodes == crate::isa::Opcode::COUNT,
            "artifact opcode vocabulary {} != ISA {}",
            self.num_opcodes,
            crate::isa::Opcode::COUNT
        );
        ensure!(
            self.feature_dim == self.features.feature_dim(),
            "artifact feature_dim {} inconsistent with its feature_config {}",
            self.feature_dim,
            self.features.feature_dim()
        );
        let expected_outputs: &[&str] = match self.kind {
            ModelKind::Tao => &["fetch", "exec", "branch", "access", "icache", "tlb"],
            ModelKind::SimNet => &["fetch", "exec"],
        };
        ensure!(
            self.outputs == expected_outputs,
            "artifact outputs {:?} != expected {:?}",
            self.outputs,
            expected_outputs
        );
        ensure!(self.batch > 0 && self.context > 0, "degenerate shape");
        Ok(())
    }
}

/// `foo.hlo.txt` → `foo.meta.json`.
pub fn meta_path_for(hlo_path: &Path) -> PathBuf {
    let s = hlo_path.to_string_lossy();
    PathBuf::from(s.replace(".hlo.txt", ".meta.json"))
}

/// Write a surrogate Tao artifact (HLO text + metadata) under `dir`,
/// shaped like the default AOT export and executable by the vendored
/// PJRT stand-in. Support code for engine tests and benches: it lets
/// the full extract→batch→execute→accumulate path run without trained
/// models. Returns the `.hlo.txt` path to pass to [`Session::load`].
pub fn write_surrogate_artifact(
    dir: &Path,
    name: &str,
    batch: usize,
    context: usize,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    let fc = FeatureConfig::default();
    let meta = format!(
        r#"{{
          "kind": "tao", "batch": {batch}, "context": {context},
          "feature_dim": {fd}, "num_opcodes": {nop},
          "outputs": ["fetch", "exec", "branch", "access", "icache", "tlb"],
          "feature_config": {{"nb": {nb}, "nq": {nq}, "nm": {nm}}},
          "vocab_hash": "surrogate", "kernel": "surrogate"
        }}"#,
        fd = fc.feature_dim(),
        nop = crate::isa::Opcode::COUNT,
        nb = fc.nb,
        nq = fc.nq,
        nm = fc.nm,
    );
    std::fs::write(dir.join(format!("{name}.meta.json")), meta)?;
    let hlo = dir.join(format!("{name}.hlo.txt"));
    std::fs::write(&hlo, format!("HloModule {name}"))?;
    Ok(hlo)
}

/// One model's outputs for a batch (post-processed to probabilities /
/// clamped latencies on the Rust side).
#[derive(Debug, Clone, Default)]
pub struct ModelOutputs {
    /// Predicted fetch latency per window (cycles, clamped ≥ 0).
    pub fetch: Vec<f32>,
    /// Predicted execution latency per window (cycles, clamped ≥ 0).
    pub exec: Vec<f32>,
    /// P(branch mispredicted).
    pub branch: Vec<f32>,
    /// Access-level probabilities, `[B × 4]` row-major.
    pub access: Vec<f32>,
    /// P(L1I miss).
    pub icache: Vec<f32>,
    /// P(dTLB miss).
    pub tlb: Vec<f32>,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// A compiled model on a PJRT client. One `Session` per worker thread —
/// the underlying client is not shared across threads.
pub struct Session {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// Reused staging buffers (hot path: no per-batch allocation).
    opcode_buf: Vec<i32>,
    feat_buf: Vec<f32>,
    ctx_buf: Vec<f32>,
}

impl Session {
    /// Load + compile an artifact.
    pub fn load(hlo_path: &Path) -> Result<Session> {
        let meta = ArtifactMeta::load(hlo_path)?;
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(anyhow_xla)?;
        let b = meta.batch;
        let t = meta.context;
        let f = meta.feature_dim;
        Ok(Session {
            exe,
            opcode_buf: vec![0; b * t],
            feat_buf: vec![0.0; b * t * f],
            ctx_buf: vec![0.0; b * t * 6],
            meta,
        })
    }

    /// Artifact metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Mutable staging buffers `(opcodes[B*T], features[B*T*F])` — the
    /// batcher writes windows directly into these to avoid copies.
    pub fn buffers(&mut self) -> (&mut [i32], &mut [f32]) {
        (&mut self.opcode_buf, &mut self.feat_buf)
    }

    /// SimNet context-metric staging buffer `[B*T*6]`.
    pub fn ctx_buffer(&mut self) -> &mut [f32] {
        &mut self.ctx_buf
    }

    /// Execute one batch from the staging buffers; `valid` rows of output
    /// are post-processed (probabilities, clamps) into `ModelOutputs`.
    pub fn run(&self, valid: usize) -> Result<ModelOutputs> {
        let b = self.meta.batch as i64;
        let t = self.meta.context as i64;
        let f = self.meta.feature_dim as i64;
        ensure!(valid <= b as usize, "valid {valid} > batch {b}");
        let ops = xla::Literal::vec1(&self.opcode_buf)
            .reshape(&[b, t])
            .map_err(anyhow_xla)?;
        let feats = xla::Literal::vec1(&self.feat_buf)
            .reshape(&[b, t, f])
            .map_err(anyhow_xla)?;
        let result = match self.meta.kind {
            ModelKind::Tao => self.exe.execute::<xla::Literal>(&[ops, feats]),
            ModelKind::SimNet => {
                let ctx = xla::Literal::vec1(&self.ctx_buf)
                    .reshape(&[b, t, 6])
                    .map_err(anyhow_xla)?;
                self.exe.execute::<xla::Literal>(&[ops, feats, ctx])
            }
        }
        .map_err(anyhow_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let parts = tuple.to_tuple().map_err(anyhow_xla)?;
        let vec_of = |lit: &xla::Literal| -> Result<Vec<f32>> {
            lit.to_vec::<f32>().map_err(anyhow_xla)
        };
        let mut out = ModelOutputs::default();
        match self.meta.kind {
            ModelKind::Tao => {
                ensure!(parts.len() == 6, "expected 6 outputs, got {}", parts.len());
                out.fetch = vec_of(&parts[0])?;
                out.exec = vec_of(&parts[1])?;
                out.branch = vec_of(&parts[2])?.iter().map(|&x| sigmoid(x)).collect();
                // Softmax rows of the access-level logits.
                let logits = vec_of(&parts[3])?;
                out.access = vec![0.0; logits.len()];
                for (row_in, row_out) in logits.chunks(4).zip(out.access.chunks_mut(4)) {
                    let m = row_in.iter().cloned().fold(f32::MIN, f32::max);
                    let exps: Vec<f32> = row_in.iter().map(|&x| (x - m).exp()).collect();
                    let sum: f32 = exps.iter().sum();
                    for (o, e) in row_out.iter_mut().zip(exps) {
                        *o = e / sum;
                    }
                }
                out.icache = vec_of(&parts[4])?.iter().map(|&x| sigmoid(x)).collect();
                out.tlb = vec_of(&parts[5])?.iter().map(|&x| sigmoid(x)).collect();
            }
            ModelKind::SimNet => {
                ensure!(parts.len() == 2, "expected 2 outputs, got {}", parts.len());
                out.fetch = vec_of(&parts[0])?;
                out.exec = vec_of(&parts[1])?;
            }
        }
        for v in out.fetch.iter_mut().chain(out.exec.iter_mut()) {
            *v = v.max(0.0);
        }
        out.truncate(valid);
        Ok(out)
    }
}

impl ModelOutputs {
    fn truncate(&mut self, n: usize) {
        self.fetch.truncate(n);
        self.exec.truncate(n);
        self.branch.truncate(n);
        self.access.truncate(n * 4);
        self.icache.truncate(n);
        self.tlb.truncate(n);
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta_json() -> String {
        format!(
            r#"{{
              "kind": "tao", "batch": 4, "context": 8,
              "feature_dim": {fd}, "num_opcodes": {nop},
              "latency_transform": "linear",
              "outputs": ["fetch", "exec", "branch", "access", "icache", "tlb"],
              "feature_config": {{"nb": 1024, "nq": 32, "nm": 64}},
              "num_regs": 48, "vocab_hash": "deadbeef", "kernel": "pallas"
            }}"#,
            fd = FeatureConfig::default().feature_dim(),
            nop = crate::isa::Opcode::COUNT,
        )
    }

    fn write_meta(dir: &Path, name: &str, body: &str) -> PathBuf {
        std::fs::create_dir_all(dir).unwrap();
        let hlo = dir.join(format!("{name}.hlo.txt"));
        std::fs::write(dir.join(format!("{name}.meta.json")), body).unwrap();
        hlo
    }

    fn tmp() -> PathBuf {
        std::env::temp_dir().join(format!("tao-artifact-{}", std::process::id()))
    }

    #[test]
    fn meta_loads_and_validates() {
        let hlo = write_meta(&tmp(), "ok", &sample_meta_json());
        let m = ArtifactMeta::load(&hlo).unwrap();
        assert_eq!(m.kind, ModelKind::Tao);
        assert_eq!(m.batch, 4);
        assert_eq!(m.features.nm, 64);
        assert_eq!(m.kernel, "pallas");
    }

    #[test]
    fn meta_rejects_wrong_vocab_size() {
        let body = sample_meta_json().replace(
            &format!("\"num_opcodes\": {}", crate::isa::Opcode::COUNT),
            "\"num_opcodes\": 7",
        );
        let hlo = write_meta(&tmp(), "badvocab", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_rejects_inconsistent_feature_dim() {
        let body = sample_meta_json().replace(
            &format!("\"feature_dim\": {}", FeatureConfig::default().feature_dim()),
            "\"feature_dim\": 3",
        );
        let hlo = write_meta(&tmp(), "baddim", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_rejects_wrong_outputs() {
        let body = sample_meta_json().replace("\"tlb\"", "\"bogus\"");
        let hlo = write_meta(&tmp(), "badout", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_path_mapping() {
        assert_eq!(
            meta_path_for(Path::new("/a/tao_x.hlo.txt")),
            PathBuf::from("/a/tao_x.meta.json")
        );
    }
}
