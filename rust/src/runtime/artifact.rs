//! Artifact metadata + PJRT session.

use crate::features::FeatureConfig;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Which model family an artifact holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Tao multi-metric model: inputs (opcodes, features); 6 outputs.
    Tao,
    /// SimNet baseline: inputs (opcodes, features, ctx_metrics); 2 outputs.
    SimNet,
}

/// Parsed `<artifact>.meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Model family.
    pub kind: ModelKind,
    /// Fixed batch size `B` the HLO was lowered with.
    pub batch: usize,
    /// Context window length `T`.
    pub context: usize,
    /// Per-instruction feature width `F`.
    pub feature_dim: usize,
    /// Opcode vocabulary size.
    pub num_opcodes: usize,
    /// Feature-engineering hyperparameters baked into the model.
    pub features: FeatureConfig,
    /// Names of the output tensors, in tuple order.
    pub outputs: Vec<String>,
    /// Hash of the opcode vocabulary at training time.
    pub vocab_hash: String,
    /// Which kernel implementation was lowered ("pallas" / "jnp").
    pub kernel: String,
}

impl ArtifactMeta {
    /// Load and validate `<path>.meta.json` given the HLO path.
    pub fn load(hlo_path: &Path) -> Result<ArtifactMeta> {
        let meta_path = meta_path_for(hlo_path);
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {meta_path:?}"))?;
        let kind = match j.req_str("kind")? {
            "tao" => ModelKind::Tao,
            "simnet" => ModelKind::SimNet,
            other => bail!("unknown artifact kind {other:?}"),
        };
        let fc = j
            .get("feature_config")
            .context("missing feature_config")?;
        let meta = ArtifactMeta {
            kind,
            batch: j.req_u64("batch")? as usize,
            context: j.req_u64("context")? as usize,
            feature_dim: j.req_u64("feature_dim")? as usize,
            num_opcodes: j.req_u64("num_opcodes")? as usize,
            features: FeatureConfig {
                nb: fc.req_u64("nb")? as usize,
                nq: fc.req_u64("nq")? as usize,
                nm: fc.req_u64("nm")? as usize,
            },
            outputs: j
                .get("outputs")
                .and_then(Json::as_arr)
                .context("missing outputs")?
                .iter()
                .map(|o| o.as_str().unwrap_or("?").to_string())
                .collect(),
            vocab_hash: j.req_str("vocab_hash")?.to_string(),
            kernel: j.req_str("kernel")?.to_string(),
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Cross-check against the Rust-side constants.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.num_opcodes == crate::isa::Opcode::COUNT,
            "artifact opcode vocabulary {} != ISA {}",
            self.num_opcodes,
            crate::isa::Opcode::COUNT
        );
        ensure!(
            self.feature_dim == self.features.feature_dim(),
            "artifact feature_dim {} inconsistent with its feature_config {}",
            self.feature_dim,
            self.features.feature_dim()
        );
        let expected_outputs: &[&str] = match self.kind {
            ModelKind::Tao => &["fetch", "exec", "branch", "access", "icache", "tlb"],
            ModelKind::SimNet => &["fetch", "exec"],
        };
        ensure!(
            self.outputs == expected_outputs,
            "artifact outputs {:?} != expected {:?}",
            self.outputs,
            expected_outputs
        );
        ensure!(self.batch > 0 && self.context > 0, "degenerate shape");
        Ok(())
    }
}

/// `foo.hlo.txt` → `foo.meta.json`.
pub fn meta_path_for(hlo_path: &Path) -> PathBuf {
    let s = hlo_path.to_string_lossy();
    PathBuf::from(s.replace(".hlo.txt", ".meta.json"))
}

/// Write a surrogate Tao artifact (HLO text + metadata) under `dir`,
/// shaped like the default AOT export and executable by the vendored
/// PJRT stand-in. Support code for engine tests and benches: it lets
/// the full extract→batch→execute→accumulate path run without trained
/// models. Returns the `.hlo.txt` path to pass to [`Session::load`].
pub fn write_surrogate_artifact(
    dir: &Path,
    name: &str,
    batch: usize,
    context: usize,
) -> Result<PathBuf> {
    write_surrogate_artifact_kind(dir, name, ModelKind::Tao, batch, context)
}

/// [`write_surrogate_artifact`] with an explicit model family; the
/// SimNet variant declares the 2-output shape and the ctx input the
/// vendored PJRT stand-in already understands, so serve/loadgen tests
/// can exercise mixed Tao/SimNet lanes without trained models.
pub fn write_surrogate_artifact_kind(
    dir: &Path,
    name: &str,
    kind: ModelKind,
    batch: usize,
    context: usize,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    let fc = FeatureConfig::default();
    let (kind_str, outputs) = match kind {
        ModelKind::Tao => ("tao", r#"["fetch", "exec", "branch", "access", "icache", "tlb"]"#),
        ModelKind::SimNet => ("simnet", r#"["fetch", "exec"]"#),
    };
    let meta = format!(
        r#"{{
          "kind": "{kind_str}", "batch": {batch}, "context": {context},
          "feature_dim": {fd}, "num_opcodes": {nop},
          "outputs": {outputs},
          "feature_config": {{"nb": {nb}, "nq": {nq}, "nm": {nm}}},
          "vocab_hash": "surrogate", "kernel": "surrogate"
        }}"#,
        fd = fc.feature_dim(),
        nop = crate::isa::Opcode::COUNT,
        nb = fc.nb,
        nq = fc.nq,
        nm = fc.nm,
    );
    std::fs::write(dir.join(format!("{name}.meta.json")), meta)?;
    let hlo = dir.join(format!("{name}.hlo.txt"));
    std::fs::write(&hlo, format!("HloModule {name}"))?;
    Ok(hlo)
}

/// One model's outputs for a batch (post-processed to probabilities /
/// clamped latencies on the Rust side).
#[derive(Debug, Clone, Default)]
pub struct ModelOutputs {
    /// Predicted fetch latency per window (cycles, clamped ≥ 0).
    pub fetch: Vec<f32>,
    /// Predicted execution latency per window (cycles, clamped ≥ 0).
    pub exec: Vec<f32>,
    /// P(branch mispredicted).
    pub branch: Vec<f32>,
    /// Access-level probabilities, `[B × 4]` row-major.
    pub access: Vec<f32>,
    /// P(L1I miss).
    pub icache: Vec<f32>,
    /// P(dTLB miss).
    pub tlb: Vec<f32>,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// A compiled model on a PJRT client. One `Session` per worker thread —
/// the underlying client is not shared across threads.
pub struct Session {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// Reused staging buffers (hot path: no per-batch allocation).
    opcode_buf: Vec<i32>,
    feat_buf: Vec<f32>,
    ctx_buf: Vec<f32>,
}

impl Session {
    /// Load + compile an artifact.
    pub fn load(hlo_path: &Path) -> Result<Session> {
        let meta = ArtifactMeta::load(hlo_path)?;
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(anyhow_xla)?;
        let b = meta.batch;
        let t = meta.context;
        let f = meta.feature_dim;
        Ok(Session {
            exe,
            opcode_buf: vec![0; b * t],
            feat_buf: vec![0.0; b * t * f],
            ctx_buf: vec![0.0; b * t * 6],
            meta,
        })
    }

    /// Artifact metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Mutable staging buffers `(opcodes[B*T], features[B*T*F])` — the
    /// batcher writes windows directly into these to avoid copies.
    pub fn buffers(&mut self) -> (&mut [i32], &mut [f32]) {
        (&mut self.opcode_buf, &mut self.feat_buf)
    }

    /// SimNet context-metric staging buffer `[B*T*6]`.
    pub fn ctx_buffer(&mut self) -> &mut [f32] {
        &mut self.ctx_buf
    }

    /// Execute one batch from the staging buffers; `valid` rows of output
    /// are post-processed (probabilities, clamps) into `ModelOutputs`.
    pub fn run(&self, valid: usize) -> Result<ModelOutputs> {
        let ctx = match self.meta.kind {
            ModelKind::Tao => None,
            ModelKind::SimNet => Some(&self.ctx_buf[..]),
        };
        self.run_on(&self.opcode_buf, &self.feat_buf, ctx, valid)
    }

    /// Execute one batch straight from caller-owned staging buffers
    /// (`opcodes [B*T]`, `features [B*T*F]`, SimNet `ctx [B*T*6]`).
    /// The external-buffer surface the serving scheduler's pipelined
    /// executor uses: the stager fills one buffer set while the model
    /// executes from the other, with no hand-off copy through the
    /// session's internal buffers.
    pub fn run_on(
        &self,
        opcodes: &[i32],
        features: &[f32],
        ctx: Option<&[f32]>,
        valid: usize,
    ) -> Result<ModelOutputs> {
        let b = self.meta.batch as i64;
        let t = self.meta.context as i64;
        let f = self.meta.feature_dim as i64;
        ensure!(valid <= b as usize, "valid {valid} > batch {b}");
        ensure!(opcodes.len() == (b * t) as usize, "opcode staging shape");
        ensure!(features.len() == (b * t * f) as usize, "feature staging shape");
        let ops = xla::Literal::vec1(opcodes)
            .reshape(&[b, t])
            .map_err(anyhow_xla)?;
        let feats = xla::Literal::vec1(features)
            .reshape(&[b, t, f])
            .map_err(anyhow_xla)?;
        let result = match self.meta.kind {
            ModelKind::Tao => self.exe.execute::<xla::Literal>(&[ops, feats]),
            ModelKind::SimNet => {
                let ctx = ctx.context("SimNet execution requires a ctx staging buffer")?;
                ensure!(ctx.len() == (b * t * 6) as usize, "ctx staging shape");
                let ctx = xla::Literal::vec1(ctx)
                    .reshape(&[b, t, 6])
                    .map_err(anyhow_xla)?;
                self.exe.execute::<xla::Literal>(&[ops, feats, ctx])
            }
        }
        .map_err(anyhow_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let parts = tuple.to_tuple().map_err(anyhow_xla)?;
        let vec_of = |lit: &xla::Literal| -> Result<Vec<f32>> {
            lit.to_vec::<f32>().map_err(anyhow_xla)
        };
        let mut out = ModelOutputs::default();
        match self.meta.kind {
            ModelKind::Tao => {
                ensure!(parts.len() == 6, "expected 6 outputs, got {}", parts.len());
                out.fetch = vec_of(&parts[0])?;
                out.exec = vec_of(&parts[1])?;
                out.branch = vec_of(&parts[2])?.iter().map(|&x| sigmoid(x)).collect();
                // Softmax rows of the access-level logits.
                let logits = vec_of(&parts[3])?;
                out.access = vec![0.0; logits.len()];
                for (row_in, row_out) in logits.chunks(4).zip(out.access.chunks_mut(4)) {
                    let m = row_in.iter().cloned().fold(f32::MIN, f32::max);
                    let exps: Vec<f32> = row_in.iter().map(|&x| (x - m).exp()).collect();
                    let sum: f32 = exps.iter().sum();
                    for (o, e) in row_out.iter_mut().zip(exps) {
                        *o = e / sum;
                    }
                }
                out.icache = vec_of(&parts[4])?.iter().map(|&x| sigmoid(x)).collect();
                out.tlb = vec_of(&parts[5])?.iter().map(|&x| sigmoid(x)).collect();
            }
            ModelKind::SimNet => {
                ensure!(parts.len() == 2, "expected 2 outputs, got {}", parts.len());
                out.fetch = vec_of(&parts[0])?;
                out.exec = vec_of(&parts[1])?;
            }
        }
        for v in out.fetch.iter_mut().chain(out.exec.iter_mut()) {
            *v = v.max(0.0);
        }
        out.truncate(valid);
        Ok(out)
    }
}

impl ModelOutputs {
    fn truncate(&mut self, n: usize) {
        self.fetch.truncate(n);
        self.exec.truncate(n);
        self.branch.truncate(n);
        self.access.truncate(n * 4);
        self.icache.truncate(n);
        self.tlb.truncate(n);
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

// ---------------------------------------------------------------------
// Artifact pool
// ---------------------------------------------------------------------

/// One artifact registered in an [`ArtifactPool`]: validated metadata
/// plus a content fingerprint over the HLO text and the metadata JSON.
/// The fingerprint keys the serving layer's chunk-level prediction
/// cache, so two artifacts hit the same cache entries iff their model
/// bytes are identical.
#[derive(Debug, Clone)]
pub struct PooledArtifact {
    /// Registry name (the `.hlo.txt` file stem).
    pub name: String,
    /// Path to the HLO text.
    pub hlo_path: PathBuf,
    /// Validated metadata.
    pub meta: ArtifactMeta,
    /// FNV-1a over HLO text ++ metadata JSON.
    pub fingerprint: u64,
}

impl PooledArtifact {
    /// Compile a fresh session for this artifact (one per worker
    /// thread; the underlying client is not shared across threads).
    pub fn open_session(&self) -> Result<Session> {
        Session::load(&self.hlo_path)
    }
}

/// A set of artifacts shared across concurrent simulation jobs: the
/// serving daemon loads every `--model` once at startup, validates the
/// metadata, fingerprints the bytes, and hands lanes/jobs cheap
/// references instead of re-reading `meta.json` per request.
#[derive(Debug, Default)]
pub struct ArtifactPool {
    arts: Vec<PooledArtifact>,
}

impl ArtifactPool {
    /// Load and fingerprint every artifact. Names (file stems) must be
    /// unique — they are the request-side registry keys.
    pub fn load(hlo_paths: &[PathBuf]) -> Result<ArtifactPool> {
        use crate::util::hash::{fnv1a64, FNV_OFFSET};
        let mut arts: Vec<PooledArtifact> = Vec::with_capacity(hlo_paths.len());
        for path in hlo_paths {
            let meta = ArtifactMeta::load(path)?;
            let name = artifact_name(path)?;
            ensure!(
                arts.iter().all(|a| a.name != name),
                "duplicate artifact name {name:?} in pool"
            );
            let hlo_bytes =
                std::fs::read(path).with_context(|| format!("read {path:?}"))?;
            let meta_bytes = std::fs::read(meta_path_for(path))
                .with_context(|| format!("read {:?}", meta_path_for(path)))?;
            let fingerprint = fnv1a64(&meta_bytes, fnv1a64(&hlo_bytes, FNV_OFFSET));
            arts.push(PooledArtifact {
                name,
                hlo_path: path.clone(),
                meta,
                fingerprint,
            });
        }
        Ok(ArtifactPool { arts })
    }

    /// Look up an artifact by registry name.
    pub fn get(&self, name: &str) -> Option<&PooledArtifact> {
        self.arts.iter().find(|a| a.name == name)
    }

    /// All artifacts, load order.
    pub fn iter(&self) -> impl Iterator<Item = &PooledArtifact> {
        self.arts.iter()
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.arts.len()
    }

    /// True when no artifacts are loaded.
    pub fn is_empty(&self) -> bool {
        self.arts.is_empty()
    }
}

/// Registry name for an artifact path: the file name with the
/// `.hlo.txt` suffix stripped.
pub fn artifact_name(hlo_path: &Path) -> Result<String> {
    let file = hlo_path
        .file_name()
        .and_then(|s| s.to_str())
        .with_context(|| format!("non-utf8 artifact path {hlo_path:?}"))?;
    Ok(file.strip_suffix(".hlo.txt").unwrap_or(file).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta_json() -> String {
        format!(
            r#"{{
              "kind": "tao", "batch": 4, "context": 8,
              "feature_dim": {fd}, "num_opcodes": {nop},
              "latency_transform": "linear",
              "outputs": ["fetch", "exec", "branch", "access", "icache", "tlb"],
              "feature_config": {{"nb": 1024, "nq": 32, "nm": 64}},
              "num_regs": 48, "vocab_hash": "deadbeef", "kernel": "pallas"
            }}"#,
            fd = FeatureConfig::default().feature_dim(),
            nop = crate::isa::Opcode::COUNT,
        )
    }

    fn write_meta(dir: &Path, name: &str, body: &str) -> PathBuf {
        std::fs::create_dir_all(dir).unwrap();
        let hlo = dir.join(format!("{name}.hlo.txt"));
        std::fs::write(dir.join(format!("{name}.meta.json")), body).unwrap();
        hlo
    }

    fn tmp() -> PathBuf {
        std::env::temp_dir().join(format!("tao-artifact-{}", std::process::id()))
    }

    #[test]
    fn meta_loads_and_validates() {
        let hlo = write_meta(&tmp(), "ok", &sample_meta_json());
        let m = ArtifactMeta::load(&hlo).unwrap();
        assert_eq!(m.kind, ModelKind::Tao);
        assert_eq!(m.batch, 4);
        assert_eq!(m.features.nm, 64);
        assert_eq!(m.kernel, "pallas");
    }

    #[test]
    fn meta_rejects_wrong_vocab_size() {
        let body = sample_meta_json().replace(
            &format!("\"num_opcodes\": {}", crate::isa::Opcode::COUNT),
            "\"num_opcodes\": 7",
        );
        let hlo = write_meta(&tmp(), "badvocab", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_rejects_inconsistent_feature_dim() {
        let body = sample_meta_json().replace(
            &format!("\"feature_dim\": {}", FeatureConfig::default().feature_dim()),
            "\"feature_dim\": 3",
        );
        let hlo = write_meta(&tmp(), "baddim", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_rejects_wrong_outputs() {
        let body = sample_meta_json().replace("\"tlb\"", "\"bogus\"");
        let hlo = write_meta(&tmp(), "badout", &body);
        assert!(ArtifactMeta::load(&hlo).is_err());
    }

    #[test]
    fn meta_path_mapping() {
        assert_eq!(
            meta_path_for(Path::new("/a/tao_x.hlo.txt")),
            PathBuf::from("/a/tao_x.meta.json")
        );
    }

    #[test]
    fn artifact_names_strip_hlo_suffix() {
        assert_eq!(artifact_name(Path::new("/a/tao_x.hlo.txt")).unwrap(), "tao_x");
        assert_eq!(artifact_name(Path::new("plain")).unwrap(), "plain");
    }

    #[test]
    fn pool_loads_fingerprints_and_rejects_duplicates() {
        let dir = tmp().join("pool");
        let a = write_surrogate_artifact(&dir, "pool_a", 4, 8).unwrap();
        let b = write_surrogate_artifact(&dir, "pool_b", 4, 8).unwrap();
        let sn =
            write_surrogate_artifact_kind(&dir, "pool_sn", ModelKind::SimNet, 4, 8).unwrap();
        let pool = ArtifactPool::load(&[a.clone(), b, sn]).unwrap();
        assert_eq!(pool.len(), 3);
        let pa = pool.get("pool_a").unwrap();
        let pb = pool.get("pool_b").unwrap();
        let psn = pool.get("pool_sn").unwrap();
        assert_eq!(pa.meta.kind, ModelKind::Tao);
        assert_eq!(psn.meta.kind, ModelKind::SimNet);
        // Different model bytes ⇒ different cache-key fingerprints.
        assert_ne!(pa.fingerprint, pb.fingerprint);
        assert_ne!(pa.fingerprint, psn.fingerprint);
        assert!(pool.get("missing").is_none());
        // Same file twice collides on the registry name.
        assert!(ArtifactPool::load(&[a.clone(), a]).is_err());
    }

    #[test]
    fn run_on_matches_run_from_internal_buffers() {
        let dir = tmp().join("runon");
        let hlo = write_surrogate_artifact(&dir, "runon", 4, 8).unwrap();
        let mut session = Session::load(&hlo).unwrap();
        let (b, t, f) = (4, 8, session.meta().feature_dim);
        let mut ops = vec![0i32; b * t];
        let mut feats = vec![0.0f32; b * t * f];
        for (i, o) in ops.iter_mut().enumerate() {
            *o = (i % 7) as i32;
        }
        for (i, v) in feats.iter_mut().enumerate() {
            *v = (i % 13) as f32 * 0.25;
        }
        {
            let (ob, fb) = session.buffers();
            ob.copy_from_slice(&ops);
            fb.copy_from_slice(&feats);
        }
        let via_internal = session.run(3).unwrap();
        let via_external = session.run_on(&ops, &feats, None, 3).unwrap();
        assert_eq!(via_internal.fetch, via_external.fetch);
        assert_eq!(via_internal.exec, via_external.exec);
        assert_eq!(via_internal.branch, via_external.branch);
        assert_eq!(via_internal.access, via_external.access);
        // Shape violations surface as errors.
        assert!(session.run_on(&ops[..1], &feats, None, 1).is_err());
        assert!(session.run_on(&ops, &feats, None, 5).is_err());
    }
}
